"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py          # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --tiny   # CI-speed variant

Exercises the full training substrate on real data flow: deterministic
pipeline -> jitted train_step (remat + AdamW) -> crash-safe checkpoints ->
resume. The same step function is what the multi-pod dry-run lowers for
the production mesh.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, init_params
from repro.distributed.fault import CheckpointManager
from repro.train import (
    DataConfig,
    Prefetcher,
    TrainConfig,
    init_opt_state,
    make_train_step,
)
from repro.train.checkpoint import save_train_state


def model_100m() -> ModelConfig:
    # ~100M params: 12L x 768d GQA decoder (GPT-2-small-class)
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_head=64, d_ff=2048, vocab=32000,
        mixer="gqa", rope=True, dtype="float32", attn_chunk=128,
    )


def model_tiny() -> ModelConfig:
    return ModelConfig(
        name="lm-tiny", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=1024,
        mixer="gqa", rope=True, dtype="float32", attn_chunk=32,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    steps = args.steps or (60 if args.tiny else 300)
    batch = args.batch or (8 if args.tiny else 4)
    seq = args.seq or (64 if args.tiny else 256)

    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{steps} steps @ batch {batch} x seq {seq}")
    params = init_params(cfg, jax.random.PRNGKey(0))
    tcfg = TrainConfig(lr=3e-4, remat=True)
    opt = init_opt_state(params, tcfg)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    data = Prefetcher(DataConfig(cfg.vocab, batch, seq))
    mgr = CheckpointManager("/tmp/repro_train_lm", every=100)
    t0 = time.perf_counter()
    first = None
    try:
        for step in range(steps):
            b = {k: jnp.asarray(v) for k, v in next(data).items()}
            params, opt, m = step_fn(params, opt, b)
            loss = float(m["loss"])
            first = first if first is not None else loss
            if (step + 1) % 20 == 0:
                dt = (time.perf_counter() - t0) / (step + 1)
                print(f"  step {step+1:4d}: loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms/step, "
                      f"{batch*seq/dt:.0f} tok/s)", flush=True)
            if (step + 1) % 100 == 0:
                save_train_state(f"/tmp/repro_train_lm/ck_{step+1}.npz",
                                 step + 1, params, opt)
    finally:
        data.close()
    print(f"loss: {first:.4f} -> {loss:.4f} "
          f"({'improved' if loss < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
