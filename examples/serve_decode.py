"""Serving example: batched greedy decoding with KV caches across
architecture families (GQA, MLA, hybrid attn+SSM, RWKV6).

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import init_cache, init_params
from repro.train.serve_step import make_serve_step


def main():
    for arch in ("granite-3-8b", "minicpm3-4b", "hymba-1.5b", "rwkv6-1.6b"):
        cfg = get_smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        B, prompt_len, gen_len = 4, 8, 24
        serve = jax.jit(make_serve_step(cfg))
        cache = init_cache(cfg, B, max_len=prompt_len + gen_len)
        prompt = jax.random.randint(
            jax.random.PRNGKey(1), (B, prompt_len), 0, cfg.vocab)
        # prefill through the decode path (exactness over speed here)
        for t in range(prompt_len):
            tok, _, cache = serve(params, prompt[:, t:t + 1], cache)
        out = [prompt]
        cur = tok[:, None]
        t0 = time.perf_counter()
        for _ in range(gen_len):
            tok, _, cache = serve(params, cur, cache)
            cur = tok[:, None]
            out.append(cur)
        dt = time.perf_counter() - t0
        seq = jnp.concatenate(out, axis=1)
        print(f"{arch:18s} ({cfg.mixer:6s}): generated {gen_len} tokens x "
              f"{B} seqs in {dt:.2f}s "
              f"({B*gen_len/dt:.0f} tok/s); sample: "
              f"{seq[0, :16].tolist()}")


if __name__ == "__main__":
    main()
