"""Beyond-paper demo: LM inference through the crossbar substrate.

The paper closes by noting its MVM-centric framework "is adaptable to a
broader class of ... machine learning problems".  This example runs a tiny
LM's final projection through the simulated analog crossbar (encode-once
weights, noisy reads) and measures how device noise perturbs next-token
argmax agreement — connecting the LP substrate to the assigned LM stack.

    PYTHONPATH=src python examples/analog_inference.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.crossbar import EPIRAM, TAOX_HFOX, CrossbarArray
from repro.models import forward, init_params


def main():
    cfg = get_smoke_config("granite-3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 8, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    logits = forward(params, cfg, tokens=toks)          # digital reference
    h_states = np.asarray(logits)                        # (B, S, V)
    digital_next = h_states[:, -1, :].argmax(-1)

    # re-do the final projection on the analog accelerator: encode the
    # (V, d) embedding matrix once, stream the hidden states
    # (we recompute h via a forward hook-free trick: logits = h @ E^T, so
    # we recover h by projecting through the pseudo-inverse-free path —
    # here we simply re-run the backbone up to the final norm)
    from repro.models import lm as lm_mod

    h = jnp.take(params["embed"], toks, axis=0)

    def body(hh, layer_p):
        return lm_mod._block(layer_p, hh, cfg), None

    h, _ = jax.lax.scan(body, h, params["layers"])
    h = lm_mod.rms_norm(h, params["final_norm"])
    h_last = np.asarray(h[:, -1, :])                     # (B, d)

    E = np.asarray(params["embed"])                      # (V, d)
    for dev in (EPIRAM, TAOX_HFOX):
        arr = CrossbarArray.program(E, dev, key=jax.random.PRNGKey(2))
        analog_logits = np.stack([
            np.asarray(arr.mvm(h_last[i], key=jax.random.PRNGKey(10 + i)))
            for i in range(B)
        ])
        agree = (analog_logits.argmax(-1) == digital_next).mean()
        drift = np.abs(analog_logits - h_states[:, -1, :]).max() / \
            np.abs(h_states[:, -1, :]).max()
        print(f"{dev.name:10s}: argmax agreement {agree*100:.0f}%  "
              f"max logit drift {drift*100:.1f}%  "
              f"(write {arr.ledger.write_energy_j*1e3:.2f} mJ, "
              f"{arr.ledger.mvm_count} analog MVMs)")


if __name__ == "__main__":
    main()
