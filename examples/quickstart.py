"""Quickstart: solve an LP on the simulated RRAM crossbar accelerator.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full pipeline (Figure 1) in ~30 lines of user code:
generate an instance -> enhanced PDHG on two simulated RRAM devices and
the exact backend -> compare objective, iterations, energy.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core import PDHGOptions, solve_jit                    # noqa: E402
from repro.crossbar import EPIRAM, TAOX_HFOX, solve_crossbar_jit  # noqa: E402
from repro.lp import random_standard_lp                          # noqa: E402


def main():
    # A standard-form LP with a known optimum (constructed via
    # complementary slackness — no external solver needed).
    lp = random_standard_lp(m=96, n=160, seed=0)   # fills the 256^2 crossbar
    print(f"instance: K {lp.K.shape}, known optimum {lp.obj_opt:.6f}\n")

    opts = PDHGOptions(max_iters=30000, tol=1e-6, check_every=100)

    r = solve_jit(lp, opts)
    print(f"exact PDHG    : obj={r.obj:.6f} "
          f"rel_err={abs(r.obj - lp.obj_opt) / abs(lp.obj_opt):.2e} "
          f"iters={r.iterations}")

    for dev in (EPIRAM, TAOX_HFOX):
        rep = solve_crossbar_jit(lp, opts, device=dev)
        res, led = rep.result, rep.ledger
        print(f"{dev.name:14s}: obj={res.obj:.6f} "
              f"rel_err={abs(res.obj - lp.obj_opt) / abs(lp.obj_opt):.2e} "
              f"iters={res.iterations} | energy: write "
              f"{led.write_energy_j:.3f} J + read {led.read_energy_j:.3f} J"
              f" | latency {led.total_latency_s:.3f} s")
    print("\nNote how the encode-once write cost is amortized over ~60k "
          "analog MVMs — the paper's core design point.")


if __name__ == "__main__":
    main()
