"""Distributed PDHG: one LP sharded across a device mesh (crossbar-style),
plus the batched solver-as-a-service mode.

    PYTHONPATH=src python examples/distributed_pdhg.py

This example forces 8 host devices (it must run as its own process).
On TPU hardware the same code runs on the real 256/512-chip meshes via
repro.launch.mesh.make_production_mesh.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                                        # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np                                                # noqa: E402

from repro.core import PDHGOptions                                # noqa: E402
from repro.distributed import solve_batch, stack_problems         # noqa: E402
from repro.distributed.pdhg_dist import solve_dist                # noqa: E402
from repro.launch.mesh import make_mesh                           # noqa: E402
from repro.lp import random_standard_lp                           # noqa: E402


def main():
    print(f"devices: {len(jax.devices())}")
    opts = PDHGOptions(max_iters=30000, tol=1e-6, check_every=100)

    # --- one large LP, 2-D sharded like the paper's crossbar grid -------
    mesh = make_mesh((2, 4), ("data", "model"))
    lp = random_standard_lp(128, 256, seed=0)
    r = solve_dist(lp, mesh, opts)
    print(f"sharded solve  : mesh 2x4 obj={r.obj:.6f} "
          f"rel_err={abs(r.obj - lp.obj_opt) / abs(lp.obj_opt):.2e} "
          f"iters={r.iterations}")

    # --- multi-pod mesh: the 'pod' axis joins the row-block sharding ----
    mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    r3 = solve_dist(lp, mesh3, opts)
    print(f"multi-pod solve: mesh 2x2x2 obj={r3.obj:.6f} "
          f"rel_err={abs(r3.obj - lp.obj_opt) / abs(lp.obj_opt):.2e}")

    # --- batched mode: 8 independent LPs, one per device -----------------
    flat = make_mesh((8,), ("data",))
    lps = [random_standard_lp(24, 40, seed=s) for s in range(8)]
    Ks, bs, cs, lbs, ubs = stack_problems(lps)
    out = solve_batch(Ks, bs, cs, lbs, ubs, flat, opts)
    objs = np.einsum("bn,bn->b", cs, out["x"])
    errs = [abs(o - lp.obj_opt) / abs(lp.obj_opt)
            for o, lp in zip(objs, lps)]
    print(f"batched solve  : 8 LPs, max rel_err={max(errs):.2e}, "
          f"converged={int(out['converged'].sum())}/8")


if __name__ == "__main__":
    main()
