"""Unit tests for the paper's core machinery (Algorithms 1-4 components)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    MODE_AX,
    MODE_ATY,
    MODE_FULL,
    PDHGOptions,
    apply_ruiz,
    build_sym_block,
    diagonal_precondition,
    encode_exact,
    kkt_residuals,
    matmul_accel,
    scaled_accel,
    solve,
    solve_jit,
)
from repro.lp import infeasible_lp, random_standard_lp


def test_build_sym_block_structure():
    K = np.arange(12.0).reshape(3, 4)
    M = np.asarray(build_sym_block(K))
    assert M.shape == (7, 7)
    np.testing.assert_allclose(M[:3, 3:], K)
    np.testing.assert_allclose(M[3:, :3], K.T)
    np.testing.assert_allclose(M[:3, :3], 0)
    np.testing.assert_allclose(M[3:, 3:], 0)
    np.testing.assert_allclose(M, M.T)


def test_matmul_accel_modes():
    rng = np.random.default_rng(0)
    K = rng.normal(size=(5, 8))
    acc = encode_exact(K)
    x = rng.normal(size=8)
    y = rng.normal(size=5)
    np.testing.assert_allclose(
        np.asarray(matmul_accel(acc, x, MODE_AX)), K @ x, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(matmul_accel(acc, y, MODE_ATY)), K.T @ y, rtol=1e-5)
    v = rng.normal(size=13)
    w = np.asarray(matmul_accel(acc, v, MODE_FULL))
    np.testing.assert_allclose(w[:5], K @ v[5:], rtol=1e-5)
    np.testing.assert_allclose(w[5:], K.T @ v[:5], rtol=1e-5)


def test_scaled_accel_is_diagonal_similarity():
    rng = np.random.default_rng(1)
    K = rng.normal(size=(4, 6))
    acc = encode_exact(K)
    r = rng.uniform(0.5, 2.0, 4)
    c = rng.uniform(0.5, 2.0, 6)
    wrapped = scaled_accel(acc, jnp.asarray(r), jnp.asarray(c))
    v = rng.normal(size=10)
    got = np.asarray(wrapped.mvm_full(jnp.asarray(v)))
    D = np.diag(np.concatenate([r, c]))
    M = np.asarray(build_sym_block(K))
    np.testing.assert_allclose(got, D @ M @ D @ v, rtol=1e-4)


def test_ruiz_equilibrates(x64):
    rng = np.random.default_rng(2)
    K = rng.normal(size=(20, 30)) * np.logspace(0, 3, 30)[None, :]
    scaled = apply_ruiz(K, np.ones(20), np.ones(30),
                        np.zeros(30), np.full(30, np.inf), iters=20)
    Ks = np.asarray(scaled.K)
    row_norms = np.abs(Ks).max(axis=1)
    col_norms = np.abs(Ks).max(axis=0)
    assert row_norms.max() / row_norms.min() < 1.2
    assert col_norms.max() / col_norms.min() < 1.2
    # the scaling is exactly invertible
    np.testing.assert_allclose(
        Ks / np.asarray(scaled.D1)[:, None] / np.asarray(scaled.D2)[None, :],
        K, rtol=1e-10)


def test_pock_chambolle_norm_bound(x64):
    rng = np.random.default_rng(3)
    K = rng.normal(size=(15, 25))
    T, Sigma = diagonal_precondition(K)
    scaled = np.sqrt(np.asarray(Sigma))[:, None] * K \
        * np.sqrt(np.asarray(T))[None, :]
    assert np.linalg.svd(scaled, compute_uv=False)[0] <= 1.0 + 1e-9


def test_kkt_residuals_zero_at_optimum(x64):
    lp = random_standard_lp(10, 20, seed=4)
    # construct exact dual candidate from the generator's construction
    x = lp.x_opt
    # solve for a compatible y via least squares on active set
    res = kkt_residuals(
        jnp.asarray(x), jnp.asarray(x), jnp.zeros(10),
        jnp.asarray(lp.c), jnp.asarray(lp.b),
        jnp.asarray(lp.K @ x), jnp.zeros(20),
        lb=jnp.asarray(lp.lb), ub=jnp.asarray(lp.ub),
    )
    assert float(res.r_pri) < 1e-10
    assert float(res.r_iter) < 1e-10


def test_pdhg_host_and_jit_agree(x64):
    lp = random_standard_lp(12, 20, seed=5)
    opts = PDHGOptions(max_iters=20000, tol=1e-6, check_every=64)
    r1 = solve(lp, opts)
    r2 = solve_jit(lp, opts)
    assert r1.status == "optimal"
    assert r2.status == "optimal"
    assert abs(r1.obj - lp.obj_opt) / abs(lp.obj_opt) < 1e-4
    assert abs(r2.obj - lp.obj_opt) / abs(lp.obj_opt) < 1e-4


def test_pdhg_respects_bounds(x64):
    lp = random_standard_lp(8, 16, seed=6)
    lp.ub = np.full(16, 1.5)
    r = solve_jit(lp, PDHGOptions(max_iters=20000, tol=1e-6))
    assert np.all(r.x >= -1e-9)
    assert np.all(r.x <= 1.5 + 1e-9)


def test_jit_seed_changes_trajectory(x64):
    """Regression: _solve_jit_core used to hardcode PRNGKey(0) for the
    iterate init, so ``opts.seed`` never reached the jitted start point."""
    lp = random_standard_lp(8, 14, seed=3)
    mk = lambda s: PDHGOptions(  # noqa: E731
        max_iters=128, tol=1e-30, check_every=64, seed=s)
    r0 = solve_jit(lp, mk(0))
    r0b = solve_jit(lp, mk(0))
    r1 = solve_jit(lp, mk(1))
    np.testing.assert_allclose(r0.x, r0b.x)     # deterministic given seed
    assert not np.allclose(r0.x, r1.x)          # seed reaches the init


def test_host_residual_checks_use_fresh_noise_keys(x64):
    """Regression: the restart check reused k3/k4 for the averaged-iterate
    MVMs, correlating read noise between the current- and averaged-iterate
    residual evaluations.  Every key an accelerator sees must be unique."""
    from repro.core.symblock import Accel

    lp = random_standard_lp(8, 14, seed=2)
    seen = []

    def factory(K):
        base = encode_exact(K)

        def mvm(v, key=None):
            if key is not None:
                seen.append(tuple(np.asarray(key).tolist()))
            return base.mvm_full(v)

        return Accel(mvm_full=mvm, m=base.m, n=base.n, name="crossbar:spy")

    opts = PDHGOptions(max_iters=256, tol=1e-12, check_every=64)
    solve(lp, opts, accel_factory=factory)
    assert len(seen) > 8                        # lanczos + iters + checks
    assert len(seen) == len(set(seen))


def test_jit_mvm_accounting_includes_residual_checks(x64):
    """Regression: solve_jit reported 2*it, dropping the Lanczos MVMs and
    the 4 residual-check MVMs per check that the energy ledger charges."""
    lp = random_standard_lp(8, 14, seed=0)
    opts = PDHGOptions(max_iters=20000, tol=1e-6, check_every=64)
    r = solve_jit(lp, opts)
    assert r.status == "optimal"
    n_checks = max(1, r.iterations // opts.check_every)
    assert r.mvm_calls == (opts.lanczos_iters + 2 * r.iterations
                           + 4 * n_checks)
    assert r.mvm_calls > 2 * r.iterations


def test_host_mvm_accounting_matches_jit_formula(x64):
    """Host path (stats-counted) and jit path (analytic) agree on the
    per-iteration accounting: 2 MVMs/iter + 4 per residual check (the
    host skips the 2 averaged-iterate MVMs on the final, converging
    check because it breaks first)."""
    lp = random_standard_lp(8, 14, seed=1)
    opts = PDHGOptions(max_iters=20000, tol=1e-6, check_every=64)
    r = solve(lp, opts)
    assert r.status == "optimal"
    n_checks = r.iterations // opts.check_every  # converged at a check
    expected = r.lanczos_iters + 2 * r.iterations + 4 * n_checks - 2
    assert r.mvm_calls == expected


def test_infeasibility_divergence_detected(x64):
    lp = infeasible_lp(8, 12, seed=7)
    r = solve_jit(lp, PDHGOptions(max_iters=4000, tol=1e-9))
    # an infeasible LP cannot reach optimality
    assert r.status != "optimal"


def test_farkas_certificate_checker():
    from repro.core import check_farkas

    K = np.array([[1.0, 0.0], [1.0, 0.0]])
    b = np.array([1.0, 2.0])          # x1 = 1 and x1 = 2: infeasible
    y = np.array([-1.0, 1.0])         # K^T y = 0, b^T y = 1 > 0
    cert = check_farkas(K, b, y)
    assert cert.kind == "primal_infeasible"
    y_bad = np.array([1.0, 1.0])
    assert check_farkas(K, b, y_bad).kind == "none"


def test_infeasible_lp_yields_farkas_certificate(x64):
    """Host solver attaches a verified Farkas certificate on divergence."""
    from repro.core import solve

    lp = infeasible_lp(8, 12, seed=7)
    r = solve(lp, PDHGOptions(max_iters=8000, tol=1e-9, check_every=100,
                              restart=False))
    assert r.status in ("primal_infeasible", "diverged", "iteration_limit")
    if r.status == "primal_infeasible":
        assert r.certificate is not None
        # independently re-verify the certificate
        from repro.core import check_farkas
        cert = check_farkas(lp.K, lp.b, r.certificate.y_ray)
        assert cert.kind == "primal_infeasible"


# --------------------------------------------- truthful divergence status ---

def test_blown_up_solve_reports_diverged_not_iteration_limit(x64):
    """Regression: a numerically blown-up solve (non-finite merit) used to
    report ``iteration_limit`` — indistinguishable from a clean
    out-of-budget exit.  An absurd norm override (rho ~ 1e-12 makes the
    steps ~1e12x too large) drives the iterates to NaN within one check
    window; every reporting surface must call that ``diverged``."""
    lp = random_standard_lp(8, 14, seed=0)
    opts = PDHGOptions(max_iters=256, tol=1e-6, check_every=64,
                       norm_override=1e-12)

    r_jit = solve_jit(lp, opts)
    assert not np.isfinite(r_jit.merit)
    assert r_jit.status == "diverged"
    # the loop exits at the first check (NaN > tol is false), so the
    # report is immediate, not a 256-iteration slog
    assert r_jit.iterations == opts.check_every

    r_host = solve(lp, opts)
    assert not np.isfinite(r_host.merit)
    assert r_host.status == "diverged"


def test_batch_stream_reports_diverged_items(x64):
    """The batch scheduler surfaces per-item divergence: a blown-up item
    reports status='diverged' (converged=False), while a healthy stream
    mate in the SAME bucket still reports its own clean status."""
    from repro.runtime import BatchSolver

    lp = random_standard_lp(8, 14, seed=0)
    bad = BatchSolver(PDHGOptions(max_iters=256, tol=1e-6, check_every=64,
                                  norm_override=1e-12)).solve_stream([lp])[0]
    assert bad.status == "diverged"
    assert not bad.converged
    good = BatchSolver(PDHGOptions(max_iters=20000, tol=1e-5,
                                   check_every=64)).solve_stream([lp])[0]
    assert good.status == "optimal"
