"""Optional-``hypothesis`` shim for the property-based test modules.

When ``hypothesis`` is installed (the ``[test]`` extra), this module
re-exports the real ``given``/``settings``/``strategies``.  Otherwise it
provides a minimal deterministic stand-in: ``@given`` draws
``max_examples`` pseudo-random examples from a fixed-seed RNG and calls
the test once per example.  No shrinking, no database — just enough for
the KKT/theory property sweeps to run (and fail meaningfully) without
the dependency.
"""
try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random
    import types

    _DEFAULT_EXAMPLES = 10
    _SEED = 0xC0FFEE

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _tuples(*strategies):
        return _Strategy(
            lambda rng: tuple(s.draw(rng) for s in strategies))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[rng.randrange(len(elements))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.randrange(2)))

    st = types.SimpleNamespace(
        integers=_integers,
        floats=_floats,
        tuples=_tuples,
        sampled_from=_sampled_from,
        booleans=_booleans,
    )

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(_SEED)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            # pytest must not see the drawn parameters as fixtures: hide
            # the original signature and expose only the leftover params
            del wrapper.__wrapped__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            # keep a settings() applied below @given (functools.wraps
            # already copied fn._max_examples onto the wrapper)
            wrapper._max_examples = getattr(
                fn, "_max_examples", _DEFAULT_EXAMPLES)
            return wrapper
        return deco
