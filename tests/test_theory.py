"""Property-based tests for the paper's theoretical results (§4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    NoiseModel,
    build_sym_block,
    encode_exact,
    encode_noisy,
    lanczos_svd,
    lemma2_worst_case,
    safe_coupling,
    spectral_ratio,
    theorem2_envelope,
)
from repro.lp import random_standard_lp

dims = st.tuples(st.integers(2, 12), st.integers(2, 12))


@settings(max_examples=25, deadline=None)
@given(dims=dims, seed=st.integers(0, 10_000))
def test_proposition1_lambda_max_equals_sigma_max(dims, seed):
    """Prop. 1: lambda_max(M) == sigma_max(K) for arbitrary K."""
    m, n = dims
    rng = np.random.default_rng(seed)
    K = rng.normal(size=(m, n))
    M = np.asarray(build_sym_block(K))
    lam = np.max(np.abs(np.linalg.eigvalsh(M)))
    sig = np.linalg.svd(K, compute_uv=False)[0]
    np.testing.assert_allclose(lam, sig, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(dims=dims, seed=st.integers(0, 10_000))
def test_proposition1_plus_minus_pairs(dims, seed):
    """Prop. 1 proof detail: eigenvalues of M come in +-sigma_i pairs."""
    m, n = dims
    rng = np.random.default_rng(seed)
    K = rng.normal(size=(m, n))
    M = np.block([[np.zeros((m, m)), K], [K.T, np.zeros((n, n))]])
    eigs = np.sort(np.linalg.eigvalsh(M))
    svs = np.linalg.svd(K, compute_uv=False)
    for s in svs:
        assert np.min(np.abs(eigs - s)) < 1e-8 * max(1, s)
        assert np.min(np.abs(eigs + s)) < 1e-8 * max(1, s)


@settings(max_examples=50, deadline=None)
@given(L=st.floats(0.1, 100.0), delta=st.floats(0.0, 0.5),
       eta=st.floats(0.1, 0.99), omega=st.floats(0.25, 4.0),
       err=st.floats(-1.0, 1.0))
def test_lemma2_safe_coupling(L, delta, eta, omega, err):
    """Whenever |L^ - L| <= delta*L, the chosen steps keep tau*sigma*L^2<1."""
    L_hat = L * (1.0 + err * delta)      # any estimate within the band
    sc = safe_coupling(L_hat, delta_bar=delta, eta=eta, omega=omega)
    assert sc.satisfied
    lhs, ok = lemma2_worst_case(L, L_hat, sc.tau, sc.sigma, delta)
    assert ok, (lhs, sc)
    assert sc.tau * sc.sigma * L * L < 1.0 + 1e-9


def test_theorem1_noisy_lanczos_error_tracks_envelope():
    """Ritz error under MVM noise stays within C*rho^k + k*eps (Thm. 1)."""
    rng = np.random.default_rng(0)
    K = rng.normal(size=(20, 30))
    sigma_true = np.linalg.svd(K, compute_uv=False)[0]
    eps = 1e-3
    noise = NoiseModel("multiplicative", eps)
    acc = encode_noisy(K, noise.apply)
    res = lanczos_svd(acc, k_max=30, tol=0.0, noise_keys=True,
                      key=jax.random.PRNGKey(0))
    errors = np.abs(res.ritz_history - sigma_true) / sigma_true
    M = np.asarray(build_sym_block(K))
    rho, p = spectral_ratio(np.linalg.eigvalsh(M))
    ks = np.arange(1, len(errors) + 1)
    # generous constant C; eps_max scaled by sigma (relative noise)
    envelope = 10.0 * rho ** (ks - 1) + ks * eps * 4.0
    assert np.all(errors <= envelope), (errors, envelope)
    # and the estimate is still good enough for step sizing (Lemma 2 band)
    assert errors[-1] < 0.1


def test_theorem1_lanczos_beats_power_iteration_under_noise():
    """The paper's motivation for Lanczos: faster reliable estimates."""
    from repro.core import power_iteration

    rng = np.random.default_rng(1)
    K = rng.normal(size=(24, 36))
    sigma_true = np.linalg.svd(K, compute_uv=False)[0]
    acc = encode_exact(K)
    res = lanczos_svd(acc, k_max=12, tol=0.0)
    lanczos_err = abs(res.sigma_max - sigma_true) / sigma_true
    pi_est = float(power_iteration(jnp.asarray(K), iters=12))
    pi_err = abs(pi_est - sigma_true) / sigma_true
    assert lanczos_err <= pi_err + 1e-12


@pytest.mark.parametrize("sigma_noise", [3e-3])
def test_theorem2_noise_floor_scales_with_delta(x64, sigma_noise):
    """Thm. 2: gap(K) = O(1/K) + O(delta/sqrt(K)) — the noisy solve
    plateaus near its noise floor while the clean solve keeps going."""
    from repro.core import PDHGOptions, solve_jit

    lp = random_standard_lp(12, 20, seed=3)
    opts = PDHGOptions(max_iters=8000, tol=1e-10, check_every=100)
    clean = solve_jit(lp, opts)
    noisy = solve_jit(lp, opts, sigma_read=sigma_noise)
    gap_clean = abs(clean.obj - lp.obj_opt) / abs(lp.obj_opt)
    gap_noisy = abs(noisy.obj - lp.obj_opt) / abs(lp.obj_opt)
    assert gap_clean < 1e-6
    # noise floor: worse than clean, but bounded by ~O(delta)
    assert gap_noisy < 50 * sigma_noise
    # envelope shape sanity
    env = theorem2_envelope(np.array([8000.0]), C0=10.0, delta=sigma_noise)
    assert gap_noisy < 100 * env[0] + 10 * sigma_noise


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), sigma=st.floats(1e-4, 1e-2))
def test_noise_model_unbiased(seed, sigma):
    """Assumption 2: E[noise] = 0 (multiplicative model, clipped)."""
    noise = NoiseModel("multiplicative", sigma)
    w = jnp.ones(4096)
    keys = jax.random.split(jax.random.PRNGKey(seed), 64)
    mean = np.mean([np.mean(np.asarray(noise.apply(k, w))) for k in keys])
    assert abs(mean - 1.0) < 6 * sigma / np.sqrt(64 * 4096) + 1e-6
