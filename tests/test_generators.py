"""Ground-truth trust: the known-optimum constructions satisfy KKT exactly.

The whole reproduction leans on generated instances with constructed
optima (offline stand-in for Gurobi/MIPLIB).  These property tests verify
the KKT conditions of every construction directly — primal feasibility,
dual feasibility, complementary slackness — so the "known optimum" label
is earned, not assumed.
"""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.lp import (
    TABLE1_SIZES,
    assignment_lp,
    netlib_like,
    pagerank_lp,
    random_inequality_lp_known,
    random_standard_lp,
    table1_instance,
)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 15), extra=st.integers(0, 20),
       seed=st.integers(0, 10_000))
def test_standard_lp_kkt(m, extra, seed):
    lp = random_standard_lp(m, m + extra, seed=seed)
    x, K, b, c = lp.x_opt, lp.K, lp.b, lp.c
    # primal feasibility
    assert np.allclose(K @ x, b, atol=1e-9)
    assert np.all(x >= -1e-12)
    # dual feasibility + complementary slackness: by construction
    # c - K^T y* = s >= 0 with s_i x_i = 0; recover s via least squares
    y, *_ = np.linalg.lstsq(K.T[x > 0], c[x > 0], rcond=None)
    s = c - K.T @ y
    assert np.all(s >= -1e-7)
    assert np.allclose(s * x, 0.0, atol=1e-6)
    assert np.isclose(lp.obj_opt, c @ x)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(3, 20), n=st.integers(3, 20), seed=st.integers(0, 999),
       density=st.sampled_from([1.0, 0.3]))
def test_inequality_lp_kkt(m, n, seed, density):
    lp = random_inequality_lp_known(m, n, seed=seed, density=density)
    x = lp._x_opt
    G, h, c = lp.G, lp.h, lp.c
    # primal feasibility (box + inequalities)
    assert np.all(G @ x - h >= -1e-9)
    assert np.all(x >= -1e-12)
    assert np.all(x <= lp.ub + 1e-12)
    # stationarity witness exists by construction: c = G^T y + l_l - l_u
    # with complementary slackness — verify the optimum via a dual bound:
    # for any feasible z, c@z >= c@x (weak duality on a few random z)
    rng = np.random.default_rng(seed)
    obj = c @ x
    for _ in range(5):
        z = np.clip(x + rng.normal(scale=0.1, size=n), 0, lp.ub)
        if np.all(G @ z - h >= 0):
            assert c @ z >= obj - 1e-8


def test_table1_instances_feasible_and_consistent():
    for name in TABLE1_SIZES:
        lp = table1_instance(name)
        assert lp.K.shape[0] == TABLE1_SIZES[name][0]
        assert lp.obj_opt is not None
        # standard form: the constructed optimum must be recoverable —
        # check a feasible point exists at the claimed objective by
        # verifying the instance is bounded below near it (spot check)
        assert np.isfinite(lp.obj_opt)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 6), seed=st.integers(0, 100))
def test_assignment_lp_brute_force(n, seed):
    import itertools

    lp = assignment_lp(n, seed=seed)
    C = lp.c.reshape(n, n)
    best = min(sum(C[i, p[i]] for i in range(n))
               for p in itertools.permutations(range(n)))
    from repro.lp import simplex

    r = simplex.solve(lp)
    assert r.status == "optimal"
    assert abs(r.obj - best) < 1e-8


def test_pagerank_lp_is_stochastic_fixed_point():
    lp = pagerank_lp(40, seed=1, damping=0.85)
    # unique feasible point == pagerank vector: row sums of K recover it
    x = np.linalg.solve(lp.K, lp.b)
    assert np.all(x >= -1e-12)
    assert np.isclose(x.sum(), 1.0)


def test_netlib_like_condition_number():
    lp = netlib_like(20, 30, seed=0, cond=1e4)
    sv = np.linalg.svd(lp.K, compute_uv=False)
    assert 1e3 < sv[0] / sv[sv > 1e-12][-1] < 1e5
    # and the known optimum passes feasibility
    assert np.allclose(lp.K @ lp.x_opt, lp.b, atol=1e-6)


def test_ledger_snapshot_diff():
    from repro.crossbar import Ledger

    led = Ledger()
    led.write_energy_j = 2.0
    snap = led.snapshot()
    led.read_energy_j += 3.0
    led.mvm_count += 5
    d = led.diff(snap)
    assert d.write_energy_j == 0.0
    assert d.read_energy_j == 3.0
    assert d.mvm_count == 5
    assert led.total_energy_j == 5.0
