"""Per-arch smoke tests (reduced configs) + decode/prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    param_shapes,
    partition_specs,
)
from repro.train import (
    DataConfig,
    TrainConfig,
    init_opt_state,
    make_train_step,
    synth_batch,
)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one train step on the reduced config; shapes + no NaN."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    dcfg = DataConfig(vocab=cfg.vocab, batch=B, seq_len=S,
                      embeddings_dim=cfg.d_model
                      if cfg.frontend in ("vision", "audio") else 0)
    batch = {k: jnp.asarray(v) for k, v in synth_batch(dcfg, 0).items()}
    logits = forward(params, cfg, tokens=batch.get("tokens"),
                     embeddings=batch.get("embeddings"))
    from repro.models.lm import padded_vocab
    assert logits.shape == (B, S, padded_vocab(cfg))
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))

    step = jax.jit(make_train_step(cfg, TrainConfig(remat=True)))
    opt = init_opt_state(params)
    p2, o2, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, p2))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(1))
    B = 2
    cache = init_cache(cfg, B, max_len=16)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = decode_step(params, cfg, tok, cache)
    from repro.models.lm import padded_vocab
    assert logits.shape == (B, padded_vocab(cfg))
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ["granite-3-8b", "minicpm3-4b",
                                  "rwkv6-1.6b", "hymba-1.5b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the full-sequence forward logits."""
    cfg = get_smoke_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(2))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    full = np.asarray(forward(params, cfg, tokens=toks), np.float32)
    cache = init_cache(cfg, B, max_len=S)
    outs = []
    for t in range(S):
        logits, cache = decode_step(params, cfg, toks[:, t:t + 1], cache)
        outs.append(np.asarray(logits, np.float32))
    dec = np.stack(outs, axis=1)
    scale = np.abs(full).max()
    np.testing.assert_allclose(dec, full, atol=2e-2 * scale, rtol=0.05)


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    expect = {
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
    }
    for arch, (L, d, H, Hkv, ff, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == Hkv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab == V, arch
    assert get_config("olmoe-1b-7b").n_experts == 64
    assert get_config("olmoe-1b-7b").top_k == 8
    assert get_config("grok-1-314b").n_experts == 8
    assert get_config("grok-1-314b").top_k == 2
    assert get_config("hymba-1.5b").ssm_state == 16


def test_param_counts_plausible():
    """Analytic param counts should be near the advertised model sizes."""
    approx = {
        "granite-3-8b": (8e9, 0.35),
        # starcoder2's published MLP is non-gated (2 mats); our unified
        # block is gated (3 mats) => ~1.1B extra at these dims
        "starcoder2-3b": (3e9, 0.45),
        "qwen3-14b": (14e9, 0.35),
        "minicpm3-4b": (4e9, 0.45),
        "olmoe-1b-7b": (7e9, 0.35),
        "grok-1-314b": (314e9, 0.25),
        "musicgen-large": (2e9*1.7, 0.6),   # 48L/2048d backbone-only
        "rwkv6-1.6b": (1.6e9, 0.45),
        "hymba-1.5b": (1.5e9, 0.45),
        "phi-3-vision-4.2b": (4.2e9, 0.35),
    }
    for arch, (target, tol) in approx.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)


def test_partition_specs_cover_all_params():
    for arch in ("granite-3-8b", "olmoe-1b-7b", "rwkv6-1.6b"):
        cfg = get_config(arch)
        shapes = param_shapes(cfg)
        specs = partition_specs(cfg)
        flat_s = jax.tree.leaves(shapes)
        from jax.sharding import PartitionSpec
        flat_p = jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
        assert len(flat_s) == len(flat_p)
        for sds, spec in zip(flat_s, flat_p):
            assert len(spec) <= len(sds.shape)


def test_chunked_attention_matches_naive():
    from repro.models.layers import chunked_causal_attention

    key = jax.random.PRNGKey(0)
    B, S, H, D = 2, 64, 4, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    out = chunked_causal_attention(q, k, v, chunk=16)
    # naive reference
    scores = jnp.einsum("bshd,bchd->bhsc", q, k) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((S, S), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    ref = jnp.einsum("bhsc,bchd->bshd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_sliding_window_attention_masks_correctly():
    from repro.models.layers import chunked_causal_attention

    key = jax.random.PRNGKey(1)
    B, S, H, D, W = 1, 64, 2, 8, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    out = chunked_causal_attention(q, k, v, chunk=16, window=W)
    scores = jnp.einsum("bshd,bchd->bhsc", q, k) / np.sqrt(D)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = (qp >= kp) & (qp - kp < W)
    scores = jnp.where(mask[None, None], scores, -1e30)
    ref = jnp.einsum("bhsc,bchd->bshd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_int8_kv_cache_decode_close_to_fp():
    """Opt-in int8 KV cache: decode logits track the fp cache closely."""
    import dataclasses

    cfg = get_smoke_config("granite-3-8b")
    cfg8 = dataclasses.replace(cfg, kv_cache_int8=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    c_fp = init_cache(cfg, B, max_len=S)
    c_q = init_cache(cfg8, B, max_len=S)
    assert c_q["k"].dtype == jnp.int8
    for t in range(S):
        lf, c_fp = decode_step(params, cfg, toks[:, t:t + 1], c_fp)
        lq, c_q = decode_step(params, cfg8, toks[:, t:t + 1], c_q)
    lf = np.asarray(lf, np.float32)
    lq = np.asarray(lq, np.float32)
    scale = np.abs(lf).max()
    assert np.abs(lf - lq).max() < 0.05 * scale
    assert (lf.argmax(-1) == lq.argmax(-1)).all()
