"""Sparse LP serving: SparseCOO model, sparse operator backend, and the
COO bucket pipeline (ISSUE 4 tentpole).

The acceptance contract: a >=95%-sparse stream must flow through the
batch scheduler with NO dense (B, m_pad, n_pad) materialization, match
the dense path's iterates at sigma_read=0, and stack in
nonzero-proportional host memory (>=4x smaller than the dense stack).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PDHGOptions, engine
from repro.lp import SparseCOO, random_standard_lp, sparse_lp_stream, \
    sparse_random_standard_lp
from repro.runtime import BatchSolver
from repro.runtime import batch as batch_mod
from repro.runtime.batch import (
    nnz_bucket,
    pad_problem,
    stack_problems_sparse,
)

OPTS = PDHGOptions(max_iters=20000, tol=1e-5, check_every=64)


# ------------------------------------------------------------ SparseCOO ---

def test_sparse_coo_matvec_and_transpose_match_dense(rng):
    K = rng.normal(size=(7, 11)) * (rng.random((7, 11)) < 0.3)
    sp = SparseCOO.from_dense(K)
    assert sp.nnz == np.count_nonzero(K)
    x, y = rng.normal(size=11), rng.normal(size=7)
    np.testing.assert_allclose(sp @ x, K @ x)
    np.testing.assert_allclose(sp.T @ y, K.T @ y)
    np.testing.assert_allclose(sp.toarray(), K)
    np.testing.assert_allclose(sp.T.toarray(), K.T)


def test_sparse_coo_duplicate_indices_sum(rng):
    sp = SparseCOO([1.0, 2.0, 5.0], [0, 0, 1], [1, 1, 0], (2, 3))
    dense = sp.toarray()
    assert dense[0, 1] == 3.0 and dense[1, 0] == 5.0
    np.testing.assert_allclose(sp @ np.ones(3), dense @ np.ones(3))


def test_standard_lp_sparse_roundtrip():
    lp = sparse_random_standard_lp(12, 24, density=0.2, seed=0)
    assert lp.is_sparse
    dense = lp.densified()
    assert not dense.is_sparse
    np.testing.assert_allclose(dense.K, lp.K.toarray())
    back = dense.sparsified()
    assert back.is_sparse
    np.testing.assert_allclose(back.K.toarray(), dense.K)
    # known optimum is feasible under the COO matvec
    assert np.linalg.norm(lp.K @ lp.x_opt - lp.b) < 1e-10


def test_sparse_generator_density_and_coverage():
    lp = sparse_random_standard_lp(64, 128, density=0.05, seed=3)
    assert 0.02 < lp.K.density < 0.10
    # coverage guarantee: no zero rows or columns
    assert np.all(np.bincount(lp.K.row, minlength=64) > 0)
    assert np.all(np.bincount(lp.K.col, minlength=128) > 0)


# ----------------------------------------------------- padding / stacking ---

def test_pad_problem_sparse_never_densifies():
    lp = sparse_random_standard_lp(10, 20, density=0.2, seed=1)
    padded = pad_problem(lp, 16, 32)
    assert isinstance(padded.K, SparseCOO)
    assert padded.K.shape == (16, 32)
    assert padded.K.nnz == lp.K.nnz          # same data, bigger shape
    # padding preserves the optimum semantics: pinned extra vars
    assert np.all(padded.lb[20:] == 0) and np.all(padded.ub[20:] == 0)


def test_stack_problems_sparse_layout():
    lps = [sparse_random_standard_lp(8, 16, density=0.3, seed=s)
           for s in range(3)]
    nnz = nnz_bucket(max(lp.K.nnz for lp in lps))
    data, idx, b, c, lb, ub = stack_problems_sparse(lps, m=16, n=32,
                                                    nnz=nnz)
    assert data.shape == (3, nnz) and idx.shape == (3, nnz, 2)
    assert b.shape == (3, 16) and c.shape == (3, 32)
    assert idx.dtype == np.int32
    # nnz padding is explicit zeros at (0, 0): contraction-neutral
    k = lps[0].K.nnz
    assert np.all(data[0, k:] == 0) and np.all(idx[0, k:] == 0)
    # stacked operator reproduces each instance
    K0 = np.zeros((16, 32))
    np.add.at(K0, (idx[0, :, 0], idx[0, :, 1]), data[0])
    np.testing.assert_allclose(K0[:8, :16], lps[0].K.toarray())


# ------------------------------------------------- engine sparse operator ---

def test_sparse_operator_iterate_parity_with_dense(x64):
    """sparse_operator must reproduce dense_operator's PDHG trajectory
    at sigma_read=0 (the ISSUE-4 parity requirement)."""
    from jax.experimental import sparse as jsparse

    lp = sparse_random_standard_lp(12, 24, density=0.25, seed=2)
    K = jnp.asarray(lp.K.toarray())
    K_sp = jsparse.BCOO(
        (jnp.asarray(lp.K.data), jnp.asarray(
            np.stack([lp.K.row, lp.K.col], axis=1))), shape=lp.K.shape)
    b, c = jnp.asarray(lp.b), jnp.asarray(lp.c)
    lb, ub = jnp.asarray(lp.lb), jnp.asarray(lp.ub)
    T = jnp.ones(24); Sigma = jnp.ones(12)
    key, x0, y0 = engine.draw_init(jax.random.PRNGKey(0), 12, 24, lb, ub,
                                   K.dtype)
    tau = sigma = 0.9 / float(jnp.linalg.norm(K, 2))

    states = {}
    for name, op in (("dense", engine.dense_operator(K, K.T)),
                     ("sparse", engine.sparse_operator(K_sp))):
        state = engine.init_state(x0, y0, tau, sigma, gamma=0.0)
        for _ in range(50):
            state = engine.pdhg_step(op, engine.JNP_UPDATES, b, c, lb, ub,
                                     T, Sigma, 0.0, state)
        states[name] = state
    np.testing.assert_allclose(states["sparse"].x, states["dense"].x,
                               atol=1e-12, rtol=1e-10)
    np.testing.assert_allclose(states["sparse"].y, states["dense"].y,
                               atol=1e-12, rtol=1e-10)


def test_solve_core_auto_mounts_sparse_operator(x64):
    """solve_core on a BCOO K must run without a dense K anywhere and
    agree with the dense solve_core bit-for-bit at sigma_read=0 apart
    from MVM summation order (allclose)."""
    from jax.experimental import sparse as jsparse
    from repro.core.pdhg import opts_static

    lp = sparse_random_standard_lp(10, 20, density=0.3, seed=4)
    Kd = jnp.asarray(lp.K.toarray())
    K_sp = jsparse.BCOO(
        (jnp.asarray(lp.K.data), jnp.asarray(
            np.stack([lp.K.row, lp.K.col], axis=1))), shape=lp.K.shape)
    b, c = jnp.asarray(lp.b), jnp.asarray(lp.c)
    lb, ub = jnp.asarray(lp.lb), jnp.asarray(lp.ub)
    T, Sigma = jnp.ones(20), jnp.ones(10)
    rho = float(jnp.linalg.norm(Kd, 2))
    static = opts_static(PDHGOptions(max_iters=512, tol=1e-9,
                                     check_every=64))
    key = jax.random.PRNGKey(1)
    xd, yd, itd, md = engine.solve_core(Kd, Kd.T, b, c, lb, ub, T, Sigma,
                                        rho, key, static)
    xs, ys, its, ms = engine.solve_core(K_sp, None, b, c, lb, ub, T,
                                        Sigma, rho, key, static)
    assert int(its) == int(itd)
    np.testing.assert_allclose(np.asarray(xs), np.asarray(xd), atol=1e-8)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(yd), atol=1e-8)


# ------------------------------------------------------- stream serving ---

def test_sparse_stream_solves_without_dense_materialization(x64,
                                                            monkeypatch):
    """The acceptance assertion: a sparse stream through BatchSolver may
    NEVER materialize a dense (B, m_pad, n_pad) stack — dense stacking
    is poisoned for the duration and host bytes are audited."""
    def _poisoned(*a, **k):
        raise AssertionError(
            "dense stack_problems called for a sparse stream")

    monkeypatch.setattr(batch_mod, "stack_problems", _poisoned)
    lps = sparse_lp_stream(4, density=0.05, seed=0)
    solver = BatchSolver(PDHGOptions(max_iters=20000, tol=1e-4,
                                     check_every=64))
    results = solver.solve_stream(lps)
    stats = solver.last_stream_stats
    assert stats["dense_stack_bytes"] == 0
    assert stats["sparse_stack_bytes"] > 0
    for lp, r in zip(lps, results):
        assert r.sparse
        rel = abs(r.obj - lp.obj_opt) / abs(lp.obj_opt)
        assert rel < 1e-3, (lp.name, rel)
        assert r.x.shape == (lp.K.shape[1],)


def test_sparse_stream_host_memory_at_least_4x_smaller(x64):
    """>=95%-sparse 16-instance stream: the sparse stack must be >=4x
    smaller on host than the dense stack of the same stream (the
    acceptance criterion's memory leg).

    Pinned to ``sparse_kernel="bcoo"`` — the COO stacking is the
    memory-optimal backend (nnz-proportional); the default ELL backend
    trades bounded width padding for scatter-free wall clock and only
    guarantees ~2x here."""
    lps = sparse_lp_stream(16, density=0.05, seed=0)
    assert all(lp.K.density <= 0.05 + 1e-9 for lp in lps)
    opts = PDHGOptions(max_iters=64, tol=1e-30, check_every=64,
                       lanczos_iters=8, sparse_kernel="bcoo")
    sp = BatchSolver(opts)
    sp.solve_stream(lps)
    dn = BatchSolver(opts)
    dn.solve_stream([lp.densified() for lp in lps])
    mem_sparse = sp.last_stream_stats["sparse_stack_bytes"]
    mem_dense = dn.last_stream_stats["dense_stack_bytes"]
    assert mem_sparse > 0 and mem_dense > 0
    assert mem_dense >= 4 * mem_sparse, (mem_dense, mem_sparse)


def test_sparse_stream_matches_dense_stream(x64):
    """Sparse pipeline vs densified dense pipeline on the same stream:
    same iteration counts and matching objectives (sigma_read=0)."""
    lps = sparse_lp_stream(3, density=0.05, seed=0)
    opts = PDHGOptions(max_iters=4000, tol=1e-5, check_every=64)
    rs = BatchSolver(opts).solve_stream(lps)
    rd = BatchSolver(opts).solve_stream([lp.densified() for lp in lps])
    for a, d in zip(rs, rd):
        assert a.iterations == d.iterations, (a.name, a.iterations,
                                              d.iterations)
        assert abs(a.obj - d.obj) / max(abs(d.obj), 1e-12) < 1e-9
        np.testing.assert_allclose(a.x, d.x, atol=1e-6)


def test_sparse_and_dense_buckets_are_cache_disjoint(x64):
    """A sparse and a dense instance of the SAME shape must compile
    separate executables (different pipelines) and both solve."""
    sp_lp = sparse_random_standard_lp(8, 14, density=0.3, seed=0)
    dn_lp = random_standard_lp(8, 14, seed=0)
    solver = BatchSolver(PDHGOptions(max_iters=2000, tol=1e-4,
                                     check_every=64, lanczos_iters=16))
    results = solver.solve_stream([sp_lp, dn_lp])
    assert solver.cache_misses == 2          # one sparse, one dense exe
    assert results[0].sparse and not results[1].sparse
    for lp, r in zip((sp_lp, dn_lp), results):
        rel = abs(r.obj - lp.obj_opt) / abs(lp.obj_opt)
        assert rel < 1e-2, (lp.name, rel)


def test_crossbar_batch_solver_densifies_sparse(x64):
    """The crossbar tier programs every physical cell: sparse instances
    must densify on entry and still serve correctly."""
    from repro.crossbar import EPIRAM, CrossbarBatchSolver

    lp = sparse_random_standard_lp(8, 14, density=0.3, seed=1)
    opts = PDHGOptions(max_iters=2000, tol=1e-3, check_every=64,
                       lanczos_iters=16)
    rep = CrossbarBatchSolver(opts, device=EPIRAM).solve_stream([lp])[0]
    rel = abs(rep.result.obj - lp.obj_opt) / abs(lp.obj_opt)
    assert rel < 5e-2, rel


def test_sparse_stream_buckets_on_nnz_too(x64):
    """An nnz outlier must not inflate its shape bucket: same-shape
    instances with far-apart nonzero counts compile separate (smaller)
    executables instead of padding everyone to the outlier."""
    thin = sparse_random_standard_lp(64, 128, density=0.04, seed=0)
    fat = sparse_random_standard_lp(64, 128, density=0.5, seed=1)
    assert nnz_bucket(thin.K.nnz) != nnz_bucket(fat.K.nnz)
    solver = BatchSolver(PDHGOptions(max_iters=64, tol=1e-30,
                                     check_every=64, lanczos_iters=8))
    solver.solve_stream([thin, fat])
    assert solver.last_stream_stats["n_buckets"] == 2
    assert solver.cache_misses == 2
    # the thin instance's stack is nnz-proportional, not outlier-sized
    expected_thin = nnz_bucket(thin.K.nnz)
    expected_fat = nnz_bucket(fat.K.nnz)
    assert expected_thin * 4 < expected_fat


def test_sparse_duplicate_indices_match_densified(x64):
    """Duplicate COO entries sum (the BCOO convention): a duplicate-
    bearing instance must solve identically to its densified copy —
    the stacking coalesces before the scatter preconditioners."""
    base = sparse_random_standard_lp(8, 14, density=0.4, seed=5)
    K = base.K
    # split the first entry into two stored halves at the same (r, c)
    dup = SparseCOO(
        np.concatenate([[K.data[0] / 2, K.data[0] / 2], K.data[1:]]),
        np.concatenate([[K.row[0]], K.row]),
        np.concatenate([[K.col[0]], K.col]), K.shape)
    np.testing.assert_allclose(dup.toarray(), K.toarray())
    lp_dup = dataclasses.replace(base, K=dup)
    opts = PDHGOptions(max_iters=2000, tol=1e-5, check_every=64,
                       lanczos_iters=16)
    r_dup = BatchSolver(opts).solve_stream([lp_dup])[0]
    r_dense = BatchSolver(opts).solve_stream([base.densified()])[0]
    assert r_dup.iterations == r_dense.iterations
    np.testing.assert_allclose(r_dup.x, r_dense.x, atol=1e-8)


def test_nnz_bucket_rounds_to_pow2():
    assert nnz_bucket(1) == 16
    assert nnz_bucket(16) == 16
    assert nnz_bucket(17) == 32
    assert nnz_bucket(900) == 1024


# --------------------------------------- ELL backend (ISSUE 6 tentpole) ---

def _zero_k_lp(m=6, n=10):
    """Feasible degenerate LP with an all-zero K (nnz=0): K@x = 0 = b,
    optimum is the lower bound wherever c > 0."""
    sp = SparseCOO(np.zeros(0), np.zeros(0, np.int64),
                   np.zeros(0, np.int64), (m, n))
    c = np.linspace(0.5, 1.5, n)
    return batch_mod.StandardLP(c=c, K=sp, b=np.zeros(m),
                                lb=np.zeros(n), ub=np.ones(n),
                                name="zeroK", x_opt=np.zeros(n),
                                obj_opt=0.0)


def test_ell_from_coo_matches_dense(x64, rng):
    from repro.kernels.sparse_mvm import ell_from_coo, ell_matvec

    K = rng.normal(size=(9, 13)) * (rng.random((9, 13)) < 0.3)
    sp = SparseCOO.from_dense(K)
    data, cols = ell_from_coo(sp.data, sp.row, sp.col, sp.shape)
    assert data.shape == cols.shape and data.shape[0] == 9
    # width == the densest row; padded slots carry (0.0, col 0): inert
    widths = (K != 0).sum(axis=1)
    assert data.shape[1] == widths.max()
    v = rng.normal(size=13)
    np.testing.assert_allclose(np.asarray(ell_matvec(
        jnp.asarray(data), jnp.asarray(cols), jnp.asarray(v))), K @ v,
        rtol=1e-12, atol=1e-12)
    # explicit padding beyond the max width must not change the product
    data_w, cols_w = ell_from_coo(sp.data, sp.row, sp.col, sp.shape,
                                  width=widths.max() + 3)
    np.testing.assert_allclose(np.asarray(ell_matvec(
        jnp.asarray(data_w), jnp.asarray(cols_w), jnp.asarray(v))), K @ v,
        rtol=1e-12, atol=1e-12)


def test_ell_from_coo_drops_explicit_zeros_and_pads_empty_rows(x64):
    from repro.kernels.sparse_mvm import coo_row_widths, ell_from_coo, \
        ell_matvec

    # row 1 entirely empty; row 0 holds an explicit zero (must be dropped)
    data = np.array([0.0, 2.0, 3.0])
    row = np.array([0, 0, 2])
    col = np.array([1, 3, 0])
    d, c = ell_from_coo(data, row, col, (3, 4))
    assert d.shape == (3, 1)                   # densest TRUE row has 1 nnz
    assert np.all(d[1] == 0.0)                 # empty row fully padded
    wf, wa = coo_row_widths(row, col, data, (3, 4))
    assert wf == 1 and wa == 1                 # explicit zero not counted
    v = np.array([1.0, 10.0, 100.0, 1000.0])
    np.testing.assert_allclose(
        np.asarray(ell_matvec(jnp.asarray(d), jnp.asarray(c),
                              jnp.asarray(v))),
        np.array([2000.0, 0.0, 3.0]))


def test_ell_pallas_kernel_matches_reference(x64, rng):
    """The row-blocked Pallas kernel (interpret mode on CPU) and the
    gather/segment-sum reference produce the same product, including on
    row counts that are not a multiple of the 128-row block."""
    from repro.kernels.sparse_mvm import ell_from_coo, ell_matvec

    K = rng.normal(size=(150, 40)) * (rng.random((150, 40)) < 0.1)
    sp = SparseCOO.from_dense(K)
    data, cols = ell_from_coo(sp.data, sp.row, sp.col, sp.shape)
    v = rng.normal(size=40)
    ref = np.asarray(ell_matvec(jnp.asarray(data), jnp.asarray(cols),
                                jnp.asarray(v)))
    pal = np.asarray(ell_matvec(jnp.asarray(data), jnp.asarray(cols),
                                jnp.asarray(v), use_pallas=True))
    np.testing.assert_allclose(pal, ref, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(ref, K @ v, rtol=1e-10, atol=1e-10)


def test_ell_width_bucket_pow2_floor():
    from repro.kernels.sparse_mvm import MIN_ELL_WIDTH, ell_width_bucket

    assert ell_width_bucket(0) == MIN_ELL_WIDTH
    assert ell_width_bucket(3) == 4
    assert ell_width_bucket(4) == 4
    assert ell_width_bucket(5) == 8
    assert ell_width_bucket(100) == 128


def test_stack_problems_ell_layout(x64):
    from repro.runtime.batch import stack_problems_ell

    lps = sparse_lp_stream(3, [(12, 24)], density=0.2, seed=1)
    data_f, cols_f, data_a, cols_a, b, c, lb, ub = stack_problems_ell(lps)
    B = 3
    assert data_f.shape[:2] == (B, 12) and data_a.shape[:2] == (B, 24)
    assert cols_f.dtype == np.int32 and cols_a.dtype == np.int32
    for k, lp in enumerate(lps):
        K = lp.K.toarray()
        v = np.linspace(-1, 1, 24)
        got = (data_f[k] * v[cols_f[k]]).sum(axis=1)
        np.testing.assert_allclose(got, K @ v, rtol=1e-12, atol=1e-12)
        w = np.linspace(-1, 1, 12)
        got_a = (data_a[k] * w[cols_a[k]]).sum(axis=1)
        np.testing.assert_allclose(got_a, K.T @ w, rtol=1e-12, atol=1e-12)


def test_ell_and_bcoo_stream_parity(x64):
    """The acceptance contract of the kernel swap: at sigma_read=0 the
    ELL pipeline and the BCOO pipeline serve the SAME stream to the same
    iterates (fp tolerance) with identical iteration counts."""
    lps = sparse_lp_stream(6, density=0.08, seed=3)
    r_ell = BatchSolver(OPTS).solve_stream(lps)            # default = ELL
    r_bcoo = BatchSolver(dataclasses.replace(
        OPTS, sparse_kernel="bcoo")).solve_stream(lps)
    for re_, rb in zip(r_ell, r_bcoo):
        assert re_.iterations == rb.iterations
        assert re_.status == rb.status
        np.testing.assert_allclose(re_.x, rb.x, rtol=1e-7, atol=1e-9)
        np.testing.assert_allclose(re_.y, rb.y, rtol=1e-7, atol=1e-9)


def test_ell_megakernel_stream_parity(x64):
    """megakernel=True on the ELL pipeline fuses each check_every window
    into one launch; iterates must match the per-step ELL serve."""
    lps = sparse_lp_stream(4, density=0.08, seed=5)
    r_ell = BatchSolver(OPTS).solve_stream(lps)
    r_meg = BatchSolver(dataclasses.replace(
        OPTS, megakernel=True)).solve_stream(lps)
    for re_, rm in zip(r_ell, r_meg):
        assert rm.iterations == re_.iterations
        np.testing.assert_allclose(rm.x, re_.x, rtol=1e-8, atol=1e-10)


def test_ell_bucket_signature_carries_both_widths(x64):
    """ELL buckets key on (forward, adjoint) width buckets — streams
    mixing densities compile separate executables and never cross-serve;
    the BCOO backend keeps its bare-nnz signature."""
    lo = sparse_random_standard_lp(24, 40, density=0.04, seed=0)
    hi = sparse_random_standard_lp(24, 40, density=0.5, seed=1)
    solver = BatchSolver(OPTS)
    sig_lo = solver._sparse_signature(lo)
    sig_hi = solver._sparse_signature(hi)
    assert sig_lo[0] == "ell" and sig_hi[0] == "ell"
    assert sig_lo != sig_hi
    bcoo = BatchSolver(dataclasses.replace(OPTS, sparse_kernel="bcoo"))
    assert isinstance(bcoo._sparse_signature(lo), int)


def test_degenerate_zero_nnz_instances_serve_cleanly(x64):
    """An all-zero K (nnz=0) must flow through BOTH sparse backends —
    width/nnz bucketing, stacking, preconditioning, solve — without NaNs
    (rho=0 is guarded) and land on the box optimum."""
    from repro.kernels.sparse_mvm import ell_from_coo
    from repro.runtime.batch import stack_problems_ell

    zk = _zero_k_lp()
    # conversion/stacking layer holds up at zero width
    d, c = ell_from_coo(zk.K.data, zk.K.row, zk.K.col, zk.K.shape)
    assert d.shape == (6, 0)
    stacked = stack_problems_ell([zk])
    assert stacked[0].shape == (1, 6, 0)
    assert nnz_bucket(0) > 0

    opts = dataclasses.replace(OPTS, max_iters=2000)
    for kernel in ("ell", "bcoo"):
        r = BatchSolver(dataclasses.replace(
            opts, sparse_kernel=kernel)).solve_stream([zk])[0]
        assert np.all(np.isfinite(r.x)) and np.all(np.isfinite(r.y))
        assert r.status in ("optimal", "iteration_limit")
        np.testing.assert_allclose(r.x, np.zeros(10), atol=1e-4)

    # a zero-K instance mixed into a healthy stream serves in one pass
    healthy = sparse_lp_stream(3, [(6, 10)], density=0.3, seed=9)
    results = BatchSolver(opts).solve_stream([zk] + healthy)
    assert all(np.all(np.isfinite(r.x)) for r in results)
