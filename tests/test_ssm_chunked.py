"""Hillclimb 3 safety net: chunk-parallel selective scan == sequential."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig


def _cfg(**kw):
    base = dict(name="t", family="hybrid", n_layers=1, d_model=64,
                n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=97,
                mixer="hybrid", ssm_state=8, ssm_heads=4, window=16,
                dtype="float32", attn_chunk=16)
    base.update(kw)
    return ModelConfig(**base)


def _params(cfg, key):
    shapes = ssm_mod.ssm_params_shape(cfg)
    leaves, treedef = jax.tree.flatten(
        shapes, is_leaf=lambda s: isinstance(s, tuple))
    ks = jax.random.split(key, len(leaves))
    p = jax.tree.unflatten(
        treedef, [jax.random.normal(k, s) * 0.3 for k, s in zip(ks, leaves)])
    p["A_log"] = jnp.zeros(cfg.ssm_heads)
    p["dt_bias"] = jnp.full(cfg.ssm_heads, 0.5)
    p["D"] = jnp.full(cfg.ssm_heads, 0.5)
    return p


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_chunked_scan_matches_sequential(chunk):
    cfg = _cfg()
    p = _params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64)) * 0.5
    y_seq, (s_seq, t_seq) = ssm_mod.ssm_scan(p, x, cfg)
    cfg_c = dataclasses.replace(cfg, ssm_chunk=chunk)
    y_chk, (s_chk, t_chk) = ssm_mod.ssm_scan_chunked(p, x, cfg_c)
    scale = float(jnp.abs(y_seq).max())
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               atol=5e-5 * scale, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_seq),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_array_equal(np.asarray(t_chk), np.asarray(t_seq))


def test_chunked_scan_state_carry():
    """Splitting a sequence across two chunked calls == one call."""
    cfg = dataclasses.replace(_cfg(), ssm_chunk=16)
    p = _params(cfg, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, 64)) * 0.5
    y_full, _ = ssm_mod.ssm_scan_chunked(p, x, cfg)
    y1, (s1, t1) = ssm_mod.ssm_scan_chunked(p, x[:, :32], cfg)
    y2, _ = ssm_mod.ssm_scan_chunked(p, x[:, 32:], cfg, state=s1,
                                     conv_tail=t1)
    y_cat = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(y_cat), np.asarray(y_full),
                               atol=1e-5, rtol=1e-4)


def test_chunked_falls_back_on_ragged_length():
    """Non-divisible S silently uses the sequential (exact) path."""
    cfg = dataclasses.replace(_cfg(), ssm_chunk=16)
    p = _params(cfg, jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 50, 64)) * 0.5
    y_chk, _ = ssm_mod.ssm_scan_chunked(p, x, cfg)
    y_seq, _ = ssm_mod.ssm_scan(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               atol=1e-6)


def test_hybrid_block_uses_chunked_when_configured():
    cfg = dataclasses.replace(_cfg(), ssm_chunk=16)
    shapes = ssm_mod.hybrid_params_shape(cfg)
    leaves, treedef = jax.tree.flatten(
        shapes, is_leaf=lambda s: isinstance(s, tuple))
    ks = jax.random.split(jax.random.PRNGKey(6), len(leaves))
    p = jax.tree.unflatten(
        treedef, [jax.random.normal(k, s) * 0.2 for k, s in zip(ks, leaves)])
    p["ssm"]["A_log"] = jnp.zeros(cfg.ssm_heads)
    p["ssm"]["dt_bias"] = jnp.full(cfg.ssm_heads, 0.5)
    p["ssm"]["D"] = jnp.full(cfg.ssm_heads, 0.5)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 32, 64)) * 0.5
    out, _ = ssm_mod.hybrid_block(p, x, cfg)
    assert out.shape == x.shape
    assert not np.any(np.isnan(np.asarray(out)))
