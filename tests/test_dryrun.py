"""Dry-run deliverable test: lower+compile succeeds on the production
meshes (subprocess — the dry-run needs 512 fake devices, process-global).

Two representative cells keep this fast; the full 86-cell sweep artifacts
live in experiments/dryrun/ (run via ``python -m repro.launch.dryrun
--all --mesh both``)."""
import json
import os
import subprocess
import sys

import pytest

from conftest import repo_root as _repo_root
from conftest import subprocess_env


def _run_dryrun(args, out_dir):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args,
         "--out", out_dir],
        env=subprocess_env(), cwd=_repo_root(), capture_output=True,
        text=True, timeout=1200,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    # import noise (a module failing to load) would surface as a FAIL
    # cell; the driver itself must report every requested cell
    assert "[OK]" in proc.stdout or "[SKIP]" in proc.stdout, proc.stdout
    return proc.stdout


@pytest.mark.slow
def test_dryrun_lp_cell_both_meshes(tmp_path):
    out = str(tmp_path)
    _run_dryrun(["--arch", "lp_crossbar", "--shape", "dist_step",
                 "--mesh", "both"], out)
    for mesh in ("16x16", "2x16x16"):
        path = os.path.join(out, f"lp_crossbar_dist_step_{mesh}.json")
        with open(path) as f:
            cell = json.load(f)
        assert "error" not in cell, cell
        # the runtime mesh layer built the grid the cell reports
        assert cell["mesh"] == mesh
        assert cell["n_chips"] == (256 if mesh == "16x16" else 512)
        assert cell["memory"]["peak_per_device_bytes"] > 0
        assert cell["roofline"]["bottleneck"] in (
            "compute_s", "memory_s", "collective_s")


@pytest.mark.slow
def test_dryrun_lm_decode_cell_multipod(tmp_path):
    out = str(tmp_path)
    _run_dryrun(["--arch", "starcoder2-3b", "--shape", "decode_32k",
                 "--mesh", "multi"], out)
    path = os.path.join(out, "starcoder2-3b_decode_32k_2x16x16.json")
    with open(path) as f:
        cell = json.load(f)
    assert "error" not in cell, cell
    assert cell["mesh"] == "2x16x16"
    assert cell["n_chips"] == 512
    assert cell["collectives"]["total_bytes"] > 0
    # fits a 16 GiB HBM budget
    assert cell["memory"]["peak_per_device_bytes"] < 16 * 2**30


def test_sweep_artifacts_complete():
    """The committed sweep must cover all 40 LM cells x 2 meshes + LP."""
    d = os.path.join(_repo_root(), "experiments", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("sweep artifacts not generated yet")
    names = os.listdir(d)
    from repro.configs import ARCH_NAMES, LP_CONFIGS, SHAPES

    missing, failed = [], []
    for mesh in ("16x16", "2x16x16"):
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                fn = f"{arch}_{shape}_{mesh}.json"
                if fn not in names:
                    missing.append(fn)
                    continue
                with open(os.path.join(d, fn)) as f:
                    cell = json.load(f)
                if "error" in cell:
                    failed.append(fn)
        for lp in LP_CONFIGS:
            fn = f"{lp}_dist_step_{mesh}.json"
            if fn not in names:
                missing.append(fn)
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"
