"""Training substrate: optimizers, microbatching, data, checkpoints."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import (
    AdamWConfig,
    DataConfig,
    TrainConfig,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    synth_batch,
)
from repro.train.optimizer import AdafactorConfig


def _quadratic_losses(update_fn, init_fn, cfg, steps=60):
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8,)),
                               jnp.float32)}
    target = jnp.arange(8.0)
    opt = init_fn(params)
    losses = []
    for _ in range(steps):
        grads = {"w": 2 * (params["w"] - target)}
        params, opt = update_fn(cfg, grads, opt, params)
        losses.append(float(jnp.sum((params["w"] - target) ** 2)))
    return losses


def test_adamw_decreases_quadratic():
    losses = _quadratic_losses(adamw_update, adamw_init,
                               AdamWConfig(lr=0.1, weight_decay=0.0))
    assert losses[-1] < losses[0] * 0.05


def test_adafactor_decreases_quadratic():
    losses = _quadratic_losses(adafactor_update, adafactor_init,
                               AdafactorConfig(lr=0.3))
    assert losses[-1] < losses[0] * 0.2


def test_microbatch_equals_full_batch_grads():
    """Gradient accumulation is exact (same loss/grad as one big batch)."""
    from repro.configs import get_smoke_config
    from repro.models import init_params

    cfg = get_smoke_config("granite-3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in synth_batch(
        DataConfig(cfg.vocab, 4, 16), 0).items()}
    from repro.train import train_step as ts_mod
    # compare one optimizer step with and without accumulation
    step_full = jax.jit(ts_mod.make_train_step(cfg, TrainConfig(lr=1e-2)))
    step_micro = jax.jit(ts_mod.make_train_step(
        cfg, TrainConfig(lr=1e-2, microbatch=2)))
    opt = ts_mod.init_opt_state(params)
    p1, _, m1 = step_full(params, opt, batch)
    p2, _, m2 = step_micro(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_data_pipeline_deterministic_and_restartable():
    cfg = DataConfig(vocab=101, batch=4, seq_len=16, seed=3)
    b1 = synth_batch(cfg, 42)
    b2 = synth_batch(cfg, 42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synth_batch(cfg, 43)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are the shifted stream
    assert b1["tokens"].shape == (4, 16)
    assert b1["labels"].shape == (4, 16)
    assert np.all(b1["tokens"] < 101)


def test_prefetcher_delivers_in_order():
    from repro.train import Prefetcher

    cfg = DataConfig(vocab=50, batch=2, seq_len=8, seed=0)
    pf = Prefetcher(cfg, start_step=5, depth=2)
    try:
        got = next(iter(pf))
        expect = synth_batch(cfg, 5)
        np.testing.assert_array_equal(got["tokens"], expect["tokens"])
    finally:
        pf.close()


def test_train_checkpoint_roundtrip(tmp_path):
    from repro.train import load_train_state, save_train_state

    params = {"layers": {"w": np.ones((2, 3))}, "embed": np.zeros(4)}
    opt = {"m": {"layers": {"w": np.ones((2, 3)) * 2},
                 "embed": np.zeros(4)}, "step": np.asarray(9)}
    path = str(tmp_path / "t.npz")
    save_train_state(path, 123, params, opt, {"note": "x"})
    step, p2, o2, meta = load_train_state(path)
    assert step == 123 and meta["note"] == "x"
    np.testing.assert_array_equal(p2["layers"]["w"], params["layers"]["w"])
    np.testing.assert_array_equal(o2["m"]["layers"]["w"],
                                  opt["m"]["layers"]["w"])


def test_end_to_end_training_loss_decreases():
    """A few hundred steps on a tiny LM: loss must drop markedly."""
    from repro.launch import train as train_mod

    losses = train_mod.main([
        "--arch", "starcoder2-3b", "--smoke", "--steps", "60",
        "--batch", "4", "--seq", "32", "--lr", "1e-2", "--log-every", "30",
    ])
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_train_resume_from_checkpoint(tmp_path):
    from repro.launch import train as train_mod

    ckpt = str(tmp_path / "ck")
    l1 = train_mod.main([
        "--arch", "rwkv6-1.6b", "--smoke", "--steps", "20", "--batch", "2",
        "--seq", "16", "--ckpt-dir", ckpt, "--ckpt-every", "10",
        "--log-every", "10",
    ])
    l2 = train_mod.main([
        "--arch", "rwkv6-1.6b", "--smoke", "--steps", "30", "--batch", "2",
        "--seq", "16", "--ckpt-dir", ckpt, "--ckpt-every", "10",
        "--resume", "--log-every", "10",
    ])
    assert len(l2) == 10          # resumed at step 20, ran to 30
