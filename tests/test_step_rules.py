"""Step-size schedules (``PDHGOptions.step_rule``) + norm reuse.

The tentpole contract has three legs, each pinned here:

  * ``"fixed"`` is BITWISE-identical to the pre-step_rule solver on
    every backend — the 13th static-tuple entry defaults away and the
    traced loop is unchanged (no extra carry, no extra ops).
  * ``"adaptive"`` (data-driven primal-weight init + PDLP rebalancing at
    restart events + down-only step safeguard) converges at least as
    fast as fixed on scale-imbalanced instances and never worse than
    modestly on balanced ones, at equal-or-better KKT residuals.
  * ``"strongly_convex"`` is the explicit opt-in for the accelerated
    theta schedule; option validation refuses the incoherent combos.

Plus the satellite subsystems: the ``norm_backend`` estimator switch
and ``BatchSolver(norm_reuse=True)`` cross-instance norm reuse.
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PDHGOptions, engine, solve, solve_jit
from repro.core.lanczos import (
    NORM_BACKENDS,
    lanczos_svd_jit,
    power_iteration_mv,
)
from repro.core.pdhg import opts_static, prepare
from repro.core.symblock import build_sym_block
from repro.lp import random_standard_lp
from repro.runtime import BatchSolver
from repro.runtime.batch import NORM_REFINE_ITERS


def _imbalanced(m=20, n=32, seed=1, cscale=100.0):
    """Objective and rhs in mismatched units — Ruiz equilibration of K
    cannot see it, the primal weight can."""
    lp = random_standard_lp(m, n, seed=seed)
    return dc.replace(lp, c=lp.c * cscale)


# -------------------------------------------- fixed = bitwise legacy ---

def test_fixed_rule_bitwise_matches_12_tuple_core(x64):
    """The step_rule static-tuple entry (index 12) is optional; omitting
    it and passing "fixed" must produce the SAME trace → bitwise-equal
    iterates, for both the jnp and pallas update kernels and for the
    megakernel window mode."""
    lp = random_standard_lp(8, 14, seed=2)
    opts = PDHGOptions()
    scaled, T, Sigma = prepare(lp, opts)
    Keff = np.sqrt(np.asarray(Sigma))[:, None] * np.asarray(scaled.K) \
        * np.sqrt(np.asarray(T))[None, :]
    rho = float(np.linalg.svd(Keff, compute_uv=False)[0])
    key = jax.random.PRNGKey(5)
    core = jax.jit(engine.solve_core, static_argnums=(10,))
    args = (scaled.K, scaled.K.T, scaled.b, scaled.c, scaled.lb,
            scaled.ub, T, Sigma, rho, key)

    for kernel, mega in (("jnp", False), ("pallas", False),
                         ("jnp", True)):
        legacy = (256, 1e-30, 0.95, 1.0, 0.0, 64, 0.5, 0.0, kernel,
                  True, "ell", mega)
        fixed = legacy + ("fixed",)
        x_leg, y_leg, it_leg, m_leg = core(*args, legacy)
        x_fix, y_fix, it_fix, m_fix = core(*args, fixed)
        assert int(it_leg) == int(it_fix)
        np.testing.assert_array_equal(np.asarray(x_leg), np.asarray(x_fix))
        np.testing.assert_array_equal(np.asarray(y_leg), np.asarray(y_fix))
        np.testing.assert_array_equal(np.asarray(m_leg), np.asarray(m_fix))

    # ...and the adaptive rule is LIVE: same args, different trajectory
    adapt = (256, 1e-30, 0.95, 1.0, 0.0, 64, 0.5, 0.0, "jnp",
             True, "ell", False, "adaptive")
    x_ad, _, _, _ = core(*args, adapt)
    x_fix, _, _, _ = core(*args, legacy[:12] + ("fixed",))
    assert not np.array_equal(np.asarray(x_ad), np.asarray(x_fix))


def test_fixed_rule_bitwise_on_batch_and_sparse_paths(x64):
    """An explicit step_rule="fixed" option must serve bit-identical
    results through BatchSolver (dense and sparse-ELL pipelines)."""
    opts = PDHGOptions(max_iters=512, tol=1e-6, check_every=64)
    fixed = dc.replace(opts, step_rule="fixed")
    dense = random_standard_lp(8, 14, seed=3)
    sparse = random_standard_lp(12, 20, seed=4).sparsified()
    for lp in (dense, sparse):
        r0 = BatchSolver(opts).solve_stream([lp])[0]
        r1 = BatchSolver(fixed).solve_stream([lp])[0]
        assert r0.iterations == r1.iterations
        np.testing.assert_array_equal(r0.x, r1.x)
        np.testing.assert_array_equal(r0.y, r1.y)


def test_step_rule_is_in_batch_cache_key(x64):
    """adaptive and fixed trace different loops; the executable cache
    must never cross-serve them."""
    lp = random_standard_lp(8, 14, seed=1)
    opts = PDHGOptions(max_iters=128, tol=1e-30, check_every=64)
    s_fix = BatchSolver(opts)
    s_ad = BatchSolver(dc.replace(opts, step_rule="adaptive"))
    s_fix.solve_stream([lp])
    s_ad.solve_stream([lp])
    assert set(s_fix._cache).isdisjoint(set(s_ad._cache))
    assert opts_static(s_fix.opts) != opts_static(s_ad.opts)


# ----------------------------------------------- option validation ---

def test_step_rule_validation():
    with pytest.raises(ValueError, match="step_rule"):
        opts_static(PDHGOptions(step_rule="bogus"))
    # strongly_convex is the explicit opt-in for gamma > 0 ...
    with pytest.raises(ValueError, match="gamma"):
        opts_static(PDHGOptions(step_rule="strongly_convex", gamma=0.0))
    # ... and the other rules refuse a silently-ignored gamma
    with pytest.raises(ValueError, match="gamma"):
        opts_static(PDHGOptions(step_rule="adaptive", gamma=0.1))
    with pytest.raises(ValueError, match="gamma"):
        opts_static(PDHGOptions(step_rule="fixed", gamma=0.1))
    opts_static(PDHGOptions(step_rule="strongly_convex", gamma=0.1))


def test_norm_backend_validation():
    with pytest.raises(ValueError, match="norm_backend"):
        solve_jit(random_standard_lp(6, 10, seed=0),
                  PDHGOptions(norm_backend="qr", max_iters=8))


# ------------------------------------------------- adaptive behavior ---

def test_adaptive_beats_fixed_on_imbalanced_instances(x64):
    """The acceptance scenario: on objective/rhs scale-imbalanced LPs the
    primal-weight machinery must converge in at most the fixed-rule
    iteration count (typically far fewer), at equal-or-better KKT."""
    opts_f = PDHGOptions(max_iters=8000, tol=1e-4, check_every=64)
    opts_a = dc.replace(opts_f, step_rule="adaptive")
    wins = 0
    for seed, cscale in ((1, 100.0), (2, 100.0), (3, 0.01)):
        lp = _imbalanced(seed=seed, cscale=cscale)
        rf = solve_jit(lp, opts_f)
        ra = solve_jit(lp, opts_a)
        assert ra.status == "optimal"
        assert ra.iterations <= rf.iterations
        if ra.iterations < rf.iterations:
            wins += 1
        # equal-or-better: the returned iterate satisfies the SAME tol
        # the fixed rule was asked for (fixed may overshoot below it by
        # running longer; that is not a quality bar adaptive must match)
        assert float(ra.residuals.max) <= opts_a.tol
    assert wins >= 2   # strictly faster on most instances, not a tie


def test_adaptive_host_and_jit_agree_on_status(x64):
    """Host driver and jitted core run the same engine rebalance math;
    they must agree on convergence (iterates may differ slightly: the
    host checks every iteration near the end, the core on boundaries)."""
    lp = _imbalanced(m=20, n=32, seed=1)
    opts = PDHGOptions(max_iters=12000, tol=1e-4, check_every=64,
                       step_rule="adaptive")
    rh = solve(lp, opts)
    rj = solve_jit(lp, opts)
    assert rh.status == rj.status == "optimal"
    np.testing.assert_allclose(rh.obj, rj.obj, rtol=1e-3, atol=1e-6)


def test_adaptive_megakernel_matches_stepped_loop(x64):
    """tau/sigma only move at check boundaries, OUTSIDE the fused
    window — megakernel and stepped adaptive runs must agree."""
    lp = _imbalanced(m=10, n=18, seed=6)
    opts = PDHGOptions(max_iters=2000, tol=1e-4, check_every=64,
                       step_rule="adaptive")
    r_ref = solve_jit(lp, opts)
    r_meg = solve_jit(lp, dc.replace(opts, megakernel=True))
    assert r_meg.iterations == r_ref.iterations
    assert r_meg.status == r_ref.status
    np.testing.assert_allclose(r_meg.x, r_ref.x, atol=1e-8, rtol=1e-8)


def test_strongly_convex_rule_converges(x64):
    """gamma > 0 under the explicit rule: the accelerated theta schedule
    still converges to the optimum (iterates shrink tau, grow sigma)."""
    lp = random_standard_lp(12, 20, seed=7)
    opts = PDHGOptions(max_iters=20000, tol=1e-5,
                       step_rule="strongly_convex", gamma=0.05)
    r = solve_jit(lp, opts)
    assert r.status == "optimal"
    assert lp.obj_opt is not None
    np.testing.assert_allclose(r.obj, lp.obj_opt, rtol=1e-2, atol=1e-4)


# ------------------------------------- iteration quantization (audit) ---

def test_jit_iterations_quantized_to_check_every(x64):
    """Jitted paths exit only at check boundaries, so
    ``PDHGResult.iterations`` is a multiple of check_every (megakernel
    and stepped alike), and the MVM ledger charges exactly the
    ``engine.mvm_accounting`` formula for that count.  The HOST driver
    checks cheaply every iteration once past the first boundary — its
    count may be finer; this asymmetry is the documented contract."""
    lp = random_standard_lp(10, 18, seed=8)
    opts = PDHGOptions(max_iters=2000, tol=1e-5, check_every=48)
    for o in (opts, dc.replace(opts, megakernel=True)):
        r = solve_jit(lp, o)
        assert r.status == "optimal"
        assert r.iterations % o.check_every == 0
        assert r.mvm_calls == engine.mvm_accounting(
            r.iterations, o.check_every, o.lanczos_iters)


# -------------------------------------------------- norm backends ---

def test_power_backend_matches_lanczos_estimate(x64):
    """Both estimators target ||Sigma^1/2 K T^1/2||_2 through the
    symmetric block; on random LPs they agree with the exact SVD to the
    tolerance the step sizes care about."""
    assert set(NORM_BACKENDS) == {"lanczos", "power"}
    lp = random_standard_lp(16, 28, seed=9)
    scaled, T, Sigma = prepare(lp, PDHGOptions())
    Keff = jnp.sqrt(Sigma)[:, None] * scaled.K * jnp.sqrt(T)[None, :]
    exact = float(np.linalg.svd(np.asarray(Keff), compute_uv=False)[0])
    M = build_sym_block(Keff)
    lan = float(lanczos_svd_jit(M, k_max=64))
    pw = float(power_iteration_mv(lambda v: M @ v, M.shape[0], M.dtype,
                                  iters=200))
    assert abs(lan - exact) / exact < 1e-6
    assert abs(pw - exact) / exact < 1e-3

    r_l = solve_jit(lp, PDHGOptions(max_iters=2000, tol=1e-5))
    r_p = solve_jit(lp, PDHGOptions(max_iters=2000, tol=1e-5,
                                    norm_backend="power"))
    assert r_l.status == r_p.status == "optimal"
    np.testing.assert_allclose(r_l.obj, r_p.obj, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------- norm reuse ---

def test_norm_reuse_seeds_repeat_instances(x64):
    """Second pass over the same stream: every bucket is served by the
    seeded executable (short power refine instead of full Lanczos), the
    ledger charges NORM_REFINE_ITERS, and results still converge to the
    same objectives."""
    lps = [random_standard_lp(8, 14, seed=s) for s in (0, 1)]
    opts = PDHGOptions(max_iters=1500, tol=1e-4, check_every=64)
    solver = BatchSolver(opts, norm_reuse=True)
    r1 = solver.solve_stream(lps)
    assert solver.last_stream_stats["norm_seeded_buckets"] == 0
    r2 = solver.solve_stream(lps)
    assert solver.last_stream_stats["norm_seeded_buckets"] >= 1
    for a, b in zip(r1, r2):
        assert b.status == a.status
        np.testing.assert_allclose(b.obj, a.obj, rtol=1e-4, atol=1e-6)
        if a.iterations == b.iterations:
            # identical trajectory => ledger differs ONLY by the norm
            # charge: full Lanczos (pass 1) vs the seeded refine (pass 2)
            assert a.mvm_calls - b.mvm_calls \
                == opts.lanczos_iters - NORM_REFINE_ITERS


def test_norm_cache_isolated_by_fingerprint(x64):
    """Different sparsity patterns in the same shape bucket must not
    share cache entries; dense entries key on the bucket shape."""
    from repro.lp import sparse_random_standard_lp

    solver = BatchSolver(PDHGOptions(max_iters=256, tol=1e-30,
                                     check_every=64), norm_reuse=True)
    a = sparse_random_standard_lp(10, 18, density=0.3, seed=0)
    b = sparse_random_standard_lp(10, 18, density=0.3, seed=3)
    solver.solve_stream([a, b])
    fps = {solver._norm_fingerprint(lp) for lp in (a, b)}
    assert len(fps) == 2                      # patterns differ => keys do
    assert set(solver._norm_cache) == fps
    # reuse off => cache never populated
    cold = BatchSolver(PDHGOptions(max_iters=128, tol=1e-30))
    cold.solve_stream([random_standard_lp(8, 14, seed=0)])
    assert cold._norm_cache == {}
