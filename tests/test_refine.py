"""Mixed-precision iterative refinement + ECC-aware encoding.

The tentpole contract: a crossbar solve whose single pass bottoms out at
the read-noise floor must reach the EXACT path's KKT tolerance through
digital-outer/analog-inner refinement — with zero additional write
cycles (every correction LP re-solves on the same programmed
conductances), every inner analog window charged to the read ledger,
and the digital residual MVMs counted but never charged as reads.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PDHGOptions
from repro.core import engine
from repro.crossbar import (
    EPIRAM,
    TAOX_HFOX,
    CrossbarBatchSolver,
    encode_core,
    encode_matrix,
    Ledger,
    solve_crossbar_jit,
)
from repro.lp import random_standard_lp


# the acceptance instance: the exact path converges well inside the
# per-round iteration budget (refinement's per-round contraction is
# limited by inner-solve convergence, so the contrast needs an instance
# the budget can actually solve)
ACCEPT_OPTS = PDHGOptions(max_iters=8000, tol=1e-6, check_every=64)
ACCEPT_SIGMA = 2e-3


def _acceptance_reports():
    from repro.core import solve_jit

    lp = random_standard_lp(16, 28, seed=3)
    noisy = dataclasses.replace(EPIRAM, sigma_read=ACCEPT_SIGMA)
    exact = solve_jit(lp, ACCEPT_OPTS)
    plain = solve_crossbar_jit(lp, ACCEPT_OPTS, device=noisy,
                               key=jax.random.PRNGKey(0))
    refined_opts = dataclasses.replace(ACCEPT_OPTS, refine_rounds=4,
                                       refine_tol=ACCEPT_OPTS.tol)
    refined = solve_crossbar_jit(lp, refined_opts, device=noisy,
                                 key=jax.random.PRNGKey(0))
    return exact, plain, refined


@pytest.fixture(scope="module")
def acceptance():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        yield _acceptance_reports()
    finally:
        jax.config.update("jax_enable_x64", old)


def test_refinement_reaches_exact_tol_where_single_solve_fails(acceptance):
    exact, plain, refined = acceptance
    assert exact.status == "optimal"
    # the single analog pass is pinned at the read-noise floor, orders of
    # magnitude above tol
    assert plain.result.status == "iteration_limit"
    assert plain.result.merit > 100 * ACCEPT_OPTS.tol
    # refinement recovers the exact path's accuracy on the same device
    assert refined.result.status == "optimal"
    assert refined.result.merit <= ACCEPT_OPTS.tol


def test_refinement_writes_nothing_after_the_initial_encode(acceptance):
    _, plain, refined = acceptance
    # zero additional write cycles across all refinement rounds: the
    # correction solves reuse the originally programmed conductances
    assert refined.ledger.cells_written == plain.ledger.cells_written
    assert refined.ledger.write_energy_j == plain.ledger.write_energy_j
    assert refined.ledger.write_latency_s == plain.ledger.write_latency_s


def test_refinement_ledgers_every_analog_round_as_reads(acceptance):
    _, plain, refined = acceptance
    # every inner solve's windows are charged: strictly more read MVMs
    # than the single pass, and the ledger total is exactly the
    # norm-estimation plus PDHG charge (nothing silent in either
    # direction)
    assert refined.pdhg_mvms > plain.pdhg_mvms
    assert refined.ledger.mvm_count == (refined.lanczos_mvms
                                        + refined.pdhg_mvms)
    assert refined.ledger.read_energy_j > plain.ledger.read_energy_j
    # digital residual/candidate MVMs are counted but are NOT analog
    # reads — they never inflate the read ledger
    assert refined.digital_mvms == engine.refine_digital_mvms(4) == 10
    assert plain.digital_mvms == 0
    assert refined.executed_iterations == refined.result.iterations


def test_refined_core_rounds_zero_matches_solve_core(x64):
    from repro.core.pdhg import opts_static
    from repro.crossbar.refine import refined_core

    lp = random_standard_lp(8, 14, seed=0)
    from repro.core import pdhg as pdhg_mod

    opts = PDHGOptions(max_iters=256, tol=1e-6, check_every=32)
    scaled, T, Sigma = pdhg_mod.prepare(lp, opts)
    K = scaled.K
    rho = jnp.asarray(2.0, K.dtype)
    key = jax.random.PRNGKey(7)
    static = opts_static(opts)
    x0, y0, it0, m0 = engine.solve_core(
        K, K.T, scaled.b, scaled.c, scaled.lb, scaled.ub, T, Sigma, rho,
        key, static)
    x1, y1, its, m1 = refined_core(
        K, K.T, K, K.T, scaled.b, scaled.c, scaled.lb, scaled.ub, T,
        Sigma, rho, key, static)
    np.testing.assert_array_equal(np.asarray(x0), np.asarray(x1))
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    assert its.shape == (1,) and int(its[0]) == int(it0)
    assert float(m0) == float(m1)


def test_batch_solver_charges_executed_windows_not_own_iterations(x64):
    """The vmapped while_loop runs every lane until the slowest lane's
    window completes — the ledger must charge the EXECUTED (bucket-max,
    window-quantized) count, identically for every instance in the
    bucket, never the per-instance early-exit count."""
    opts = PDHGOptions(max_iters=2000, tol=1e-4, check_every=50)
    lps = [random_standard_lp(8, 14, seed=s) for s in range(3)]
    solver = CrossbarBatchSolver(opts, device=TAOX_HFOX)
    reports = solver.solve_stream(lps)
    assert len(reports) == 3
    executed = {rep.executed_iterations for rep in reports}
    assert len(executed) == 1                    # one bucket, one charge
    exe = executed.pop()
    its = [rep.result.iterations for rep in reports]
    assert exe == max(its)
    assert exe % opts.check_every == 0           # window-quantized
    assert any(it < exe for it in its) or len(set(its)) == 1
    charges = {rep.pdhg_mvms for rep in reports}
    assert charges == {engine.mvm_accounting(exe, opts.check_every, 0,
                                             restart=opts.restart)}


def test_batch_solver_refined_rounds_and_executed_accounting(x64):
    opts = PDHGOptions(max_iters=512, tol=1e-7, check_every=64,
                       refine_rounds=2, refine_tol=1e-7)
    lps = [random_standard_lp(8, 14, seed=s) for s in (0, 1)]
    solver = CrossbarBatchSolver(opts, device=TAOX_HFOX)
    reports = solver.solve_stream(lps)
    for rep in reports:
        # all three analog solves (1 + 2 rounds) are in the executed
        # count and therefore in the read charge
        assert rep.executed_iterations >= rep.result.iterations
        assert rep.digital_mvms == engine.refine_digital_mvms(2) == 6
        assert rep.pdhg_mvms >= 3 * engine.mvm_accounting(
            opts.check_every, opts.check_every, 0, restart=opts.restart)
        assert rep.ledger.mvm_count == rep.lanczos_mvms + rep.pdhg_mvms


def test_ecc_median_decode_recovers_from_stuck_cells():
    rng = np.random.default_rng(11)
    W = rng.normal(size=(64, 64))
    scale = np.abs(W).max()
    key = jax.random.PRNGKey(3)

    def mean_err(ecc):
        gp, gn, s, _ = encode_core(
            jnp.asarray(W), key, EPIRAM.g_levels, EPIRAM.sigma_program,
            ecc=ecc, ecc_decode="median", stuck_rate=0.03)
        dec = np.asarray((gp - gn) * s)
        return np.abs(dec - W).mean() / scale

    # 3% stuck cells wreck the single copy on average; 3-way median
    # voting needs >= 2 of 3 replicas faulted on the SAME cell to fail
    assert mean_err(3) < mean_err(1) / 3


def test_ecc_mean_decode_averages_programming_noise():
    rng = np.random.default_rng(12)
    W = rng.normal(size=(64, 64))
    key = jax.random.PRNGKey(4)

    def err(ecc):
        gp, gn, s, _ = encode_core(
            jnp.asarray(W), key, EPIRAM.g_levels, EPIRAM.sigma_program,
            ecc=ecc, ecc_decode="mean", stuck_rate=0.0, drift=0.0)
        dec = np.asarray((gp - gn) * s)
        # subtract the (shared) quantization part by comparing to the
        # ecc-free quantized target via a noiseless encode
        return np.abs(dec - W).mean()

    assert err(4) < err(1)


def test_ecc_ledger_overhead_is_split_and_latency_free(x64):
    rng = np.random.default_rng(13)
    W = rng.normal(size=(64, 64))
    led1, led3 = Ledger(), Ledger()
    dev3 = dataclasses.replace(EPIRAM, ecc=3)
    enc1 = encode_matrix(W, EPIRAM, jax.random.PRNGKey(0), ledger=led1)
    enc3 = encode_matrix(W, dev3, jax.random.PRNGKey(0), ledger=led3)
    # write energy and cells scale k-fold; replicas 1..k-1 are ledgered
    # separately, exactly like the logical/padding split
    np.testing.assert_allclose(led3.write_energy_j,
                               3 * led1.write_energy_j)
    np.testing.assert_allclose(led3.write_energy_ecc_j,
                               2 * led1.write_energy_j)
    assert led3.cells_written == 3 * led1.cells_written
    assert led3.cells_written_ecc == 2 * led1.cells_written
    assert led1.cells_written_ecc == 0 and led1.write_energy_ecc_j == 0.0
    np.testing.assert_allclose(
        led3.write_energy_logical_j, led1.write_energy_logical_j)
    # replicas program on parallel tile sets: latency is ecc-independent
    assert led3.write_latency_s == led1.write_latency_s
    # every replica draws read current on every MVM
    np.testing.assert_allclose(enc3.active_cells, 3 * enc1.active_cells)
    assert "write_energy_ecc_j" in led3.as_dict()


def test_ecc_rejects_bad_knobs():
    W = jnp.ones((4, 4))
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="ecc_decode"):
        encode_core(W, key, 256, 0.01, ecc=3, ecc_decode="vote")
    with pytest.raises(ValueError, match="replication factor"):
        encode_core(W, key, 256, 0.01, ecc=0)
