"""Runtime sanitizers: compile counting, the zero-recompile warm-stream
contract, and the implicit-transfer guard over the jitted solve paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PDHGOptions, solve_jit
from repro.lp import random_standard_lp
from repro.runtime import BatchSolver, CompileGuard, RecompileError
from repro.runtime import sanitize

OPTS = PDHGOptions(max_iters=2000, tol=1e-6, check_every=64)


def _stream(shapes, seed0=0):
    return [random_standard_lp(m, n, seed=seed0 + i)
            for i, (m, n) in enumerate(shapes)]


# ------------------------------------------------------ compile guard ---

def test_compile_counter_sees_cold_and_not_warm():
    if not sanitize.supported():
        pytest.skip("jax.monitoring not available")

    @jax.jit
    def f(x):
        return x * 3 + 1

    with CompileGuard() as cold:
        f(jnp.ones(7)).block_until_ready()
    assert cold.compiles > 0
    with CompileGuard(max_compiles=0) as warm:
        f(jnp.ones(7)).block_until_ready()
    assert warm.compiles == 0


def test_compile_guard_raises_over_budget():
    if not sanitize.supported():
        pytest.skip("jax.monitoring not available")
    with pytest.raises(RecompileError, match="budget 0"):
        with CompileGuard(max_compiles=0):
            # a never-seen shape forces a fresh executable
            jax.jit(lambda x: x - 2)(jnp.ones(11)).block_until_ready()


def test_warm_stream_compiles_zero():
    """The executable-cache contract as a hard check: a second
    solve_stream over an identical bucket mix compiles NOTHING."""
    if not sanitize.supported():
        pytest.skip("jax.monitoring not available")
    solver = BatchSolver(OPTS)
    shapes = [(5, 6), (6, 8), (10, 12), (5, 6), (7, 8)]
    solver.solve_stream(_stream(shapes))
    assert solver.last_stream_stats["compiles"] > 0     # cold pass
    with CompileGuard(max_compiles=0, label="warm solve_stream"):
        # same bucket mix from different instances: keys/operands are
        # fresh, only the (bucket, B, dtype, opts) signatures repeat
        solver.solve_stream(_stream(shapes, seed0=100))
    assert solver.last_stream_stats["compiles"] == 0


# ----------------------------------------------------- transfer guard ---

def _transfer_guard_available():
    return getattr(jax, "transfer_guard", None) is not None


def test_transfer_guard_catches_implicit_transfer():
    if not _transfer_guard_available():
        pytest.skip("jax.transfer_guard not available")
    x = jnp.arange(4.0)
    with pytest.raises(Exception, match="[Dd]isallow"):
        with sanitize.no_implicit_transfers():
            float(x[0])     # traced-value host sync: implicit d2h


def test_transfer_guard_allows_device_side_work():
    f = jax.jit(lambda v: v * 2)
    x = jnp.ones(5)
    f(x).block_until_ready()       # compile (and constant upload) first
    with sanitize.no_implicit_transfers():
        y = f(x)
        y.block_until_ready()
    assert float(y[0]) == 2.0      # sync OUTSIDE the guard is fine


def test_solve_jit_core_is_transfer_clean():
    """``solve_jit(..., transfer_sanitize=True)`` runs the jitted
    iteration core under the guard: a solve must not smuggle any
    implicit host<->device transfer once its inputs are device
    resident."""
    if not _transfer_guard_available():
        pytest.skip("jax.transfer_guard not available")
    lp = random_standard_lp(6, 9, seed=3)
    solve_jit(lp, OPTS)            # compile + upload outside the guard
    res = solve_jit(lp, OPTS, transfer_sanitize=True)
    assert res.status in ("optimal", "iteration_limit")


def test_batch_solver_transfer_sanitize_serves_clean():
    solver = BatchSolver(OPTS, transfer_sanitize=True)
    shapes = [(5, 6), (6, 8), (5, 6)]
    for seed0 in (0, 50):          # cold then warm, both guarded
        results = solver.solve_stream(_stream(shapes, seed0=seed0))
        assert all(np.isfinite(r.merit) for r in results)
        assert all(r.bucket for r in results)
