"""tools/traceaudit: the trace-level audit independently reproduces the
energy ledger's MVM accounting on every supported path, catches seeded
lies (extra in-loop MVM, silent f64->f32 demotion, host callbacks in the
hot loop), and pins traced structure against TRACE_BASELINE.json."""
import copy
import json
import sys
from pathlib import Path

import jax.numpy as jnp
import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT))     # tools/ is not on PYTHONPATH=src

from repro.core import engine  # noqa: E402
from tools.traceaudit import (  # noqa: E402
    CHECK_EVERY,
    TRACE_M,
    TRACE_N,
    PathSpec,
    _TRACE_CACHE,
    analyze_path,
    audit_paths,
    check_budget,
    check_dtype,
    check_effects,
    compare_to_baseline,
    count_mvms,
    fingerprint,
    load_baseline,
    supported_paths,
    trace_path,
)

DENSE = PathSpec("dense", "jnp", "fixed", False, True)


@pytest.fixture(scope="module")
def full_audit():
    """One full-matrix audit shared by every assertion below (tracing 47
    paths once is the expensive part; the analyzers are cheap)."""
    baseline = load_baseline()
    assert baseline is not None, \
        "TRACE_BASELINE.json missing — run --update-baseline and commit"
    records, findings, notes = audit_paths(
        supported_paths(), baseline, full_matrix=True)
    return baseline, records, findings, notes


# ------------------------------------------------------- the green path ---

def test_matrix_covers_every_axis():
    specs = supported_paths()
    names = {s.name for s in specs}
    assert len(names) == len(specs)            # names are unique ids
    assert {s.backend for s in specs} == \
        {"dense", "ell", "bcoo", "crossbar", "sharded"}
    assert {s.kernel for s in specs} == {"jnp", "pallas"}
    assert {s.step_rule for s in specs} == \
        {"fixed", "adaptive", "strongly_convex"}
    assert any(s.megakernel for s in specs)
    assert any(not s.restart for s in specs)
    # every backend gets a restart=False variant
    assert {s.backend for s in specs if not s.restart} == \
        {s.backend for s in specs}
    # the refinement shells are audited too (crossbar mount + the dense
    # self-mount), and only they carry the /refineN name suffix
    assert {s.refine for s in specs} == {0, 1, 2}
    assert all(("/refine" in s.name) == (s.refine > 0) for s in specs)


def test_full_matrix_is_clean(full_audit):
    _, records, findings, notes = full_audit
    assert findings == [], "\n".join(str(f) for f in findings)
    assert notes == [], notes                  # same jax version as CI
    assert len(records) == len(supported_paths())


def test_every_path_reproduces_the_ledger(full_audit):
    """The acceptance claim: traced per-window MVMs == the formula the
    energy ledger charges (times the number of analog solves on refined
    paths), and nothing MVM-shaped leaks outside beyond the refinement
    shell's counted digital residual MVMs."""
    _, records, _, _ = full_audit
    for rec in records:
        expected = (engine.refine_window_factor(rec.spec.refine)
                    * engine.mvm_window_budget(CHECK_EVERY,
                                               rec.spec.restart))
        assert rec.counts["per_window"] == expected, rec.spec.name
        assert rec.counts["outside"] == \
            engine.refine_digital_mvms(rec.spec.refine), rec.spec.name


def test_mvm_accounting_decomposes_into_window_budgets():
    """mvm_accounting == lanczos + n_windows * window_budget whenever
    iterations quantize to check_every (which every jitted path does)."""
    for ce in (1, 4, 25):
        for windows in (1, 3, 10):
            for lz in (0, 16):
                for restart in (True, False):
                    it = windows * ce
                    assert engine.mvm_accounting(it, ce, lz, restart) == \
                        lz + windows * engine.mvm_window_budget(ce, restart)


def test_trace_cache_and_fingerprint_are_deterministic():
    jx1 = trace_path(DENSE)
    assert trace_path(DENSE) is jx1            # cached by name
    fp1 = fingerprint(jx1)
    _TRACE_CACHE.pop(DENSE.name)
    fp2 = fingerprint(trace_path(DENSE))
    assert fp1 == fp2                          # stable across retrace


# ----------------------------------------------------------- seeded lies ---

def _K():
    # built inside the traced fns, where trace_path has x64 enabled
    return jnp.ones((TRACE_M, TRACE_N), jnp.float64)


def _lying_operator():
    """A dense operator whose fwd sneaks in a SECOND operator MVM the
    ledger never charges (make_jaxpr does no CSE, so both dots stay)."""

    def fwd(v, key=None):
        K = _K()
        w = K @ v
        w2 = K @ (2.0 * v)       # the unledgered extra device read
        return w + 0.0 * w2

    def adj(v, key=None):
        return _K().T @ v

    return engine.Operator(fwd, adj, "dense")


def test_budget_checker_catches_extra_in_loop_mvm():
    jaxpr = trace_path(DENSE, operator_override=_lying_operator())
    counts = count_mvms(jaxpr)
    findings = check_budget(DENSE, counts)
    assert findings, "seeded extra MVM went undetected"
    assert any("mvm_window_budget" in f.message for f in findings)
    # fwd runs check_every times stepping + 2x at the check: +6 MVMs
    assert counts["per_window"] == \
        engine.mvm_window_budget(CHECK_EVERY, True) + CHECK_EVERY + 2


def _demoting_operator():
    """fwd computes in f32 and silently casts back up — the classic
    'works on CPU, wrong answer on the device' demotion."""

    def fwd(v, key=None):
        w32 = _K().astype(jnp.float32) @ v.astype(jnp.float32)
        return w32.astype(jnp.float64)

    def adj(v, key=None):
        return _K().T @ v

    return engine.Operator(fwd, adj, "dense")


def test_dtype_checker_catches_f64_to_f32_demotion():
    jaxpr = trace_path(DENSE, operator_override=_demoting_operator())
    findings = check_dtype(DENSE.name, jaxpr)
    assert findings, "seeded f64->f32 demotion went undetected"
    assert any("narrowing" in f.message for f in findings)
    # the clean trace of the same path carries no dtype findings
    assert check_dtype(DENSE.name, trace_path(DENSE)) == []


def _chatty_operator():
    import jax

    def fwd(v, key=None):
        jax.debug.print("fwd norm {x}", x=jnp.sum(v))
        return _K() @ v

    def adj(v, key=None):
        return _K().T @ v

    return engine.Operator(fwd, adj, "dense")


def test_effects_checker_catches_callback_in_hot_loop():
    jaxpr = trace_path(DENSE, operator_override=_chatty_operator())
    findings = check_effects(DENSE.name, jaxpr)
    assert findings, "seeded in-loop host callback went undetected"
    assert any("hot loop" in f.message for f in findings)
    assert check_effects(DENSE.name, trace_path(DENSE)) == []


# ---------------------------------------------------------- the baseline ---

def _records(full_audit):
    return full_audit[1]


def test_baseline_drift_reports_primitive_diff(full_audit):
    baseline, records = full_audit[0], _records(full_audit)
    bad = copy.deepcopy(baseline)
    name = records[0].spec.name
    bad["paths"][name]["fingerprint"] = "0" * 64
    bad["paths"][name]["primitives"]["dot_general"] = 999.0
    findings, notes = compare_to_baseline(records, bad, full_matrix=True)
    assert notes == []
    assert len(findings) == 1 and findings[0].path == name
    assert "drifted" in findings[0].message
    assert "dot_general: 999 ->" in findings[0].message   # human diff
    assert "--update-baseline" in findings[0].message


def test_baseline_missing_and_stale_entries(full_audit):
    baseline, records = full_audit[0], _records(full_audit)
    bad = copy.deepcopy(baseline)
    victim = records[0].spec.name
    del bad["paths"][victim]
    bad["paths"]["dense/jnp/fixed/mega9/restart1"] = \
        {"fingerprint": "x", "mvms": {}, "primitives": {}}
    findings, _ = compare_to_baseline(records, bad, full_matrix=True)
    msgs = {f.path: f.message for f in findings}
    assert "missing from TRACE_BASELINE.json" in msgs[victim]
    assert "stale" in msgs["dense/jnp/fixed/mega9/restart1"]
    # a filtered run must NOT judge completeness
    findings, _ = compare_to_baseline(records, bad, full_matrix=False)
    assert all("stale" not in f.message for f in findings)


def test_version_skew_downgrades_fingerprints_to_notes(full_audit):
    baseline, records = full_audit[0], _records(full_audit)
    skew = copy.deepcopy(baseline)
    skew["jax_version"] = "0.0.0"
    skew["paths"][records[0].spec.name]["fingerprint"] = "0" * 64
    findings, notes = compare_to_baseline(records, skew, full_matrix=True)
    assert findings == []                      # soft under version skew
    assert any("0.0.0" in n for n in notes)
    assert any("drifted" in n for n in notes)


def test_adaptive_traces_identical_mvm_budget_to_fixed(full_audit):
    """PR 8's zero-extra-MVM claim, per family, from the traces."""
    _, records, _, _ = full_audit
    by_family = {}
    for rec in records:
        s = rec.spec
        fam = (s.backend, s.kernel, s.megakernel, s.restart, s.refine)
        by_family.setdefault(fam, {})[s.step_rule] = rec
    checked = 0
    for rules in by_family.values():
        if "fixed" in rules and "adaptive" in rules:
            assert rules["adaptive"].counts == rules["fixed"].counts
            checked += 1
    assert checked >= 8    # every backend x kernel (x mega) family


# ------------------------------------------------------------------ CLI ---

def test_cli_list_and_filtered_run(capsys):
    from tools.traceaudit.__main__ import main
    assert main(["--list-paths"]) == 0
    out = capsys.readouterr().out.splitlines()
    assert len(out) == len(supported_paths())

    assert main(["--paths", "dense/jnp/fixed", "--json"]) == 0
    assert json.loads(capsys.readouterr().out) == []


def test_cli_json_reports_findings(tmp_path, capsys):
    from tools.traceaudit.__main__ import main
    baseline = copy.deepcopy(load_baseline())
    name = "dense/jnp/fixed/mega0/restart1"
    baseline["paths"][name]["fingerprint"] = "0" * 64
    bad = tmp_path / "baseline.json"
    bad.write_text(json.dumps(baseline))
    diff = tmp_path / "diff.txt"
    rc = main(["--paths", name, "--json", "--baseline", str(bad),
               "--diff-out", str(diff)])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert [e["rule"] for e in payload] == ["fingerprint"]
    assert payload[0]["file"] == name
    assert "drifted" in diff.read_text()       # the CI artifact


def test_analyze_path_matches_committed_baseline_entry(full_audit):
    """Spot-check the baseline file content against a live record."""
    baseline, records = full_audit[0], _records(full_audit)
    assert baseline["schema"] == "traceaudit/v1"
    assert baseline["trace_shape"] == [TRACE_M, TRACE_N]
    rec = records[0]
    entry = baseline["paths"][rec.spec.name]
    assert entry["fingerprint"] == rec.fingerprint
    assert entry["mvms"] == rec.counts
    assert entry["primitives"] == \
        {k: rec.histogram[k] for k in sorted(rec.histogram)}
    fresh = analyze_path(rec.spec, trace_path(rec.spec))
    assert fresh.fingerprint == rec.fingerprint
