"""Operator-norm estimation tests (Algorithm 3 + eq. 8 baseline)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import (
    build_sym_block,
    encode_exact,
    lanczos_svd,
    lanczos_svd_jit,
    power_iteration,
)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(3, 20), n=st.integers(3, 20), seed=st.integers(0, 999))
def test_lanczos_matches_svd(m, n, seed):
    rng = np.random.default_rng(seed)
    K = rng.normal(size=(m, n))
    true = np.linalg.svd(K, compute_uv=False)[0]
    res = lanczos_svd(encode_exact(K), k_max=m + n, tol=1e-12,
                      key=jax.random.PRNGKey(seed))
    assert abs(res.sigma_max - true) / true < 1e-6


def test_lanczos_jit_matches_host():
    rng = np.random.default_rng(0)
    K = rng.normal(size=(15, 25)).astype(np.float32)
    host = lanczos_svd(encode_exact(K), k_max=30, tol=1e-10)
    jit = float(lanczos_svd_jit(build_sym_block(jnp.asarray(K)), k_max=30))
    assert abs(host.sigma_max - jit) / host.sigma_max < 1e-3


def test_lanczos_early_exit_on_exact_subspace():
    """A rank-1 K: the Ritz value locks on within a few iterations and
    the recurrence terminates early (beta collapse, fp-roundoff floor)."""
    u = np.random.default_rng(1).normal(size=(10, 1))
    v = np.random.default_rng(2).normal(size=(1, 6))
    K = u @ v
    res = lanczos_svd(encode_exact(K), k_max=32, tol=1e-10)
    true = np.linalg.norm(u) * np.linalg.norm(v)
    assert res.iterations < 32                      # early exit triggered
    assert abs(res.ritz_history[2] - true) / true < 1e-5
    assert abs(res.sigma_max - true) / true < 1e-6


def test_power_iteration_agrees():
    rng = np.random.default_rng(3)
    K = rng.normal(size=(30, 20))
    true = np.linalg.svd(K, compute_uv=False)[0]
    est = float(power_iteration(jnp.asarray(K), iters=300))
    assert abs(est - true) / true < 1e-3


def test_ergodic_estimate_stabilizes():
    """Theorem 1's averaged estimator has small dispersion late in the run."""
    rng = np.random.default_rng(4)
    K = rng.normal(size=(20, 20))
    res = lanczos_svd(encode_exact(K), k_max=40, tol=0.0)
    tail = res.ritz_history[-5:]
    assert tail.std() / tail.mean() < 1e-6
