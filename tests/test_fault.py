"""Fault-tolerance contracts of ``distributed.fault``.

The module's three claims, each tested here:

  * **Atomicity** — a crash mid-write (torn temp file, failed rename)
    never corrupts the last good checkpoint, and no temp litter stays
    behind.
  * **Bit-determinism** — PDHG state is (x, x_bar, y, tau, sigma);
    restoring a snapshot reproduces the EXACT iterate stream the
    uninterrupted solve would have produced.
  * **Elastic remesh** — checkpoints are stored unsharded, so a restore
    can target a smaller mesh and the iterates still match (device-
    adaptive: with 1 local device both meshes degenerate to (1, 1) and
    the match is bitwise; the 8-device CI job exercises a real 4x2 -> 1x1
    shrink, identical to f64 round-off).
"""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import PDHGOptions
from repro.core import pdhg as pdhg_mod
from repro.distributed import (
    load_checkpoint,
    make_dist_step,
    reshard,
    save_checkpoint,
    shard_problem,
)
from repro.distributed.fault import CheckpointManager
from repro.lp import random_standard_lp
from repro.runtime.mesh import make_local_mesh, make_mesh


# ----------------------------------------------------------- atomicity ---

def test_crash_mid_write_preserves_last_good_checkpoint(tmp_path,
                                                        monkeypatch):
    """A crash between temp-write and rename must leave the previous
    snapshot untouched and loadable, with no temp litter."""
    path = str(tmp_path / "ck.npz")
    save_checkpoint(path, 1, {"x": np.arange(4.0)}, {"tag": "good"})

    calls = {"n": 0}
    real_replace = os.replace

    def dying_replace(src, dst):
        calls["n"] += 1
        raise OSError("simulated crash before rename")

    monkeypatch.setattr(os, "replace", dying_replace)
    with pytest.raises(OSError, match="simulated crash"):
        save_checkpoint(path, 2, {"x": np.zeros(4)}, {"tag": "bad"})
    monkeypatch.setattr(os, "replace", real_replace)

    assert calls["n"] == 1
    ck = load_checkpoint(path)                 # old snapshot intact
    assert ck.step == 1 and ck.meta["tag"] == "good"
    np.testing.assert_array_equal(ck.arrays["x"], np.arange(4.0))
    # the aborted write cleaned up after itself
    assert glob.glob(str(tmp_path / "*.tmp")) == []


def test_torn_temp_file_is_invisible_to_manager(tmp_path):
    """A torn ``*.tmp`` from a crashed writer is never listed as a
    checkpoint and never shadows ``latest()``."""
    mgr = CheckpointManager(str(tmp_path), keep=3, every=1)
    mgr.maybe_save(1, {"a": np.ones(2)})
    # a writer died mid-write: partial npz bytes under a temp name
    with open(tmp_path / "tmpXXXX.tmp", "wb") as f:
        f.write(b"PK\x03\x04 torn")
    assert mgr.latest().endswith("ckpt_000000000001.npz")
    ck = load_checkpoint(mgr.latest())
    np.testing.assert_array_equal(ck.arrays["a"], np.ones(2))


# ---------------------------------------------------- iterate streams ---

STEP_OPTS = PDHGOptions(max_iters=64, tol=1e-30, check_every=64,
                        ruiz_iters=4, lanczos_iters=8)


def _dist_state(lp, mesh, dtype=jnp.float64):
    """Padded + sharded problem and a deterministic initial PDHG state."""
    scaled, T, Sigma = pdhg_mod.prepare(lp, STEP_OPTS)
    prob = shard_problem(scaled, T, Sigma, mesh)
    n_pad, m_pad = prob.c.shape[0], prob.b.shape[0]
    kx, ky = jax.random.split(jax.random.PRNGKey(3))
    x0 = jnp.clip(jax.random.normal(kx, (n_pad,), dtype),
                  jnp.asarray(prob.lb), jnp.asarray(prob.ub))
    y0 = jax.random.normal(ky, (m_pad,), dtype)
    tau = jnp.asarray(0.01, dtype)
    sigma = jnp.asarray(0.01, dtype)
    return prob, (x0, x0, y0, tau, sigma)


def _run_steps(step, prob, state, k):
    x, x_bar, y, tau, sigma = state
    for _ in range(k):
        x, x_bar, y, tau, sigma = step(prob.K, prob.b, prob.c, prob.lb,
                                       prob.ub, prob.T, prob.Sigma,
                                       x, x_bar, y, tau, sigma)
    return x, x_bar, y, tau, sigma


def _state_arrays(state):
    return {k: np.asarray(v) for k, v in
            zip(("x", "x_bar", "y", "tau", "sigma"), state)}


def test_restore_reproduces_exact_iterate_stream(x64, tmp_path):
    """snapshot at step 3 of 6 -> restore -> the remaining iterates are
    bitwise-identical to the uninterrupted stream."""
    mesh = make_mesh((1, 1), ("data", "model"))
    lp = random_standard_lp(12, 20, seed=7)
    step = make_dist_step(mesh, n_inner=1)
    prob, state0 = _dist_state(lp, mesh)

    mid = _run_steps(step, prob, state0, 3)
    path = str(tmp_path / "mid.npz")
    save_checkpoint(path, 3, _state_arrays(mid))
    final_uninterrupted = _run_steps(step, prob, mid, 3)

    ck = load_checkpoint(path)
    placed = reshard(ck.arrays, mesh,
                     {"x": P("model"), "x_bar": P("model"),
                      "y": P("data"), "tau": P(), "sigma": P()})
    restored = (placed["x"], placed["x_bar"], placed["y"],
                placed["tau"], placed["sigma"])
    final_restored = _run_steps(step, prob, restored, 3)

    for name, a, b in zip(("x", "x_bar", "y", "tau", "sigma"),
                          final_uninterrupted, final_restored):
        assert np.array_equal(np.asarray(a), np.asarray(b)), name


def test_elastic_remesh_restore_smaller_mesh_identical_iterates(x64,
                                                                tmp_path):
    """Snapshot on the full local mesh, restore onto a 1x1 mesh: the
    continued iterate streams match (bitwise when both meshes are 1x1;
    to f64 round-off when the big mesh really shards, since psum
    grouping differs)."""
    big = make_local_mesh()                        # all local devices
    small = make_mesh((1, 1), ("data", "model"))
    # dims divisible by any local mesh shape (device counts are powers
    # of two here), so padding is identical on both meshes
    lp = random_standard_lp(16, 32, seed=9)
    step_big = make_dist_step(big, n_inner=1)
    step_small = make_dist_step(small, n_inner=1)
    prob_big, state0 = _dist_state(lp, big)
    prob_small, _ = _dist_state(lp, small)
    assert prob_big.b.shape == prob_small.b.shape  # no mesh-dependent pad

    mid = _run_steps(step_big, prob_big, state0, 3)
    path = str(tmp_path / "mid.npz")
    save_checkpoint(path, 3, _state_arrays(mid),
                    {"mesh": list(big.devices.shape)})

    on_big = _run_steps(step_big, prob_big, mid, 3)
    ck = load_checkpoint(path)
    placed = reshard(ck.arrays, small,
                     {"x": P("model"), "x_bar": P("model"),
                      "y": P("data"), "tau": P(), "sigma": P()})
    on_small = _run_steps(
        step_small, prob_small,
        (placed["x"], placed["x_bar"], placed["y"], placed["tau"],
         placed["sigma"]), 3)

    bitwise = big.devices.size == 1
    for name, a, b in zip(("x", "x_bar", "y", "tau", "sigma"),
                          on_big, on_small):
        a, b = np.asarray(a), np.asarray(b)
        if bitwise:
            assert np.array_equal(a, b), name
        else:
            np.testing.assert_allclose(a, b, rtol=1e-12, atol=1e-12,
                                       err_msg=name)


def test_snapshot_is_valid_solver_state_for_survivors(x64, tmp_path):
    """Straggler mitigation: a snapshot restored onto a FRESH mesh (the
    survivors after dropping a worker group) continues without
    algorithmic penalty — the continued stream equals the original's."""
    mesh = make_mesh((1, 1), ("data", "model"))
    lp = random_standard_lp(8, 12, seed=2)
    step = make_dist_step(mesh, n_inner=2)
    prob, state0 = _dist_state(lp, mesh)
    mid = _run_steps(step, prob, state0, 2)
    path = str(tmp_path / "drop.npz")
    save_checkpoint(path, 2, _state_arrays(mid))

    # "survivors": a brand-new mesh + freshly sharded problem, as after
    # an elastic restart of the job
    mesh2 = make_mesh((1, 1), ("data", "model"))
    step2 = make_dist_step(mesh2, n_inner=2)
    prob2, _ = _dist_state(lp, mesh2)
    ck = load_checkpoint(path)
    placed = reshard(ck.arrays, mesh2,
                     {"x": P("model"), "x_bar": P("model"),
                      "y": P("data"), "tau": P(), "sigma": P()})
    a = _run_steps(step, prob, mid, 2)
    b = _run_steps(step2, prob2,
                   (placed["x"], placed["x_bar"], placed["y"],
                    placed["tau"], placed["sigma"]), 2)
    for name, u, v in zip(("x", "x_bar", "y", "tau", "sigma"), a, b):
        assert np.array_equal(np.asarray(u), np.asarray(v)), name
