"""jaxlint: every rule fires on the fixture reproducing its historical
bug, stays quiet on the fixed code, honours pragmas — and the repo
itself lints clean (the CI gate, asserted here so a local run catches a
new violation before CI does)."""
import sys
import textwrap
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT))     # tools/ is not on PYTHONPATH=src

from tools.jaxlint import (  # noqa: E402
    Config,
    lint_file,
    lint_paths,
    lint_source,
)

FIXTURES = REPO_ROOT / "tools" / "jaxlint" / "fixtures"
# fixtures exercise R5's hot-path scoping by declaring themselves hot,
# and R7's benchmark scoping by declaring only the r7_* pair benchmarks
FIXTURE_CFG = Config(hot_paths=("fixtures/",),
                     bench_paths=("fixtures/r7_",))

# rule -> (bad fixture finding count, historical bug it reproduces)
EXPECTED = {
    "R1": 1,    # sparse_kernel shipped without an opts_static entry
    "R2": 2,    # PRNGKey(0) in _solve_jit_core + k3 reused twice
    "R3": 1,    # time.time() duration in the benchmark harness
    "R4": 2,    # Python while/if on jnp values under jit
    "R5": 3,    # float()/.item()/np.asarray in a traced hot path
    "R6": 2,    # carried-along stale pragma + unknown-rule typo
    "R7": 2,    # cold and warm windows both closing unsynchronized
}


@pytest.mark.parametrize("rule", sorted(EXPECTED))
def test_rule_fires_on_historical_bug_fixture(rule):
    findings = lint_file(FIXTURES / f"{rule.lower()}_bad.py", FIXTURE_CFG)
    assert [f.rule for f in findings] == [rule] * EXPECTED[rule], findings


@pytest.mark.parametrize("rule", sorted(EXPECTED))
def test_rule_quiet_on_fixed_fixture(rule):
    findings = lint_file(FIXTURES / f"{rule.lower()}_good.py", FIXTURE_CFG)
    assert findings == [], findings


def test_pragma_suppresses_and_is_rule_specific():
    src = textwrap.dedent("""\
        import time
        t0 = time.time()
        wall = time.time() - t0  # jaxlint: disable=R3
        wall2 = time.time() - t0  # jaxlint: disable=R2
    """)
    findings = lint_source(src, "x.py")
    # the R3 pragma eats line 3; the R2 pragma on line 4 does NOT — and
    # since R2 never fires on line 4 it is additionally a stale pragma
    assert [(f.rule, f.line) for f in findings] == [("R3", 4), ("R6", 4)]


def test_r6_stale_pragma_and_unknown_rule():
    src = textwrap.dedent("""\
        import time
        t0 = time.perf_counter()
        wall = time.perf_counter() - t0  # jaxlint: disable=R3
        n = 1  # jaxlint: disable=R99
    """)
    findings = lint_source(src, "x.py")
    assert [(f.rule, f.line) for f in findings] == [("R6", 3), ("R6", 4)]
    assert "stale" in findings[0].message
    assert "unknown rule" in findings[1].message


def test_r6_self_suppression_and_in_string_pragmas():
    src = textwrap.dedent("""\
        import time
        n = 1  # jaxlint: disable=R3,R6
        doc = "example pragma:  # jaxlint: disable=R2"
    """)
    # line 2: R3 is stale but R6 on the same line self-suppresses;
    # line 3: the pragma lives inside a string literal — not a pragma
    assert lint_source(src, "x.py") == []


def test_r7_scoped_to_benchmarks_and_reused_timer_names():
    src = textwrap.dedent("""\
        import time
        import jax

        def bench(solver, lps):
            t0 = time.perf_counter()
            out = jax.block_until_ready(solver.solve(lps))
            cold = time.perf_counter() - t0
            t0 = time.perf_counter()
            out = solver.solve(lps)
            warm = time.perf_counter() - t0
            return out, cold, warm
    """)
    findings = lint_source(src, "benchmarks/bench_x.py")
    # the reused ``t0`` must anchor the SECOND window only: the first
    # window is fenced, the second is not
    assert [(f.rule, f.line) for f in findings] == [("R7", 10)]
    assert lint_source(src, "src/repro/x.py") == []


def test_r1_missing_allowlist_is_one_finding():
    src = textwrap.dedent("""\
        import dataclasses

        @dataclasses.dataclass
        class FooOptions:
            a: int = 1
            b: int = 2

        def opts_static(opts):
            return (opts.a,)
    """)
    findings = lint_source(src, "m.py")
    assert len(findings) == 1 and "DYNAMIC_FIELDS" in findings[0].message


def test_r1_stale_and_double_listed_entries():
    src = textwrap.dedent("""\
        import dataclasses

        @dataclasses.dataclass
        class FooOptions:
            a: int = 1
            b: int = 2

        DYNAMIC_FIELDS = ("a", "b", "ghost")

        def opts_static(opts):
            return (opts.a,)
    """)
    msgs = [f.message for f in lint_source(src, "m.py")]
    assert any("ghost" in m and "stale" in m for m in msgs)
    assert any("FooOptions.a" in m and "remove it" in m for m in msgs)
    # b is correctly allowlisted: no finding mentions it alone
    assert not any("FooOptions.b" in m for m in msgs)


def test_r2_hardcoded_key_allowed_in_test_trees():
    src = "import jax\nk = jax.random.PRNGKey(0)\n"
    assert lint_source(src, "tests/test_x.py") == []
    assert len(lint_source(src, "src/repro/x.py")) == 1


def test_r2_branch_arms_do_not_alias():
    # draws in mutually exclusive if/else arms share a key legitimately
    src = textwrap.dedent("""\
        import jax

        def f(key, flag, shape):
            if flag:
                return jax.random.normal(key, shape)
            else:
                return jax.random.uniform(key, shape)
    """)
    assert lint_source(src, "src/m.py") == []


def test_r2_comparator_key_kwarg_is_not_a_prng_key():
    src = textwrap.dedent("""\
        def f(items, tag):
            a = sorted(items, key=tag)
            b = sorted(items, key=tag)
            return a, b
    """)
    assert lint_source(src, "src/m.py") == []


def test_r4_requires_traced_context():
    # same control flow outside any jit-reachable function: quiet
    src = textwrap.dedent("""\
        import jax.numpy as jnp

        def host_fn(x):
            if jnp.sum(x) > 0:
                return -x
            return x
    """)
    assert lint_source(src, "src/m.py") == []


def test_r5_scoped_to_hot_paths():
    src = textwrap.dedent("""\
        import jax

        @jax.jit
        def f(x):
            return float(x + 1)
    """)
    assert lint_source(src, "src/repro/core/engine.py") != []
    assert lint_source(src, "src/repro/launch/train.py") == []


def test_repo_lints_clean():
    """The CI gate: src/tests/benchmarks carry zero undisabled findings."""
    paths = [REPO_ROOT / d for d in ("src", "tests", "benchmarks")]
    assert lint_paths(paths) == []


def test_cli_exit_codes():
    from tools.jaxlint.__main__ import main
    assert main(["--list-rules"]) == 0
    assert main([str(FIXTURES / "r3_good.py")]) == 0
    assert main([str(FIXTURES / "r3_bad.py")]) == 1


def test_cli_json_output(capsys):
    import json

    from tools.jaxlint.__main__ import main
    assert main(["--json", str(FIXTURES / "r3_bad.py")]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert [sorted(entry) for entry in payload] == \
        [["file", "line", "message", "rule"]]
    assert payload[0]["rule"] == "R3" and payload[0]["line"] > 0

    assert main(["--json", str(FIXTURES / "r3_good.py")]) == 0
    assert json.loads(capsys.readouterr().out) == []
