"""jaxlint: every rule fires on the fixture reproducing its historical
bug, stays quiet on the fixed code, honours pragmas — and the repo
itself lints clean (the CI gate, asserted here so a local run catches a
new violation before CI does)."""
import sys
import textwrap
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT))     # tools/ is not on PYTHONPATH=src

from tools.jaxlint import (  # noqa: E402
    Config,
    lint_file,
    lint_paths,
    lint_source,
)

FIXTURES = REPO_ROOT / "tools" / "jaxlint" / "fixtures"
# fixtures exercise R5's hot-path scoping by declaring themselves hot
FIXTURE_CFG = Config(hot_paths=("fixtures/",))

# rule -> (bad fixture finding count, historical bug it reproduces)
EXPECTED = {
    "R1": 1,    # sparse_kernel shipped without an opts_static entry
    "R2": 2,    # PRNGKey(0) in _solve_jit_core + k3 reused twice
    "R3": 1,    # time.time() duration in the benchmark harness
    "R4": 2,    # Python while/if on jnp values under jit
    "R5": 3,    # float()/.item()/np.asarray in a traced hot path
}


@pytest.mark.parametrize("rule", sorted(EXPECTED))
def test_rule_fires_on_historical_bug_fixture(rule):
    findings = lint_file(FIXTURES / f"{rule.lower()}_bad.py", FIXTURE_CFG)
    assert [f.rule for f in findings] == [rule] * EXPECTED[rule], findings


@pytest.mark.parametrize("rule", sorted(EXPECTED))
def test_rule_quiet_on_fixed_fixture(rule):
    findings = lint_file(FIXTURES / f"{rule.lower()}_good.py", FIXTURE_CFG)
    assert findings == [], findings


def test_pragma_suppresses_and_is_rule_specific():
    src = textwrap.dedent("""\
        import time
        t0 = time.time()
        wall = time.time() - t0  # jaxlint: disable=R3
        wall2 = time.time() - t0  # jaxlint: disable=R2
    """)
    findings = lint_source(src, "x.py")
    # the R3 pragma eats line 3; the R2 pragma on line 4 does NOT
    assert [(f.rule, f.line) for f in findings] == [("R3", 4)]


def test_r1_missing_allowlist_is_one_finding():
    src = textwrap.dedent("""\
        import dataclasses

        @dataclasses.dataclass
        class FooOptions:
            a: int = 1
            b: int = 2

        def opts_static(opts):
            return (opts.a,)
    """)
    findings = lint_source(src, "m.py")
    assert len(findings) == 1 and "DYNAMIC_FIELDS" in findings[0].message


def test_r1_stale_and_double_listed_entries():
    src = textwrap.dedent("""\
        import dataclasses

        @dataclasses.dataclass
        class FooOptions:
            a: int = 1
            b: int = 2

        DYNAMIC_FIELDS = ("a", "b", "ghost")

        def opts_static(opts):
            return (opts.a,)
    """)
    msgs = [f.message for f in lint_source(src, "m.py")]
    assert any("ghost" in m and "stale" in m for m in msgs)
    assert any("FooOptions.a" in m and "remove it" in m for m in msgs)
    # b is correctly allowlisted: no finding mentions it alone
    assert not any("FooOptions.b" in m for m in msgs)


def test_r2_hardcoded_key_allowed_in_test_trees():
    src = "import jax\nk = jax.random.PRNGKey(0)\n"
    assert lint_source(src, "tests/test_x.py") == []
    assert len(lint_source(src, "src/repro/x.py")) == 1


def test_r2_branch_arms_do_not_alias():
    # draws in mutually exclusive if/else arms share a key legitimately
    src = textwrap.dedent("""\
        import jax

        def f(key, flag, shape):
            if flag:
                return jax.random.normal(key, shape)
            else:
                return jax.random.uniform(key, shape)
    """)
    assert lint_source(src, "src/m.py") == []


def test_r2_comparator_key_kwarg_is_not_a_prng_key():
    src = textwrap.dedent("""\
        def f(items, tag):
            a = sorted(items, key=tag)
            b = sorted(items, key=tag)
            return a, b
    """)
    assert lint_source(src, "src/m.py") == []


def test_r4_requires_traced_context():
    # same control flow outside any jit-reachable function: quiet
    src = textwrap.dedent("""\
        import jax.numpy as jnp

        def host_fn(x):
            if jnp.sum(x) > 0:
                return -x
            return x
    """)
    assert lint_source(src, "src/m.py") == []


def test_r5_scoped_to_hot_paths():
    src = textwrap.dedent("""\
        import jax

        @jax.jit
        def f(x):
            return float(x + 1)
    """)
    assert lint_source(src, "src/repro/core/engine.py") != []
    assert lint_source(src, "src/repro/launch/train.py") == []


def test_repo_lints_clean():
    """The CI gate: src/tests/benchmarks carry zero undisabled findings."""
    paths = [REPO_ROOT / d for d in ("src", "tests", "benchmarks")]
    assert lint_paths(paths) == []


def test_cli_exit_codes():
    from tools.jaxlint.__main__ import main
    assert main(["--list-rules"]) == 0
    assert main([str(FIXTURES / "r3_good.py")]) == 0
    assert main([str(FIXTURES / "r3_bad.py")]) == 1
