"""Device-simulation substrate tests (encode/write-verify/energy ledger)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.crossbar import (
    EPIRAM,
    TAOX_HFOX,
    CrossbarArray,
    Ledger,
    analog_linear,
    charge_write,
    encode_matrix,
    solve_crossbar_jit,
    write_verify_error,
)
from repro.lp import random_standard_lp


def test_encode_decode_error_bounded():
    rng = np.random.default_rng(0)
    W = rng.normal(size=(64, 64))
    for dev in (EPIRAM, TAOX_HFOX):
        enc = encode_matrix(W, dev, jax.random.PRNGKey(0))
        err = write_verify_error(enc, W)
        # quantization (1/levels) + programming noise (few sigma)
        bound = 1.5 / dev.g_levels + 6 * dev.sigma_program
        assert err < bound, (dev.name, err, bound)


def test_differential_encoding_nonnegative():
    rng = np.random.default_rng(1)
    W = rng.normal(size=(32, 48))
    enc = encode_matrix(W, EPIRAM, jax.random.PRNGKey(0))
    assert float(enc.g_pos.min()) >= 0.0
    assert float(enc.g_neg.min()) >= 0.0
    # a cell is nonzero in at most one of the pair (target-wise)
    both = (np.asarray(enc.g_pos)[:32, :48] > 0.05) \
        & (np.asarray(enc.g_neg)[:32, :48] > 0.05)
    assert both.mean() < 0.02


def test_ledger_write_once_read_many():
    rng = np.random.default_rng(2)
    W = rng.normal(size=(80, 70))
    led = Ledger()
    arr = CrossbarArray.program(W, EPIRAM, ledger=led)
    write_e = led.write_energy_j
    assert write_e > 0
    for i in range(5):
        arr.mvm(rng.normal(size=70), key=jax.random.PRNGKey(i))
    assert led.write_energy_j == write_e          # encode-once: no rewrites
    assert led.mvm_count == 5
    assert led.read_energy_j > 0
    # reads are much cheaper than the write (the paper's core premise)
    assert led.read_energy_j / led.mvm_count < write_e / 10


def test_ledger_splits_logical_and_padding_write_energy():
    """Tile padding programs RESET pulses on cells the operator never
    uses; those must be ledgered apart from the logical cells."""
    rng = np.random.default_rng(7)
    W = rng.normal(size=(30, 40))                # tile-pads to 64x64
    led = Ledger()
    encode_matrix(W, EPIRAM, jax.random.PRNGKey(0), ledger=led)
    assert led.cells_written == 2 * 64 * 64
    assert led.cells_written_padding == 2 * (64 * 64 - 30 * 40)
    # padding cells: exactly one RESET pulse per cell
    expected_pad = led.cells_written_padding * EPIRAM.write_pulse_energy_j
    np.testing.assert_allclose(led.write_energy_padding_j, expected_pad)
    assert 0 < led.write_energy_padding_j < led.write_energy_j
    np.testing.assert_allclose(
        led.write_energy_logical_j,
        led.write_energy_j - led.write_energy_padding_j)

    # an exact-fit matrix has zero padding cost
    led2 = Ledger()
    encode_matrix(rng.normal(size=(64, 64)), EPIRAM,
                  jax.random.PRNGKey(1), ledger=led2)
    assert led2.cells_written_padding == 0
    assert led2.write_energy_padding_j == 0.0
    assert led2.write_energy_logical_j == led2.write_energy_j


def test_encode_core_vmaps_over_a_stacked_operator_batch():
    """The pure programming model batches: one call programs (B, R, C)."""
    from repro.crossbar import encode_core

    rng = np.random.default_rng(8)
    Ws = jnp.asarray(rng.normal(size=(3, 64, 64)))
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    g_pos, g_neg, scales, nzs = jax.vmap(
        lambda W, k: encode_core(W, k, EPIRAM.g_levels,
                                 EPIRAM.sigma_program))(Ws, keys)
    assert g_pos.shape == (3, 64, 64) and g_neg.shape == (3, 64, 64)
    for i in range(3):
        dec = np.asarray((g_pos[i] - g_neg[i]) * scales[i])
        err = np.abs(dec - np.asarray(Ws[i])).max() \
            / np.abs(np.asarray(Ws[i])).max()
        assert err < 1.5 / EPIRAM.g_levels + 6 * EPIRAM.sigma_program
        assert 0 < int(nzs[i]) <= 64 * 64


def test_encode_nz_counts_post_quantization_targets():
    """Regression: entries below half an LSB quantize to zero conductance
    — they take one RESET pulse and draw no read current, so they must
    not count as nonzero-target pairs (the pre-quantization count
    inflated both the write-pulse charge and the read-current fill)."""
    from repro.crossbar import encode_core

    W = np.zeros((16, 16))
    W[0, 0] = 1.0                      # sets the scale
    W[1:5, 1:5] = 1e-6                 # << LSB at 1.0 scale: quantize to 0
    g_pos, g_neg, scale, nz = encode_core(
        jnp.asarray(W), jax.random.PRNGKey(0), EPIRAM.g_levels,
        EPIRAM.sigma_program)
    assert int(nz) == 1
    # the sub-LSB cells really are zero conductance (no read current)
    dec = np.asarray((g_pos - g_neg) * scale)
    assert np.all(dec[1:5, 1:5] == 0.0)

    # and the ledger sees the honest fill: one pulse train, RESET for
    # the rest
    led = Ledger()
    fill = charge_write(led, EPIRAM, float(nz), pairs_logical=16 * 16,
                        pairs_total=16 * 16)
    assert fill == 1 / 256
    expected_pulses = (1 * 2 * EPIRAM.avg_write_pulses
                       + (2 * 256 - 2) * 1.0)
    np.testing.assert_allclose(
        led.write_energy_j, expected_pulses * EPIRAM.write_pulse_energy_j)


def test_write_latency_includes_reset_pulses():
    """Regression: zero-target cells take a real RESET pulse through the
    same row-serial programming path — the latency model must charge it
    like the energy model always did, not floor it away."""
    led = Ledger()
    tr, tc = EPIRAM.crossbar_rows, EPIRAM.crossbar_cols
    pairs = tr * tc
    nz = pairs // 4                    # quarter-full array
    fill = charge_write(led, EPIRAM, float(nz), pairs_logical=pairs,
                        pairs_total=pairs)
    pulses_serial = 2 * tr * tc * (fill * EPIRAM.avg_write_pulses
                                   + (1.0 - fill) * 1.0)
    np.testing.assert_allclose(
        led.write_latency_s, pulses_serial * EPIRAM.write_pulse_latency_s)
    # a RESET-only (empty) array still takes one pulse per cell, not
    # zero time
    led0 = Ledger()
    charge_write(led0, EPIRAM, 0.0, pairs_logical=pairs, pairs_total=pairs)
    np.testing.assert_allclose(
        led0.write_latency_s,
        2 * tr * tc * EPIRAM.write_pulse_latency_s)
    assert led0.write_latency_s < led.write_latency_s


def test_taox_writes_cheaper_than_epiram():
    """Table 3's headline: TaOx-HfOx programming is far cheaper."""
    rng = np.random.default_rng(3)
    W = rng.normal(size=(64, 64))
    led_e, led_t = Ledger(), Ledger()
    encode_matrix(W, EPIRAM, jax.random.PRNGKey(0), ledger=led_e)
    encode_matrix(W, TAOX_HFOX, jax.random.PRNGKey(0), ledger=led_t)
    assert led_t.write_energy_j < led_e.write_energy_j / 10
    assert led_t.write_latency_s < led_e.write_latency_s / 3


def test_crossbar_solve_reaches_noise_floor(x64):
    from repro.core import PDHGOptions

    lp = random_standard_lp(16, 28, seed=4)
    rep = solve_crossbar_jit(
        lp, PDHGOptions(max_iters=15000, tol=1e-5, check_every=100,
                        lanczos_iters=32), device=TAOX_HFOX)
    gap = abs(rep.result.obj - lp.obj_opt) / abs(lp.obj_opt)
    assert gap < 5e-3, gap                       # paper-range optimality gap
    assert rep.ledger.total_energy_j > 0
    assert rep.ledger.mvm_count > 0


def test_analog_linear_shapes_and_accuracy():
    rng = np.random.default_rng(5)
    W = rng.normal(size=(24, 16))
    x = rng.normal(size=(4, 16))
    y = np.asarray(analog_linear(x, W, device=TAOX_HFOX))
    assert y.shape == (4, 24)
    clean = x @ W.T
    rel = np.abs(y - clean).max() / np.abs(clean).max()
    assert rel < 0.05
