"""Multi-process cluster harness: coordinator + worker over localhost.

Real multi-host CI is unavailable, so this harness IS the multi-process
test bed for the distributed serving stack: it spawns N (=2) Python
processes against a shared transport directory, each running
``ClusterBatchSolver.solve_stream`` over the SAME deterministic mixed
stream, and the coordinator publishes the gathered results as an atomic
``fault.save_checkpoint`` snapshot for the pytest process to compare
against the single-process path (bitwise at ``sigma_read=0``).

Two modes:

  * transport-only (default): no ``jax.distributed`` — pods coordinate
    purely through the routing table (deterministic, communication-free)
    and the shared-filesystem result plane.  This is the mode the
    worker-kill test uses (killing a process must not take the
    coordination service down with it).
  * ``--jaxdist``: the REPRO_* env vars are set and
    ``runtime.cluster.init_cluster("auto")`` performs a real
    ``jax.distributed.initialize`` over localhost; the harness asserts
    the process grid (process_count, global device count, cluster-mesh
    pod axis) before serving.

Process entry (run by ``spawn_pod``):

    python tests/_cluster_harness.py --pod 0 --pods 2 \
        --transport /tmp/t --out /tmp/t/final.npz [--jaxdist ...]

``--stall-after-buckets K`` makes a pod hang forever after publishing K
bucket results — the deterministic "mid-stream" point at which the kill
test murders the worker.
"""
from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys
import time

# stream composition: mixed shapes -> several dense buckets + one sparse
# bucket, so routing has real work to spread across pods
DENSE_SHAPES = [(8, 14), (10, 18), (20, 34), (7, 13)]
SPARSE_SHAPES = [(96, 192)]
SPARSE_DENSITY = 0.05
N_INSTANCES = 16


def build_stream(n: int = N_INSTANCES, seed: int = 0):
    """The harness stream: every pod (and the pytest process) rebuilds
    the identical stream from (n, seed) — no data plane needed."""
    from repro.lp import random_standard_lp, sparse_random_standard_lp

    lps = []
    for i in range(n):
        if i % 4 == 3:      # every 4th instance exercises the COO path
            m, nn = SPARSE_SHAPES[i % len(SPARSE_SHAPES)]
            lps.append(sparse_random_standard_lp(
                m, nn, density=SPARSE_DENSITY, seed=seed + i))
        else:
            m, nn = DENSE_SHAPES[i % len(DENSE_SHAPES)]
            lps.append(random_standard_lp(m, nn, seed=seed + i))
    return lps


def harness_opts():
    from repro.core import PDHGOptions

    return PDHGOptions(max_iters=2000, tol=1e-4, check_every=64,
                       lanczos_iters=16, seed=0)


def results_arrays(lps, results):
    """Flatten per-instance results into comparable arrays."""
    import numpy as np

    return {
        "x_cat": np.concatenate([r.x for r in results]),
        "y_cat": np.concatenate([r.y for r in results]),
        "merits": np.asarray([r.merit for r in results]),
        "iterations": np.asarray([r.iterations for r in results]),
        "objs": np.asarray([r.obj for r in results]),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pod", type=int, required=True)
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--transport", required=True)
    ap.add_argument("--out", default=None,
                    help="coordinator writes the gathered results here")
    ap.add_argument("--n", type=int, default=N_INSTANCES)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggler-timeout", type=float, default=30.0)
    ap.add_argument("--gather-timeout", type=float, default=240.0)
    ap.add_argument("--stall-after-buckets", type=int, default=None)
    ap.add_argument("--jaxdist", default=None,
                    help="coordinator address host:port -> real "
                         "jax.distributed.initialize via REPRO_* env")
    args = ap.parse_args(argv)

    if args.jaxdist:
        os.environ["REPRO_COORDINATOR"] = args.jaxdist
        os.environ["REPRO_NUM_PROCESSES"] = str(args.pods)
        os.environ["REPRO_PROCESS_ID"] = str(args.pod)

    from repro.runtime import cluster as cluster_mod

    info = cluster_mod.init_cluster("auto")
    if args.jaxdist:
        import jax

        assert info.is_multiprocess and info.initialized, info
        assert jax.process_count() == args.pods, jax.process_count()
        assert len(jax.devices()) >= args.pods     # one+ device per pod
        from repro.runtime.mesh import make_cluster_mesh
        mesh = make_cluster_mesh()
        assert mesh.shape["pod"] == args.pods, dict(mesh.shape)
        # pod blocks are addressable-device-aligned: pod i = process i
        assert all(d.process_index == i
                   for i, row in enumerate(mesh.devices)
                   for d in row.flat), "pod axis crosses process boundaries"
        print(f"HARNESS JAXDIST OK pod={args.pod} "
              f"devices={len(jax.devices())}", flush=True)

    import jax
    jax.config.update("jax_enable_x64", True)

    from repro.distributed.fault import save_checkpoint
    from repro.runtime.cluster import ClusterBatchSolver, DirectoryTransport

    class HarnessSolver(ClusterBatchSolver):
        """Optionally stalls mid-stream after K published buckets (the
        kill test's deterministic straggler point)."""

        published = 0

        def _bucket_served(self, key, idxs, out):
            if args.stall_after_buckets is not None \
                    and self.published >= args.stall_after_buckets:
                print(f"HARNESS POD{args.pod} STALLED "
                      f"after {self.published} buckets", flush=True)
                time.sleep(3600)
            super()._bucket_served(key, idxs, out)
            self.published += 1

    lps = build_stream(args.n, seed=args.seed)
    solver = HarnessSolver(
        harness_opts(), pod=args.pod, n_pods=args.pods,
        live_pods=args.pods,
        transport=DirectoryTransport(args.transport),
        straggler_timeout=args.straggler_timeout,
        gather_timeout=args.gather_timeout)
    results = solver.solve_stream(lps)
    assert all(r is not None for r in results)
    st = solver.last_stream_stats
    print(f"HARNESS POD{args.pod} routing={st['routing']} "
          f"local={st['n_local_buckets']} "
          f"rerouted={st['rerouted_buckets']}", flush=True)
    if args.out and args.pod == 0:
        save_checkpoint(args.out, 0, results_arrays(lps, results),
                        {"routing": st["routing"],
                         "rerouted": int(st["rerouted_buckets"]),
                         "n_buckets": int(st["n_buckets"])})
    # exit barrier: workers drop a done-marker; the coordinator (which
    # hosts the jax.distributed coordination service in --jaxdist mode)
    # lingers until every worker finished gathering, so its exit never
    # tears the service down under a live worker.
    done = os.path.join(args.transport, f"done_pod{args.pod}")
    with open(done, "w") as f:
        f.write("done\n")
    if args.pod == 0 and args.jaxdist:      # transport-only pods may die
        deadline = time.time() + 60.0
        others = [p for p in range(args.pods) if p != 0]
        while time.time() < deadline and any(
                not os.path.exists(os.path.join(args.transport,
                                                f"done_pod{p}"))
                for p in others):
            time.sleep(0.1)
    print(f"HARNESS POD{args.pod} DONE", flush=True)
    return 0


# ---------------------------------------------------------- test driver ---

def free_port() -> int:
    s = socket.socket()
    s.bind(("localhost", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def spawn_pod(pod: int, pods: int, transport: str, *, out=None,
              jaxdist=None, stall_after=None, straggler_timeout=30.0,
              gather_timeout=240.0, env=None) -> subprocess.Popen:
    """Start one harness pod as a subprocess (test-side helper)."""
    cmd = [sys.executable, os.path.abspath(__file__),
           "--pod", str(pod), "--pods", str(pods),
           "--transport", transport,
           "--straggler-timeout", str(straggler_timeout),
           "--gather-timeout", str(gather_timeout)]
    if out:
        cmd += ["--out", out]
    if jaxdist:
        cmd += ["--jaxdist", jaxdist]
    if stall_after is not None:
        cmd += ["--stall-after-buckets", str(stall_after)]
    return subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)


if __name__ == "__main__":
    sys.exit(main())
