"""Distribution runtime tests.

Single-device meshes exercise the full shard_map code paths here; the
8-fake-device equivalence test runs in a subprocess (XLA device count is
process-global and must stay 1 for everything else)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import PDHGOptions, solve_jit
from repro.distributed import (
    CheckpointManager,
    load_checkpoint,
    reshard,
    save_checkpoint,
    solve_batch,
    stack_problems,
)
from repro.distributed.pdhg_dist import solve_dist
from repro.launch.mesh import make_mesh
from repro.lp import random_standard_lp


def test_solve_dist_single_device_mesh(x64):
    mesh = make_mesh((1, 1), ("data", "model"))
    lp = random_standard_lp(10, 18, seed=0)
    opts = PDHGOptions(max_iters=20000, tol=1e-6, check_every=64)
    r = solve_dist(lp, mesh, opts)
    assert r.status == "optimal"
    assert abs(r.obj - lp.obj_opt) / abs(lp.obj_opt) < 1e-4


def test_solve_dist_residual_components_are_real(x64):
    """Regression (ISSUE 4): ``solve_dist`` used to stuff the scalar
    in-loop merit into all four ``KKTResiduals`` fields, so
    ``residuals.as_dict()`` reported r_pri == r_dual == r_iter == r_gap.
    The components must now be the actual per-component KKT residuals of
    the unscaled solution — matching a dense ``kkt_residuals``
    evaluation on the same (x, y)."""
    from repro.core.residuals import kkt_residuals

    mesh = make_mesh((1, 1), ("data", "model"))
    lp = random_standard_lp(10, 18, seed=0)
    opts = PDHGOptions(max_iters=20000, tol=1e-6, check_every=64)
    r = solve_dist(lp, mesh, opts)
    got = r.residuals.as_dict()
    # four genuinely distinct components (the old bug made them equal)
    assert len({f"{v:.12e}" for v in got.values()}) > 1, got
    import jax.numpy as jnp
    want = kkt_residuals(
        jnp.asarray(r.x), jnp.asarray(r.x), jnp.asarray(r.y),
        jnp.asarray(lp.c), jnp.asarray(lp.b),
        jnp.asarray(lp.K @ r.x), jnp.asarray(lp.K.T @ r.y),
        lb=jnp.asarray(lp.lb), ub=jnp.asarray(lp.ub)).as_dict()
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-12,
                                   err_msg=k)
    # the in-loop merit still drives status, and the post-hoc noiseless
    # residuals must corroborate the claimed convergence
    assert r.status == "optimal"
    assert float(r.residuals.max) < 10 * opts.tol


def test_solve_dist_auto_single_process_fallback(x64):
    """``solve_dist_auto`` degrades to the local mesh when the env
    names no cluster — existing entry points work unchanged."""
    from repro.distributed import solve_dist_auto
    from repro.runtime import cluster as cluster_mod

    cluster_mod._reset_for_tests()
    try:
        lp = random_standard_lp(10, 18, seed=0)
        opts = PDHGOptions(max_iters=20000, tol=1e-6, check_every=64)
        r = solve_dist_auto(lp, opts, cluster="off")
        assert r.status == "optimal"
        assert abs(r.obj - lp.obj_opt) / abs(lp.obj_opt) < 1e-4
    finally:
        cluster_mod._reset_for_tests()


def test_batch_solve(x64):
    mesh = make_mesh((1,), ("data",))
    lps = [random_standard_lp(8, 14, seed=s) for s in range(3)]
    Ks, bs, cs, lbs, ubs = stack_problems(lps)
    out = solve_batch(Ks, bs, cs, lbs, ubs, mesh,
                      PDHGOptions(max_iters=20000, tol=1e-6, check_every=64))
    objs = np.einsum("bn,bn->b", cs, out["x"])
    for lp, obj in zip(lps, objs):
        assert abs(obj - lp.obj_opt) / abs(lp.obj_opt) < 1e-4


def test_checkpoint_atomicity_and_roundtrip(tmp_path):
    path = str(tmp_path / "ck.npz")
    arrays = {"x": np.arange(10.0), "nested/w": np.ones((3, 4))}
    save_checkpoint(path, 7, arrays, {"tag": "t"})
    ck = load_checkpoint(path)
    assert ck.step == 7
    assert ck.meta["tag"] == "t"
    np.testing.assert_array_equal(ck.arrays["x"], np.arange(10.0))
    # overwrite is atomic (file is always loadable)
    save_checkpoint(path, 8, arrays)
    assert load_checkpoint(path).step == 8


def test_checkpoint_manager_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=10)
    for step in range(1, 51):
        mgr.maybe_save(step, {"a": np.zeros(2)})
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 2
    assert mgr.latest().endswith("ckpt_000000000050.npz")


def test_elastic_reshard_roundtrip(tmp_path):
    """Save on one mesh topology, restore onto another."""
    path = str(tmp_path / "ck.npz")
    arrays = {"w": np.arange(32.0).reshape(8, 4)}
    save_checkpoint(path, 1, arrays)
    ck = load_checkpoint(path)
    mesh = make_mesh((1,), ("data",))
    placed = reshard(ck.arrays, mesh, {"w": P("data", None)})
    np.testing.assert_array_equal(np.asarray(placed["w"]), arrays["w"])


def test_quantize_roundtrip():
    from repro.distributed import dequantize_int8, quantize_int8
    import jax.numpy as jnp

    x = jnp.asarray(np.random.default_rng(0).normal(size=256),
                    jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    rel = float(jnp.abs(back - x).max() / jnp.abs(x).max())
    assert rel < 1.0 / 100            # int8 grid error


MULTIDEV_SCRIPT = textwrap.dedent("""
    from repro.runtime import compat
    assert compat.request_cpu_devices(8), "backend initialized too early"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from repro.lp import random_standard_lp
    from repro.core import PDHGOptions, solve_jit
    from repro.distributed.pdhg_dist import solve_dist
    from repro.runtime.mesh import make_mesh

    assert len(jax.devices()) == 8
    lp = random_standard_lp(24, 40, seed=11)
    opts = PDHGOptions(max_iters=20000, tol=1e-6, check_every=64)
    r_single = solve_jit(lp, opts)
    for shape, axes in [((2, 4), ("data", "model")),
                        ((2, 2, 2), ("pod", "data", "model"))]:
        mesh = make_mesh(shape, axes)
        r = solve_dist(lp, mesh, opts)
        rel = abs(r.obj - lp.obj_opt) / abs(lp.obj_opt)
        assert rel < 1e-4, (shape, rel)
        print(f"OK {shape} obj={r.obj:.8f} iters={r.iterations}")
    print("MULTIDEV PASS")
""")


@pytest.mark.slow
def test_distributed_solve_multidevice_subprocess():
    """2-axis and 3-axis sharded PDHG on 8 fake devices == known optimum."""
    from conftest import repo_root, subprocess_env

    proc = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT], env=subprocess_env(),
        cwd=repo_root(), capture_output=True, text=True, timeout=900,
    )
    assert "MULTIDEV PASS" in proc.stdout, proc.stdout + proc.stderr
