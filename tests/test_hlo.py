"""Unit tests for the HLO roofline parser (the §Roofline foundation)."""
import numpy as np

from repro.launch.hlo import (
    CostEstimate,
    estimate_costs,
    parse_collectives,
    scan_trip_counts,
    shape_bytes,
)

SAMPLE = """HloModule jit_f, is_scheduled=true

%body.1 (arg: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %arg = (s32[], f32[128,256]{1,0}) parameter(0)
  %gte0 = s32[] get-tuple-element(%arg), index=0
  %gte1 = f32[128,256]{1,0} get-tuple-element(%arg), index=1
  %w = f32[256,256]{1,0} constant({...})
  %dot.2 = f32[128,256]{1,0} dot(%gte1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,256]{1,0} all-reduce(%dot.2), channel_id=1, replica_groups=[2,4]<=[8]
  ROOT %tup = (s32[], f32[128,256]{1,0}) tuple(%gte0, %ar)
}

%cond.1 (arg2: (s32[], f32[128,256])) -> pred[] {
  %arg2 = (s32[], f32[128,256]{1,0}) parameter(0)
  ROOT %lt = pred[] compare(%arg2, %arg2), direction=LT
}

ENTRY %main.9 (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %init = (s32[], f32[128,256]{1,0}) tuple(%p0, %p0)
  %while.3 = (s32[], f32[128,256]{1,0}) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"7"}}
  %ag = f32[512,256]{1,0} all-gather(%p0), dimensions={0}
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%while.3), index=1
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("pred[7]") == 7
    assert shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert shape_bytes("s32[]") == 4


def test_trip_counts():
    assert scan_trip_counts(SAMPLE) == [7]


def test_collectives_trip_scaled():
    stats = parse_collectives(SAMPLE)
    # all-reduce inside the 7-trip loop: 2x multiplier x 7 trips
    ar = 128 * 256 * 4 * 2 * 7
    # all-gather at top level, 1x
    ag = 512 * 256 * 4
    assert stats.counts == {"all-reduce": 1, "all-gather": 1}
    np.testing.assert_allclose(stats.bytes_by_kind["all-reduce"], ar)
    np.testing.assert_allclose(stats.bytes_by_kind["all-gather"], ag)
    np.testing.assert_allclose(stats.total_bytes, ar + ag)


def test_flops_trip_scaled():
    est = estimate_costs(SAMPLE)
    # dot 128x256 @ 256x256 = 2*128*256*256 flops, x7 trips
    np.testing.assert_allclose(est.flops, 2 * 128 * 256 * 256 * 7)


def test_real_compile_matches_analytic():
    """End-to-end: compile a scan of matmuls, estimator == closed form."""
    import jax
    import jax.numpy as jnp

    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, None, length=5)[0]

    sx = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    sw = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    comp = jax.jit(f).lower(sx, sw).compile()
    est = estimate_costs(comp.as_text())
    np.testing.assert_allclose(est.flops, 5 * 2 * 64 * 32 * 32)
