"""Unit tests for the HLO roofline parser (the §Roofline foundation)."""
import numpy as np

from repro.launch.hlo import (
    CostEstimate,
    estimate_costs,
    parse_collectives,
    propagate_multipliers,
    scan_trip_counts,
    shape_bytes,
)

SAMPLE = """HloModule jit_f, is_scheduled=true

%body.1 (arg: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %arg = (s32[], f32[128,256]{1,0}) parameter(0)
  %gte0 = s32[] get-tuple-element(%arg), index=0
  %gte1 = f32[128,256]{1,0} get-tuple-element(%arg), index=1
  %w = f32[256,256]{1,0} constant({...})
  %dot.2 = f32[128,256]{1,0} dot(%gte1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,256]{1,0} all-reduce(%dot.2), channel_id=1, replica_groups=[2,4]<=[8]
  ROOT %tup = (s32[], f32[128,256]{1,0}) tuple(%gte0, %ar)
}

%cond.1 (arg2: (s32[], f32[128,256])) -> pred[] {
  %arg2 = (s32[], f32[128,256]{1,0}) parameter(0)
  ROOT %lt = pred[] compare(%arg2, %arg2), direction=LT
}

ENTRY %main.9 (p0: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %init = (s32[], f32[128,256]{1,0}) tuple(%p0, %p0)
  %while.3 = (s32[], f32[128,256]{1,0}) while(%init), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"7"}}
  %ag = f32[512,256]{1,0} all-gather(%p0), dimensions={0}
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%while.3), index=1
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("pred[7]") == 7
    assert shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert shape_bytes("s32[]") == 4


def test_trip_counts():
    assert scan_trip_counts(SAMPLE) == [7]


def test_collectives_trip_scaled():
    stats = parse_collectives(SAMPLE)
    # all-reduce inside the 7-trip loop: 2x multiplier x 7 trips
    ar = 128 * 256 * 4 * 2 * 7
    # all-gather at top level, 1x
    ag = 512 * 256 * 4
    assert stats.counts == {"all-reduce": 1, "all-gather": 1}
    np.testing.assert_allclose(stats.bytes_by_kind["all-reduce"], ar)
    np.testing.assert_allclose(stats.bytes_by_kind["all-gather"], ag)
    np.testing.assert_allclose(stats.total_bytes, ar + ag)


def test_flops_trip_scaled():
    est = estimate_costs(SAMPLE)
    # dot 128x256 @ 256x256 = 2*128*256*256 flops, x7 trips
    np.testing.assert_allclose(est.flops, 2 * 128 * 256 * 256 * 7)


NESTED = """HloModule nested_whiles, is_scheduled=true

%inner_body.1 (a: (s32[], f32[64])) -> (s32[], f32[64]) {
  %a = (s32[], f32[64]) parameter(0)
  %g0 = s32[] get-tuple-element(%a), index=0
  %g1 = f32[64]{0} get-tuple-element(%a), index=1
  %ar = f32[64]{0} all-reduce(%g1), channel_id=1, replica_groups={}
  ROOT %t = (s32[], f32[64]{0}) tuple(%g0, %ar)
}

%inner_cond.1 (a2: (s32[], f32[64])) -> pred[] {
  %a2 = (s32[], f32[64]) parameter(0)
  ROOT %lt = pred[] compare(%a2, %a2), direction=LT
}

%outer_body.1 (b: (s32[], f32[64])) -> (s32[], f32[64]) {
  %b = (s32[], f32[64]) parameter(0)
  %while.inner = (s32[], f32[64]{0}) while(%b), condition=%inner_cond.1, body=%inner_body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %t2 = (s32[], f32[64]{0}) tuple(%while.inner)
}

%outer_cond.1 (b2: (s32[], f32[64])) -> pred[] {
  %b2 = (s32[], f32[64]) parameter(0)
  ROOT %lt2 = pred[] compare(%b2, %b2), direction=LT
}

ENTRY %main.2 (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  %init = (s32[], f32[64]{0}) tuple(%p, %p)
  %while.outer = (s32[], f32[64]{0}) while(%init), condition=%outer_cond.1, body=%outer_body.1, backend_config={"known_trip_count":{"n":"3"}}
  ROOT %o = f32[64]{0} get-tuple-element(%while.outer), index=1
}
"""


def test_nested_while_multiplies_trip_counts():
    stats = parse_collectives(NESTED)
    assert scan_trip_counts(NESTED) == [5, 3]
    # all-reduce in the inner body: 2x bytes x (3 outer x 5 inner) trips
    np.testing.assert_allclose(stats.bytes_by_kind["all-reduce"],
                               64 * 4 * 2 * 15)
    assert stats.counts == {"all-reduce": 1}
    np.testing.assert_allclose(stats.static_bytes, 64 * 4 * 2)


COND = """HloModule cond_collectives, is_scheduled=true

%true_comp.1 (t: f32[32]) -> f32[64] {
  %t = f32[32]{0} parameter(0)
  ROOT %ag = f32[64]{0} all-gather(%t), dimensions={0}
}

%false_comp.1 (f: f32[32]) -> f32[64] {
  %f = f32[32]{0} parameter(0)
  %ar2 = f32[32]{0} all-reduce(%f), channel_id=2, replica_groups={}
  ROOT %bc = f32[64]{0} broadcast(%ar2), dimensions={0}
}

ENTRY %main.3 (p2: f32[32], q: pred[]) -> f32[64] {
  %p2 = f32[32]{0} parameter(0)
  %q = pred[] parameter(1)
  ROOT %c = f32[64]{0} conditional(%q, %p2, %p2), true_computation=%true_comp.1, false_computation=%false_comp.1
}
"""


def test_cond_branch_collectives_counted_unscaled():
    """Both arms of a conditional are charged at 1x: the roofline upper
    bound does not know which branch runs, and neither arm is a loop."""
    stats = parse_collectives(COND)
    assert stats.counts == {"all-gather": 1, "all-reduce": 1}
    np.testing.assert_allclose(stats.bytes_by_kind["all-gather"], 64 * 4)
    np.testing.assert_allclose(stats.bytes_by_kind["all-reduce"],
                               32 * 4 * 2)


def test_while_without_known_trip_count_defaults_to_one():
    """Regression: a while lowered WITHOUT backend_config (trip count
    unknowable) must parse gracefully — no crash, trip defaults to 1."""
    unknown = NESTED.replace(
        ', backend_config={"known_trip_count":{"n":"5"}}', "").replace(
        ', backend_config={"known_trip_count":{"n":"3"}}', "")
    assert scan_trip_counts(unknown) == []
    stats = parse_collectives(unknown)
    np.testing.assert_allclose(stats.bytes_by_kind["all-reduce"],
                               64 * 4 * 2)


def test_propagate_multipliers_converges_out_of_order():
    """The shared fixed-point walker (hlo parser + traceaudit): edges
    listed child-first still converge to the product of enclosing trips."""
    nodes = {"root": None, "a": None, "b": None, "c": None, "free": None}
    edges = [("b", "c", 5.0), ("a", "b", 4.0), ("root", "a", 3.0)]
    mult = propagate_multipliers(nodes, edges)
    assert mult == {"root": 1.0, "a": 3.0, "b": 12.0, "c": 60.0,
                    "free": 1.0}
    # an edge to an unknown body is ignored, not an error
    assert propagate_multipliers({"x": None}, [("x", "ghost", 9.0)]) == \
        {"x": 1.0}


def test_real_compile_matches_analytic():
    """End-to-end: compile a scan of matmuls, estimator == closed form."""
    import jax
    import jax.numpy as jnp

    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, None, length=5)[0]

    sx = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    sw = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    comp = jax.jit(f).lower(sx, sw).compile()
    est = estimate_costs(comp.as_text())
    np.testing.assert_allclose(est.flops, 5 * 2 * 64 * 32 * 32)
