"""Multi-host serving layer: routing, transport, cluster solver, harness.

Fast tests cover the deterministic routing/cost model, the env-driven
cluster bring-up fallback, the fault.py-snapshot transport, and the
single-process ClusterBatchSolver parity guarantees (virtual pods force
the reroute path without any second process).  The ``slow``-marked tests
spawn real coordinator+worker processes over localhost through
``tests/_cluster_harness.py`` — including one with an actual
``jax.distributed.initialize`` — and assert the routed stream is
bitwise-identical to the single-process path, with and without a worker
being killed mid-stream.
"""
import os
import subprocess
import sys
import time

import numpy as np
import pytest

import _cluster_harness as harness
from conftest import repo_root, subprocess_env

from repro.core import PDHGOptions
from repro.distributed.fault import load_checkpoint
from repro.lp import random_standard_lp
from repro.runtime import BatchSolver, ClusterBatchSolver
from repro.runtime import cluster as cluster_mod
from repro.runtime.cluster import (
    DirectoryTransport,
    bucket_cost,
    bucket_tag,
    route_buckets,
)

OPTS = PDHGOptions(max_iters=2000, tol=1e-4, check_every=64,
                   lanczos_iters=16)


def _stream():
    return [random_standard_lp(8, 14, seed=0),
            random_standard_lp(10, 18, seed=1),
            random_standard_lp(20, 34, seed=2),
            random_standard_lp(7, 13, seed=3)]


# ------------------------------------------------------------- routing ---

def test_bucket_cost_model():
    """Cost = padded FLOPs per MVM x queue depth; sparse buckets pay for
    stored entries, not the logical dense rectangle."""
    assert bucket_cost(((16, 32), None), 4) == 2 * 16 * 32 * 4
    assert bucket_cost(((128, 256), 512), 4) == 2 * 512 * 4
    # a sparse bucket is cheaper than its dense twin whenever nnz is
    # below the dense cell count
    assert bucket_cost(((128, 256), 512), 4) < \
        bucket_cost(((128, 256), None), 4)


def test_route_buckets_lpt_and_determinism():
    keys = [((64, 64), None), ((16, 32), None), ((8, 16), None)]
    costs = {k: bucket_cost(k, 8) for k in keys}
    routing = route_buckets(costs, 2)
    # the heaviest bucket lands alone; the two lighter ones balance it
    assert routing[((64, 64), None)] == 0
    assert routing[((16, 32), None)] == 1
    assert routing[((8, 16), None)] == 1
    # pure function of (costs, n_pods): insertion order is irrelevant
    shuffled = {k: costs[k] for k in reversed(keys)}
    assert route_buckets(shuffled, 2) == routing
    # single pod: everything local
    assert set(route_buckets(costs, 1).values()) == {0}
    # more pods than buckets: no pod gets two before another gets one
    spread = route_buckets(costs, 8)
    assert len(set(spread.values())) == len(keys)


def test_bucket_tag_distinguishes_sparse_and_dense():
    assert bucket_tag(((16, 32), None)) != bucket_tag(((16, 32), 256))
    assert bucket_tag(((16, 32), None)) == "16x32-dense"
    assert bucket_tag(((16, 32), 256)) == "16x32-nnz256"


# ------------------------------------------------------- cluster init ---

def test_detect_env_requires_complete_spec(monkeypatch):
    for var in ("REPRO_COORDINATOR", "REPRO_NUM_PROCESSES",
                "REPRO_PROCESS_ID"):
        monkeypatch.delenv(var, raising=False)
    assert cluster_mod.detect_env() is None
    monkeypatch.setenv("REPRO_COORDINATOR", "host0:1234")
    assert cluster_mod.detect_env() is None       # partial spec: no cluster
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "2")
    monkeypatch.setenv("REPRO_PROCESS_ID", "1")
    spec = cluster_mod.detect_env()
    assert spec == {"coordinator_address": "host0:1234",
                    "num_processes": 2, "process_id": 1}
    # a 1-process "cluster" is the single-process fallback
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "1")
    assert cluster_mod.detect_env() is None


def test_init_cluster_single_process_fallback(monkeypatch):
    for var in ("REPRO_COORDINATOR", "REPRO_NUM_PROCESSES",
                "REPRO_PROCESS_ID"):
        monkeypatch.delenv(var, raising=False)
    cluster_mod._reset_for_tests()
    try:
        info = cluster_mod.init_cluster("auto")
        assert info.num_processes == 1 and info.process_id == 0
        assert not info.is_multiprocess and info.is_coordinator
        # idempotent: the same resolution comes back
        assert cluster_mod.init_cluster("auto") is info
        assert cluster_mod.pod_count() == 1 and cluster_mod.pod_id() == 0
    finally:
        cluster_mod._reset_for_tests()


def test_detect_env_tolerates_malformed_values(monkeypatch):
    """Stray/typo'd numeric vars mean 'no cluster', never a crash (the
    single-process fallback contract)."""
    monkeypatch.setenv("REPRO_COORDINATOR", "host0:1234")
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "2x")
    monkeypatch.setenv("REPRO_PROCESS_ID", "0")
    assert cluster_mod.detect_env() is None
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "2")
    monkeypatch.setenv("REPRO_PROCESS_ID", "zero")
    assert cluster_mod.detect_env() is None


def test_cluster_solver_multiprocess_requires_shared_transport(monkeypatch,
                                                               tmp_path):
    """A private mkdtemp per pod would silently hide every result from
    the other pods — a live multi-process solver without a shared
    transport dir must fail loudly at construction."""
    monkeypatch.delenv("REPRO_TRANSPORT_DIR", raising=False)
    monkeypatch.setattr(cluster_mod, "pod_count", lambda: 2)
    with pytest.raises(RuntimeError, match="REPRO_TRANSPORT_DIR"):
        ClusterBatchSolver(OPTS, pod=0, n_pods=2)
    # ...but the env var satisfies it
    shared = str(tmp_path / "shared")
    monkeypatch.setenv("REPRO_TRANSPORT_DIR", shared)
    s = ClusterBatchSolver(OPTS, pod=0, n_pods=2)
    assert s.transport.root == shared
    assert not s._owns_transport


def test_cluster_solver_owned_scratch_is_cleaned_per_stream(x64):
    """Single-process virtual-pod serving with no explicit transport
    uses a private scratch dir and leaves nothing behind."""
    lps = [random_standard_lp(8, 14, seed=0)]
    solver = ClusterBatchSolver(OPTS, pod=0, n_pods=2, live_pods=1,
                                straggler_timeout=30.0)
    assert solver._owns_transport
    solver.solve_stream(lps)
    assert os.listdir(solver.transport.root) == []


def test_init_cluster_off_ignores_env(monkeypatch):
    monkeypatch.setenv("REPRO_COORDINATOR", "nowhere:1")
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "4")
    monkeypatch.setenv("REPRO_PROCESS_ID", "2")
    cluster_mod._reset_for_tests()
    try:
        info = cluster_mod.init_cluster("off")
        assert info.num_processes == 1 and not info.initialized
    finally:
        cluster_mod._reset_for_tests()
    with pytest.raises(ValueError, match="auto|off"):
        cluster_mod.init_cluster("definitely")


# ----------------------------------------------------------- transport ---

def test_transport_publish_fetch_and_manifest(tmp_path):
    tr = DirectoryTransport(str(tmp_path))
    key = ((16, 32), None)
    routing = {key: 1, ((8, 16), None): 0}
    tr.publish_manifest(0, routing, {"n_pods": 2})
    ck = tr.fetch_manifest(0)
    assert ck.meta["routing"] == {"16x32-dense": 1, "8x16-dense": 0}
    # nothing published yet: both pods' buckets are pending
    assert tr.pending_from_manifest(0, [0, 1]) == ["16x32-dense",
                                                   "8x16-dense"] or \
        set(tr.pending_from_manifest(0, [0, 1])) == {"16x32-dense",
                                                     "8x16-dense"}
    assert tr.try_fetch_bucket(0, bucket_tag(key)) is None
    tr.publish_bucket(0, bucket_tag(key), 1,
                      {"xs": np.ones((2, 3))}, {"idxs": [0, 1]})
    got = tr.try_fetch_bucket(0, bucket_tag(key))
    np.testing.assert_array_equal(got.arrays["xs"], np.ones((2, 3)))
    assert got.meta["idxs"] == [0, 1] and got.meta["pod"] == 1
    # pod 1's pending list is now empty; pod 0 still owes its bucket
    assert tr.pending_from_manifest(0, [1]) == []
    assert tr.pending_from_manifest(0, [0]) == ["8x16-dense"]
    # streams are isolated
    assert tr.try_fetch_bucket(1, bucket_tag(key)) is None


def test_transport_never_observes_torn_writes(tmp_path):
    """A crash mid-publish leaves a *.tmp the reader never opens."""
    tr = DirectoryTransport(str(tmp_path))
    sd = tr._stream_dir(0)
    with open(os.path.join(sd, "bucket_16x32-dense.npz.tmp"), "wb") as f:
        f.write(b"\x00garbage torn write")
    assert tr.try_fetch_bucket(0, "16x32-dense") is None


# ------------------------------------------- single-process cluster ---

def test_cluster_solver_single_pod_is_base_solver(x64):
    lps = _stream()
    base = BatchSolver(OPTS).solve_stream(lps)
    clus = ClusterBatchSolver(OPTS, n_pods=1).solve_stream(lps)
    for b, c in zip(base, clus):
        assert np.array_equal(b.x, c.x) and np.array_equal(b.y, c.y)
        assert b.merit == c.merit and b.iterations == c.iterations


def test_cluster_solver_virtual_pod_reroute_bitwise(x64, tmp_path):
    """Buckets routed to a pod with no live process are rerouted by the
    coordinator — and the results are bitwise-identical to the
    single-process path (keys derive from global stream positions)."""
    lps = _stream()
    base = BatchSolver(OPTS).solve_stream(lps)
    solver = ClusterBatchSolver(
        OPTS, pod=0, n_pods=2, live_pods=1,
        transport=DirectoryTransport(str(tmp_path)),
        straggler_timeout=30.0)
    routed = solver.solve_stream(lps)
    st = solver.last_stream_stats
    assert st["n_pods"] == 2
    assert st["rerouted_buckets"] > 0          # pod 1 is virtual
    assert st["n_local_buckets"] < st["n_buckets"]
    assert set(st["routing"].values()) == {0, 1}
    for b, c in zip(base, routed):
        assert np.array_equal(b.x, c.x), b.name
        assert np.array_equal(b.y, c.y)
        assert b.merit == c.merit and b.iterations == c.iterations
    # the rerouted buckets were published for (hypothetical) survivors,
    # and the manifest snapshot shows nothing pending anywhere
    assert solver.transport.pending_from_manifest(0, [0, 1]) == []


def test_cluster_solver_repeat_streams_use_fresh_transport_dirs(x64,
                                                                tmp_path):
    """A warm solver serves stream after stream without colliding on the
    transport (per-stream subdirectories) and keeps its executable
    cache across them."""
    lps = _stream()
    solver = ClusterBatchSolver(
        OPTS, pod=0, n_pods=2, live_pods=1,
        transport=DirectoryTransport(str(tmp_path)),
        straggler_timeout=30.0)
    first = solver.solve_stream(lps)
    misses = solver.cache_misses
    second = solver.solve_stream(lps)
    assert solver.cache_misses == misses       # warm: no recompilation
    for a, b in zip(first, second):
        assert np.array_equal(a.x, b.x)
    assert solver.stream_seq == 2


def test_cluster_solver_gather_timeout_raises(x64, tmp_path):
    """A non-coordinator pod that never receives a remote bucket fails
    loudly (StragglerTimeout) instead of hanging forever."""
    from repro.runtime.cluster import StragglerTimeout

    lps = _stream()
    solver = ClusterBatchSolver(
        OPTS, pod=1, n_pods=2, live_pods=2,
        transport=DirectoryTransport(str(tmp_path)),
        straggler_timeout=0.2, gather_timeout=1.0)
    with pytest.raises(StragglerTimeout):
        solver.solve_stream(lps)


# ------------------------------------------------- multi-process harness ---

def _wait(proc: subprocess.Popen, timeout: float) -> str:
    try:
        out, _ = proc.communicate(timeout=timeout)
    except subprocess.TimeoutExpired:
        proc.kill()
        out, _ = proc.communicate()
        pytest.fail(f"harness pod timed out; output so far:\n{out}")
    return out


def _single_process_reference():
    """The bitwise ground truth, computed in-process on the SAME stream
    the harness pods rebuild from (n, seed)."""
    lps = harness.build_stream()
    results = BatchSolver(harness.harness_opts()).solve_stream(lps)
    return harness.results_arrays(lps, results)


@pytest.mark.slow
def test_harness_two_process_routed_stream_bitwise(x64, tmp_path):
    """Coordinator + worker over localhost (real jax.distributed
    bring-up): a mixed 16-instance stream routed across 2 pods returns
    results bitwise-identical to single-process ``solve_stream``."""
    out = str(tmp_path / "final.npz")
    env = subprocess_env()
    coord = f"localhost:{harness.free_port()}"
    procs = [harness.spawn_pod(p, 2, str(tmp_path / "transport"),
                               out=out, jaxdist=coord, env=env,
                               straggler_timeout=180.0)
             for p in (1, 0)]
    logs = [_wait(p, 600) for p in procs]
    for p, log in zip(procs, logs):
        assert p.returncode == 0, log
    log0 = logs[1]
    assert "HARNESS JAXDIST OK" in log0, log0
    assert "HARNESS POD0 DONE" in log0, log0
    ck = load_checkpoint(out)
    # both pods actually served something
    assert set(ck.meta["routing"].values()) == {0, 1}, ck.meta
    assert ck.meta["rerouted"] == 0, ck.meta
    ref = _single_process_reference()
    for k, v in ref.items():
        np.testing.assert_array_equal(ck.arrays[k], v, err_msg=k)


@pytest.mark.slow
def test_harness_worker_killed_mid_stream_reroutes(x64, tmp_path):
    """Kill the worker mid-stream (stalled before publishing anything):
    the coordinator's straggler policy reroutes the worker's pending
    buckets through the manifest snapshot and the final iterates are
    STILL bitwise-identical to the single-process path."""
    out = str(tmp_path / "final.npz")
    env = subprocess_env()
    tdir = str(tmp_path / "transport")
    worker = harness.spawn_pod(1, 2, tdir, stall_after=0, env=env)
    time.sleep(2.0)                    # worker is now solving or stalled
    worker.kill()                      # ... either way: dead mid-stream
    coord = harness.spawn_pod(0, 2, tdir, out=out, straggler_timeout=5.0,
                              env=env)
    log0 = _wait(coord, 600)
    worker.communicate()
    assert coord.returncode == 0, log0
    assert "HARNESS POD0 DONE" in log0, log0
    ck = load_checkpoint(out)
    assert ck.meta["rerouted"] > 0, (ck.meta, log0)
    ref = _single_process_reference()
    for k, v in ref.items():
        np.testing.assert_array_equal(ck.arrays[k], v, err_msg=k)


@pytest.mark.slow
def test_launch_solve_cluster_flags_smoke():
    """--cluster auto (single-process fallback) + --pods 2 virtual
    routing through the CLI."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.solve", "--backend", "batch",
         "--pods", "2", "--cluster", "auto",
         "--instances", "rand:8x14,rand:10x18",
         "--max-iters", "500", "--tol", "1e-3"],
        env=subprocess_env(), cwd=repo_root(), capture_output=True,
        text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "cluster: pod=0/2" in proc.stdout, proc.stdout
    assert "routing=" in proc.stdout
