"""End-to-end behaviour tests for the paper's system.

The full pipeline of Figure 1: model preparation (Ruiz + preconditioning)
-> encode-once to the accelerator -> Lanczos step sizing -> PDHG -> KKT
stopping -> unscale, on every backend (exact / noisy / crossbar-sim /
distributed), validated against ground truth (bundled simplex or
constructed optima)."""
import jax
import numpy as np
import pytest

from repro.core import NoiseModel, PDHGOptions, solve, solve_jit
from repro.crossbar import EPIRAM, TAOX_HFOX, solve_crossbar_jit
from repro.crossbar.array import crossbar_accel_factory
from repro.lp import (
    assignment_lp,
    pagerank_lp,
    random_standard_lp,
    simplex,
    table1_instance,
)


def test_pipeline_exact_vs_simplex(x64):
    """Figure-1 'first function': RRAM-solver answer vs ground truth."""
    lp = table1_instance("gen-ip002")
    gt = simplex.solve(lp)
    assert gt.status == "optimal"
    r = solve_jit(lp, PDHGOptions(max_iters=40000, tol=1e-7))
    assert r.status == "optimal"
    assert abs(r.obj - gt.obj) / abs(gt.obj) < 1e-5


def test_pipeline_all_table1_instances(x64):
    """Every Table-1-shaped instance solves to its known optimum."""
    for name in ("gen-ip016", "gen-ip021", "gen-ip036", "gen-ip054"):
        lp = table1_instance(name)
        r = solve_jit(lp, PDHGOptions(max_iters=60000, tol=1e-6))
        rel = abs(r.obj - lp.obj_opt) / abs(lp.obj_opt)
        assert rel < 1e-4, (name, rel, r.status)


def test_pipeline_noisy_backend_converges(x64):
    lp = random_standard_lp(16, 28, seed=0)
    r = solve(lp, PDHGOptions(max_iters=12000, tol=1e-4, check_every=100),
              noise=NoiseModel("multiplicative", 1e-3))
    rel = abs(r.obj - lp.obj_opt) / abs(lp.obj_opt)
    assert rel < 2e-2


def test_pipeline_crossbar_host_loop(x64):
    """Full device-physics path through Algorithm 2 host iterations."""
    lp = random_standard_lp(12, 20, seed=1)
    fac = crossbar_accel_factory(device=TAOX_HFOX)
    r = solve(lp, PDHGOptions(max_iters=6000, tol=1e-4, check_every=100,
                              lanczos_iters=24), accel_factory=fac)
    rel = abs(r.obj - lp.obj_opt) / abs(lp.obj_opt)
    # conductance quantization + programming error perturb the problem
    # itself; the paper's Table-2 gaps reach 2.98e-2 — same band here
    assert rel < 5e-2
    led = fac.ledger
    assert led.mvm_count == r.mvm_calls
    assert led.write_energy_j > 0 and led.read_energy_j > 0


def test_pipeline_crossbar_jit_both_devices(x64):
    lp = random_standard_lp(16, 28, seed=2)
    for dev in (EPIRAM, TAOX_HFOX):
        rep = solve_crossbar_jit(
            lp, PDHGOptions(max_iters=15000, tol=1e-5, check_every=100,
                            lanczos_iters=32), device=dev)
        rel = abs(rep.result.obj - lp.obj_opt) / abs(lp.obj_opt)
        assert rel < 5e-2, (dev.name, rel)   # paper Table-2 gap band


def test_assignment_lp_integral_solution(x64):
    """Assignment LP optimum is integral (total unimodularity)."""
    lp = assignment_lp(4, seed=0)
    r = solve_jit(lp, PDHGOptions(max_iters=40000, tol=1e-7))
    gt = simplex.solve(lp)
    assert abs(r.obj - gt.obj) / abs(gt.obj) < 1e-4
    X = r.x.reshape(4, 4)
    assert np.allclose(X.sum(0), 1, atol=1e-3)
    assert np.allclose(X.sum(1), 1, atol=1e-3)
    assert np.all((X < 1e-2) | (X > 1 - 1e-2))   # integral


def test_pagerank_lp(x64):
    lp = pagerank_lp(64, seed=0)
    r = solve_jit(lp, PDHGOptions(max_iters=40000, tol=1e-7))
    assert r.status == "optimal"
    assert abs(r.x.sum() - 1.0) < 1e-4            # pagerank sums to 1
    assert np.all(r.x >= -1e-8)


def test_energy_factors_match_paper_magnitudes(x64):
    """The headline claim: orders-of-magnitude energy savings vs GPU.

    Uses the same cost models as the benchmark harness; asserts the
    factor ranges of Tables 2-3 (10x..5000x energy, >=1x latency for the
    PDHG phase on TaOx-HfOx)."""
    from repro.crossbar import Ledger, RTX6000

    lp = random_standard_lp(24, 41, seed=3)
    m, n = lp.K.shape
    opts = PDHGOptions(max_iters=15000, tol=1e-5, check_every=100,
                       lanczos_iters=32)
    rep = solve_crossbar_jit(lp, opts, device=TAOX_HFOX)
    gpu = Ledger()
    res = solve_jit(lp, opts)
    RTX6000.h2d(8 * (m * n + m + n), gpu)
    for _ in range(res.iterations):
        RTX6000.pdhg_iteration(m, n, gpu)
    e_factor = gpu.total_energy_j / rep.ledger.total_energy_j
    t_factor = gpu.total_latency_s / rep.ledger.total_latency_s
    assert e_factor > 10, e_factor
    assert t_factor > 1, t_factor
