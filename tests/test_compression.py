"""Quantized-collective (``distributed.compression``) and stacked-batch
(``distributed.batch_solve``) contracts.

Round-trip / error-bound properties of the int8 pipeline, unbiasedness
of the stochastic-rounding mode, and parity of the compressed psum with
the exact (uncompressed) collective at high bit width — plus the
stacked same-shape serving path against the single-instance solver.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import PDHGOptions, solve_jit
from repro.distributed import solve_batch, stack_problems
from repro.distributed.compression import (
    _stochastic_round,
    compressed_psum,
    dequantize_int8,
    quantize_int8,
)
from repro.lp import random_standard_lp
from repro.runtime import compat
from repro.runtime.mesh import make_mesh


def _x(n=256, seed=0, scale=3.0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(scale=scale, size=n),
        jnp.float32)


# -------------------------------------------------- (de)quantization ---

def test_quantize_int8_error_bound():
    """Deterministic rounding lands within half a quantization step
    everywhere (no clipping bias: the max-abs element maps to ±127)."""
    x = _x()
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    back = dequantize_int8(q, scale)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert err.max() <= 0.5 * float(scale) * (1 + 1e-6)
    # the extreme element is represented exactly at the grid edge
    i = int(jnp.argmax(jnp.abs(x)))
    assert abs(int(q[i])) == 127


def test_quantize_roundtrip_exact_on_grid():
    """Values already on the int8 grid survive the round trip exactly."""
    ints = jnp.arange(-127, 128, dtype=jnp.float32)
    q, scale = quantize_int8(ints)
    np.testing.assert_array_equal(np.asarray(q), np.arange(-127, 128))
    np.testing.assert_allclose(np.asarray(dequantize_int8(q, scale)),
                               np.asarray(ints), rtol=1e-6)


def test_stochastic_round_is_unbiased():
    """E[stochastic_round(x)] == x: the mean over many keys converges to
    the unquantized value (this is what preserves Assumption 2)."""
    x = jnp.asarray([0.25, 1.75, -2.4, 3.0, -0.1], jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(0), 4096)
    rounded = jax.vmap(lambda k: _stochastic_round(x, k))(keys)
    mean = np.asarray(rounded).mean(axis=0)
    # integers round to themselves, always
    np.testing.assert_array_equal(np.asarray(rounded)[:, 3], 3.0)
    np.testing.assert_allclose(mean, np.asarray(x), atol=0.05)


# ----------------------------------------------------- compressed psum ---

def _psum_fn(bits, with_key=False):
    mesh = make_mesh({"data": 1})
    if with_key:
        return compat.shard_map(
            lambda x, k: compressed_psum(x, "data", key=k, bits=bits),
            mesh=mesh, in_specs=(P(), P()), out_specs=P(),
            check_vma=False)
    return compat.shard_map(
        lambda x: compressed_psum(x, "data", bits=bits),
        mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False)


def test_compressed_psum_error_bound_and_monotone_bits():
    """Per-element error stays within half a step of the GLOBAL scale,
    and more bits mean a finer grid (monotonically tighter error)."""
    x = _x(seed=1)
    errs = {}
    for bits in (4, 8, 16):
        out = _psum_fn(bits)(x)
        qmax = 2.0 ** (bits - 1) - 1.0
        scale = float(jnp.max(jnp.abs(x))) / qmax
        err = np.abs(np.asarray(out) - np.asarray(x))
        assert err.max() <= 0.5 * scale * (1 + 1e-5), bits
        errs[bits] = err.max()
    assert errs[16] < errs[8] < errs[4]


def test_compressed_psum_parity_with_exact_collective():
    """At high bit width the quantized collective matches the exact
    psum to float32 round-off — compression is lossless in the limit."""
    x = _x(seed=2)
    mesh = make_mesh({"data": 1})
    exact = compat.shard_map(lambda v: jax.lax.psum(v, "data"),
                             mesh=mesh, in_specs=(P(),), out_specs=P(),
                             check_vma=False)(x)
    compressed = _psum_fn(24)(x)
    np.testing.assert_allclose(np.asarray(compressed), np.asarray(exact),
                               rtol=1e-5, atol=1e-5)


def test_compressed_psum_stochastic_mode_unbiased():
    x = _x(n=64, seed=3)
    f = _psum_fn(6, with_key=True)
    keys = jax.random.split(jax.random.PRNGKey(1), 512)
    outs = np.stack([np.asarray(f(x, k)) for k in keys[:128]])
    qmax = 2.0 ** 5 - 1.0
    scale = float(jnp.max(jnp.abs(x))) / qmax
    # mean error well under the worst-case half-step of a single draw
    np.testing.assert_allclose(outs.mean(axis=0), np.asarray(x),
                               atol=0.25 * scale)


def test_compressed_psum_int32_accumulation_is_exact():
    """The transport sum runs in int32 (bit-exact associativity): on a
    1-device axis the output is exactly dequantize(quantize(x))."""
    x = _x(seed=4)
    out = _psum_fn(8)(x)
    q, scale = quantize_int8(x)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(q.astype(jnp.float32) * scale))


# ------------------------------------------------------- batch_solve ---

BATCH_OPTS = PDHGOptions(max_iters=20000, tol=1e-6, check_every=64)


def test_solve_batch_parity_with_single_instance(x64):
    """The stacked same-shape path agrees with per-instance solve_jit
    on every component of the result dict."""
    lps = [random_standard_lp(8, 14, seed=s) for s in (0, 1, 2)]
    mesh = make_mesh({"data": 1})
    Ks, bs, cs, lbs, ubs = stack_problems(lps)
    out = solve_batch(Ks, bs, cs, lbs, ubs, mesh, BATCH_OPTS)
    assert out["x"].shape == (3, 14) and out["y"].shape == (3, 8)
    assert out["converged"].all()
    for k, lp in enumerate(lps):
        single = solve_jit(lp, BATCH_OPTS)
        obj = float(lp.c @ out["x"][k])
        assert abs(obj - single.obj) / max(abs(single.obj), 1e-12) < 1e-4
        assert abs(obj - lp.obj_opt) / abs(lp.obj_opt) < 1e-4
        assert out["merit"][k] <= BATCH_OPTS.tol


def test_solve_batch_deterministic_and_seeded(x64):
    """Same call -> identical arrays; different seed -> different
    trajectories (per-instance keys split from opts.seed)."""
    lps = [random_standard_lp(8, 14, seed=4)] * 2
    mesh = make_mesh({"data": 1})
    stacked = stack_problems(lps)
    short = PDHGOptions(max_iters=128, tol=1e-30, check_every=64)
    a = solve_batch(*stacked, mesh, short)
    b = solve_batch(*stacked, mesh, short)
    np.testing.assert_array_equal(a["x"], b["x"])
    c = solve_batch(*stacked, mesh,
                    PDHGOptions(max_iters=128, tol=1e-30, check_every=64,
                                seed=11))
    assert not np.allclose(a["x"], c["x"])
    # instances in one stack follow distinct trajectories
    assert not np.allclose(a["x"][0], a["x"][1])


def test_solve_batch_rejects_mismatched_stack(x64):
    """Stacked arrays must agree on B (shape errors surface as the
    assertion/lowering error, not silent truncation)."""
    lps = [random_standard_lp(8, 14, seed=s) for s in (0, 1)]
    mesh = make_mesh({"data": 1})
    Ks, bs, cs, lbs, ubs = stack_problems(lps)
    with pytest.raises(Exception):
        solve_batch(Ks[:1], bs, cs, lbs, ubs, mesh, BATCH_OPTS)
