"""MPS reader/writer: roundtrip + solve-equivalence + format corners."""
import numpy as np
import pytest

from repro.lp import mps, random_inequality_lp_known
from repro.lp.generators import lp_known_objective


def test_mps_roundtrip_preserves_problem(tmp_path):
    lp = random_inequality_lp_known(6, 9, seed=0)
    path = str(tmp_path / "t.mps")
    mps.write(lp, path)
    lp2 = mps.read(path)
    np.testing.assert_allclose(lp2.c, lp.c, rtol=1e-12)
    np.testing.assert_allclose(lp2.G, lp.G, rtol=1e-12)
    np.testing.assert_allclose(lp2.h, lp.h, rtol=1e-12)
    np.testing.assert_allclose(lp2.lb, lp.lb)
    np.testing.assert_allclose(lp2.ub, lp.ub)


def test_mps_roundtrip_solves_to_same_optimum(tmp_path, x64):
    from repro.core import PDHGOptions, solve_jit

    lp = random_inequality_lp_known(8, 12, seed=1)
    obj = lp_known_objective(lp)
    path = str(tmp_path / "t.mps")
    mps.write(lp, path)
    lp2 = mps.read(path)
    r = solve_jit(lp2.to_standard(), PDHGOptions(max_iters=30000, tol=1e-6))
    assert abs(r.obj - obj) / abs(obj) < 1e-4


FIXTURE = """* tiny knapsack-ish LP
NAME          TINY
ROWS
 N  COST
 L  CAP
 G  MIN
 E  FIX
COLUMNS
    X0  COST  1.0   CAP  2.0
    X0  MIN   1.0
    X1  COST  -3.0  CAP  1.0
    X1  FIX   1.0
    MARKER1  'MARKER'  'INTORG'
    X2  COST  0.5   CAP  1.0   MIN  1.0
    MARKER2  'MARKER'  'INTEND'
RHS
    RHS  CAP  10.0   MIN  1.0
    RHS  FIX  2.5
BOUNDS
 UP BND  X0  4.0
 BV BND  X2
ENDATA
"""


def test_mps_fixture_parse():
    lp = mps.parse(FIXTURE)
    assert lp.n == 3
    np.testing.assert_allclose(lp.c, [1.0, -3.0, 0.5])
    # L row becomes -row >= -rhs; G row kept
    assert lp.m1 == 2
    assert lp.m2 == 1                  # the E row
    np.testing.assert_allclose(lp.b, [2.5])
    np.testing.assert_allclose(lp.ub, [4.0, np.inf, 1.0])  # BV -> [0,1]
    np.testing.assert_allclose(lp.lb, [0.0, 0.0, 0.0])


def test_mps_fixture_solves(x64):
    """LP relaxation of the fixture has a verifiable optimum.

    min x0 - 3 x1 + 0.5 x2  s.t. 2x0 + x1 + x2 <= 10, x0 + x2 >= 1,
    x1 = 2.5, 0<=x0<=4, x2 in [0,1].
    Optimal: x1=2.5 fixed; minimize x0 + 0.5 x2 with x0 + x2 >= 1
    => x2=1 (cost .5) beats x0=1 (cost 1): x=(0, 2.5, 1), obj=-7.0.
    """
    from repro.core import PDHGOptions, solve_jit

    lp = mps.parse(FIXTURE)
    r = solve_jit(lp.to_standard(), PDHGOptions(max_iters=30000, tol=1e-7))
    assert abs(r.obj - (-7.0)) < 1e-3
