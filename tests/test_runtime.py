"""Runtime portability layer: compat shims, mesh API, bucketed batching.

The compat tests must pass on BOTH JAX generations (0.4.x and the
explicit-sharding >=0.6 line) — they assert behaviour, not which branch
of the shim was taken."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PDHGOptions, solve_jit
from repro.lp import random_standard_lp
from repro.runtime import BatchSolver, compat, solve_stream
from repro.runtime.batch import bucket_dims, pad_problem, stack_problems
from repro.runtime.mesh import make_local_mesh, make_mesh

OPTS = PDHGOptions(max_iters=20000, tol=1e-6, check_every=64)


# ------------------------------------------------------------ compat ---

def test_compat_shims_resolve_on_installed_jax():
    """Every shim is callable on whatever JAX this container has."""
    # mesh construction never needs the (possibly absent) AxisType
    mesh = compat.make_mesh((1,), ("data",))
    assert tuple(mesh.axis_names) == ("data",)
    # ambient-mesh query degrades to "no mesh", never AttributeError
    amb = compat.get_abstract_mesh()
    assert amb is None or hasattr(amb, "axis_names")
    # feature flags are booleans and coherent: new-API names either all
    # exist (new JAX) or the fallbacks must be importable (old JAX)
    if not compat.HAS_TOPLEVEL_SHARD_MAP:
        from jax.experimental.shard_map import shard_map  # noqa: F401
    assert isinstance(compat.HAS_AXIS_TYPE, bool)


def test_compat_constrain_no_mesh_is_identity():
    x = jnp.ones((4, 8))
    out = compat.constrain(x, "data", None)
    assert out is x or np.array_equal(np.asarray(out), np.asarray(x))


def test_compat_use_mesh_scopes_ambient_mesh():
    mesh = make_mesh({"data": 1})
    with compat.use_mesh(mesh):
        assert compat.mesh_axis_names() == ("data",)
        assert compat.batch_axes() == ("data",)
        # constraining against the ambient mesh works inside jit
        y = jax.jit(lambda v: compat.constrain(v, "data") * 2)(jnp.ones(4))
        np.testing.assert_array_equal(np.asarray(y), 2 * np.ones(4))
    assert "data" not in compat.mesh_axis_names()


def test_compat_shard_map_psum():
    mesh = make_mesh({"data": 1})
    from jax.sharding import PartitionSpec as P

    f = compat.shard_map(
        lambda x: jax.lax.psum(x, "data"), mesh=mesh,
        in_specs=P("data"), out_specs=P(), check_vma=False)
    out = f(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0))


# -------------------------------------------------------------- mesh ---

def test_make_mesh_roundtrips_axes_single_device():
    mesh = make_mesh({"data": 1, "model": 1})
    assert tuple(mesh.axis_names) == ("data", "model")
    assert tuple(mesh.devices.shape) == (1, 1)
    legacy = make_mesh((1, 1), ("data", "model"))
    assert tuple(legacy.axis_names) == tuple(mesh.axis_names)
    pairs = make_mesh([("data", 1), ("model", 1)])
    assert tuple(pairs.axis_names) == ("data", "model")


def test_make_mesh_capacity_error_names_the_fallback():
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_mesh({"data": 4096, "model": 4096})


def test_make_local_mesh_covers_all_devices():
    mesh = make_local_mesh()
    assert mesh.devices.size == len(jax.devices())
    assert tuple(mesh.axis_names) == ("data", "model")


def test_make_production_mesh_pod_axis_from_process_count(monkeypatch):
    """Regression (ISSUE 5): ``multi_pod=True`` used to hard-code a
    2-pod axis regardless of how many processes the cluster actually
    has.  The pod axis must now derive from the process count (the old
    2 survives only as the single-process dry-run default)."""
    from repro.runtime import cluster, mesh as rmesh

    # single process: legacy 2-pod dry-run grid
    monkeypatch.setattr(cluster, "pod_count", lambda: 1)
    assert rmesh._default_pod_count() == 2
    # multi-process: one pod per process
    for n in (2, 3, 8):
        monkeypatch.setattr(cluster, "pod_count", lambda n=n: n)
        assert rmesh._default_pod_count() == n
    # the derived count reaches the mesh: with a shrunken per-pod grid
    # the pod axis is exactly the process count (build it if this host
    # has the devices; otherwise the capacity error must name it)
    monkeypatch.setattr(cluster, "pod_count", lambda: 3)
    try:
        mesh = rmesh.make_production_mesh(multi_pod=True, grid=(1, 1))
        assert tuple(mesh.devices.shape) == (3, 1, 1)
        assert tuple(mesh.axis_names) == ("pod", "data", "model")
    except RuntimeError as e:
        assert "'pod': 3" in str(e)
    # explicit override beats derivation
    mesh1 = rmesh.make_production_mesh(multi_pod=True, pods=1, grid=(1, 1))
    assert tuple(mesh1.devices.shape) == (1, 1, 1)


def test_make_cluster_mesh_single_process_fallback():
    """Single-process: a 1-pod mesh over all local devices, so callers
    need no separate code path."""
    from repro.runtime.mesh import make_cluster_mesh

    mesh = make_cluster_mesh()
    assert tuple(mesh.axis_names) == ("pod", "data", "model")
    assert mesh.shape["pod"] == max(1, jax.process_count())
    assert mesh.devices.size == len(jax.devices())


@pytest.mark.slow
def test_make_mesh_multidevice_subprocess():
    """make_mesh round-trips axis names/sizes on 8 fan-out CPU devices."""
    from conftest import repo_root, subprocess_env

    script = textwrap.dedent("""
        from repro.runtime import compat
        assert compat.request_cpu_devices(8)
        import jax
        from repro.runtime.mesh import make_mesh
        mesh = make_mesh({"pod": 2, "data": 2, "model": 2})
        assert tuple(mesh.axis_names) == ("pod", "data", "model")
        assert tuple(mesh.devices.shape) == (2, 2, 2)
        assert len(jax.devices()) == 8
        print("MESH PASS")
    """)
    proc = subprocess.run(
        [sys.executable, "-c", script], env=subprocess_env(),
        cwd=repo_root(), capture_output=True, text=True, timeout=300)
    assert "MESH PASS" in proc.stdout, proc.stdout + proc.stderr


# ------------------------------------------------------------- batch ---

def test_bucket_dims_power_of_two():
    assert bucket_dims(8, 14) == (8, 16)
    assert bucket_dims(9, 16) == (16, 16)
    assert bucket_dims(1, 1) == (8, 8)          # floor
    assert bucket_dims(129, 300) == (256, 512)


def test_bucket_dims_device_tile_mode():
    """Tile mode snaps to multiples of the physical crossbar dims."""
    assert bucket_dims(8, 70, tile=(64, 64)) == (64, 128)
    assert bucket_dims(64, 64, tile=(64, 64)) == (64, 64)
    assert bucket_dims(65, 1, tile=(64, 32)) == (128, 32)
    assert bucket_dims(1, 1, tile=(64, 64)) == (64, 64)
    # device models feed their geometry straight in
    from repro.crossbar import EPIRAM
    tile = (EPIRAM.crossbar_rows, EPIRAM.crossbar_cols)
    assert bucket_dims(20, 70, tile=tile) == (64, 128)


def test_pad_problem_preserves_optimum(x64):
    lp = random_standard_lp(8, 14, seed=3)
    padded = pad_problem(lp, 16, 32)
    assert padded.K.shape == (16, 32)
    r = solve_jit(padded, OPTS)
    rel = abs(r.obj - lp.obj_opt) / abs(lp.obj_opt)
    assert r.status == "optimal" and rel < 1e-4


def test_pad_problem_preserves_dtype():
    """Regression (ISSUE 4): padding used to allocate ``np.zeros`` in
    the default float64 regardless of ``lp.K.dtype``, doubling host
    memory for f32 streams before the device cast."""
    from repro.lp import StandardLP

    rng = np.random.default_rng(0)
    lp32 = StandardLP(
        c=rng.normal(size=14).astype(np.float32),
        K=rng.normal(size=(8, 14)).astype(np.float32),
        b=rng.normal(size=8).astype(np.float32),
        lb=np.zeros(14, np.float32), ub=np.full(14, np.inf, np.float32))
    assert lp32.K.dtype == np.float32          # StandardLP preserves f32
    padded = pad_problem(lp32, 16, 32)
    for field in ("K", "b", "c", "lb", "ub"):
        assert getattr(padded, field).dtype == np.float32, field
    # f64 problems still pad in f64
    padded64 = pad_problem(random_standard_lp(8, 14, seed=0), 16, 32)
    assert padded64.K.dtype == np.float64
    # and stacking follows the padded dtype (no silent promotion)
    Ks, bs, cs, lbs, ubs = stack_problems([lp32, lp32])
    assert Ks.dtype == np.float32 and cs.dtype == np.float32


def test_solve_stream_async_matches_sync(x64):
    """Submit-all-then-collect dispatch returns the SAME results as
    blocking per-bucket serving (async is pure scheduling, not math)."""
    lps = [
        random_standard_lp(8, 14, seed=0),
        random_standard_lp(10, 18, seed=1),
        random_standard_lp(20, 34, seed=2),
        random_standard_lp(7, 13, seed=3),
    ]
    opts = PDHGOptions(max_iters=2000, tol=1e-4, check_every=64,
                       lanczos_iters=16)
    r_async = BatchSolver(opts).solve_stream(lps)
    r_sync = BatchSolver(opts, async_dispatch=False).solve_stream(lps)
    for a, s in zip(r_async, r_sync):
        assert a.name == s.name and a.iterations == s.iterations
        np.testing.assert_allclose(a.x, s.x)
        assert a.merit == s.merit


def test_solve_stream_records_stream_stats(x64):
    """Every solve_stream call audits what it stacked and when it
    dispatched/collected (the serving observability surface)."""
    solver = BatchSolver(PDHGOptions(max_iters=128, tol=1e-30,
                                     check_every=64, lanczos_iters=8))
    solver.solve_stream([random_standard_lp(8, 14, seed=0),
                         random_standard_lp(20, 34, seed=1)])
    st = solver.last_stream_stats
    assert st["n_buckets"] == 2
    assert st["dense_stack_bytes"] > 0
    assert st["sparse_stack_bytes"] == 0
    assert st["dispatch_s"] >= 0 and st["collect_s"] >= 0


def test_stack_problems_legacy_max_shape():
    lps = [random_standard_lp(8, 14, seed=0), random_standard_lp(6, 11, seed=1)]
    Ks, bs, cs, lbs, ubs = stack_problems(lps)
    assert Ks.shape == (2, 8, 14) and cs.shape == (2, 14)
    # padded variables are pinned at zero
    assert np.all(lbs[1, 11:] == 0) and np.all(ubs[1, 11:] == 0)


def test_solve_stream_mixed_shapes_matches_single_solve(x64):
    """>= 3 distinct-shape LPs in ONE call, each matching the
    single-solve objective to <= 1e-4 relative gap."""
    lps = [
        random_standard_lp(8, 14, seed=0),
        random_standard_lp(10, 18, seed=1),
        random_standard_lp(20, 34, seed=2),
        random_standard_lp(7, 13, seed=3),
    ]
    assert len({lp.K.shape for lp in lps}) >= 3
    results = solve_stream(lps, OPTS)
    assert [r.name for r in results] == [lp.name for lp in lps]
    for lp, r in zip(lps, results):
        single = solve_jit(lp, OPTS)
        assert r.converged, (lp.K.shape, r.merit)
        assert abs(r.obj - single.obj) / max(abs(single.obj), 1e-12) < 1e-4
        assert abs(r.obj - lp.obj_opt) / abs(lp.obj_opt) < 1e-4
        assert r.x.shape == (lp.K.shape[1],)
        assert r.y.shape == (lp.K.shape[0],)


def test_solve_stream_executable_cache_hits_on_repeat_shapes(x64):
    solver = BatchSolver(OPTS)
    first = solver.solve_stream([random_standard_lp(8, 14, seed=0),
                                 random_standard_lp(7, 13, seed=1)])
    assert solver.cache_info() == {"hits": 0, "misses": 1, "entries": 1}
    # same bucket, same batch size, new instances -> compiled reuse
    second = solver.solve_stream([random_standard_lp(6, 12, seed=2),
                                  random_standard_lp(8, 15, seed=3)])
    assert solver.cache_hits == 1 and solver.cache_misses == 1
    # a genuinely new bucket still compiles
    third = solver.solve_stream([random_standard_lp(20, 40, seed=4)] * 2)
    assert solver.cache_misses == 2
    for r in first + second + third:
        assert r.converged


def test_solve_stream_on_mesh(x64):
    """The zero-collective data-parallel path through an explicit mesh."""
    mesh = make_mesh({"data": 1})
    lps = [random_standard_lp(8, 14, seed=s) for s in range(3)]
    results = solve_stream(lps, OPTS, mesh=mesh)
    for lp, r in zip(lps, results):
        assert abs(r.obj - lp.obj_opt) / abs(lp.obj_opt) < 1e-4


def test_batch_instances_get_distinct_streams(x64):
    """Regression: every instance in a bucket used to share PRNGKey(1),
    giving identical inits and read-noise streams.  Two copies of the
    SAME problem must now follow different trajectories."""
    lp = random_standard_lp(8, 14, seed=4)
    opts = PDHGOptions(max_iters=128, tol=1e-30, check_every=64)
    solver = BatchSolver(opts, sigma_read=0.01)
    r = solver.solve_stream([lp, lp])
    assert not np.allclose(r[0].x, r[1].x)
    assert r[0].merit != r[1].merit


def test_batch_sigma_read_is_applied(x64):
    """Regression: the batched path used to drop ``sigma_read`` on the
    floor (always solving noiselessly)."""
    lp = random_standard_lp(8, 14, seed=5)
    opts = PDHGOptions(max_iters=256, tol=1e-30, check_every=64)
    clean = BatchSolver(opts).solve_stream([lp])[0]
    noisy = BatchSolver(opts, sigma_read=0.05).solve_stream([lp])[0]
    assert not np.allclose(clean.x, noisy.x)


def test_batch_seed_reaches_bucket_pipeline(x64):
    """opts.seed drives the per-instance keys of the compiled pipeline."""
    lp = random_standard_lp(8, 14, seed=6)
    mk = lambda s: PDHGOptions(  # noqa: E731
        max_iters=128, tol=1e-30, check_every=64, seed=s)
    r0 = BatchSolver(mk(0)).solve_stream([lp])[0]
    r0b = BatchSolver(mk(0)).solve_stream([lp])[0]
    r1 = BatchSolver(mk(7)).solve_stream([lp])[0]
    np.testing.assert_allclose(r0.x, r0b.x)
    assert not np.allclose(r0.x, r1.x)


# --------------------------------------------------- crossbar streaming ---

CB_OPTS = PDHGOptions(max_iters=2000, tol=1e-3, check_every=64,
                      lanczos_iters=16)


def test_crossbar_stream_bucket_reuse_and_cache(x64):
    """Device-tile-aware serving: distinct shapes share one tile bucket,
    encode+solve compiles once per (bucket, batch, device) signature,
    and per-instance ledgers survive."""
    from repro.crossbar import EPIRAM, TAOX_HFOX, CrossbarBatchSolver

    solver = CrossbarBatchSolver(CB_OPTS, device=EPIRAM)
    lps = [random_standard_lp(8, 14, seed=0), random_standard_lp(7, 12, seed=1)]
    reports = solver.solve_stream(lps)
    assert solver.cache_info() == {"hits": 0, "misses": 1, "entries": 1}
    for lp, rep in zip(lps, reports):
        assert rep.result.x.shape == (lp.K.shape[1],)
        rel = abs(rep.result.obj - lp.obj_opt) / abs(lp.obj_opt)
        assert rel < 5e-2      # device physics (quantization + read noise)
        assert rep.ledger.write_energy_j > 0
        assert rep.ledger.write_energy_padding_j > 0   # 64x64 tile, small LP
        assert rep.ledger.mvm_count == rep.lanczos_mvms + rep.pdhg_mvms

    # same tile bucket + batch size, new instances -> compiled reuse
    solver.solve_stream([random_standard_lp(9, 13, seed=2),
                         random_standard_lp(6, 10, seed=3)])
    assert solver.cache_info() == {"hits": 1, "misses": 1, "entries": 1}

    # the executable cache key carries the device model
    other = CrossbarBatchSolver(CB_OPTS, device=TAOX_HFOX)
    other.solve_stream([random_standard_lp(8, 14, seed=0),
                        random_standard_lp(7, 12, seed=1)])
    assert other.cache_misses == 1
    assert set(other._cache).isdisjoint(set(solver._cache))


def test_crossbar_stream_rectangular_tiles_ledger_whole_tiles(x64):
    """With non-square tiles the symmetric block M lands mid-tile in one
    dimension; the ledger must still account whole physical tiles."""
    import dataclasses as dc

    from repro.crossbar import EPIRAM, CrossbarBatchSolver

    dev = dc.replace(EPIRAM, name="rect", crossbar_rows=32, crossbar_cols=16)
    opts = PDHGOptions(max_iters=128, tol=1.0, check_every=64,
                       lanczos_iters=4)
    lp = random_standard_lp(8, 14, seed=0)      # bucket (32, 16), M is 48x48
    rep = CrossbarBatchSolver(opts, device=dev).solve_stream([lp])[0]
    # M tile-pads to (64, 48): rows to 2x32, cols already 3x16
    assert rep.ledger.cells_written == 2 * 64 * 48
    assert rep.ledger.cells_written_padding == 2 * (64 * 48 - (8 + 14) ** 2)


def test_crossbar_stream_matches_per_instance_jit(x64):
    """Batched encode->solve agrees with the single-instance crossbar
    path on a mixed-shape stream (both sit at the device noise floor)."""
    from repro.crossbar import TAOX_HFOX, solve_crossbar_jit, \
        solve_crossbar_stream

    lps = [
        random_standard_lp(8, 14, seed=0),
        random_standard_lp(10, 18, seed=3),
        random_standard_lp(16, 28, seed=4),
        random_standard_lp(20, 70, seed=2),     # second tile bucket
    ]
    opts = PDHGOptions(max_iters=8000, tol=1e-4, check_every=64,
                       lanczos_iters=32)
    reports = solve_crossbar_stream(lps, opts, device=TAOX_HFOX)
    tile = (TAOX_HFOX.crossbar_rows, TAOX_HFOX.crossbar_cols)
    for lp, rep in zip(lps, reports):
        single = solve_crossbar_jit(
            pad_problem(lp, *bucket_dims(*lp.K.shape, tile=tile)),
            opts, device=TAOX_HFOX)
        assert rep.result.x.shape == (lp.K.shape[1],)
        rel_b = abs(rep.result.obj - lp.obj_opt) / abs(lp.obj_opt)
        rel_s = abs(single.result.obj - lp.obj_opt) / abs(lp.obj_opt)
        assert rel_b < 5e-2, (lp.name, rel_b)
        assert rel_s < 5e-2, (lp.name, rel_s)
        agree = abs(rep.result.obj - single.result.obj) \
            / max(abs(single.result.obj), 1e-12)
        assert agree < 1e-1, (lp.name, agree)
