"""Runtime portability layer: compat shims, mesh API, bucketed batching.

The compat tests must pass on BOTH JAX generations (0.4.x and the
explicit-sharding >=0.6 line) — they assert behaviour, not which branch
of the shim was taken."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PDHGOptions, solve_jit
from repro.lp import random_standard_lp
from repro.runtime import BatchSolver, compat, solve_stream
from repro.runtime.batch import bucket_dims, pad_problem, stack_problems
from repro.runtime.mesh import make_local_mesh, make_mesh

OPTS = PDHGOptions(max_iters=20000, tol=1e-6, check_every=64)


# ------------------------------------------------------------ compat ---

def test_compat_shims_resolve_on_installed_jax():
    """Every shim is callable on whatever JAX this container has."""
    # mesh construction never needs the (possibly absent) AxisType
    mesh = compat.make_mesh((1,), ("data",))
    assert tuple(mesh.axis_names) == ("data",)
    # ambient-mesh query degrades to "no mesh", never AttributeError
    amb = compat.get_abstract_mesh()
    assert amb is None or hasattr(amb, "axis_names")
    # feature flags are booleans and coherent: new-API names either all
    # exist (new JAX) or the fallbacks must be importable (old JAX)
    if not compat.HAS_TOPLEVEL_SHARD_MAP:
        from jax.experimental.shard_map import shard_map  # noqa: F401
    assert isinstance(compat.HAS_AXIS_TYPE, bool)


def test_compat_constrain_no_mesh_is_identity():
    x = jnp.ones((4, 8))
    out = compat.constrain(x, "data", None)
    assert out is x or np.array_equal(np.asarray(out), np.asarray(x))


def test_compat_use_mesh_scopes_ambient_mesh():
    mesh = make_mesh({"data": 1})
    with compat.use_mesh(mesh):
        assert compat.mesh_axis_names() == ("data",)
        assert compat.batch_axes() == ("data",)
        # constraining against the ambient mesh works inside jit
        y = jax.jit(lambda v: compat.constrain(v, "data") * 2)(jnp.ones(4))
        np.testing.assert_array_equal(np.asarray(y), 2 * np.ones(4))
    assert "data" not in compat.mesh_axis_names()


def test_compat_shard_map_psum():
    mesh = make_mesh({"data": 1})
    from jax.sharding import PartitionSpec as P

    f = compat.shard_map(
        lambda x: jax.lax.psum(x, "data"), mesh=mesh,
        in_specs=P("data"), out_specs=P(), check_vma=False)
    out = f(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0))


# -------------------------------------------------------------- mesh ---

def test_make_mesh_roundtrips_axes_single_device():
    mesh = make_mesh({"data": 1, "model": 1})
    assert tuple(mesh.axis_names) == ("data", "model")
    assert tuple(mesh.devices.shape) == (1, 1)
    legacy = make_mesh((1, 1), ("data", "model"))
    assert tuple(legacy.axis_names) == tuple(mesh.axis_names)
    pairs = make_mesh([("data", 1), ("model", 1)])
    assert tuple(pairs.axis_names) == ("data", "model")


def test_make_mesh_capacity_error_names_the_fallback():
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_mesh({"data": 4096, "model": 4096})


def test_make_local_mesh_covers_all_devices():
    mesh = make_local_mesh()
    assert mesh.devices.size == len(jax.devices())
    assert tuple(mesh.axis_names) == ("data", "model")


@pytest.mark.slow
def test_make_mesh_multidevice_subprocess():
    """make_mesh round-trips axis names/sizes on 8 fan-out CPU devices."""
    from conftest import repo_root, subprocess_env

    script = textwrap.dedent("""
        from repro.runtime import compat
        assert compat.request_cpu_devices(8)
        import jax
        from repro.runtime.mesh import make_mesh
        mesh = make_mesh({"pod": 2, "data": 2, "model": 2})
        assert tuple(mesh.axis_names) == ("pod", "data", "model")
        assert tuple(mesh.devices.shape) == (2, 2, 2)
        assert len(jax.devices()) == 8
        print("MESH PASS")
    """)
    proc = subprocess.run(
        [sys.executable, "-c", script], env=subprocess_env(),
        cwd=repo_root(), capture_output=True, text=True, timeout=300)
    assert "MESH PASS" in proc.stdout, proc.stdout + proc.stderr


# ------------------------------------------------------------- batch ---

def test_bucket_dims_power_of_two():
    assert bucket_dims(8, 14) == (8, 16)
    assert bucket_dims(9, 16) == (16, 16)
    assert bucket_dims(1, 1) == (8, 8)          # floor
    assert bucket_dims(129, 300) == (256, 512)


def test_pad_problem_preserves_optimum(x64):
    lp = random_standard_lp(8, 14, seed=3)
    padded = pad_problem(lp, 16, 32)
    assert padded.K.shape == (16, 32)
    r = solve_jit(padded, OPTS)
    rel = abs(r.obj - lp.obj_opt) / abs(lp.obj_opt)
    assert r.status == "optimal" and rel < 1e-4


def test_stack_problems_legacy_max_shape():
    lps = [random_standard_lp(8, 14, seed=0), random_standard_lp(6, 11, seed=1)]
    Ks, bs, cs, lbs, ubs = stack_problems(lps)
    assert Ks.shape == (2, 8, 14) and cs.shape == (2, 14)
    # padded variables are pinned at zero
    assert np.all(lbs[1, 11:] == 0) and np.all(ubs[1, 11:] == 0)


def test_solve_stream_mixed_shapes_matches_single_solve(x64):
    """>= 3 distinct-shape LPs in ONE call, each matching the
    single-solve objective to <= 1e-4 relative gap."""
    lps = [
        random_standard_lp(8, 14, seed=0),
        random_standard_lp(10, 18, seed=1),
        random_standard_lp(20, 34, seed=2),
        random_standard_lp(7, 13, seed=3),
    ]
    assert len({lp.K.shape for lp in lps}) >= 3
    results = solve_stream(lps, OPTS)
    assert [r.name for r in results] == [lp.name for lp in lps]
    for lp, r in zip(lps, results):
        single = solve_jit(lp, OPTS)
        assert r.converged, (lp.K.shape, r.merit)
        assert abs(r.obj - single.obj) / max(abs(single.obj), 1e-12) < 1e-4
        assert abs(r.obj - lp.obj_opt) / abs(lp.obj_opt) < 1e-4
        assert r.x.shape == (lp.K.shape[1],)
        assert r.y.shape == (lp.K.shape[0],)


def test_solve_stream_executable_cache_hits_on_repeat_shapes(x64):
    solver = BatchSolver(OPTS)
    first = solver.solve_stream([random_standard_lp(8, 14, seed=0),
                                 random_standard_lp(7, 13, seed=1)])
    assert solver.cache_info() == {"hits": 0, "misses": 1, "entries": 1}
    # same bucket, same batch size, new instances -> compiled reuse
    second = solver.solve_stream([random_standard_lp(6, 12, seed=2),
                                  random_standard_lp(8, 15, seed=3)])
    assert solver.cache_hits == 1 and solver.cache_misses == 1
    # a genuinely new bucket still compiles
    third = solver.solve_stream([random_standard_lp(20, 40, seed=4)] * 2)
    assert solver.cache_misses == 2
    for r in first + second + third:
        assert r.converged


def test_solve_stream_on_mesh(x64):
    """The zero-collective data-parallel path through an explicit mesh."""
    mesh = make_mesh({"data": 1})
    lps = [random_standard_lp(8, 14, seed=s) for s in range(3)]
    results = solve_stream(lps, OPTS, mesh=mesh)
    for lp, r in zip(lps, results):
        assert abs(r.obj - lp.obj_opt) / abs(lp.obj_opt) < 1e-4


def test_crossbar_stream_bucket_reuse(x64):
    """Crossbar serving path: distinct shapes share a bucket trace and
    keep their per-instance ledgers."""
    from repro.crossbar import EPIRAM, solve_crossbar_stream

    lps = [random_standard_lp(8, 14, seed=0), random_standard_lp(7, 12, seed=1)]
    reports = solve_crossbar_stream(lps, OPTS, device=EPIRAM)
    for lp, rep in zip(lps, reports):
        assert rep.result.x.shape == (lp.K.shape[1],)
        rel = abs(rep.result.obj - lp.obj_opt) / abs(lp.obj_opt)
        assert rel < 5e-2      # device physics (quantization + read noise)
        assert rep.ledger.write_energy_j > 0
