"""Shared fixtures.  NOTE: never set xla_force_host_platform_device_count
here — smoke tests and benches must see the real single device; only the
dry-run subprocess uses 512 fake devices."""
import os

import jax
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (subprocess dry-runs, multi-device)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


def repo_root():
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def subprocess_env():
    """Environment for ``python -m repro...`` / -c subprocess tests.

    The repo's ``src`` must be importable regardless of the caller's cwd,
    so the path is absolute and any pre-existing PYTHONPATH is preserved.
    """
    env = dict(os.environ)
    src = os.path.join(repo_root(), "src")
    extra = env.get("PYTHONPATH")
    env["PYTHONPATH"] = src + (os.pathsep + extra if extra else "")
    return env


@pytest.fixture
def x64():
    """Enable f64 for precision-sensitive LP assertions, then restore."""
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


@pytest.fixture
def rng():
    import numpy as np

    return np.random.default_rng(0)
