"""Shared fixtures.  NOTE: never set xla_force_host_platform_device_count
here — smoke tests and benches must see the real single device; only the
dry-run subprocess uses 512 fake devices."""
import jax
import pytest


@pytest.fixture
def x64():
    """Enable f64 for precision-sensitive LP assertions, then restore."""
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


@pytest.fixture
def rng():
    import numpy as np

    return np.random.default_rng(0)
