"""Property tests for the ``runtime.batch`` bucketing invariants.

Runs under real ``hypothesis`` when installed (the CI distributed job)
and under the deterministic stand-in of ``tests/_hypothesis_compat``
otherwise, so the sweeps always execute.

Invariants:
  * ``bucket_dims(tile=...)`` snaps UP to the smallest whole-tile
    multiple — never below the logical dims, never skipping a tile.
  * default ``bucket_dims`` is the enclosing power of two (floored at
    ``min_size``), idempotent on its own outputs.
  * ``nnz_bucket`` is a monotone power-of-two step function.
  * sparse and dense buckets can never share an executable-cache key,
    whatever their dims.
"""
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core import PDHGOptions
from repro.runtime.batch import (
    MIN_BUCKET,
    MIN_NNZ_BUCKET,
    BatchSolver,
    bucket_dims,
    nnz_bucket,
)

DIMS = st.integers(min_value=1, max_value=4096)
TILES = st.integers(min_value=1, max_value=512)
NNZ = st.integers(min_value=1, max_value=1 << 20)


def _is_pow2(v: int) -> bool:
    return v >= 1 and (v & (v - 1)) == 0


@settings(max_examples=200)
@given(m=DIMS, n=DIMS, tr=TILES, tc=TILES)
def test_tile_mode_snaps_up_to_tile_multiples(m, n, tr, tc):
    mb, nb = bucket_dims(m, n, tile=(tr, tc))
    # whole tiles only
    assert mb % tr == 0 and nb % tc == 0
    # never below the logical dims
    assert mb >= m and nb >= n
    # minimal: one tile less would not fit
    assert mb - tr < m and nb - tc < n
    # idempotent: a bucket is its own bucket
    assert bucket_dims(mb, nb, tile=(tr, tc)) == (mb, nb)


@settings(max_examples=200)
@given(m=DIMS, n=DIMS)
def test_default_mode_is_minimal_enclosing_power_of_two(m, n):
    mb, nb = bucket_dims(m, n)
    assert _is_pow2(mb) and _is_pow2(nb)
    assert mb >= max(m, MIN_BUCKET) and nb >= max(n, MIN_BUCKET)
    # minimal: halving drops below the dim (or the floor)
    assert mb // 2 < m or mb == MIN_BUCKET
    assert nb // 2 < n or nb == MIN_BUCKET
    assert bucket_dims(mb, nb) == (mb, nb)


@settings(max_examples=200)
@given(a=NNZ, b=NNZ)
def test_nnz_bucket_monotone_power_of_two(a, b):
    ba, bb = nnz_bucket(a), nnz_bucket(b)
    assert _is_pow2(ba) and _is_pow2(bb)
    assert ba >= max(a, MIN_NNZ_BUCKET) and ba // 2 < max(a, MIN_NNZ_BUCKET)
    if a <= b:                       # monotone step function
        assert ba <= bb
    assert nnz_bucket(ba) == ba      # idempotent on bucket values


@settings(max_examples=100)
@given(m=DIMS, n=DIMS, nnz=NNZ, B=st.integers(min_value=1, max_value=64))
def test_sparse_and_dense_buckets_never_share_cache_keys(m, n, nnz, B):
    """Whatever the dims, a sparse signature can never collide with a
    dense one (the executables take different argument layouts)."""
    solver = BatchSolver(PDHGOptions())
    mb, nb = bucket_dims(m, n)
    kd = solver._cache_key(("dense", mb, nb), B, np.float64, False)
    ks = solver._cache_key(("sparse", mb, nb, nnz_bucket(nnz)), B,
                           np.float64, False)
    assert kd != ks
    # and the tags stay distinct even if nnz numerically equals a dim
    ks2 = solver._cache_key(("sparse", mb, nb, nb), B, np.float64, False)
    assert kd != ks2


@settings(max_examples=50)
@given(m=DIMS, n=DIMS, tr=TILES, tc=TILES)
def test_tile_and_pow2_buckets_agree_when_tile_is_pow2_multiple(m, n, tr,
                                                                tc):
    """Sanity cross-check: tile mode with a (1, 1) tile is the identity
    ceiling (no padding at all)."""
    assert bucket_dims(m, n, tile=(1, 1)) == (max(m, 1), max(n, 1))
    mb, nb = bucket_dims(m, n, tile=(tr, tc))
    assert (mb // tr) == -(-m // tr) and (nb // tc) == -(-n // tc)
