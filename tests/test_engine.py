"""Engine parity: ONE iteration core, N operator backends, 2 update
backends.

Every solver path (host / jit / batch / crossbar / distributed) runs
``core.engine``'s step; these tests pin that the backends agree iterate-
for-iterate in exact mode, that the MVM-ledger accounting is the single
``engine.mvm_accounting`` formula everywhere, and that the ``kernel``
flag (jnp vs fused Pallas) never leaks across executable caches."""
import dataclasses as dc
import inspect
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import NoiseModel, PDHGOptions, engine, solve, solve_jit
from repro.core.pdhg import opts_static, prepare
from repro.core.symblock import encode_exact
from repro.lp import random_standard_lp
from repro.runtime import BatchSolver


def _prepped(seed=0, m=8, n=14):
    """Common preconditioned problem + exact operator norm."""
    lp = random_standard_lp(m, n, seed=seed)
    scaled, T, Sigma = prepare(lp, PDHGOptions())
    Keff = np.sqrt(np.asarray(Sigma))[:, None] * np.asarray(scaled.K) \
        * np.sqrt(np.asarray(T))[None, :]
    rho = float(np.linalg.svd(Keff, compute_uv=False)[0])
    return lp, scaled, T, Sigma, rho


# ------------------------------------------------------ backend parity ---

def test_engine_backends_identical_iterates(x64):
    """host-style accel / jit dense / vmapped batch / fused Pallas all run
    the SAME seeded 2-check exact-mode solve to identical iterates."""
    _, scaled, T, Sigma, rho = _prepped(seed=0)
    m, n = scaled.K.shape
    b, c, lb, ub = scaled.b, scaled.c, scaled.lb, scaled.ub
    key = jax.random.PRNGKey(42)
    static = (128, 1e-30, 0.95, 1.0, 0.0, 64, 0.5, 0.0, "jnp")

    # (a) jitted dense engine (the solve_jit / batch core)
    core = jax.jit(engine.solve_core, static_argnums=(10,))
    x_jit, y_jit, it_jit, _ = core(scaled.K, scaled.K.T, b, c, lb, ub,
                                   T, Sigma, rho, key, static)
    assert int(it_jit) == 128

    # (b) eager host-style engine over an Accel handle
    op = engine.accel_operator(encode_exact(scaled.K))
    key2, x0, y0 = engine.draw_init(key, m, n, lb, ub, scaled.K.dtype)
    x_acc, y_acc, _, _ = engine.pdhg_loop(
        op, engine.JNP_UPDATES, b, c, lb, ub, T, Sigma, x0, y0,
        0.95 / rho, 0.95 * rho / rho**2, key2,
        max_iters=128, tol=1e-30, gamma=0.0, check_every=64,
        restart_beta=0.5)

    # (c) vmapped batch-of-2 engine; slot 0 carries the same key
    keys = jnp.stack([key, jax.random.PRNGKey(7)])
    xs, ys, _, _ = jax.jit(jax.vmap(
        lambda k: engine.solve_core(scaled.K, scaled.K.T, b, c, lb, ub,
                                    T, Sigma, rho, k, static)))(keys)

    # (d) fused Pallas update backend
    x_pal, y_pal, _, _ = core(scaled.K, scaled.K.T, b, c, lb, ub,
                              T, Sigma, rho, key,
                              static[:-1] + ("pallas",))

    for tag, (xv, yv) in {
        "accel": (x_acc, y_acc),
        "batch": (xs[0], ys[0]),
        "pallas": (x_pal, y_pal),
    }.items():
        np.testing.assert_allclose(np.asarray(xv), np.asarray(x_jit),
                                   rtol=1e-12, atol=1e-12, err_msg=tag)
        np.testing.assert_allclose(np.asarray(yv), np.asarray(y_jit),
                                   rtol=1e-12, atol=1e-12, err_msg=tag)
    # distinct key in slot 1 => genuinely different trajectory
    assert not np.allclose(np.asarray(xs[1]), np.asarray(x_jit))


def test_pdhg_loop_reports_merit_of_returned_iterate(x64):
    """Regression (ISSUE 4): the check block used to carry
    ``min(merit, merit_avg)``, adopting the AVERAGED iterate's merit even
    when ``use_avg`` was False — so a stream whose averaged merit dips
    below the current iterate's (without being adopted) exited reporting
    a residual the returned solution does not satisfy, and every jitted
    path derived ``converged``/``status`` from that lie.

    The contrived residual_fn below distinguishes the two evaluations
    structurally (the averaged check passes x_prev == x): the averaged
    merit (0.5) dips below the current one (2.0) but stays above tol
    with restarts disabled, so the average is never adopted — the loop
    must report 2.0, the merit of the iterate it actually returns.
    """
    _, scaled, T, Sigma, rho = _prepped(seed=3)
    m, n = scaled.K.shape
    op = engine.dense_operator(scaled.K, scaled.K.T)
    key, x0, y0 = engine.draw_init(jax.random.PRNGKey(0), m, n,
                                   scaled.lb, scaled.ub, scaled.K.dtype)

    def residual_fn(x, x_prev, y, Kx, KTy):
        is_avg = jnp.all(x == x_prev)
        return jnp.where(is_avg, jnp.asarray(0.5, x.dtype),
                         jnp.asarray(2.0, x.dtype))

    x, y, it, merit = engine.pdhg_loop(
        op, engine.JNP_UPDATES, scaled.b, scaled.c, scaled.lb, scaled.ub,
        T, Sigma, x0, y0, 0.95 / rho, 0.95 / rho, key,
        max_iters=8, tol=0.1, gamma=0.0, check_every=8,
        restart_beta=0.0, residual_fn=residual_fn)
    assert int(it) == 8
    # the returned iterate's merit, NOT the (lower) unadopted average's
    assert float(merit) == 2.0
    assert not float(merit) <= 0.1          # must not claim convergence


def test_pdhg_loop_adopted_average_reports_average_merit(x64):
    """Counterpart: when the averaged iterate IS adopted (its merit
    beats tol), the reported merit must be the average's — the returned
    vector satisfies it."""
    _, scaled, T, Sigma, rho = _prepped(seed=3)
    m, n = scaled.K.shape
    op = engine.dense_operator(scaled.K, scaled.K.T)
    key, x0, y0 = engine.draw_init(jax.random.PRNGKey(0), m, n,
                                   scaled.lb, scaled.ub, scaled.K.dtype)

    def residual_fn(x, x_prev, y, Kx, KTy):
        is_avg = jnp.all(x == x_prev)
        return jnp.where(is_avg, jnp.asarray(0.05, x.dtype),
                         jnp.asarray(2.0, x.dtype))

    x, y, it, merit = engine.pdhg_loop(
        op, engine.JNP_UPDATES, scaled.b, scaled.c, scaled.lb, scaled.ub,
        T, Sigma, x0, y0, 0.95 / rho, 0.95 / rho, key,
        max_iters=64, tol=0.1, gamma=0.0, check_every=8,
        restart_beta=0.0, residual_fn=residual_fn)
    # averaged merit 0.05 <= tol -> average adopted, loop exits truthfully
    assert int(it) == 8
    assert float(merit) == 0.05


def test_solve_jit_kernel_pallas_matches_jnp(x64):
    """Public API: the fused-Pallas executable reproduces the jnp one."""
    lp = random_standard_lp(8, 14, seed=1)
    mk = lambda k: PDHGOptions(  # noqa: E731
        max_iters=256, tol=1e-30, check_every=64, kernel=k)
    r_jnp = solve_jit(lp, mk("jnp"))
    r_pal = solve_jit(lp, mk("pallas"))
    np.testing.assert_allclose(r_pal.x, r_jnp.x, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(r_pal.y, r_jnp.y, rtol=1e-9, atol=1e-12)
    assert r_pal.iterations == r_jnp.iterations
    assert r_pal.mvm_calls == r_jnp.mvm_calls


def test_batch_solver_kernel_parity_and_cache_isolation(x64):
    """BatchSolver(kernel="pallas") matches jnp to fp tolerance and the
    executable cache signatures never collide across kernels."""
    lp = random_standard_lp(8, 14, seed=2)
    opts = PDHGOptions(max_iters=128, tol=1e-30, check_every=64)
    s_jnp = BatchSolver(opts)
    s_pal = BatchSolver(opts, kernel="pallas")
    r_jnp = s_jnp.solve_stream([lp])[0]
    r_pal = s_pal.solve_stream([lp])[0]
    np.testing.assert_allclose(r_pal.x, r_jnp.x, rtol=1e-9, atol=1e-12)
    assert r_pal.mvm_calls == r_jnp.mvm_calls > 0
    assert s_pal.opts.kernel == "pallas"
    # kernel choice is part of the signature: no silent cross-kernel hits
    assert set(s_jnp._cache).isdisjoint(set(s_pal._cache))
    assert opts_static(s_jnp.opts)[8] != opts_static(s_pal.opts)[8]


def test_crossbar_pallas_operator_matches_dense_decode(x64):
    """kernel="pallas" routes the crossbar pipeline's MVMs through the
    differential-pair Pallas kernel against the programmed M; with read
    noise off, iterates must match the dense-decode path."""
    from repro.crossbar import EPIRAM, CrossbarBatchSolver

    dev = dc.replace(EPIRAM, name="epiram-quiet", sigma_read=0.0)
    lp = random_standard_lp(10, 18, seed=3)
    opts = PDHGOptions(max_iters=128, tol=1e-30, check_every=64,
                       lanczos_iters=8)
    rep_jnp = CrossbarBatchSolver(opts, device=dev).solve_stream([lp])[0]
    rep_pal = CrossbarBatchSolver(opts, device=dev,
                                  kernel="pallas").solve_stream([lp])[0]
    np.testing.assert_allclose(rep_pal.result.x, rep_jnp.result.x,
                               rtol=1e-8, atol=1e-10)
    assert rep_pal.ledger.mvm_count == rep_jnp.ledger.mvm_count
    # noisy device still converges through the kernel operator
    noisy = CrossbarBatchSolver(
        PDHGOptions(max_iters=2000, tol=1e-3, check_every=64,
                    lanczos_iters=16),
        device=EPIRAM, kernel="pallas").solve_stream([lp])[0]
    rel = abs(noisy.result.obj - lp.obj_opt) / abs(lp.obj_opt)
    assert rel < 5e-2, rel


def test_dist_matches_jit_iterates_on_single_device_mesh(x64):
    """The shard_map path runs the same engine loop: on an unpadded
    1-device mesh its iterates coincide with solve_jit (restart off so
    the psum-reduced merit formula cannot steer the trajectory)."""
    from repro.distributed.pdhg_dist import solve_dist
    from repro.launch.mesh import make_mesh

    lp = random_standard_lp(10, 18, seed=0)
    opts = PDHGOptions(max_iters=128, tol=1e-30, check_every=64,
                       restart=False)
    mesh = make_mesh((1, 1), ("data", "model"))
    r_dist = solve_dist(lp, mesh, opts)
    r_jit = solve_jit(lp, opts)
    assert r_dist.iterations == r_jit.iterations == 128
    np.testing.assert_allclose(r_dist.x, r_jit.x, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(r_dist.y, r_jit.y, rtol=1e-9, atol=1e-12)


# -------------------------------------------------------- MVM ledger ---

def test_mvm_accounting_is_the_single_formula_everywhere(x64):
    """jit / batch / dist / crossbar all report engine.mvm_accounting."""
    from repro.crossbar import TAOX_HFOX, CrossbarBatchSolver
    from repro.distributed.pdhg_dist import solve_dist
    from repro.launch.mesh import make_mesh

    lp = random_standard_lp(8, 14, seed=4)
    opts = PDHGOptions(max_iters=512, tol=1e-30, check_every=64)

    r_jit = solve_jit(lp, opts)
    assert r_jit.mvm_calls == engine.mvm_accounting(
        r_jit.iterations, opts.check_every, opts.lanczos_iters)

    r_b = BatchSolver(opts).solve_stream([lp])[0]
    assert r_b.mvm_calls == engine.mvm_accounting(
        r_b.iterations, opts.check_every, opts.lanczos_iters)

    r_d = solve_dist(lp, make_mesh((1, 1), ("data", "model")), opts)
    assert r_d.mvm_calls == engine.mvm_accounting(
        r_d.iterations, opts.check_every, opts.lanczos_iters)

    rep = CrossbarBatchSolver(
        PDHGOptions(max_iters=256, tol=1e-30, check_every=64,
                    lanczos_iters=16),
        device=TAOX_HFOX).solve_stream([lp])[0]
    assert rep.result.mvm_calls == engine.mvm_accounting(
        rep.result.iterations, 64, 16)
    assert rep.ledger.mvm_count == rep.lanczos_mvms + rep.pdhg_mvms


# ---------------------------------------- noisy residual checks (jit) ---

def test_jit_and_host_merits_agree_in_distribution_under_read_noise(x64):
    """Regression: the jitted merit check used noiseless K products while
    the host path (and the 4-MVMs-per-check ledger charge) issues NOISY
    device MVMs.  Both paths now measure the same noise-floor merit: at
    sigma_read=0.05 the final in-loop merits must agree in distribution
    (same decade), and sit clearly above the clean tolerance."""
    lp = random_standard_lp(8, 14, seed=2)
    sigma = 0.05
    host_merits, jit_merits = [], []
    for s in range(4):
        opts = PDHGOptions(max_iters=384, tol=1e-12, check_every=64,
                           seed=s)
        r_h = solve(lp, opts,
                    noise=NoiseModel(kind="multiplicative", sigma=sigma))
        host_merits.append(float(r_h.residuals.max))
        r_j = solve_jit(lp, opts, sigma_read=sigma)
        jit_merits.append(r_j.merit)
    gmean = lambda v: float(np.exp(np.mean(np.log(v))))  # noqa: E731
    gh, gj = gmean(host_merits), gmean(jit_merits)
    assert gj < 10 * gh and gh < 10 * gj, (host_merits, jit_merits)
    # the noise floor is visible to the jitted check (a noiseless check
    # would let merit collapse toward the true residual of the average)
    assert min(jit_merits) > 1e-6, jit_merits


# ------------------------------------------------- interpret defaults ---

def test_padded_kernel_wrappers_autodetect_interpret():
    """Regression: the low-level ``*_padded`` wrappers hardcoded
    interpret=True — a real-TPU caller would silently run interpreted.
    They now default through the shared backend detection."""
    from repro.kernels import crossbar_mvm as xbar
    from repro.kernels import interpret_default, ops
    from repro.kernels import pdhg_update as upd

    for fn in (xbar.crossbar_mvm_padded, upd.primal_update_padded,
               upd.dual_update_padded):
        default = inspect.signature(fn).parameters["interpret"].default
        assert default is None, fn
    assert ops._interpret_default() is interpret_default()
    assert interpret_default() == (jax.default_backend() == "cpu")
    # and the auto-detected default actually runs on this backend
    col = jnp.ones((upd.BLOCK, 1), jnp.float32)
    out = upd.dual_update_padded(col, 0 * col, col, col,
                                 jnp.ones((1, 1), jnp.float32))
    np.testing.assert_allclose(np.asarray(out), 2 * np.ones((upd.BLOCK, 1)))


# ------------------------------------------------------- single home ---

def test_step_math_lives_only_in_engine():
    """Acceptance guard: the PDHG half-iteration (extrapolation / theta
    adaptation) appears in core/engine.py and NOWHERE else."""
    root = pathlib.Path(repro.__file__).parent
    for rel in ("core/pdhg.py", "runtime/batch.py",
                "distributed/pdhg_dist.py", "crossbar/solver.py"):
        src = (root / rel).read_text()
        assert "theta_k * (x - x_prev)" not in src, rel
        assert "jnp.sqrt(1.0 + 2.0" not in src, rel
    assert "jnp.sqrt(1.0 + 2.0" in (root / "core/engine.py").read_text()


# ------------------------------------------------------------- launch ---

def test_launch_solve_kernel_flag(x64, capsys):
    """--kernel pallas runs green end-to-end on CPU (interpret mode)."""
    from repro.launch.solve import main

    res = main(["--instance", "rand:6x10", "--backend", "exact",
                "--kernel", "pallas", "--max-iters", "2000",
                "--tol", "1e-4"])
    assert res.status == "optimal"
    out = capsys.readouterr().out
    assert "status=optimal" in out

    results = main(["--backend", "batch", "--kernel", "pallas",
                    "--instances", "rand:6x10,rand:8x12",
                    "--max-iters", "2000", "--tol", "1e-4"])
    assert all(r.converged for r in results)


# ------------------------------------- restart flag + megakernel mode ---

def test_restart_false_matches_legacy_nan_trick_bitwise(x64):
    """``restart=False`` rides as an explicit static boolean.  The old
    encoding (restart_beta=0.0) only worked because ``0.0 * inf == NaN``
    and NaN comparisons are false inside the jitted body; the explicit
    flag must reproduce it bitwise — same iterates, same merit — and the
    average is provably never adopted (a restart=True run on the same
    seed differs)."""
    _, scaled, T, Sigma, rho = _prepped(seed=3)
    b, c, lb, ub = scaled.b, scaled.c, scaled.lb, scaled.ub
    key = jax.random.PRNGKey(7)
    core = jax.jit(engine.solve_core, static_argnums=(10,))
    args = (scaled.K, scaled.K.T, b, c, lb, ub, T, Sigma, rho, key)

    legacy = (512, 1e-30, 0.95, 1.0, 0.0, 64, 0.0, 0.0, "jnp")
    flag = (512, 1e-30, 0.95, 1.0, 0.0, 64, 0.5, 0.0, "jnp",
            False, "ell", False)
    on = (512, 1e-30, 0.95, 1.0, 0.0, 64, 0.5, 0.0, "jnp",
          True, "ell", False)

    x_leg, y_leg, it_leg, m_leg = core(*args, legacy)
    x_off, y_off, it_off, m_off = core(*args, flag)
    x_on, y_on, _, _ = core(*args, on)

    assert int(it_leg) == int(it_off)
    np.testing.assert_array_equal(np.asarray(x_leg), np.asarray(x_off))
    np.testing.assert_array_equal(np.asarray(y_leg), np.asarray(y_off))
    np.testing.assert_array_equal(np.asarray(m_leg), np.asarray(m_off))
    # the flag is live: restarts DO change the trajectory on this seed
    assert not np.array_equal(np.asarray(x_on), np.asarray(x_off))


def test_mvm_accounting_restart_flag_and_batch_ledger(x64):
    """restart=False residual checks cost 2 MVMs (no averaged-iterate
    pair); every reporting surface charges the flag it actually ran."""
    assert engine.mvm_accounting(128, 64, 16) \
        == engine.mvm_accounting(128, 64, 16, restart=True)
    assert engine.mvm_accounting(128, 64, 16, restart=True) \
        - engine.mvm_accounting(128, 64, 16, restart=False) == 2 * 2

    lp = random_standard_lp(8, 14, seed=4)
    opts = PDHGOptions(max_iters=256, tol=1e-30, check_every=64,
                       restart=False)
    r = solve_jit(lp, opts)
    assert r.mvm_calls == engine.mvm_accounting(
        r.iterations, opts.check_every, opts.lanczos_iters, restart=False)
    rb = BatchSolver(opts).solve_stream([lp])[0]
    assert rb.mvm_calls == engine.mvm_accounting(
        rb.iterations, opts.check_every, opts.lanczos_iters, restart=False)


def test_dense_megakernel_matches_per_step_loop(x64):
    """megakernel=True fuses each check_every window into ONE launch
    (restart/residual check hoisted out) — iterates must match the
    per-step loop to fp tolerance at sigma_read=0, with the identical
    iteration count."""
    lp = random_standard_lp(10, 18, seed=6)
    opts = PDHGOptions(max_iters=2000, tol=1e-6, check_every=64)
    mega = dc.replace(opts, megakernel=True)
    r_ref = solve_jit(lp, opts)
    r_meg = solve_jit(lp, mega)
    assert r_meg.iterations == r_ref.iterations
    assert r_meg.status == r_ref.status
    np.testing.assert_allclose(r_meg.x, r_ref.x, atol=1e-9, rtol=1e-9)
    np.testing.assert_allclose(r_meg.y, r_ref.y, atol=1e-9, rtol=1e-9)


def test_megakernel_rejects_read_noise():
    """Per-MVM noise keys cannot be split inside a fused launch; the
    static-tuple builder refuses the combination up front."""
    with pytest.raises(ValueError, match="noiseless-only"):
        opts_static(PDHGOptions(megakernel=True), 0.05)


def test_megakernel_batch_cache_key_disjoint(x64):
    """The megakernel flag is part of the executable cache key: serving
    the same bucket with and without it must compile twice, never
    cross-serve."""
    lp = random_standard_lp(8, 14, seed=1)
    opts = PDHGOptions(max_iters=128, tol=1e-30, check_every=64)
    solver = BatchSolver(opts)
    solver.solve_stream([lp])
    solver_m = BatchSolver(dc.replace(opts, megakernel=True))
    solver_m.solve_stream([lp])
    assert set(solver._cache).isdisjoint(set(solver_m._cache))
    assert opts_static(solver.opts) != opts_static(solver_m.opts)
