"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret=True on CPU per the container contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

SHAPES = [(64, 64), (128, 128), (128, 256), (200, 300), (65, 65),
          (256, 192), (1, 129)]
DTYPES = [jnp.float32]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_crossbar_mvm_matches_ref(shape, dtype):
    R, C = shape
    key = jax.random.PRNGKey(R * 1000 + C)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    gp = jax.random.uniform(k1, (R, C), dtype)
    gn = jax.random.uniform(k2, (R, C), dtype)
    v = jax.random.normal(k3, (C,), dtype)
    noise = 0.01 * jax.random.normal(k4, (R,), dtype)
    got = ops.crossbar_mvm(gp, gn, v, 1.7, noise)
    want = ref.crossbar_mvm_ref(
        gp, gn, v.reshape(-1, 1), (1.7 * (1 + noise)).reshape(-1, 1))[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 700), seed=st.integers(0, 100),
       tau=st.floats(1e-4, 1.0), theta=st.floats(0.0, 1.0))
def test_primal_update_matches_ref(n, seed, tau, theta):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (n,))
    kty = jax.random.normal(ks[1], (n,))
    c = jax.random.normal(ks[2], (n,))
    T = jax.random.uniform(ks[3], (n,), minval=0.1, maxval=2.0)
    lb = -jax.random.uniform(ks[4], (n,))
    ub = jax.random.uniform(ks[5], (n,))
    xn, xb = ops.primal_update(x, kty, c, T, lb, ub, tau, theta)
    xn_r, xb_r = ref.primal_update_ref(x, kty, c, T, lb, ub, tau, theta)
    np.testing.assert_allclose(np.asarray(xn), np.asarray(xn_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(xb), np.asarray(xb_r),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 700), seed=st.integers(0, 100),
       sigma=st.floats(1e-4, 1.0))
def test_dual_update_matches_ref(m, seed, sigma):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    y = jax.random.normal(ks[0], (m,))
    kxb = jax.random.normal(ks[1], (m,))
    b = jax.random.normal(ks[2], (m,))
    Sig = jax.random.uniform(ks[3], (m,), minval=0.1, maxval=2.0)
    got = ops.dual_update(y, kxb, b, Sig, sigma)
    want = ref.dual_update_ref(y, kxb, b, Sig, sigma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_crossbar_mvm_zero_padding_is_inert():
    """Padding rows/cols to tile boundaries must not leak into results."""
    R, C = 100, 90
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    gp = jax.random.uniform(k1, (R, C))
    gn = jax.random.uniform(k2, (R, C))
    v = jax.random.normal(k3, (C,))
    noise = jnp.zeros(R)
    got = ops.crossbar_mvm(gp, gn, v, 1.0, noise)
    want = (gp - gn) @ v
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert got.shape == (R,)


def test_kernel_inside_crossbar_array_matches_jnp_path():
    """The CrossbarArray kernel path == its jnp path, same key."""
    from repro.crossbar import CrossbarArray, EPIRAM

    rng = np.random.default_rng(0)
    W = rng.normal(size=(96, 80))
    key = jax.random.PRNGKey(3)
    # parity test: BOTH paths must see identical keys on purpose
    a1 = CrossbarArray.program(W, EPIRAM, key=key, use_kernel=False)
    a2 = CrossbarArray.program(W, EPIRAM, key=key, use_kernel=True)  # jaxlint: disable=R2
    v = rng.normal(size=80)
    kread = jax.random.PRNGKey(9)
    w1 = np.asarray(a1.mvm(v, key=kread))
    w2 = np.asarray(a2.mvm(v, key=kread))  # jaxlint: disable=R2
    # same programmed conductances; read-noise draws differ in shape
    # (per-row vs per-output) so compare against the noiseless product
    clean = np.asarray(a1.enc.decode() @ v)
    assert np.abs(w1 - clean).max() <= np.abs(clean).max() * 0.02
    assert np.abs(w2 - clean).max() <= np.abs(clean).max() * 0.02
