"""Paper Table 1: problem types, sizes, objective values, solve times.

Instances are generated to the MIPLIB-2017 shapes with known optima
(DESIGN.md ground-truth caveat); "solve time" is the bundled simplex
oracle (Gurobi stand-in) on instances small enough, else the
high-precision jitted PDHG.
"""
from __future__ import annotations

import time


def run():
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.lp import TABLE1_SIZES, simplex, table1_instance

    rows = []
    for name, (m, n) in TABLE1_SIZES.items():
        lp = table1_instance(name)
        t0 = time.perf_counter()
        if lp.K.shape[1] <= 120:
            r = simplex.solve(lp)
            solver, obj = "simplex", r.obj
        else:
            from repro.core import PDHGOptions, solve_jit
            r = solve_jit(lp, PDHGOptions(max_iters=60000, tol=1e-8))
            solver, obj = "pdhg-hp", r.obj
        # simplex branch is pure host; solve_jit returns host numpy —
        # the fence makes the window honest either way (jaxlint R7)
        jax.block_until_ready(obj)
        dt = time.perf_counter() - t0
        rows.append((name, f"{m}x{n}", f"{lp.obj_opt:.4f}", f"{obj:.4f}",
                     solver, f"{dt:.2f}"))
    header = ("problem", "size(mxn)", "known_obj", "solved_obj", "oracle",
              "time_s")
    return header, rows


def main():
    header, rows = run()
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
