"""Shared benchmark machinery: run each (instance x backend) solve once,
cache the results + energy ledgers, let every table module read from the
cache.  Mirrors the paper's experimental setup (§5.1):

  instances : Table-1 shapes (generated with known optima — see
              DESIGN.md ground-truth caveat)
  backends  : gpuPDLP (analytic RTX6000 cost model wrapping the exact
              jitted solver), EpiRAM, TaOx-HfOx (device-physics sim)
  metrics   : relative objective gap (eq. 13), per-phase energy/latency
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

CACHE_PATH = os.environ.get(
    "REPRO_BENCH_CACHE", os.path.join("experiments", "bench_cache.json"))

INSTANCES = ["gen-ip002", "gen-ip016", "gen-ip021", "gen-ip036",
             "gen-ip054", "neos5", "assign1-5-8"]
BACKENDS = ["gpuPDLP", "EpiRAM", "TaOx-HfOx"]

MAX_ITERS = int(os.environ.get("REPRO_BENCH_MAX_ITERS", "30000"))
TOL = 1e-6


def _solve_all():
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.core import (
        PDHGOptions, encode_exact, lanczos_svd, solve_jit)
    from repro.crossbar import (
        EPIRAM, TAOX_HFOX, Ledger, RTX6000, solve_crossbar_jit)
    from repro.crossbar.encode import encode_matrix
    from repro.core.symblock import build_sym_block, scaled_accel, Accel
    from repro.lp import table1_instance

    results = {}
    for name in INSTANCES:
        lp = table1_instance(name)
        m, n = lp.K.shape
        true_sigma = float(np.linalg.svd(np.asarray(lp.K),
                                         compute_uv=False)[0])
        opts = PDHGOptions(max_iters=MAX_ITERS, tol=TOL, check_every=100,
                           lanczos_iters=48)
        inst = {"shape": [int(m), int(n)], "obj_opt": float(lp.obj_opt),
                "sigma_true": true_sigma, "backends": {}}

        # ---- gpuPDLP: exact solve + analytic GPU cost model ------------
        import jax as _jax
        t0 = time.perf_counter()
        acc = encode_exact(lp.K)
        lres = lanczos_svd(acc, k_max=64, tol=1e-10)
        res = solve_jit(lp, opts)
        # results are host numpy today; the explicit fence keeps the
        # wall-clock honest under async dispatch (jaxlint R7)
        _jax.block_until_ready((lres.sigma_max, res.obj))
        wall = time.perf_counter() - t0
        led = Ledger()
        nbytes = 8 * (m * n + m + n)
        RTX6000.h2d(nbytes, led)
        for _ in range(lres.iterations):
            RTX6000.lanczos_iteration(m + n, led)
        lan_snapshot = led.snapshot()
        for _ in range(res.iterations):
            RTX6000.pdhg_iteration(m, n, led)
        RTX6000.d2h(8 * (m + n), led)
        inst["backends"]["gpuPDLP"] = {
            "wall_s": wall,
            "lanczos": {
                "sigma": float(lres.sigma_max),
                "k": int(lres.iterations),
                "gap": abs(lres.sigma_max - true_sigma) / true_sigma,
                "energy_j": lan_snapshot.total_energy_j,
                "latency_s": lan_snapshot.total_latency_s,
                "breakdown": lan_snapshot.as_dict(),
            },
            "pdhg": {
                "obj": float(res.obj),
                "k": int(res.iterations),
                "gap": abs(res.obj - lp.obj_opt) / abs(lp.obj_opt),
                "energy_j": led.total_energy_j - lan_snapshot.total_energy_j,
                "latency_s": (led.total_latency_s
                              - lan_snapshot.total_latency_s),
                "breakdown": led.diff(lan_snapshot).as_dict(),
            },
            "total": led.as_dict(),
        }

        # ---- RRAM devices ---------------------------------------------
        for dev in (EPIRAM, TAOX_HFOX):
            t0 = time.perf_counter()
            # Lanczos phase on the device (noisy MVMs through encoded M)
            import jax as _jax
            led = Ledger()
            # deliberate fixed programming key: Table-1 numbers must be
            # reproducible across benchmark runs
            enc = encode_matrix(
                build_sym_block(np.asarray(lp.K)), dev,
                _jax.random.PRNGKey(1),  # jaxlint: disable=R2
                ledger=led)
            Mp = enc.decode()

            def noisy_mvm(v, key=None, _Mp=Mp, _dev=dev, _led=led,
                          _cells=enc.active_cells):
                w = _Mp @ v
                _led.read_energy_j += _dev.read_energy_per_cell_j * _cells
                _led.read_latency_s += _dev.read_latency_s
                _led.mvm_count += 1
                if key is not None:
                    g = _jax.random.normal(key, w.shape, w.dtype)
                    w = w * (1.0 + _dev.sigma_read * g)
                return w

            acc = Accel(mvm_full=noisy_mvm, m=m, n=n, name="crossbar:bench")
            lres = lanczos_svd(acc, k_max=64, tol=1e-10,
                               noise_keys=True)
            lan_snapshot = led.snapshot()
            rep = solve_crossbar_jit(lp, opts, device=dev, ledger=led)
            _jax.block_until_ready((lres.sigma_max, rep.result.obj))
            wall = time.perf_counter() - t0
            res = rep.result
            inst["backends"][dev.name] = {
                "wall_s": wall,
                "lanczos": {
                    "sigma": float(lres.sigma_max),
                    "k": int(lres.iterations),
                    "gap": abs(lres.sigma_max - true_sigma) / true_sigma,
                    "energy_j": lan_snapshot.total_energy_j,
                    "latency_s": lan_snapshot.total_latency_s,
                    "breakdown": lan_snapshot.as_dict(),
                },
                "pdhg": {
                    "obj": float(res.obj),
                    "k": int(res.iterations),
                    "gap": abs(res.obj - lp.obj_opt) / abs(lp.obj_opt),
                    "energy_j": (led.total_energy_j
                                 - lan_snapshot.total_energy_j),
                    "latency_s": (led.total_latency_s
                                  - lan_snapshot.total_latency_s),
                    "breakdown": led.diff(lan_snapshot).as_dict(),
                },
                "total": led.as_dict(),
            }
        results[name] = inst
    return results


def cached_results(refresh: bool = False):
    if not refresh and os.path.exists(CACHE_PATH):
        with open(CACHE_PATH) as f:
            return json.load(f)
    results = _solve_all()
    os.makedirs(os.path.dirname(CACHE_PATH) or ".", exist_ok=True)
    with open(CACHE_PATH, "w") as f:
        json.dump(results, f, indent=1)
    return results


def fmt_factor(gpu: float, dev: float) -> str:
    if dev <= 0:
        return "--"
    return f"{gpu / dev:.2f}x"
