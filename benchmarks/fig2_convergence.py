"""Paper Figure 2: convergence (primal residual / optimality gap) versus
simulated latency on gen-ip054, for EpiRAM / TaOx-HfOx / GPU.

Writes a CSV trace per accelerator under experiments/fig2/ and prints a
coarse ASCII rendition (this container has no display)."""
from __future__ import annotations

import os

import numpy as np

OUT_DIR = os.path.join("experiments", "fig2")


def run(max_iters: int = 30000):
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.core import PDHGOptions, solve
    from repro.crossbar import EPIRAM, RTX6000, TAOX_HFOX, Ledger
    from repro.crossbar.array import crossbar_accel_factory
    from repro.lp import table1_instance

    lp = table1_instance("gen-ip054")
    m, n = lp.K.shape
    opts = PDHGOptions(max_iters=max_iters, tol=1e-7, check_every=200,
                       track_history=True, lanczos_iters=40)
    traces = {}

    # GPU: exact solve; latency from the analytic per-iteration model
    res = solve(lp, opts)
    led = Ledger()
    RTX6000.pdhg_iteration(m, n, led)
    per_iter_gpu = led.solve_latency_s
    traces["GPU"] = [
        (h["iter"] * per_iter_gpu, h["r_pri"],
         abs(h["obj"] - lp.obj_opt) / abs(lp.obj_opt))
        for h in res.history
    ]

    for dev in (EPIRAM, TAOX_HFOX):
        fac = crossbar_accel_factory(device=dev)
        res = solve(lp, opts, accel_factory=fac)
        per_iter = 2 * dev.read_latency_s
        traces[dev.name] = [
            (h["iter"] * per_iter, h["r_pri"],
             abs(h["obj"] - lp.obj_opt) / abs(lp.obj_opt))
            for h in res.history
        ]
    os.makedirs(OUT_DIR, exist_ok=True)
    for name, tr in traces.items():
        with open(os.path.join(OUT_DIR, f"{name}.csv"), "w") as f:
            f.write("latency_s,r_pri,opt_gap\n")
            for t, rp, g in tr:
                f.write(f"{t:.6e},{rp:.6e},{g:.6e}\n")
    return traces


def ascii_plot(traces, field: int = 2, width: int = 70, height: int = 16):
    lines = []
    pts = []
    for name, tr in traces.items():
        for t, rp, g in tr:
            v = (rp, g)[field - 1]
            if t > 0 and v > 0:
                pts.append((np.log10(t), np.log10(v), name[0]))
    if not pts:
        return ""
    xs = [p[0] for p in pts]
    ys = [p[1] for p in pts]
    x0, x1 = min(xs), max(xs)
    y0, y1 = min(ys), max(ys)
    grid = [[" "] * width for _ in range(height)]
    for x, y, c in pts:
        i = int((x - x0) / max(x1 - x0, 1e-9) * (width - 1))
        j = int((y1 - y) / max(y1 - y0, 1e-9) * (height - 1))
        grid[j][i] = c
    lines.append(f"log10(metric) {y1:.1f} .. {y0:.1f} | "
                 f"log10(latency s) {x0:.1f} .. {x1:.1f}")
    lines.extend("".join(row) for row in grid)
    lines.append("G=GPU  E=EpiRAM  T=TaOx-HfOx")
    return "\n".join(lines)


def main():
    traces = run()
    print("fig2: traces written to", OUT_DIR)
    for name, tr in traces.items():
        print(f"  {name}: {len(tr)} checkpoints, "
              f"final gap {tr[-1][2]:.2e} at {tr[-1][0]:.2f}s")
    print(ascii_plot(traces))


if __name__ == "__main__":
    main()
