"""Paper Table 5: PDHG-phase breakdown (objective vs relaxed optimum, k*,
per-phase energy/latency components)."""
from __future__ import annotations

from ._shared import BACKENDS, cached_results


def run(refresh: bool = False):
    res = cached_results(refresh)
    header = ("problem", "relaxed_obj", "accelerator", "objective", "k",
              "E_h2d_or_write_J", "E_solve_or_read_J", "E_d2h_J",
              "t_h2d_or_write_s", "t_solve_or_read_s", "t_d2h_s",
              "E_total_J", "t_total_s")
    rows = []
    for name, inst in res.items():
        for bk in BACKENDS:
            b = inst["backends"][bk]["pdhg"]
            d = b["breakdown"]
            if bk == "gpuPDLP":
                parts = (d["h2d_energy_j"], d["solve_energy_j"],
                         d["d2h_energy_j"], d["h2d_latency_s"],
                         d["solve_latency_s"], d["d2h_latency_s"])
            else:
                parts = (d["write_energy_j"], d["read_energy_j"], 0.0,
                         d["write_latency_s"], d["read_latency_s"], 0.0)
            rows.append((
                name, f"{inst['obj_opt']:.4f}", bk, f"{b['obj']:.4f}",
                b["k"],
                *(f"{p:.4f}" for p in parts),
                f"{b['energy_j']:.4f}", f"{b['latency_s']:.4f}",
            ))
    return header, rows


def main():
    header, rows = run()
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
