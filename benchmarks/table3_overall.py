"""Paper Table 3: overall (Lanczos + PDHG) energy & latency improvement
factors of the RRAM solvers over the GPU baseline."""
from __future__ import annotations

from ._shared import cached_results, fmt_factor


def run(refresh: bool = False):
    res = cached_results(refresh)
    header = ("problem", "EpiRAM_power", "EpiRAM_latency",
              "TaOx-HfOx_power", "TaOx-HfOx_latency")
    rows = []
    for name, inst in res.items():
        gpu = inst["backends"]["gpuPDLP"]["total"]
        epi = inst["backends"]["EpiRAM"]["total"]
        tao = inst["backends"]["TaOx-HfOx"]["total"]
        rows.append((
            name,
            fmt_factor(gpu["total_energy_j"], epi["total_energy_j"]),
            fmt_factor(gpu["total_latency_s"], epi["total_latency_s"]),
            fmt_factor(gpu["total_energy_j"], tao["total_energy_j"]),
            fmt_factor(gpu["total_latency_s"], tao["total_latency_s"]),
        ))
    return header, rows


def main():
    header, rows = run()
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
