"""Benchmark guard: validate BENCH_stream.json and gate warm regressions.

CI runs this right after the smoke stream benchmark:

  1. **Schema validation** — the candidate record must be
     ``bench_stream/v7``: every serving path (dense batched /
     per-instance, crossbar batched / per-instance, the
     mixed-precision refined crossbar solve, the three sparse
     backends — default ELL, nnz-bucketed BCOO, ELL + fused
     multi-iteration megakernel — and the densified sparse baseline,
     async + sync dispatch, per-pod routed cluster serving, the
     adaptive step rule on the imbalanced acceptance stream, and the
     norm-reuse seeded second pass) present with finite numeric
     ``cold_s``/``warm_s``/``mvm_total`` AND a finite per-instance
     ``iterations_to_tol`` {median, p90} distribution, plus the
     ``sparse`` host-memory summary, the ``cluster`` routing summary
     (non-empty routing table, per-pod throughput shares), the
     ``adaptive`` iteration-reduction summary, the ``norm_reuse``
     summary, the ``refinement`` acceptance summary, and the
     ``sanitize`` section (per-path warm-pass XLA compile counts from
     ``repro.runtime.sanitize``).
  2. **Regression gate** — the warm BUCKETED paths (the steady-state
     serving numbers) must not regress more than ``--max-regression``
     (default 2x) against the committed baseline
     (``git show HEAD:BENCH_stream.json`` in CI).  v1-v6 baselines are
     accepted: only the path keys both records share are compared.
  3. **Sparse-wins gate** — the acceptance criterion of the ELL
     backend: the default sparse pipeline's warm serving must be at
     least ``--min-sparse-speedup`` (default 1x) as fast as the
     densified dense baseline on the same >=95%-sparse stream.
  4. **Iteration-reduction gate** — with ``--min-iter-reduction R``,
     the adaptive step rule's median per-instance iteration reduction
     over the fixed rule (same scale-imbalanced stream, same tol) must
     be at least R, and no adaptive instance may have failed to reach
     the tol the fixed rule was asked for.  Skipped when R is omitted
     or the record predates the ``adaptive`` section.
  5. **Refinement gate** — with ``--min-refine-accuracy G``, the
     iterative-refinement acceptance experiment must show an
     unrefined/refined KKT-merit improvement of at least G, the
     refined solve must reach the exact-path tolerance, and ZERO
     additional cells may have been written across refinement rounds
     (correction solves reuse the original programmed conductances).
     Skipped when G is omitted.
  6. **Zero-recompile gate** — with ``--max-warm-compiles N`` (CI
     passes 0), every warm batched pass must have compiled at most N
     fresh XLA executables.  A violation means an executable-cache key
     drifted (stale ``opts_static`` field, unstable bucket signature).
     Skipped when the record says compile counting was unsupported.

Exit code 0 = pass; 1 = schema or regression failure (messages on
stderr).

  python benchmarks/bench_guard.py --candidate BENCH_stream.json \
      --baseline /tmp/bench_baseline.json --max-regression 2.0
"""
from __future__ import annotations

import argparse
import json
import math
import sys

SCHEMA = "bench_stream/v7"

# every serving path a v7 record must carry
REQUIRED_PATHS = (
    "exact_batched",
    "exact_per_instance",
    "crossbar_batched",
    "crossbar_per_instance",
    "crossbar_refined",
    "sparse_batched",
    "sparse_batched_dense",
    "sparse_ell",
    "sparse_bcoo",
    "sparse_ell_mega",
    "exact_batched_async",
    "exact_batched_sync",
    "exact_routed",
    "exact_adaptive",
    "exact_norm_reuse",
)
PATH_FIELDS = ("cold_s", "warm_s", "mvm_total")
ITER_FIELDS = ("median", "p90")      # per-path iterations_to_tol (v6)
ADAPTIVE_FIELDS = ("iter_reduction_median", "iter_reduction_p10",
                   "n_unconverged_fixed", "n_unconverged_adaptive",
                   "max_merit_adaptive", "tol")
NORM_REUSE_FIELDS = ("norm_seeded_buckets", "cache_entries",
                     "mvm_total_cold", "mvm_total_warm",
                     "max_rel_disagreement_vs_cold")
REFINEMENT_FIELDS = ("merit_exact", "merit_unrefined", "merit_refined",
                     "accuracy_gain", "cells_written_unrefined",
                     "cells_written_refined", "write_cells_delta",
                     "digital_mvms", "rounds", "sigma_read", "tol")
SPARSE_FIELDS = ("density", "host_stack_bytes_dense",
                 "host_stack_bytes_sparse", "host_mem_improvement",
                 "speedup_warm", "speedup_warm_bcoo",
                 "speedup_warm_ell_mega")
CLUSTER_FIELDS = ("n_pods", "routing", "per_pod", "rerouted_buckets",
                  "max_rel_disagreement_vs_unrouted")
PER_POD_FIELDS = ("n_buckets", "n_instances", "flops_cost", "flops_share",
                  "warm_s", "instances_per_s_warm")

# warm steady-state serving paths gated against the committed baseline
GUARDED_WARM_PATHS = ("exact_batched", "crossbar_batched", "sparse_batched",
                      "exact_routed")

# warm passes whose XLA compile counts the sanitize section must carry
SANITIZE_PATHS = ("exact_batched", "sparse_batched", "crossbar_batched",
                  "adaptive_batched", "norm_reuse_batched")

def _fail(msg: str) -> None:
    print(f"bench_guard: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def _finite_number(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool) \
        and math.isfinite(v)


def validate_schema(bench: dict) -> None:
    if bench.get("schema") != SCHEMA:
        _fail(f"schema is {bench.get('schema')!r}, expected {SCHEMA!r}")
    paths = bench.get("paths")
    if not isinstance(paths, dict):
        _fail("missing 'paths' object")
    for name in REQUIRED_PATHS:
        entry = paths.get(name)
        if not isinstance(entry, dict):
            _fail(f"missing path entry {name!r}")
        for field in PATH_FIELDS:
            if not _finite_number(entry.get(field)):
                _fail(f"paths.{name}.{field} is not a finite number: "
                      f"{entry.get(field)!r}")
            if entry[field] < 0:
                _fail(f"paths.{name}.{field} is negative: {entry[field]}")
        iters = entry.get("iterations_to_tol")
        if not isinstance(iters, dict):
            _fail(f"paths.{name}.iterations_to_tol missing (v6 requires "
                  f"a median/p90 distribution per path)")
        for field in ITER_FIELDS:
            if not _finite_number(iters.get(field)) or iters[field] <= 0:
                _fail(f"paths.{name}.iterations_to_tol.{field} is not a "
                      f"positive finite number: {iters.get(field)!r}")
    adaptive = bench.get("adaptive")
    if not isinstance(adaptive, dict):
        _fail("missing 'adaptive' summary")
    for field in ADAPTIVE_FIELDS:
        if not _finite_number(adaptive.get(field)):
            _fail(f"adaptive.{field} is not a finite number: "
                  f"{adaptive.get(field)!r}")
    for leg in ("iters_fixed", "iters_adaptive"):
        d = adaptive.get(leg)
        if not isinstance(d, dict) \
                or not all(_finite_number(d.get(f)) for f in ITER_FIELDS):
            _fail(f"adaptive.{leg} must carry finite median/p90")
    reuse = bench.get("norm_reuse")
    if not isinstance(reuse, dict):
        _fail("missing 'norm_reuse' summary")
    for field in NORM_REUSE_FIELDS:
        if not _finite_number(reuse.get(field)):
            _fail(f"norm_reuse.{field} is not a finite number: "
                  f"{reuse.get(field)!r}")
    refinement = bench.get("refinement")
    if not isinstance(refinement, dict):
        _fail("missing 'refinement' summary")
    for field in REFINEMENT_FIELDS:
        if not _finite_number(refinement.get(field)):
            _fail(f"refinement.{field} is not a finite number: "
                  f"{refinement.get(field)!r}")
    if not isinstance(refinement.get("refined_reached_tol"), bool):
        _fail("refinement.refined_reached_tol must be a bool")
    sparse = bench.get("sparse")
    if not isinstance(sparse, dict):
        _fail("missing 'sparse' summary")
    for field in SPARSE_FIELDS:
        if not _finite_number(sparse.get(field)):
            _fail(f"sparse.{field} is not a finite number: "
                  f"{sparse.get(field)!r}")
    cluster = bench.get("cluster")
    if not isinstance(cluster, dict):
        _fail("missing 'cluster' summary")
    for field in CLUSTER_FIELDS:
        if field not in cluster:
            _fail(f"cluster.{field} missing")
    if not isinstance(cluster["routing"], dict) or not cluster["routing"]:
        _fail("cluster.routing must be a non-empty bucket->pod table")
    if not isinstance(cluster["per_pod"], dict) or not cluster["per_pod"]:
        _fail("cluster.per_pod must be a non-empty pod->stats table")
    for pod, entry in cluster["per_pod"].items():
        for field in PER_POD_FIELDS:
            if not _finite_number(entry.get(field)):
                _fail(f"cluster.per_pod[{pod}].{field} is not a finite "
                      f"number: {entry.get(field)!r}")
    pods_routed = set(cluster["routing"].values())
    if not pods_routed <= set(range(int(cluster["n_pods"]))):
        _fail(f"cluster.routing targets unknown pods: {pods_routed}")
    san = bench.get("sanitize")
    if not isinstance(san, dict):
        _fail("missing 'sanitize' section")
    if not isinstance(san.get("compile_counting"), bool):
        _fail("sanitize.compile_counting must be a bool")
    warm = san.get("warm_compiles")
    if not isinstance(warm, dict):
        _fail("sanitize.warm_compiles must be a path->count object")
    for name in SANITIZE_PATHS:
        v = warm.get(name)
        if not (isinstance(v, int) and not isinstance(v, bool) and v >= 0):
            _fail(f"sanitize.warm_compiles.{name} is not a non-negative "
                  f"int: {v!r}")


def check_regressions(candidate: dict, baseline: dict,
                      max_regression: float) -> None:
    base_paths = baseline.get("paths") or {}
    compared = 0
    for name in GUARDED_WARM_PATHS:
        base = base_paths.get(name)
        if not isinstance(base, dict):
            continue        # v1/v2 baselines predate sparse/async/routed
        base_warm = base.get("warm_s")
        cand_warm = candidate["paths"][name]["warm_s"]
        if not _finite_number(base_warm) or base_warm <= 0:
            continue
        compared += 1
        ratio = cand_warm / base_warm
        status = "ok" if ratio <= max_regression else "REGRESSION"
        print(f"bench_guard: {name}: warm {base_warm:.3f}s -> "
              f"{cand_warm:.3f}s ({ratio:.2f}x) [{status}]")
        if ratio > max_regression:
            _fail(f"{name} warm path regressed {ratio:.2f}x "
                  f"(> {max_regression}x allowed)")
    if compared == 0:
        print("bench_guard: no comparable warm paths in baseline "
              "(schema migration?); regression gate skipped")


def check_sparse_wins(candidate: dict, min_speedup: float) -> None:
    """Acceptance criterion: sparse serving must not lose to densifying."""
    dense = candidate["paths"]["sparse_batched_dense"]["warm_s"]
    sparse = candidate["paths"]["sparse_batched"]["warm_s"]
    speedup = dense / max(sparse, 1e-12)
    status = "ok" if speedup >= min_speedup else "TOO SLOW"
    print(f"bench_guard: sparse_batched warm {sparse:.3f}s vs densified "
          f"{dense:.3f}s ({speedup:.2f}x) [{status}]")
    if speedup < min_speedup:
        _fail(f"sparse_batched warm is only {speedup:.2f}x the densified "
              f"baseline (>= {min_speedup}x required)")


def check_iter_reduction(candidate: dict, min_reduction: float) -> None:
    """Acceptance criterion of the adaptive step rule: median
    per-instance iteration reduction over fixed on the imbalanced
    stream, at the SAME tol (unconverged adaptive instances fail the
    gate outright — a reduction bought by stopping early is no
    reduction)."""
    ad = candidate["adaptive"]
    red = ad["iter_reduction_median"]
    unconv = ad["n_unconverged_adaptive"]
    status = "ok" if red >= min_reduction and unconv == 0 else "TOO SLOW"
    print(f"bench_guard: adaptive median iteration reduction "
          f"{red:.2f}x (p10 {ad['iter_reduction_p10']:.2f}x), "
          f"{unconv} unconverged [{status}]")
    if unconv > 0:
        _fail(f"{unconv} adaptive instance(s) missed tol "
              f"{ad['tol']:g} within the iteration budget")
    if red < min_reduction:
        _fail(f"adaptive median iteration reduction is only {red:.2f}x "
              f"(>= {min_reduction}x required)")


def check_refinement(candidate: dict, min_gain: float) -> None:
    """Acceptance criterion of mixed-precision refinement: the refined
    crossbar solve must reach the exact-path tolerance at a sigma_read
    where the single solve fails, improve the KKT merit by at least
    ``min_gain``x, and program ZERO additional cells across refinement
    rounds (the same conductances serve every correction solve)."""
    ref = candidate["refinement"]
    gain = ref["accuracy_gain"]
    reached = ref["refined_reached_tol"]
    delta = ref["write_cells_delta"]
    ok = gain >= min_gain and reached and delta == 0
    print(f"bench_guard: refinement merit {ref['merit_unrefined']:.2e} -> "
          f"{ref['merit_refined']:.2e} ({gain:.1e}x gain, "
          f"{ref['rounds']} rounds), write cells delta {delta} "
          f"[{'ok' if ok else 'FAIL'}]")
    if delta != 0:
        _fail(f"refinement programmed {delta} additional cell(s) — the "
              "correction solves must reuse the original conductances")
    if not reached:
        _fail(f"refined merit {ref['merit_refined']:.2e} missed the "
              f"exact-path tol {ref['tol']:g} at sigma_read "
              f"{ref['sigma_read']:g}")
    if gain < min_gain:
        _fail(f"refinement accuracy gain is only {gain:.2f}x "
              f"(>= {min_gain}x required)")


def check_warm_compiles(candidate: dict, max_compiles: int) -> None:
    """Zero-recompile gate: warm batched passes must stay compile-free."""
    san = candidate["sanitize"]
    if not san["compile_counting"]:
        print("bench_guard: compile counting unsupported on the producing "
              "runtime; warm-compile gate skipped")
        return
    for name, count in sorted(san["warm_compiles"].items()):
        status = "ok" if count <= max_compiles else "RECOMPILE"
        print(f"bench_guard: {name}: warm pass compiled {count} "
              f"executable(s) [{status}]")
        if count > max_compiles:
            _fail(f"{name} warm pass compiled {count} fresh XLA "
                  f"executable(s) (> {max_compiles} allowed) — an "
                  f"executable-cache key drifted (stale opts_static "
                  f"field or unstable bucket signature)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--candidate", default="BENCH_stream.json",
                    help="freshly produced benchmark record")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline record (omit to skip the "
                         "regression gate and only validate schema)")
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="max allowed warm-time ratio candidate/baseline")
    ap.add_argument("--min-sparse-speedup", type=float, default=1.0,
                    help="min required densified/sparse warm-time ratio "
                         "(0 disables the sparse-wins gate)")
    ap.add_argument("--max-warm-compiles", type=int, default=None,
                    help="max XLA compilations allowed in each warm "
                         "batched pass (CI passes 0; omit to skip)")
    ap.add_argument("--min-iter-reduction", type=float, default=None,
                    help="min required median iteration reduction of "
                         "step_rule=adaptive over fixed on the "
                         "imbalanced stream (omit to skip)")
    ap.add_argument("--min-refine-accuracy", type=float, default=None,
                    help="min required unrefined/refined KKT-merit "
                         "ratio of the iterative-refinement acceptance "
                         "experiment; also enforces refined-reaches-tol "
                         "and a zero write-cells delta (omit to skip)")
    args = ap.parse_args(argv)

    with open(args.candidate) as f:
        candidate = json.load(f)
    validate_schema(candidate)
    print(f"bench_guard: schema {SCHEMA} ok "
          f"({len(candidate['paths'])} paths)")
    if args.min_sparse_speedup > 0:
        check_sparse_wins(candidate, args.min_sparse_speedup)
    if args.max_warm_compiles is not None:
        check_warm_compiles(candidate, args.max_warm_compiles)
    if args.min_iter_reduction is not None:
        check_iter_reduction(candidate, args.min_iter_reduction)
    if args.min_refine_accuracy is not None:
        check_refinement(candidate, args.min_refine_accuracy)

    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        check_regressions(candidate, baseline, args.max_regression)
    print("bench_guard: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
