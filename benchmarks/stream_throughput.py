"""Stream-throughput benchmark: bucketed batched serving vs. per-instance.

The ROADMAP benchmark item: compare bucketed stream throughput against
per-instance solves on a realistic mixed-shape LP stream, for both the
exact jitted path and the crossbar device-physics path, and record the
energy-ledger totals the device path accumulates (write split into
logical vs. padding cells, so the tile-alignment overhead is visible).

Per-instance baselines replicate what serving without the batch scheduler
looks like: a Python loop calling the jitted single-instance solver on
each (bucket-padded) instance — jit caching still applies per shape, so
the comparison isolates batching, not compilation.

  PYTHONPATH=src python benchmarks/stream_throughput.py --smoke
  PYTHONPATH=src python benchmarks/stream_throughput.py \
      --instances 32 --device taox --out experiments/stream_throughput.json
  PYTHONPATH=src python benchmarks/stream_throughput.py --kernel pallas

Each timed path runs twice: COLD includes compilation, WARM is the
steady-state serving cost (the number that matters for throughput).
``--kernel`` selects the engine's update backend (jnp vs fused Pallas).
Besides the full record, every run emits ``BENCH_stream.json`` at the
repo root (schema ``bench_stream/v7``: per-path warm/cold seconds +
device-MVM totals + per-instance ``iterations_to_tol`` distributions
(median/p90) — including the three sparse backends (``sparse_ell``
= the default row-blocked ELL pipeline, ``sparse_bcoo`` = nnz-bucketed
COO, ``sparse_ell_mega`` = ELL with the fused multi-iteration
megakernel), the async-vs-sync dispatch split, the per-pod ROUTED
cluster path, the ``exact_adaptive`` step-rule path on a scale-
imbalanced acceptance stream, the ``exact_norm_reuse`` seeded
second pass and the ``crossbar_refined`` mixed-precision refinement
solve — plus ``sparse``/``cluster`` summaries, an ``adaptive``
summary with the fixed-vs-adaptive iteration-reduction statistics, a
``norm_reuse`` summary, a ``refinement`` acceptance summary (merit
contrast, write-cells delta), and a ``sanitize`` section recording the
XLA compilation count of every warm batched pass) as the perf baseline
for future PRs; CI uploads it and ``benchmarks/bench_guard.py`` gates
regressions against it, including the acceptance-criterion gates that
the default sparse pipeline's warm serving is at least as fast as the
densified baseline, that the adaptive rule's median iteration reduction
stays above ``--min-iter-reduction``, that refinement's accuracy gain
stays above ``--min-refine-accuracy`` with zero extra write cells, and
the zero-recompile gate (``--max-warm-compiles 0``) on the warm passes.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def block_until_ready(x):
    """Fence for timing windows (jaxlint R7): today every solve path
    returns host-materialized numpy results, so this is a no-op — but
    the explicit block keeps the perf_counter windows honest if a path
    ever starts returning device arrays under async dispatch."""
    import jax

    return jax.block_until_ready(x)


SMOKE_SHAPES = [(8, 14), (10, 18), (20, 34), (12, 24), (7, 13), (16, 28)]
FULL_SHAPES = [(8, 14), (10, 18), (20, 34), (12, 24), (7, 13), (16, 28),
               (40, 70), (28, 52), (56, 96), (24, 44)]
# the sparse stream: >=95%-sparse paper-class shapes (acceptance target)
SPARSE_DENSITY = 0.05
SPARSE_SMOKE_SHAPES = [(96, 192), (128, 256), (80, 160), (112, 224)]
SPARSE_FULL_SHAPES = [(192, 384), (256, 512), (160, 320), (224, 448)]


def build_stream(n_instances: int, shapes, seed: int = 0):
    from repro.lp import random_standard_lp

    lps = []
    for i in range(n_instances):
        m, n = shapes[i % len(shapes)]
        lps.append(random_standard_lp(m, n, seed=seed + i))
    return lps


def build_imbalanced_stream(n_instances: int, shapes, seed: int = 0):
    """Objective/rhs scale-imbalanced variants of the mixed stream: c is
    scaled by 100 or 0.01 alternately.  Ruiz equilibration of K cannot
    see the mismatch; the adaptive rule's primal weight can — this is
    the stream the ``adaptive`` acceptance gate measures on."""
    import dataclasses

    lps = build_stream(n_instances, shapes, seed=seed)
    return [dataclasses.replace(lp, c=lp.c * (100.0 if i % 2 == 0
                                              else 0.01))
            for i, lp in enumerate(lps)]


def _iter_stats(results):
    """{median, p90} of per-instance iteration counts (iterations to the
    requested tol; iteration-limited instances are included as-is, i.e.
    censored at max_iters)."""
    its = [int(getattr(r, "result", r).iterations) for r in results]
    return {"median": float(np.median(its)),
            "p90": float(np.percentile(its, 90))}


def _sum_ledgers(reports):
    total = {}
    for rep in reports:
        for k, v in rep.ledger.as_dict().items():
            total[k] = total.get(k, 0.0) + v
    return total


def bench_exact(lps, opts):
    """Bucketed BatchSolver vs. a per-instance solve_jit loop."""
    from repro.core import solve_jit
    from repro.runtime import BatchSolver
    from repro.runtime.batch import bucket_dims, pad_problem

    def per_instance():
        results = []
        for lp in lps:
            padded = pad_problem(lp, *bucket_dims(*lp.K.shape))
            results.append(solve_jit(padded, opts))
        return results

    timings = {}
    t0 = time.perf_counter(); loop_results = block_until_ready(per_instance())
    timings["per_instance_cold_s"] = time.perf_counter() - t0
    t0 = time.perf_counter(); loop_results = block_until_ready(per_instance())
    timings["per_instance_warm_s"] = time.perf_counter() - t0

    solver = BatchSolver(opts)
    t0 = time.perf_counter(); results = block_until_ready(solver.solve_stream(lps))
    timings["batched_cold_s"] = time.perf_counter() - t0
    t0 = time.perf_counter(); block_until_ready(solver.solve_stream(lps))
    timings["batched_warm_s"] = time.perf_counter() - t0

    gaps = [abs(r.obj - lp.obj_opt) / max(abs(lp.obj_opt), 1e-12)
            for lp, r in zip(lps, results)]
    return {
        **timings,
        "speedup_warm": timings["per_instance_warm_s"]
        / max(timings["batched_warm_s"], 1e-12),
        # sanitizer surface: XLA compilations during the warm pass (the
        # executable-cache contract says this must be 0)
        "warm_compiles": solver.last_stream_stats["compiles"],
        "cache": solver.cache_info(),
        "buckets": sorted({str(r.bucket) for r in results}),
        "max_rel_gap": float(max(gaps)),
        "max_rel_disagreement_vs_loop": float(max(
            abs(r.obj - lr.obj) / max(abs(lr.obj), 1e-12)
            for r, lr in zip(results, loop_results))),
        "mvm_total_batched": int(sum(r.mvm_calls for r in results)),
        "mvm_total_per_instance": int(sum(r.mvm_calls
                                          for r in loop_results)),
        "iters_batched": _iter_stats(results),
        "iters_per_instance": _iter_stats(loop_results),
    }


def bench_sparse(lps, opts):
    """Sparse serving backends vs. the densified dense pipeline on the
    SAME >=95%-sparse stream.

    The dense baseline pads every instance into its (B, m_pad, n_pad)
    bucket stack — exactly what serving sparse traffic without the
    sparse path costs; ``host_stack_bytes`` records what each path
    actually materialized on the host.  Three sparse variants run:

      - ``sparse_*``      the default pipeline (= the ELL backend; the
                          steady-state serving number the guard gates)
      - ``bcoo_*``        the nnz-bucketed BCOO backend (memory-optimal,
                          scatter-bound on CPU)
      - ``ell_mega_*``    the ELL backend with the fused multi-iteration
                          megakernel (``check_every`` PDHG steps per
                          launch, residual check hoisted out)
    """
    import dataclasses

    from repro.runtime import BatchSolver

    dense_lps = [lp.densified() for lp in lps]

    def timed(solver, stream, tag, timings):
        t0 = time.perf_counter(); out = block_until_ready(solver.solve_stream(stream))
        timings[f"{tag}_cold_s"] = time.perf_counter() - t0
        t0 = time.perf_counter(); out = block_until_ready(solver.solve_stream(stream))
        timings[f"{tag}_warm_s"] = time.perf_counter() - t0
        return out

    timings = {}
    solver_d = BatchSolver(opts)
    dense_results = timed(solver_d, dense_lps, "dense", timings)
    dense_stats = dict(solver_d.last_stream_stats)

    assert opts.sparse_kernel == "ell"      # default pipeline == ELL
    solver_s = BatchSolver(opts)
    results = timed(solver_s, lps, "sparse", timings)
    sparse_stats = dict(solver_s.last_stream_stats)

    solver_b = BatchSolver(dataclasses.replace(opts, sparse_kernel="bcoo"))
    bcoo_results = timed(solver_b, lps, "bcoo", timings)

    solver_m = BatchSolver(dataclasses.replace(opts, megakernel=True))
    mega_results = timed(solver_m, lps, "ell_mega", timings)

    gaps = [abs(r.obj - lp.obj_opt) / max(abs(lp.obj_opt), 1e-12)
            for lp, r in zip(lps, results)]
    mem_dense = dense_stats["dense_stack_bytes"]
    mem_sparse = sparse_stats["sparse_stack_bytes"]
    return {
        **timings,
        "speedup_warm": timings["dense_warm_s"]
        / max(timings["sparse_warm_s"], 1e-12),
        "speedup_warm_bcoo": timings["dense_warm_s"]
        / max(timings["bcoo_warm_s"], 1e-12),
        "speedup_warm_ell_mega": timings["dense_warm_s"]
        / max(timings["ell_mega_warm_s"], 1e-12),
        "density": float(np.mean([lp.K.density for lp in lps])),
        "nnz_total": int(sum(lp.K.nnz for lp in lps)),
        "host_stack_bytes_dense": int(mem_dense),
        "host_stack_bytes_sparse": int(mem_sparse),
        "host_mem_improvement": mem_dense / max(mem_sparse, 1),
        "warm_compiles": sparse_stats["compiles"],
        "cache": solver_s.cache_info(),
        "max_rel_gap": float(max(gaps)),
        "max_rel_disagreement_vs_dense": float(max(
            abs(r.obj - dr.obj) / max(abs(dr.obj), 1e-12)
            for r, dr in zip(results, dense_results))),
        "max_rel_disagreement_bcoo_vs_ell": float(max(
            abs(br.obj - r.obj) / max(abs(r.obj), 1e-12)
            for br, r in zip(bcoo_results, results))),
        "max_rel_disagreement_mega_vs_ell": float(max(
            abs(mr.obj - r.obj) / max(abs(r.obj), 1e-12)
            for mr, r in zip(mega_results, results))),
        "mvm_total_sparse": int(sum(r.mvm_calls for r in results)),
        "mvm_total_dense": int(sum(r.mvm_calls for r in dense_results)),
        "mvm_total_bcoo": int(sum(r.mvm_calls for r in bcoo_results)),
        "mvm_total_ell_mega": int(sum(r.mvm_calls for r in mega_results)),
        "iters_sparse": _iter_stats(results),
        "iters_dense": _iter_stats(dense_results),
        "iters_bcoo": _iter_stats(bcoo_results),
        "iters_ell_mega": _iter_stats(mega_results),
    }


def bench_async(lps, opts):
    """Submit-all-then-collect dispatch vs. blocking per-bucket serving
    on the mixed-shape dense stream (same executables, same results —
    the delta is pure dispatch overlap)."""
    from repro.runtime import BatchSolver

    timings = {}
    sync = BatchSolver(opts, async_dispatch=False)
    t0 = time.perf_counter(); block_until_ready(sync.solve_stream(lps))
    timings["sync_cold_s"] = time.perf_counter() - t0
    t0 = time.perf_counter(); r_sync = block_until_ready(sync.solve_stream(lps))
    timings["sync_warm_s"] = time.perf_counter() - t0

    al = BatchSolver(opts)          # async is the default
    t0 = time.perf_counter(); block_until_ready(al.solve_stream(lps))
    timings["async_cold_s"] = time.perf_counter() - t0
    t0 = time.perf_counter(); r_async = block_until_ready(al.solve_stream(lps))
    timings["async_warm_s"] = time.perf_counter() - t0

    agree = max(abs(a.obj - s.obj) / max(abs(s.obj), 1e-12)
                for a, s in zip(r_async, r_sync))
    return {
        **timings,
        "speedup_warm": timings["sync_warm_s"]
        / max(timings["async_warm_s"], 1e-12),
        "dispatch_s": al.last_stream_stats["dispatch_s"],
        "collect_s": al.last_stream_stats["collect_s"],
        "n_buckets": al.last_stream_stats["n_buckets"],
        "max_rel_disagreement_vs_sync": float(agree),
        "mvm_total_async": int(sum(r.mvm_calls for r in r_async)),
        "mvm_total_sync": int(sum(r.mvm_calls for r in r_sync)),
        "iters_async": _iter_stats(r_async),
        "iters_sync": _iter_stats(r_sync),
    }


def bench_cluster(lps, opts, n_pods: int = 2):
    """Per-pod routed serving vs. the unrouted scheduler on the same
    mixed stream.

    Runs single-process with ``n_pods`` routing targets (pods beyond
    the live process are *virtual*: the coordinator reroutes their
    buckets through the straggler path), so the routed timings capture
    the routing + transport + reroute machinery itself.  Per-pod
    throughput is then measured HONESTLY: each pod's routed sub-stream
    is served on its own (what that pod of a real deployment would
    actually run) and warm-timed separately.
    """
    from repro.runtime import BatchSolver, ClusterBatchSolver
    from repro.runtime.cluster import bucket_tag

    timings = {}
    base = BatchSolver(opts)
    base_results = base.solve_stream(lps)

    # no explicit transport: the solver owns a private scratch dir and
    # cleans it up per stream (single-process virtual-pod mode)
    solver = ClusterBatchSolver(opts, pod=0, n_pods=n_pods, live_pods=1,
                                straggler_timeout=30.0)
    t0 = time.perf_counter(); results = block_until_ready(solver.solve_stream(lps))
    timings["routed_cold_s"] = time.perf_counter() - t0
    t0 = time.perf_counter(); results = block_until_ready(solver.solve_stream(lps))
    timings["routed_warm_s"] = time.perf_counter() - t0
    st = solver.last_stream_stats

    # per-pod shares from the solver's own audit surface (the table the
    # routing actually used — never re-derived here), throughput from
    # serving each pod's routed sub-stream separately
    buckets = solver._group_buckets(lps)
    pod_instances = {}
    per_pod = {}
    for key, idxs in buckets.items():
        tag = bucket_tag(key)
        pod = st["routing"][tag]
        d = per_pod.setdefault(str(pod), {"n_buckets": 0, "n_instances": 0,
                                          "flops_cost": 0})
        d["n_buckets"] += 1
        d["n_instances"] += solver.last_bucket_sizes[tag]
        d["flops_cost"] += solver.last_costs[tag]
        pod_instances.setdefault(str(pod), []).extend(
            lps[i] for i in idxs)
    total_cost = max(sum(d["flops_cost"] for d in per_pod.values()), 1)
    for pod, d in per_pod.items():
        d["flops_share"] = d["flops_cost"] / total_cost
        pod_solver = BatchSolver(opts)
        pod_solver.solve_stream(pod_instances[pod])          # compile
        t0 = time.perf_counter(); block_until_ready(pod_solver.solve_stream(pod_instances[pod]))
        d["warm_s"] = time.perf_counter() - t0
        d["instances_per_s_warm"] = d["n_instances"] / max(d["warm_s"],
                                                           1e-12)

    agree = max(abs(r.obj - b.obj) / max(abs(b.obj), 1e-12)
                for r, b in zip(results, base_results))
    return {
        **timings,
        "n_pods": n_pods,
        "routing": dict(st["routing"]),
        "per_pod": per_pod,
        "rerouted_buckets": int(st["rerouted_buckets"]),
        "gather_s": st.get("gather_s", 0.0),
        "max_rel_disagreement_vs_unrouted": float(agree),
        "mvm_total_routed": int(sum(r.mvm_calls for r in results)),
        "iters_routed": _iter_stats(results),
    }


def bench_device(lps, opts, device):
    """CrossbarBatchSolver vs. a per-instance solve_crossbar_jit loop.

    The loop pads each instance to the same device-tile bucket the batch
    path uses (a crossbar burns whole tiles either way), so the delta is
    pure batching + dispatch, not array size.
    """
    import jax
    from repro.crossbar import CrossbarBatchSolver, solve_crossbar_jit
    from repro.runtime.batch import bucket_dims, pad_problem

    tile = (device.crossbar_rows, device.crossbar_cols)

    def per_instance():
        reports = []
        for i, lp in enumerate(lps):
            padded = pad_problem(lp, *bucket_dims(*lp.K.shape, tile=tile))
            reports.append(solve_crossbar_jit(
                padded, opts, device=device,
                key=jax.random.PRNGKey(opts.seed + i)))
        return reports

    timings = {}
    t0 = time.perf_counter(); loop_reports = block_until_ready(per_instance())
    timings["per_instance_cold_s"] = time.perf_counter() - t0
    t0 = time.perf_counter(); loop_reports = block_until_ready(per_instance())
    timings["per_instance_warm_s"] = time.perf_counter() - t0

    solver = CrossbarBatchSolver(opts, device=device)
    t0 = time.perf_counter(); reports = block_until_ready(solver.solve_stream(lps))
    timings["batched_cold_s"] = time.perf_counter() - t0
    t0 = time.perf_counter(); reports = block_until_ready(solver.solve_stream(lps))
    timings["batched_warm_s"] = time.perf_counter() - t0

    gaps = [abs(rep.result.obj - lp.obj_opt) / max(abs(lp.obj_opt), 1e-12)
            for lp, rep in zip(lps, reports)]
    return {
        **timings,
        "speedup_warm": timings["per_instance_warm_s"]
        / max(timings["batched_warm_s"], 1e-12),
        "warm_compiles": solver.last_stream_stats["compiles"],
        "cache": solver.cache_info(),
        "max_rel_gap": float(max(gaps)),
        "ledger_batched": _sum_ledgers(reports),
        "ledger_per_instance": _sum_ledgers(loop_reports),
        "mvm_total_batched": int(sum(rep.result.mvm_calls
                                     for rep in reports)),
        "mvm_total_per_instance": int(sum(rep.result.mvm_calls
                                          for rep in loop_reports)),
        "iters_batched": _iter_stats(reports),
        "iters_per_instance": _iter_stats(loop_reports),
    }


def bench_adaptive(lps, opts):
    """step_rule="adaptive" vs "fixed" on the scale-imbalanced stream —
    the acceptance gate's measurement: per-instance iterations-to-tol
    under both rules through the SAME BatchSolver serving path, plus the
    warm/cold wall clock of the adaptive stream.

    ``iter_reduction_median`` is the median of per-instance
    fixed/adaptive iteration ratios; every adaptive instance must reach
    the same tol (``n_unconverged_*`` records any censoring at
    max_iters, which deflates the measured reduction rather than
    inflating it)."""
    import dataclasses

    from repro.runtime import BatchSolver

    timings = {}
    solver_f = BatchSolver(opts)
    t0 = time.perf_counter(); r_fixed = block_until_ready(solver_f.solve_stream(lps))
    timings["fixed_cold_s"] = time.perf_counter() - t0
    t0 = time.perf_counter(); r_fixed = block_until_ready(solver_f.solve_stream(lps))
    timings["fixed_warm_s"] = time.perf_counter() - t0

    solver_a = BatchSolver(dataclasses.replace(opts,
                                               step_rule="adaptive"))
    t0 = time.perf_counter(); r_adapt = block_until_ready(solver_a.solve_stream(lps))
    timings["adaptive_cold_s"] = time.perf_counter() - t0
    t0 = time.perf_counter(); r_adapt = block_until_ready(solver_a.solve_stream(lps))
    timings["adaptive_warm_s"] = time.perf_counter() - t0

    ratios = [f.iterations / max(a.iterations, 1)
              for f, a in zip(r_fixed, r_adapt)]
    return {
        **timings,
        "iters_fixed": _iter_stats(r_fixed),
        "iters_adaptive": _iter_stats(r_adapt),
        "iter_reduction_median": float(np.median(ratios)),
        "iter_reduction_p10": float(np.percentile(ratios, 10)),
        "n_unconverged_fixed": int(sum(not r.converged for r in r_fixed)),
        "n_unconverged_adaptive": int(sum(not r.converged
                                          for r in r_adapt)),
        "max_merit_adaptive": float(max(r.merit for r in r_adapt)),
        "warm_compiles": solver_a.last_stream_stats["compiles"],
        "speedup_warm": timings["fixed_warm_s"]
        / max(timings["adaptive_warm_s"], 1e-12),
        "mvm_total_fixed": int(sum(r.mvm_calls for r in r_fixed)),
        "mvm_total_adaptive": int(sum(r.mvm_calls for r in r_adapt)),
    }


def bench_refinement(opts, device):
    """Mixed-precision iterative-refinement acceptance experiment: on an
    instance where the exact path converges but the analog solve bottoms
    out at the read-noise floor, the refined crossbar path (digital
    residual outer loop re-solving the correction LP on the SAME
    programmed conductances) must recover exact-path accuracy with ZERO
    additional write cycles — ``bench_guard --min-refine-accuracy``
    gates the unrefined/refined merit ratio and the write-cells delta.

    The instance, iteration budget and ``sigma_read`` are fixed
    (independent of ``--smoke``): this measures convergence behaviour,
    not throughput, and the contrast needs a noise level where the
    single solve demonstrably fails.
    """
    import dataclasses

    import jax
    from repro.core import solve_jit
    from repro.crossbar import solve_crossbar_jit
    from repro.lp import random_standard_lp

    lp = random_standard_lp(16, 28, seed=3)
    noisy = dataclasses.replace(device,
                                sigma_read=max(device.sigma_read, 2e-3))
    base = dataclasses.replace(opts, max_iters=8000, tol=1e-6,
                               check_every=64, refine_rounds=0,
                               refine_tol=0.0)
    refined_opts = dataclasses.replace(base, refine_rounds=4,
                                       refine_tol=base.tol)

    exact = solve_jit(lp, base)
    rep0 = solve_crossbar_jit(lp, base, device=noisy,
                              key=jax.random.PRNGKey(base.seed))
    t0 = time.perf_counter()
    rep1 = block_until_ready(solve_crossbar_jit(
        lp, refined_opts, device=noisy,
        key=jax.random.PRNGKey(base.seed)))
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rep1 = block_until_ready(solve_crossbar_jit(
        lp, refined_opts, device=noisy,
        key=jax.random.PRNGKey(base.seed)))
    warm_s = time.perf_counter() - t0

    merit_ref = rep1.result.merit
    return {
        "cold_s": cold_s, "warm_s": warm_s,
        "tol": base.tol, "rounds": refined_opts.refine_rounds,
        "sigma_read": noisy.sigma_read,
        "merit_exact": float(exact.merit),
        "merit_unrefined": float(rep0.result.merit),
        "merit_refined": float(merit_ref),
        "accuracy_gain": float(rep0.result.merit / max(merit_ref, 1e-300)),
        "status_unrefined": rep0.result.status,
        "status_refined": rep1.result.status,
        "refined_reached_tol": bool(merit_ref <= base.tol),
        "cells_written_unrefined": int(rep0.ledger.cells_written),
        "cells_written_refined": int(rep1.ledger.cells_written),
        "write_cells_delta": int(rep1.ledger.cells_written
                                 - rep0.ledger.cells_written),
        "executed_iterations": int(rep1.executed_iterations),
        "digital_mvms": int(rep1.digital_mvms),
        "mvm_total": int(rep1.result.mvm_calls),
        "mvm_total_unrefined": int(rep0.result.mvm_calls),
    }


def bench_norm_reuse(lps, opts):
    """Cross-instance norm reuse: pass 2 of the same stream is served by
    the seeded executables (short power refine instead of full Lanczos).
    Records the warm-pass compile count (must stay 0: the seeded twin is
    compiled eagerly during the cold pass) and the per-pass MVM ledgers
    whose delta is the reused Lanczos work."""
    from repro.runtime import BatchSolver

    solver = BatchSolver(opts, norm_reuse=True)
    t0 = time.perf_counter(); r1 = block_until_ready(solver.solve_stream(lps))
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter(); r2 = block_until_ready(solver.solve_stream(lps))
    warm_s = time.perf_counter() - t0
    agree = max(abs(a.obj - b.obj) / max(abs(b.obj), 1e-12)
                for a, b in zip(r2, r1))
    return {
        "cold_s": cold_s,
        "warm_s": warm_s,
        "warm_compiles": solver.last_stream_stats["compiles"],
        "norm_seeded_buckets":
            solver.last_stream_stats["norm_seeded_buckets"],
        "cache_entries": len(solver._norm_cache),
        "mvm_total_cold": int(sum(r.mvm_calls for r in r1)),
        "mvm_total_warm": int(sum(r.mvm_calls for r in r2)),
        "max_rel_disagreement_vs_cold": float(agree),
        "iters_warm": _iter_stats(r2),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small stream + loose tolerance (CI)")
    ap.add_argument("--instances", type=int, default=None,
                    help="stream length (default: 16 smoke / 32 full)")
    ap.add_argument("--device", default="epiram",
                    choices=["epiram", "taox"])
    ap.add_argument("--kernel", default="jnp", choices=["jnp", "pallas"],
                    help="engine update backend (pallas = fused kernels; "
                         "on the crossbar path also the differential-pair "
                         "MVM kernel)")
    ap.add_argument("--max-iters", type=int, default=None)
    ap.add_argument("--tol", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pods", type=int, default=2,
                    help="routing targets for the cluster path (pods "
                         "beyond the live process are virtual)")
    ap.add_argument("--out", default=None,
                    help="JSON output path (default under experiments/)")
    args = ap.parse_args(argv)

    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.core import PDHGOptions
    from repro.crossbar import DEVICES

    n = args.instances if args.instances is not None \
        else (16 if args.smoke else 32)
    shapes = SMOKE_SHAPES if args.smoke else FULL_SHAPES
    max_iters = args.max_iters if args.max_iters is not None \
        else (2000 if args.smoke else 20000)
    # the device path bottoms out at the read-noise floor; don't ask the
    # while_loop to chase an unreachable tolerance in smoke mode
    tol = args.tol if args.tol is not None else (1e-3 if args.smoke else 1e-5)
    device = DEVICES["EpiRAM" if args.device == "epiram" else "TaOx-HfOx"]
    opts = PDHGOptions(max_iters=max_iters, tol=tol, check_every=64,
                       lanczos_iters=16 if args.smoke else 48,
                       seed=args.seed, kernel=args.kernel)

    from repro.lp import sparse_lp_stream

    lps = build_stream(n, shapes, seed=args.seed)
    sparse_shapes = SPARSE_SMOKE_SHAPES if args.smoke else SPARSE_FULL_SHAPES
    sparse_lps = sparse_lp_stream(n, sparse_shapes, density=SPARSE_DENSITY,
                                  seed=args.seed)
    record = {
        "config": {
            "n_instances": n, "shapes": [list(s) for s in shapes],
            "sparse_shapes": [list(s) for s in sparse_shapes],
            "sparse_density": SPARSE_DENSITY,
            "max_iters": max_iters, "tol": tol, "device": device.name,
            "tile": [device.crossbar_rows, device.crossbar_cols],
            "kernel": args.kernel,
            "smoke": bool(args.smoke), "seed": args.seed,
            "jax": jax.__version__,
        },
        "exact": bench_exact(lps, opts),
        "crossbar": bench_device(lps, opts, device),
        "sparse": bench_sparse(sparse_lps, opts),
        "async": bench_async(lps, opts),
        "cluster": bench_cluster(lps, opts, n_pods=args.pods),
    }

    # the adaptive acceptance stream: scale-imbalanced instances with a
    # generous iteration budget so the FIXED baseline is not censored at
    # max_iters (censoring deflates the measured reduction)
    import dataclasses
    imb_lps = build_imbalanced_stream(min(n, 8 if args.smoke else 16),
                                      shapes, seed=args.seed)
    adapt_opts = dataclasses.replace(
        opts, max_iters=max(max_iters, 20000 if args.smoke else 40000))
    record["adaptive"] = bench_adaptive(imb_lps, adapt_opts)
    record["norm_reuse"] = bench_norm_reuse(lps, opts)
    record["refinement"] = bench_refinement(opts, device)

    out = args.out or os.path.join(
        "experiments",
        "stream_throughput_smoke.json" if args.smoke
        else "stream_throughput.json")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    with open(out, "w") as f:
        json.dump(record, f, indent=1)

    # Compact perf-baseline record for future PRs: per-path warm/cold
    # seconds + device-MVM totals, written at the repo root so CI can
    # upload it as a stable-named artifact next to the full record and
    # ``bench_guard.py`` can gate schema + warm-path regressions on it.
    from repro.runtime import sanitize

    bench = {
        "schema": "bench_stream/v7",
        "kernel": args.kernel,
        "config": record["config"],
        # runtime-sanitizer surface: XLA compilations during each warm
        # serving pass.  The executable-cache contract says all of these
        # are 0; ``bench_guard --max-warm-compiles 0`` gates it in CI.
        "sanitize": {
            "compile_counting": bool(sanitize.supported()),
            "warm_compiles": {
                "exact_batched": record["exact"]["warm_compiles"],
                "sparse_batched": record["sparse"]["warm_compiles"],
                "crossbar_batched": record["crossbar"]["warm_compiles"],
                "adaptive_batched": record["adaptive"]["warm_compiles"],
                "norm_reuse_batched":
                    record["norm_reuse"]["warm_compiles"],
            },
        },
        "paths": {
            **{
                f"{path}_{variant}": {
                    "cold_s": record[path][f"{variant}_cold_s"],
                    "warm_s": record[path][f"{variant}_warm_s"],
                    "mvm_total": record[path][f"mvm_total_{variant}"],
                }
                for path in ("exact", "crossbar")
                for variant in ("batched", "per_instance")
            },
            "sparse_batched": {
                "cold_s": record["sparse"]["sparse_cold_s"],
                "warm_s": record["sparse"]["sparse_warm_s"],
                "mvm_total": record["sparse"]["mvm_total_sparse"],
            },
            "sparse_batched_dense": {
                "cold_s": record["sparse"]["dense_cold_s"],
                "warm_s": record["sparse"]["dense_warm_s"],
                "mvm_total": record["sparse"]["mvm_total_dense"],
            },
            # the default sparse pipeline IS the ELL backend; the
            # explicit entry keeps the backend comparison stable even
            # if the default ever changes
            "sparse_ell": {
                "cold_s": record["sparse"]["sparse_cold_s"],
                "warm_s": record["sparse"]["sparse_warm_s"],
                "mvm_total": record["sparse"]["mvm_total_sparse"],
            },
            "sparse_bcoo": {
                "cold_s": record["sparse"]["bcoo_cold_s"],
                "warm_s": record["sparse"]["bcoo_warm_s"],
                "mvm_total": record["sparse"]["mvm_total_bcoo"],
            },
            "sparse_ell_mega": {
                "cold_s": record["sparse"]["ell_mega_cold_s"],
                "warm_s": record["sparse"]["ell_mega_warm_s"],
                "mvm_total": record["sparse"]["mvm_total_ell_mega"],
            },
            "exact_batched_async": {
                "cold_s": record["async"]["async_cold_s"],
                "warm_s": record["async"]["async_warm_s"],
                "mvm_total": record["async"]["mvm_total_async"],
            },
            "exact_batched_sync": {
                "cold_s": record["async"]["sync_cold_s"],
                "warm_s": record["async"]["sync_warm_s"],
                "mvm_total": record["async"]["mvm_total_sync"],
            },
            "exact_routed": {
                "cold_s": record["cluster"]["routed_cold_s"],
                "warm_s": record["cluster"]["routed_warm_s"],
                "mvm_total": record["cluster"]["mvm_total_routed"],
            },
            # v6: the adaptive step rule served on the imbalanced
            # acceptance stream (its fixed-rule twin rides in the
            # top-level "adaptive" section, same stream, same opts)
            "exact_adaptive": {
                "cold_s": record["adaptive"]["adaptive_cold_s"],
                "warm_s": record["adaptive"]["adaptive_warm_s"],
                "mvm_total": record["adaptive"]["mvm_total_adaptive"],
            },
            "exact_norm_reuse": {
                "cold_s": record["norm_reuse"]["cold_s"],
                "warm_s": record["norm_reuse"]["warm_s"],
                "mvm_total": record["norm_reuse"]["mvm_total_warm"],
            },
            # v7: the iterative-refinement crossbar solve (acceptance
            # instance; convergence details in the "refinement" section)
            "crossbar_refined": {
                "cold_s": record["refinement"]["cold_s"],
                "warm_s": record["refinement"]["warm_s"],
                "mvm_total": record["refinement"]["mvm_total"],
            },
        },
        "cluster": {
            "n_pods": record["cluster"]["n_pods"],
            "routing": record["cluster"]["routing"],
            "per_pod": record["cluster"]["per_pod"],
            "rerouted_buckets": record["cluster"]["rerouted_buckets"],
            "max_rel_disagreement_vs_unrouted":
                record["cluster"]["max_rel_disagreement_vs_unrouted"],
        },
        # v6: per-instance iteration-count distributions per path — the
        # iteration-reduction gate reads these, and cross-PR drift in
        # them flags algorithmic (not wall-clock) regressions
        "adaptive": {
            "iter_reduction_median":
                record["adaptive"]["iter_reduction_median"],
            "iter_reduction_p10":
                record["adaptive"]["iter_reduction_p10"],
            "iters_fixed": record["adaptive"]["iters_fixed"],
            "iters_adaptive": record["adaptive"]["iters_adaptive"],
            "n_unconverged_fixed":
                record["adaptive"]["n_unconverged_fixed"],
            "n_unconverged_adaptive":
                record["adaptive"]["n_unconverged_adaptive"],
            "max_merit_adaptive":
                record["adaptive"]["max_merit_adaptive"],
            "tol": adapt_opts.tol,
        },
        "norm_reuse": {
            "norm_seeded_buckets":
                record["norm_reuse"]["norm_seeded_buckets"],
            "cache_entries": record["norm_reuse"]["cache_entries"],
            "mvm_total_cold": record["norm_reuse"]["mvm_total_cold"],
            "mvm_total_warm": record["norm_reuse"]["mvm_total_warm"],
            "max_rel_disagreement_vs_cold":
                record["norm_reuse"]["max_rel_disagreement_vs_cold"],
        },
        # v7: the refinement acceptance summary bench_guard's
        # --min-refine-accuracy gate reads — unrefined/refined merit
        # ratio and the zero-additional-writes evidence
        "refinement": {k: record["refinement"][k] for k in (
            "merit_exact", "merit_unrefined", "merit_refined",
            "accuracy_gain", "refined_reached_tol",
            "cells_written_unrefined", "cells_written_refined",
            "write_cells_delta", "digital_mvms", "rounds",
            "sigma_read", "tol")},
        "sparse": {
            "density": record["sparse"]["density"],
            "host_stack_bytes_dense":
                record["sparse"]["host_stack_bytes_dense"],
            "host_stack_bytes_sparse":
                record["sparse"]["host_stack_bytes_sparse"],
            "host_mem_improvement":
                record["sparse"]["host_mem_improvement"],
            "speedup_warm": record["sparse"]["speedup_warm"],
            "speedup_warm_bcoo": record["sparse"]["speedup_warm_bcoo"],
            "speedup_warm_ell_mega":
                record["sparse"]["speedup_warm_ell_mega"],
        },
    }
    # v6: every path entry carries its iterations-to-tol distribution
    iters_map = {
        "exact_batched": record["exact"]["iters_batched"],
        "exact_per_instance": record["exact"]["iters_per_instance"],
        "crossbar_batched": record["crossbar"]["iters_batched"],
        "crossbar_per_instance": record["crossbar"]["iters_per_instance"],
        "sparse_batched": record["sparse"]["iters_sparse"],
        "sparse_batched_dense": record["sparse"]["iters_dense"],
        "sparse_ell": record["sparse"]["iters_sparse"],
        "sparse_bcoo": record["sparse"]["iters_bcoo"],
        "sparse_ell_mega": record["sparse"]["iters_ell_mega"],
        "exact_batched_async": record["async"]["iters_async"],
        "exact_batched_sync": record["async"]["iters_sync"],
        "exact_routed": record["cluster"]["iters_routed"],
        "exact_adaptive": record["adaptive"]["iters_adaptive"],
        "exact_norm_reuse": record["norm_reuse"]["iters_warm"],
        # single acceptance instance: the distribution degenerates to
        # the executed (bucket-max, all-rounds) iteration count
        "crossbar_refined": {
            "median": float(record["refinement"]["executed_iterations"]),
            "p90": float(record["refinement"]["executed_iterations"]),
        },
    }
    for name, st in iters_map.items():
        bench["paths"][name]["iterations_to_tol"] = st

    bench_out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_stream.json")
    with open(bench_out, "w") as f:
        json.dump(bench, f, indent=1)

    for path in ("exact", "crossbar"):
        r = record[path]
        print(f"[{path}] per-instance warm {r['per_instance_warm_s']:.3f}s"
              f" | batched warm {r['batched_warm_s']:.3f}s"
              f" | speedup {r['speedup_warm']:.2f}x"
              f" | max rel gap {r['max_rel_gap']:.2e}"
              f" | cache {r['cache']}")
    r = record["sparse"]
    print(f"[sparse] dense warm {r['dense_warm_s']:.3f}s"
          f" | ell warm {r['sparse_warm_s']:.3f}s"
          f" ({r['speedup_warm']:.2f}x)"
          f" | bcoo warm {r['bcoo_warm_s']:.3f}s"
          f" ({r['speedup_warm_bcoo']:.2f}x)"
          f" | ell+mega warm {r['ell_mega_warm_s']:.3f}s"
          f" ({r['speedup_warm_ell_mega']:.2f}x)"
          f" | host stack {r['host_stack_bytes_dense']}B ->"
          f" {r['host_stack_bytes_sparse']}B"
          f" ({r['host_mem_improvement']:.1f}x smaller)"
          f" | density {r['density']:.3f}")
    r = record["async"]
    print(f"[async] sync warm {r['sync_warm_s']:.3f}s"
          f" | async warm {r['async_warm_s']:.3f}s"
          f" | speedup {r['speedup_warm']:.2f}x"
          f" | dispatch {r['dispatch_s']:.3f}s"
          f" collect {r['collect_s']:.3f}s over {r['n_buckets']} buckets")
    r = record["cluster"]
    pods = ", ".join(
        f"pod{p}: {d['n_buckets']}bkt/{d['n_instances']}inst "
        f"({d['flops_share']:.0%} FLOPs)"
        for p, d in sorted(r["per_pod"].items()))
    print(f"[cluster] routed warm {r['routed_warm_s']:.3f}s over "
          f"{r['n_pods']} pods | {pods} | rerouted "
          f"{r['rerouted_buckets']} | max disagreement "
          f"{r['max_rel_disagreement_vs_unrouted']:.2e}")
    r = record["adaptive"]
    print(f"[adaptive] fixed median {r['iters_fixed']['median']:.0f} it"
          f" (p90 {r['iters_fixed']['p90']:.0f})"
          f" | adaptive median {r['iters_adaptive']['median']:.0f} it"
          f" (p90 {r['iters_adaptive']['p90']:.0f})"
          f" | reduction {r['iter_reduction_median']:.2f}x"
          f" (p10 {r['iter_reduction_p10']:.2f}x)"
          f" | unconverged fixed/adaptive "
          f"{r['n_unconverged_fixed']}/{r['n_unconverged_adaptive']}")
    r = record["norm_reuse"]
    print(f"[norm_reuse] seeded buckets {r['norm_seeded_buckets']}"
          f" | cache entries {r['cache_entries']}"
          f" | mvms {r['mvm_total_cold']} -> {r['mvm_total_warm']}"
          f" | warm compiles {r['warm_compiles']}")
    r = record["refinement"]
    print(f"[refinement] unrefined merit {r['merit_unrefined']:.2e}"
          f" ({r['status_unrefined']})"
          f" | refined merit {r['merit_refined']:.2e}"
          f" ({r['status_refined']}, {r['rounds']} rounds)"
          f" | gain {r['accuracy_gain']:.1e}x"
          f" | write cells delta {r['write_cells_delta']}"
          f" | digital mvms {r['digital_mvms']}")
    led = record["crossbar"]["ledger_batched"]
    print(f"[crossbar] stream write={led['write_energy_j']:.3f}J "
          f"(padding {led['write_energy_padding_j']:.3f}J) "
          f"read={led['read_energy_j']:.3f}J mvms={led['mvm_count']:.0f}")
    print(f"wrote {out} and {bench_out}")
    return record


if __name__ == "__main__":
    main()
