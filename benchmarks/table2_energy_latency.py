"""Paper Table 2: optimality gap + total energy/latency per accelerator,
for the Lanczos and PDHG phases, with improvement factors over gpuPDLP."""
from __future__ import annotations

from ._shared import BACKENDS, cached_results, fmt_factor


def run(refresh: bool = False):
    res = cached_results(refresh)
    header = ("problem", "accelerator",
              "lanczos_gap", "lanczos_E_J", "lanczos_E_factor",
              "lanczos_t_s", "lanczos_t_factor",
              "pdhg_gap", "pdhg_E_J", "pdhg_E_factor",
              "pdhg_t_s", "pdhg_t_factor")
    rows = []
    for name, inst in res.items():
        gpu = inst["backends"]["gpuPDLP"]
        for bk in BACKENDS:
            b = inst["backends"][bk]
            is_gpu = bk == "gpuPDLP"
            rows.append((
                name, bk,
                f"{b['lanczos']['gap']:.2e}",
                f"{b['lanczos']['energy_j']:.4f}",
                "--" if is_gpu else fmt_factor(gpu["lanczos"]["energy_j"],
                                               b["lanczos"]["energy_j"]),
                f"{b['lanczos']['latency_s']:.4f}",
                "--" if is_gpu else fmt_factor(gpu["lanczos"]["latency_s"],
                                               b["lanczos"]["latency_s"]),
                f"{b['pdhg']['gap']:.2e}",
                f"{b['pdhg']['energy_j']:.4f}",
                "--" if is_gpu else fmt_factor(gpu["pdhg"]["energy_j"],
                                               b["pdhg"]["energy_j"]),
                f"{b['pdhg']['latency_s']:.4f}",
                "--" if is_gpu else fmt_factor(gpu["pdhg"]["latency_s"],
                                               b["pdhg"]["latency_s"]),
            ))
    return header, rows


def main():
    header, rows = run()
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
