"""Roofline report: reads experiments/dryrun/*.json (written by
repro.launch.dryrun) and renders the per-cell three-term roofline table
used in EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR",
                            os.path.join("experiments", "dryrun"))


def load_cells(directory: str = DRYRUN_DIR):
    cells = {}
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            cells[os.path.basename(path)[:-5]] = json.load(f)
    return cells


def run(directory: str = DRYRUN_DIR, mesh_filter: str | None = None):
    header = ("cell", "chips", "peak_GiB/dev", "compute_s", "memory_s",
              "collective_s", "bottleneck", "model_flops_ratio",
              "compile_s", "status")
    rows = []
    for name, c in load_cells(directory).items():
        if mesh_filter and not name.endswith(mesh_filter):
            continue
        if "skipped" in c:
            rows.append((name, "--", "--", "--", "--", "--", "--", "--",
                         "--", "SKIP(" + c["skipped"][:40] + ")"))
            continue
        if "error" in c:
            rows.append((name, "--", "--", "--", "--", "--", "--", "--",
                         "--", "FAIL(" + c["error"][:60] + ")"))
            continue
        rf = c["roofline"]
        rows.append((
            name, c["n_chips"],
            f"{c['memory']['peak_per_device_bytes'] / 2**30:.2f}",
            f"{rf['compute_s']:.3e}", f"{rf['memory_s']:.3e}",
            f"{rf['collective_s']:.3e}", rf["bottleneck"].replace("_s", ""),
            f"{rf.get('model_flops_ratio', 0.0):.3f}",
            f"{c['compile_s']:.0f}", "OK",
        ))
    return header, rows


def main():
    header, rows = run()
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
