# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark aggregator.

Each paper table/figure has a module; this runner executes them all and
emits one summary CSV line per benchmark in the required
``name,us_per_call,derived`` format (us_per_call = wall microseconds per
primary solve/lower unit; derived = the benchmark's headline metric),
followed by the full tables.
"""
from __future__ import annotations

import sys
import time


def _emit(name, us_per_call, derived):
    print(f"{name},{us_per_call:.1f},{derived}")


def main() -> None:
    t_all = time.perf_counter()
    summaries = []
    full_outputs = []

    # Table 1 — instances
    from . import table1_instances
    t0 = time.perf_counter()
    h, rows = table1_instances.run()
    # callee returns host rows; its own windows are fenced (R7)
    dt = (time.perf_counter() - t0) / max(len(rows), 1)  # jaxlint: disable=R7
    summaries.append(("table1_instances", dt * 1e6,
                      f"instances={len(rows)}"))
    full_outputs.append(("TABLE 1 — problem instances", h, rows))

    # Tables 2/3/4/5 share the solve cache
    from . import table2_energy_latency, table3_overall, table4_lanczos, \
        table5_pdhg
    from ._shared import cached_results
    t0 = time.perf_counter()
    res = cached_results()
    n_solves = sum(len(v["backends"]) for v in res.values())
    # cached_results fences per-solve inside _shared (R7)
    solve_us = (time.perf_counter() - t0) / max(n_solves, 1) * 1e6  # jaxlint: disable=R7

    h, rows = table2_energy_latency.run()
    # headline: median PDHG energy factor for TaOx-HfOx
    import statistics
    factors = []
    for r in rows:
        if r[1] == "TaOx-HfOx" and r[9] != "--":
            factors.append(float(r[9].rstrip("x")))
    med = statistics.median(factors) if factors else 0.0
    summaries.append(("table2_energy_latency", solve_us,
                      f"median_taox_pdhg_energy_factor={med:.1f}x"))
    full_outputs.append(("TABLE 2 — energy/latency + factors", h, rows))

    h, rows = table3_overall.run()
    summaries.append(("table3_overall", solve_us, f"problems={len(rows)}"))
    full_outputs.append(("TABLE 3 — overall improvement factors", h, rows))

    h, rows = table4_lanczos.run()
    summaries.append(("table4_lanczos", solve_us, f"rows={len(rows)}"))
    full_outputs.append(("TABLE 4 — Lanczos breakdown", h, rows))

    h, rows = table5_pdhg.run()
    summaries.append(("table5_pdhg", solve_us, f"rows={len(rows)}"))
    full_outputs.append(("TABLE 5 — PDHG breakdown", h, rows))

    # Figure 2 — convergence vs latency
    from . import fig2_convergence
    t0 = time.perf_counter()
    traces = fig2_convergence.run()
    # fig2 traces are host floats; sync forced inside run() (R7)
    dt = time.perf_counter() - t0  # jaxlint: disable=R7
    final_gap = traces["TaOx-HfOx"][-1][2]
    summaries.append(("fig2_convergence", dt * 1e6 / 3,
                      f"taox_final_gap={final_gap:.2e}"))
    full_outputs.append((
        "FIGURE 2 — convergence vs latency (CSV in experiments/fig2)",
        ("accelerator", "checkpoints", "final_gap", "final_latency_s"),
        [(k, len(v), f"{v[-1][2]:.2e}", f"{v[-1][0]:.2f}")
         for k, v in traces.items()],
    ))

    # Roofline table from dry-run artifacts (if present)
    from . import roofline
    h, rows = roofline.run()
    ok = sum(1 for r in rows if r[-1] == "OK")
    summaries.append(("roofline", 0.0,
                      f"cells_ok={ok}/{len(rows)}"))
    if rows:
        full_outputs.append(("ROOFLINE — per (arch x shape x mesh)", h,
                             rows))

    print("name,us_per_call,derived")
    for s in summaries:
        _emit(*s)
    print()
    for title, h, rows in full_outputs:
        print(f"== {title} ==")
        print(",".join(h))
        for r in rows:
            print(",".join(str(x) for x in r))
        print()
    # whole-process wall time, not a device measurement (R7)
    print(f"total benchmark wall time: {time.perf_counter() - t_all:.1f}s",  # jaxlint: disable=R7
          file=sys.stderr)


if __name__ == "__main__":
    main()
