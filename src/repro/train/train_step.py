"""Training step: loss, grad, microbatching, optional int8 grad compression.

``make_train_step(cfg)`` builds the jittable  (params, opt_state, batch)
-> (params, opt_state, metrics)  function the launcher lowers for the
dry-run.  Batch = {"tokens" | "embeddings", "labels"}; loss is next-token
cross-entropy with label shift handled by the data pipeline (labels are
pre-shifted).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..models import lm as lm_mod
from ..models.config import ModelConfig
from . import optimizer as opt_mod


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    weight_decay: float = 0.1
    microbatch: int = 0               # 0 = no gradient accumulation
    remat: bool = True
    remat_policy: Optional[str] = None
    moe_aux_weight: float = 0.01
    z_loss: float = 1e-4


def cross_entropy(logits, labels, z_loss: float = 0.0):
    """Mean next-token xent; logits (B,S,V) f32-accumulated; z-loss reg."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss > 0.0:
        loss = loss + z_loss * jnp.mean(lse * lse)
    return loss


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig):
    def loss_fn(params, batch):
        logits = lm_mod.forward(
            params, cfg,
            tokens=batch.get("tokens"),
            embeddings=batch.get("embeddings"),
            remat=tcfg.remat, remat_policy=tcfg.remat_policy,
        )
        loss = cross_entropy(logits, batch["labels"], tcfg.z_loss)
        return loss, {"loss": loss}

    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    loss_fn = make_loss_fn(cfg, tcfg)
    if tcfg.optimizer == "adamw":
        ocfg = opt_mod.AdamWConfig(lr=tcfg.lr, weight_decay=tcfg.weight_decay)
        opt_update = functools.partial(opt_mod.adamw_update, ocfg)
    else:
        ocfg = opt_mod.AdafactorConfig(lr=tcfg.lr)
        opt_update = functools.partial(opt_mod.adafactor_update, ocfg)

    def grads_of(params, batch):
        if tcfg.microbatch and tcfg.microbatch > 1:
            # gradient accumulation over leading-dim splits of the batch
            nm = tcfg.microbatch

            def split(x):
                b = x.shape[0]
                return x.reshape(nm, b // nm, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, _aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (g, loss_sum), _ = jax.lax.scan(acc_body, (g0, 0.0), micro)
            g = jax.tree.map(lambda x: x / nm, g)
            return loss_sum / nm, g
        (loss, _aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, g

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        new_params, new_opt = opt_update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss}

    return train_step


def init_opt_state(cfg_or_params, tcfg: TrainConfig = TrainConfig()):
    params = cfg_or_params
    if tcfg.optimizer == "adamw":
        return opt_mod.adamw_init(params)
    return opt_mod.adafactor_init(params)


def opt_state_shapes(params_shapes, tcfg: TrainConfig = TrainConfig()):
    """ShapeDtypeStruct pytree of the optimizer state (dry-run input)."""
    init = (opt_mod.adamw_init if tcfg.optimizer == "adamw"
            else opt_mod.adafactor_init)
    return jax.eval_shape(init, params_shapes)
