"""Synthetic deterministic data pipeline.

Counter-based PRNG (threefry on (epoch, step)) => any batch is
reconstructable from its step index alone: restarts and elastic rescales
re-produce the exact token stream with zero coordination state.  A small
host-side prefetch queue hides generation latency.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    embeddings_dim: int = 0   # >0 => emit stub frontend embeddings


def synth_batch(cfg: DataConfig, step: int) -> dict:
    """Deterministic batch for a global step (Zipf-ish token marginals)."""
    rng = np.random.default_rng(np.uint64(cfg.seed * 1_000_003 + step))
    # Zipf-like distribution capped to the vocab (realistic marginals)
    z = rng.zipf(1.3, size=(cfg.batch, cfg.seq_len + 1)).astype(np.int64)
    tokens = (z % cfg.vocab).astype(np.int32)
    out = {
        "tokens": tokens[:, :-1],
        "labels": tokens[:, 1:].astype(np.int32),
    }
    if cfg.embeddings_dim > 0:
        out["embeddings"] = rng.standard_normal(
            (cfg.batch, cfg.seq_len, cfg.embeddings_dim), dtype=np.float32)
        del out["tokens"]
    return out


class Prefetcher:
    """Background thread generating batches ahead of the consumer."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self._q: "queue.Queue[dict]" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = synth_batch(self.cfg, step)
            try:
                self._q.put(batch, timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
