"""Serving steps: batched prefill and single-token decode.

``serve_step`` is what decode_* / long_* dry-run cells lower: one new
token against a KV cache of the cell's seq_len.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import lm as lm_mod
from ..models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, last_only: bool = True):
    """Prefill: run the backbone over the prompt, emit last-token logits.

    ``last_only=True`` (default after hillclimb 2) projects ONLY the final
    hidden state through the vocab head; ``False`` reproduces the naive
    baseline that materializes (B, S, V) logits first.
    """
    def prefill(params, batch):
        logits = lm_mod.forward(
            params, cfg,
            tokens=batch.get("tokens"),
            embeddings=batch.get("embeddings"),
            remat=False,
            last_only=last_only,
        )
        return logits[:, -1, :]

    return prefill


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, tokens, cache):
        logits, new_cache = lm_mod.decode_step(params, cfg, tokens, cache)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache

    return serve_step


def greedy_generate(params, cfg: ModelConfig, prompt, max_new: int,
                    max_len: int):
    """Host loop generation (examples/tests; small configs)."""
    B, S = prompt.shape
    cache = lm_mod.init_cache(cfg, B, max_len)
    serve = jax.jit(make_serve_step(cfg))
    # prefill token-by-token through the decode path (simple + exact)
    tok = prompt[:, :1]
    for i in range(S - 1):
        _, _, cache = serve(params, prompt[:, i : i + 1], cache)
    out = [prompt]
    tok = prompt[:, -1:]
    for _ in range(max_new):
        nxt, _, cache = serve(params, tok, cache)
        tok = nxt[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
