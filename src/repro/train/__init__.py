"""Training/serving substrate."""
from .optimizer import (
    AdafactorConfig,
    AdamWConfig,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
)
from .train_step import (
    TrainConfig,
    cross_entropy,
    init_opt_state,
    make_loss_fn,
    make_train_step,
    opt_state_shapes,
)
from .serve_step import greedy_generate, make_prefill_step, make_serve_step
from .data import DataConfig, Prefetcher, synth_batch
from .checkpoint import load_train_state, place, save_train_state

__all__ = [
    "AdafactorConfig", "AdamWConfig", "adafactor_init", "adafactor_update",
    "adamw_init", "adamw_update", "TrainConfig", "cross_entropy",
    "init_opt_state", "make_loss_fn", "make_train_step", "opt_state_shapes",
    "greedy_generate", "make_prefill_step", "make_serve_step", "DataConfig",
    "Prefetcher", "synth_batch", "load_train_state", "place",
    "save_train_state",
]
