"""Sharding-aware training checkpoints (thin wrapper over distributed.fault).

Pytrees are flattened to path-keyed arrays; restore re-places leaves onto
the current mesh with the model's partition specs — so a checkpoint
written on 512 chips restores onto 256 (elastic downscale) or onto the
CPU host (debugging) unchanged.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np

from ..distributed import fault


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return root


def save_train_state(path: str, step: int, params, opt_state,
                     meta: Optional[dict] = None) -> str:
    arrays = {}
    arrays.update({f"params/{k}": v for k, v in _flatten(params).items()})
    arrays.update({f"opt/{k}": v for k, v in _flatten(opt_state).items()})
    return fault.save_checkpoint(path, step, arrays, meta)


def load_train_state(path: str):
    ck = fault.load_checkpoint(path)
    params = _unflatten({k[len("params/"):]: v for k, v in ck.arrays.items()
                         if k.startswith("params/")})
    opt_state = _unflatten({k[len("opt/"):]: v for k, v in ck.arrays.items()
                            if k.startswith("opt/")})
    return ck.step, params, opt_state, ck.meta


def place(tree, mesh, specs_tree):
    """device_put a host pytree with a parallel PartitionSpec pytree."""
    from jax.sharding import NamedSharding

    def go(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree.map(go, tree, specs_tree)
