"""Optimizers built in-repo (no optax in the container).

AdamW keeps f32 moments regardless of param dtype (mixed-precision
convention); Adafactor-mini keeps factored second moments only (the
low-memory option for the 314B-class configs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params
                 ) -> Tuple[Any, Any]:
    step = opt_state["step"] + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32) * clip
        m_n = cfg.b1 * m + (1 - cfg.b1) * g32
        v_n = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        t = step.astype(jnp.float32)
        m_hat = m_n / (1 - cfg.b1 ** t)
        v_hat = v_n / (1 - cfg.b2 ** t)
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_n = (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype)
        return p_n, m_n, v_n

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


# ----------------------------------------------------- Adafactor-mini ---

@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    decay: float = 0.8
    eps: float = 1e-30
    grad_clip: float = 1.0


def adafactor_init(params):
    def fac(p):
        if p.ndim >= 2:
            return {
                "row": jnp.zeros(p.shape[:-1], jnp.float32),
                "col": jnp.zeros((*p.shape[:-2], p.shape[-1]), jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"f": jax.tree.map(fac, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: AdafactorConfig, grads, opt_state, params):
    step = opt_state["step"] + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-cfg.decay)
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(g, f, p):
        g32 = g.astype(jnp.float32) * clip
        if p.ndim >= 2:
            row = beta * f["row"] + (1 - beta) * jnp.mean(
                g32 * g32, axis=-1)
            col = beta * f["col"] + (1 - beta) * jnp.mean(
                g32 * g32, axis=-2)
            rf = row / jnp.maximum(
                jnp.mean(row, axis=-1, keepdims=True), cfg.eps)
            vhat = rf[..., None] * col[..., None, :]
            new_f = {"row": row, "col": col}
        else:
            vhat = beta * f["v"] + (1 - beta) * g32 * g32
            new_f = {"v": vhat}
        delta = g32 / jnp.sqrt(jnp.maximum(vhat, cfg.eps))
        p_n = (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype)
        return p_n, new_f

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    is_f = lambda x: isinstance(x, dict) and ("row" in x or "v" in x)  # noqa: E731
    flat_f = jax.tree.leaves(opt_state["f"], is_leaf=is_f)
    out = [upd(g, f, p) for g, f, p in zip(flat_g, flat_f, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_f = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_p, {"f": new_f, "step": step}
