"""Analytic GPU cost model — the paper's gpuPDLP baseline.

The paper measured a single NVIDIA Quadro RTX6000 (Zeus framework) on the
OSU Pete cluster.  This container has no GPU, so the baseline is an
analytic model with RTX6000-class constants.  The model is deliberately
simple and *favourable* to the GPU on large shapes (bandwidth-bound matmul
with a fixed per-iteration overhead); on the paper's small LPs the fixed
overhead dominates — exactly the regime where Tables 2-5 show the GPU
losing by 10^2-10^3 in energy.

Calibration (paper Table 5, gen-ip002: 834 J / 69.2 s over 2331 PDHG
iterations => ~29.7 ms and ~0.36 J per iteration on a (24,41) LP):
  * per-iteration fixed latency ~ 1.4e-2 s  (kernel launches, host sync,
    residual checks; PDLP-style implementations issue dozens of small
    kernels per iteration at these sizes)
  * active power draw ~ 60 W of a 260 W TDP card at tiny occupancy, plus
    idle draw folded in.
"""
from __future__ import annotations

import dataclasses

from .energy import Ledger

PCIE_BW = 12.0e9           # B/s effective host<->device
PCIE_EJ_PER_BYTE = 2.0e-8  # J/B transfer energy
H2D_FIXED_S = 5.0e-2       # cudaMalloc/stream setup per problem
H2D_FIXED_J = 2.2          # measured-by-Zeus style setup energy
GPU_FLOPS = 16.3e12        # RTX6000 fp32 peak
GPU_HBM_BW = 672.0e9       # B/s GDDR6
GPU_POWER_ACTIVE_W = 60.0  # small-kernel occupancy regime
ITER_FIXED_S = 1.4e-2      # per-PDHG-iteration launch+sync overhead
MVM_FIXED_S = 2.6e-3       # per standalone MVM (Lanczos) overhead


@dataclasses.dataclass
class GPUModel:
    name: str = "gpuPDLP"

    def h2d(self, nbytes: int, ledger: Ledger):
        t = H2D_FIXED_S + nbytes / PCIE_BW
        ledger.h2d_latency_s += t
        ledger.h2d_energy_j += H2D_FIXED_J + nbytes * PCIE_EJ_PER_BYTE

    def d2h(self, nbytes: int, ledger: Ledger):
        t = nbytes / PCIE_BW
        ledger.d2h_latency_s += t
        ledger.d2h_energy_j += 0.01 + nbytes * PCIE_EJ_PER_BYTE

    def _mvm_time(self, m: int, n: int) -> float:
        flops = 2.0 * m * n
        nbytes = 4.0 * (m * n + m + n)
        return max(flops / GPU_FLOPS, nbytes / GPU_HBM_BW)

    def pdhg_iteration(self, m: int, n: int, ledger: Ledger):
        """Two MVMs + ~10 vector kernels + host residual sync."""
        t = ITER_FIXED_S + 2 * self._mvm_time(m, n)
        ledger.solve_latency_s += t
        ledger.solve_energy_j += t * GPU_POWER_ACTIVE_W / 2.45
        ledger.mvm_count += 2

    def lanczos_iteration(self, dim: int, ledger: Ledger):
        t = MVM_FIXED_S + self._mvm_time(dim, dim)
        ledger.solve_latency_s += t
        ledger.solve_energy_j += t * GPU_POWER_ACTIVE_W / 2.2
        ledger.mvm_count += 1


RTX6000 = GPUModel()
