"""Distributed crossbar array: the logical analog accelerator (paper §6).

A grid of crossbar tiles holds the encoded symmetric block M.  One logical
MVM = broadcast input slices to every tile, each tile's analog MVM runs in
parallel, partial currents are summed along grid rows — no matrix movement,
no reprogramming.  This module provides:

  * ``CrossbarArray``    — the device-physics simulation (quantization,
                           programming error, cycle-to-cycle read noise,
                           energy/latency ledger).
  * ``crossbar_accel``   — an ``Accel`` factory so Algorithm 2-4 run on it
                           unchanged.
  * ``analog_linear``    — drop-in noisy/quantized linear op for arbitrary
                           dense layers (ties the paper's substrate to the
                           assigned LM architectures for inference demos).

The analog math itself is delegated to the Pallas crossbar kernel
(`repro.kernels.ops.crossbar_mvm`) when ``use_kernel=True``, or to the
pure-jnp reference implementation otherwise — both are validated against
each other in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.symblock import Accel, build_sym_block
from .device import DeviceModel, EPIRAM
from .encode import EncodedMatrix, encode_matrix
from .energy import Ledger


@dataclasses.dataclass
class CrossbarArray:
    enc: EncodedMatrix
    ledger: Ledger
    device: DeviceModel
    use_kernel: bool = False
    # base key for keyless ``mvm`` read noise, derived from the
    # programming key so the whole device history is one seed
    read_key: Optional[jax.Array] = None

    @classmethod
    def program(
        cls,
        W,
        device: DeviceModel = EPIRAM,
        key: Optional[jax.Array] = None,
        ledger: Optional[Ledger] = None,
        use_kernel: bool = False,
    ) -> "CrossbarArray":
        if key is None:
            # reproducible default: programming a crossbar with no key
            # must yield the same conductances every run
            key = jax.random.PRNGKey(0)  # jaxlint: disable=R2
        ledger = ledger if ledger is not None else Ledger()
        enc = encode_matrix(W, device, key, ledger=ledger)
        return cls(enc=enc, ledger=ledger, device=device,
                   use_kernel=use_kernel,
                   read_key=jax.random.fold_in(key, 0x52454144))

    def mvm(self, v, key: Optional[jax.Array] = None) -> jnp.ndarray:
        """One logical analog MVM: w = W @ v with device non-idealities."""
        dev = self.device
        enc = self.enc
        R, C = enc.g_pos.shape
        vp = jnp.zeros((C,), enc.g_pos.dtype).at[: enc.cols].set(
            jnp.asarray(v, enc.g_pos.dtype))
        if key is None:
            # fold the MVM count into the programming-derived read key:
            # cycle-to-cycle read noise must differ per call (a fixed
            # fallback key used to replay the SAME noise sample on every
            # keyless MVM, silently correlating whole solves)
            base = self.read_key
            if base is None:
                base = jax.random.PRNGKey(0)  # jaxlint: disable=R2
            key = jax.random.fold_in(base, self.ledger.mvm_count)
        if self.use_kernel:
            from ..kernels import ops as kops
            noise = dev.sigma_read * jax.random.normal(key, (R,), vp.dtype)
            w = kops.crossbar_mvm(enc.g_pos, enc.g_neg, vp, enc.scale, noise)
        else:
            w = (enc.g_pos - enc.g_neg) @ vp * enc.scale
            w = w * (1.0 + dev.sigma_read
                     * jax.random.normal(key, w.shape, w.dtype))
        # ledger: all tiles fire in parallel -> one read latency quantum;
        # energy scales with ACTIVE cells (zero conductances draw ~none).
        self.ledger.read_latency_s += dev.read_latency_s
        self.ledger.read_energy_j += (dev.read_energy_per_cell_j
                                      * enc.active_cells)
        self.ledger.mvm_count += 1
        return w[: enc.rows]


def crossbar_accel_factory(
    device: DeviceModel = EPIRAM,
    key: Optional[jax.Array] = None,
    ledger: Optional[Ledger] = None,
    use_kernel: bool = False,
):
    """Returns an ``accel_factory`` for ``core.pdhg.solve``: K -> Accel.

    Encodes the symmetric block M = [[0, K], [K^T, 0]] ONCE (Algorithm 1);
    every subsequent Algorithm-2 call is a read-only analog MVM.
    """
    led = ledger if ledger is not None else Ledger()

    def factory(K) -> Accel:
        M = build_sym_block(K)
        arr = CrossbarArray.program(
            M, device=device, key=key, ledger=led, use_kernel=use_kernel
        )
        m, n = K.shape

        def mvm(v, key=None):
            return arr.mvm(v, key=key)

        acc = Accel(mvm_full=mvm, m=m, n=n, name=f"crossbar:{device.name}")
        acc.ledger = led          # exposed for the benchmark harness
        acc.array = arr
        return acc

    factory.ledger = led
    return factory


def analog_linear(x, W, device: DeviceModel = EPIRAM, key=None):
    """Noisy/quantized linear op  x @ W^T  through the crossbar model.

    A convenience for running *inference* of the assigned LM architectures
    through the paper's device substrate (weights encoded once; activations
    stream).  Not used in training (the technique is inapplicable there;
    see DESIGN.md §Arch-applicability).
    """
    if key is None:
        # reproducible inference-demo default (weights + activations
        # share one seed; pass a key to decorrelate runs)
        key = jax.random.PRNGKey(0)  # jaxlint: disable=R2
    arr = CrossbarArray.program(jnp.asarray(W), device=device, key=key)
    xs = jnp.atleast_2d(x)
    k = jax.random.split(key, xs.shape[0])
    out = jnp.stack([arr.mvm(xi, key=ki) for xi, ki in zip(xs, k)])
    return out.reshape((*x.shape[:-1], W.shape[0]))
