"""Conductance encoding with write-verify (paper §3.1 + ref [40]).

RRAM conductances are non-negative, so a real matrix W is stored as a
differential pair  W ~ s * (G+ - G-)  with
    G+ = quantize(max(W, 0) / s),   G- = quantize(max(-W, 0) / s),
where s scales max|W| onto the device's usable conductance range (we work
in normalized conductance units g in [0, 1] with ``g_levels`` steps).

Write-verify: each cell is pulsed until its conductance is within half an
LSB of target; the residual error is modeled as a zero-mean Gaussian with
relative std ``sigma_program`` (device-to-device variability floor).  The
expected pulse count per cell drives the programming energy/latency ledger
entries — this is the "expensive writes" the encode-once strategy
amortizes.

Two layers:
  * ``encode_core``    — the pure device-physics map (quantize + residual
                         programming error), traced-scalar statistics
                         only.  Safe under ``jax.vmap`` — the batched
                         crossbar stream programs a whole (B, R, C) stack
                         of operators in one compiled call.
  * ``encode_matrix``  — eager single-matrix wrapper: tile padding,
                         ``EncodedMatrix`` handle, ledger side effects.

Ledger entries split LOGICAL cells (the operator itself) from PADDING
cells (programmed only because tiles/buckets are larger than the
operator; all-zero targets, one RESET pulse each), so the overhead of
device-tile-aligned bucketing is auditable per instance.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .device import DeviceModel
from .energy import Ledger


@dataclasses.dataclass
class EncodedMatrix:
    g_pos: jnp.ndarray      # (R, C) normalized conductances in [0, 1]
    g_neg: jnp.ndarray
    scale: float            # W ~ scale * (g_pos - g_neg)
    rows: int               # logical (unpadded) shape
    cols: int
    device: DeviceModel
    fill: float = 1.0       # fraction of programmed (nonzero) cells —
                            # zero-conductance cells draw ~no read current

    def decode(self) -> jnp.ndarray:
        return (self.g_pos - self.g_neg)[: self.rows, : self.cols] * self.scale

    @property
    def active_cells(self) -> float:
        return 2.0 * self.g_pos.shape[0] * self.g_pos.shape[1] * self.fill


def _quantize(g: jnp.ndarray, levels: int) -> jnp.ndarray:
    return jnp.round(g * (levels - 1)) / (levels - 1)


def encode_core(W: jnp.ndarray, key: jax.Array, g_levels: int,
                sigma_program: float) -> Tuple[jnp.ndarray, jnp.ndarray,
                                               jnp.ndarray, jnp.ndarray]:
    """Pure differential-pair programming model (vmappable).

    ``W`` must already be padded to its physical array shape.  Returns
    ``(g_pos, g_neg, scale, nz)`` where ``scale`` and ``nz`` (number of
    nonzero-target differential pairs) are traced scalars — the caller
    turns them into ledger entries.
    """
    raw = jnp.max(jnp.abs(W))
    scale = jnp.where(raw > 0, raw, 1.0)
    g_pos_t = jnp.maximum(W, 0.0) / scale
    g_neg_t = jnp.maximum(-W, 0.0) / scale
    g_pos_q = _quantize(g_pos_t, g_levels)
    g_neg_q = _quantize(g_neg_t, g_levels)
    k1, k2 = jax.random.split(key)
    # residual programming error (relative, only on nonzero cells)
    e1 = 1.0 + sigma_program * jax.random.normal(k1, g_pos_q.shape, W.dtype)
    e2 = 1.0 + sigma_program * jax.random.normal(k2, g_neg_q.shape, W.dtype)
    g_pos = jnp.clip(g_pos_q * e1, 0.0, 1.0)
    g_neg = jnp.clip(g_neg_q * e2, 0.0, 1.0)
    nz = jnp.sum((g_pos_t > 0) | (g_neg_t > 0))
    return g_pos, g_neg, scale, nz


def charge_write(ledger: Ledger, device: DeviceModel, nz: float,
                 pairs_logical: int, pairs_total: int) -> float:
    """Accumulate the programming cost of one differential array.

    ``nz`` nonzero-target pairs consume the full write-verify pulse train
    (2 cells each); zero-target pairs take one RESET pulse per cell.
    Pairs outside the logical region (tile/bucket padding — always
    zero-target) are additionally ledgered under the ``*_padding``
    fields.  Returns the fill fraction (for read-energy accounting).
    Vectorization-friendly: callers may pass numpy scalars extracted from
    a batched encode.
    """
    nz = float(nz)
    tr, tc = device.crossbar_rows, device.crossbar_cols
    fill = nz / pairs_total
    pulses_logical = (nz * 2 * device.avg_write_pulses
                      + (2 * pairs_logical - 2 * nz) * 1.0)
    pulses_padding = 2.0 * (pairs_total - pairs_logical)
    ledger.write_energy_j += ((pulses_logical + pulses_padding)
                              * device.write_pulse_energy_j)
    ledger.write_energy_padding_j += (pulses_padding
                                      * device.write_pulse_energy_j)
    # tiles program in parallel; within a tile, cells are row-serial
    cells_per_tile = tr * tc * 2
    ledger.write_latency_s += (
        cells_per_tile * max(fill, 1.0 / (tr * tc))
        * device.avg_write_pulses * device.write_pulse_latency_s
    )
    ledger.cells_written += 2 * pairs_total
    ledger.cells_written_padding += 2 * (pairs_total - pairs_logical)
    return fill


def encode_matrix(
    W,
    device: DeviceModel,
    key: jax.Array,
    ledger: Ledger | None = None,
    pad_to_tiles: bool = True,
) -> EncodedMatrix:
    """Program W onto (padded) crossbar tiles with write-verify."""
    W = jnp.asarray(W)
    rows, cols = W.shape
    tr, tc = device.crossbar_rows, device.crossbar_cols
    if pad_to_tiles:
        R = int(np.ceil(rows / tr)) * tr
        C = int(np.ceil(cols / tc)) * tc
        Wp = jnp.zeros((R, C), W.dtype).at[:rows, :cols].set(W)
    else:
        R, C = rows, cols
        Wp = W
    g_pos, g_neg, scale, nz = encode_core(
        Wp, key, device.g_levels, device.sigma_program)
    nz = float(nz)
    fill = nz / (R * C)
    if ledger is not None:
        fill = charge_write(ledger, device, nz,
                            pairs_logical=rows * cols, pairs_total=R * C)
    return EncodedMatrix(
        g_pos=g_pos, g_neg=g_neg, scale=float(scale), rows=rows, cols=cols,
        device=device, fill=fill,
    )


def write_verify_error(enc: EncodedMatrix, W) -> float:
    """Max relative deviation between programmed and target matrix."""
    W = jnp.asarray(W)
    err = jnp.abs(enc.decode() - W)
    return float(jnp.max(err) / (jnp.max(jnp.abs(W)) + 1e-30))
