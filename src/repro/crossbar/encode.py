"""Conductance encoding with write-verify (paper §3.1 + ref [40]).

RRAM conductances are non-negative, so a real matrix W is stored as a
differential pair  W ~ s * (G+ - G-)  with
    G+ = quantize(max(W, 0) / s),   G- = quantize(max(-W, 0) / s),
where s scales max|W| onto the device's usable conductance range (we work
in normalized conductance units g in [0, 1] with ``g_levels`` steps).

Write-verify: each cell is pulsed until its conductance is within half an
LSB of target; the residual error is modeled as a zero-mean Gaussian with
relative std ``sigma_program`` (device-to-device variability floor).  The
expected pulse count per cell drives the programming energy/latency ledger
entries — this is the "expensive writes" the encode-once strategy
amortizes.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .device import DeviceModel
from .energy import Ledger


@dataclasses.dataclass
class EncodedMatrix:
    g_pos: jnp.ndarray      # (R, C) normalized conductances in [0, 1]
    g_neg: jnp.ndarray
    scale: float            # W ~ scale * (g_pos - g_neg)
    rows: int               # logical (unpadded) shape
    cols: int
    device: DeviceModel
    fill: float = 1.0       # fraction of programmed (nonzero) cells —
                            # zero-conductance cells draw ~no read current

    def decode(self) -> jnp.ndarray:
        return (self.g_pos - self.g_neg)[: self.rows, : self.cols] * self.scale

    @property
    def active_cells(self) -> float:
        return 2.0 * self.g_pos.shape[0] * self.g_pos.shape[1] * self.fill


def _quantize(g: jnp.ndarray, levels: int) -> jnp.ndarray:
    return jnp.round(g * (levels - 1)) / (levels - 1)


def encode_matrix(
    W,
    device: DeviceModel,
    key: jax.Array,
    ledger: Ledger | None = None,
    pad_to_tiles: bool = True,
) -> EncodedMatrix:
    """Program W onto (padded) crossbar tiles with write-verify."""
    W = jnp.asarray(W)
    rows, cols = W.shape
    tr, tc = device.crossbar_rows, device.crossbar_cols
    if pad_to_tiles:
        R = int(np.ceil(rows / tr)) * tr
        C = int(np.ceil(cols / tc)) * tc
        Wp = jnp.zeros((R, C), W.dtype).at[:rows, :cols].set(W)
    else:
        R, C = rows, cols
        Wp = W
    scale = float(jnp.max(jnp.abs(Wp))) or 1.0
    g_pos_t = jnp.maximum(Wp, 0.0) / scale
    g_neg_t = jnp.maximum(-Wp, 0.0) / scale
    g_pos_q = _quantize(g_pos_t, device.g_levels)
    g_neg_q = _quantize(g_neg_t, device.g_levels)
    k1, k2 = jax.random.split(key)
    # residual programming error (relative, only on nonzero cells)
    e1 = 1.0 + device.sigma_program * jax.random.normal(k1, g_pos_q.shape, W.dtype)
    e2 = 1.0 + device.sigma_program * jax.random.normal(k2, g_neg_q.shape, W.dtype)
    g_pos = jnp.clip(g_pos_q * e1, 0.0, 1.0)
    g_neg = jnp.clip(g_neg_q * e2, 0.0, 1.0)

    nz = int(jnp.sum((g_pos_t > 0) | (g_neg_t > 0)))
    fill = nz / (R * C)
    if ledger is not None:
        # only nonzero targets consume verify pulses; zeros need a RESET
        # pulse each (cheap, count one pulse)
        zeros = 2 * R * C - 2 * nz
        pulses = nz * 2 * device.avg_write_pulses + zeros * 1.0
        ledger.write_energy_j += pulses * device.write_pulse_energy_j
        # tiles program in parallel; within a tile, cells are row-serial
        cells_per_tile = tr * tc * 2
        ledger.write_latency_s += (
            cells_per_tile * max(fill, 1.0 / (tr * tc))
            * device.avg_write_pulses * device.write_pulse_latency_s
        )
        ledger.cells_written += 2 * R * C
    return EncodedMatrix(
        g_pos=g_pos, g_neg=g_neg, scale=scale, rows=rows, cols=cols,
        device=device, fill=fill,
    )


def write_verify_error(enc: EncodedMatrix, W) -> float:
    """Max relative deviation between programmed and target matrix."""
    W = jnp.asarray(W)
    err = jnp.abs(enc.decode() - W)
    return float(jnp.max(err) / (jnp.max(jnp.abs(W)) + 1e-30))
