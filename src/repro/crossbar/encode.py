"""Conductance encoding with write-verify (paper §3.1 + ref [40]).

RRAM conductances are non-negative, so a real matrix W is stored as a
differential pair  W ~ s * (G+ - G-)  with
    G+ = quantize(max(W, 0) / s),   G- = quantize(max(-W, 0) / s),
where s scales max|W| onto the device's usable conductance range (we work
in normalized conductance units g in [0, 1] with ``g_levels`` steps).

Write-verify: each cell is pulsed until its conductance is within half an
LSB of target; the residual error is modeled as a zero-mean Gaussian with
relative std ``sigma_program`` (device-to-device variability floor).  The
expected pulse count per cell drives the programming energy/latency ledger
entries — this is the "expensive writes" the encode-once strategy
amortizes.

Two layers:
  * ``encode_core``    — the pure device-physics map (quantize + residual
                         programming error), traced-scalar statistics
                         only.  Safe under ``jax.vmap`` — the batched
                         crossbar stream programs a whole (B, R, C) stack
                         of operators in one compiled call.
  * ``encode_matrix``  — eager single-matrix wrapper: tile padding,
                         ``EncodedMatrix`` handle, ledger side effects.

Ledger entries split LOGICAL cells (the operator itself) from PADDING
cells (programmed only because tiles/buckets are larger than the
operator; all-zero targets, one RESET pulse each), so the overhead of
device-tile-aligned bucketing is auditable per instance.

ECC mode (``DeviceModel.ecc = k`` > 1, after arXiv 2508.13298): every
differential pair is programmed onto k physically distinct replicas
(independent programming error, independent stuck-at faults), and reads
decode the replica stack per cell — ``"median"`` votes out a minority of
stuck replicas, ``"mean"`` averages programming noise down by sqrt(k).
Replicas live on parallel tile sets, so programming LATENCY is unchanged
while write energy and read energy scale k-fold; replicas 1..k-1 are
ledgered under the ``*_ecc`` fields exactly like the logical/padding
split.  Stuck-at faults (``stuck_rate``) and retention drift (``drift``)
are applied per replica inside ``encode_core`` so the decode quality is
what the solver actually sees.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .device import DeviceModel
from .energy import Ledger


@dataclasses.dataclass
class EncodedMatrix:
    g_pos: jnp.ndarray      # (R, C) normalized conductances in [0, 1]
    g_neg: jnp.ndarray
    scale: float            # W ~ scale * (g_pos - g_neg)
    rows: int               # logical (unpadded) shape
    cols: int
    device: DeviceModel
    fill: float = 1.0       # fraction of programmed (nonzero) cells —
                            # zero-conductance cells draw ~no read current

    def decode(self) -> jnp.ndarray:
        return (self.g_pos - self.g_neg)[: self.rows, : self.cols] * self.scale

    @property
    def active_cells(self) -> float:
        # every ECC replica's cells draw read current on every MVM
        return (2.0 * self.g_pos.shape[0] * self.g_pos.shape[1] * self.fill
                * max(1, self.device.ecc))


def _quantize(g: jnp.ndarray, levels: int) -> jnp.ndarray:
    return jnp.round(g * (levels - 1)) / (levels - 1)


ECC_DECODES = ("median", "mean")


def encode_core(W: jnp.ndarray, key: jax.Array, g_levels: int,
                sigma_program: float, *, ecc: int = 1,
                ecc_decode: str = "median", stuck_rate: float = 0.0,
                drift: float = 0.0) -> Tuple[jnp.ndarray, jnp.ndarray,
                                             jnp.ndarray, jnp.ndarray]:
    """Pure differential-pair programming model (vmappable).

    ``W`` must already be padded to its physical array shape.  Returns
    ``(g_pos, g_neg, scale, nz)`` where ``scale`` and ``nz`` (number of
    nonzero-target differential pairs) are traced scalars — the caller
    turns them into ledger entries.  ``g_pos``/``g_neg`` are the DECODED
    effective conductances: with ``ecc = k > 1`` each cell is programmed
    onto k replicas (independent error/faults per replica) and reduced
    per cell by ``ecc_decode``.

    ``nz`` counts pairs whose QUANTIZED target is nonzero — a cell whose
    ``|W|`` lands below half an LSB programs to zero conductance, takes a
    single RESET pulse like any other zero target, and draws no read
    current, so it must not be charged the write-verify pulse train nor
    inflate ``fill``.  (Counting the pre-quantization target here was a
    ledger bug.)  Stuck cells keep their pulse-train charge: write-verify
    burns the full train failing to converge on a faulted cell.
    """
    if ecc_decode not in ECC_DECODES:
        raise ValueError(f"unknown ecc_decode {ecc_decode!r}; expected one "
                         f"of {ECC_DECODES}")
    if ecc < 1:
        raise ValueError(f"ecc replication factor must be >= 1 (got {ecc})")
    raw = jnp.max(jnp.abs(W))
    scale = jnp.where(raw > 0, raw, 1.0)
    g_pos_t = jnp.maximum(W, 0.0) / scale
    g_neg_t = jnp.maximum(-W, 0.0) / scale
    g_pos_q = _quantize(g_pos_t, g_levels)
    g_neg_q = _quantize(g_neg_t, g_levels)
    nz = jnp.sum((g_pos_q > 0) | (g_neg_q > 0))

    def _program(k):
        """One physical replica: residual write-verify error, then the
        fault masks (stuck-at replaces the programmed value; drift decays
        whatever is actually stored, faulted or not)."""
        k1, k2, k3, k4 = jax.random.split(k, 4)
        e1 = 1.0 + sigma_program * jax.random.normal(k1, g_pos_q.shape,
                                                     W.dtype)
        e2 = 1.0 + sigma_program * jax.random.normal(k2, g_neg_q.shape,
                                                     W.dtype)
        gp = jnp.clip(g_pos_q * e1, 0.0, 1.0)
        gn = jnp.clip(g_neg_q * e2, 0.0, 1.0)
        if stuck_rate > 0.0:
            for g, kk in ((0, k3), (1, k4)):
                ka, kb = jax.random.split(kk)
                mask = jax.random.bernoulli(ka, stuck_rate, g_pos_q.shape)
                on = jax.random.bernoulli(kb, 0.5, g_pos_q.shape)
                stuck = jnp.where(on, jnp.asarray(1.0, W.dtype),
                                  jnp.asarray(0.0, W.dtype))
                if g == 0:
                    gp = jnp.where(mask, stuck, gp)
                else:
                    gn = jnp.where(mask, stuck, gn)
        if drift > 0.0:
            gp = gp * (1.0 - drift)
            gn = gn * (1.0 - drift)
        return gp, gn

    if ecc == 1 and stuck_rate == 0.0 and drift == 0.0:
        # fault-free single-copy path: keep the historical key schedule
        # so pre-ECC traces stay bitwise identical
        k1, k2 = jax.random.split(key)
        e1 = 1.0 + sigma_program * jax.random.normal(k1, g_pos_q.shape,
                                                     W.dtype)
        e2 = 1.0 + sigma_program * jax.random.normal(k2, g_neg_q.shape,
                                                     W.dtype)
        g_pos = jnp.clip(g_pos_q * e1, 0.0, 1.0)
        g_neg = jnp.clip(g_neg_q * e2, 0.0, 1.0)
    elif ecc == 1:
        g_pos, g_neg = _program(key)
    else:
        gps, gns = jax.vmap(_program)(jax.random.split(key, ecc))
        if ecc_decode == "mean":
            g_pos, g_neg = jnp.mean(gps, axis=0), jnp.mean(gns, axis=0)
        else:
            g_pos, g_neg = (jnp.median(gps, axis=0),
                            jnp.median(gns, axis=0))
    return g_pos, g_neg, scale, nz


def charge_write(ledger: Ledger, device: DeviceModel, nz: float,
                 pairs_logical: int, pairs_total: int) -> float:
    """Accumulate the programming cost of one differential array.

    ``nz`` nonzero-target pairs consume the full write-verify pulse train
    (2 cells each); zero-target pairs take one RESET pulse per cell.
    Pairs outside the logical region (tile/bucket padding — always
    zero-target) are additionally ledgered under the ``*_padding``
    fields.  With ``device.ecc = k > 1`` the whole array (padding
    included) is programmed k times; replicas 1..k-1 are additionally
    ledgered under the ``*_ecc`` fields.  Returns the fill fraction (for
    read-energy accounting).  Vectorization-friendly: callers may pass
    numpy scalars extracted from a batched encode.
    """
    nz = float(nz)
    replicas = max(1, device.ecc)
    tr, tc = device.crossbar_rows, device.crossbar_cols
    fill = nz / pairs_total
    pulses_logical = (nz * 2 * device.avg_write_pulses
                      + (2 * pairs_logical - 2 * nz) * 1.0)
    pulses_padding = 2.0 * (pairs_total - pairs_logical)
    pulses_one = pulses_logical + pulses_padding
    ledger.write_energy_j += (replicas * pulses_one
                              * device.write_pulse_energy_j)
    ledger.write_energy_padding_j += (pulses_padding
                                      * device.write_pulse_energy_j)
    ledger.write_energy_ecc_j += ((replicas - 1) * pulses_one
                                  * device.write_pulse_energy_j)
    # tiles program in parallel (ECC replicas are parallel tile sets, so
    # latency is ecc-independent); within a tile, cells are row-serial:
    # nonzero-target cells take the full write-verify train, zero-target
    # cells one RESET pulse each — the RESET pulses are part of the
    # serial train, so latency and energy agree on what was programmed
    cells_per_tile = tr * tc * 2
    pulses_serial = cells_per_tile * (
        fill * device.avg_write_pulses + (1.0 - fill) * 1.0)
    ledger.write_latency_s += pulses_serial * device.write_pulse_latency_s
    ledger.cells_written += replicas * 2 * pairs_total
    ledger.cells_written_padding += 2 * (pairs_total - pairs_logical)
    ledger.cells_written_ecc += (replicas - 1) * 2 * pairs_total
    return fill


def encode_matrix(
    W,
    device: DeviceModel,
    key: jax.Array,
    ledger: Ledger | None = None,
    pad_to_tiles: bool = True,
) -> EncodedMatrix:
    """Program W onto (padded) crossbar tiles with write-verify."""
    W = jnp.asarray(W)
    rows, cols = W.shape
    tr, tc = device.crossbar_rows, device.crossbar_cols
    if pad_to_tiles:
        R = int(np.ceil(rows / tr)) * tr
        C = int(np.ceil(cols / tc)) * tc
        Wp = jnp.zeros((R, C), W.dtype).at[:rows, :cols].set(W)
    else:
        R, C = rows, cols
        Wp = W
    g_pos, g_neg, scale, nz = encode_core(
        Wp, key, device.g_levels, device.sigma_program,
        ecc=device.ecc, ecc_decode=device.ecc_decode,
        stuck_rate=device.stuck_rate, drift=device.drift)
    nz = float(nz)
    fill = nz / (R * C)
    if ledger is not None:
        fill = charge_write(ledger, device, nz,
                            pairs_logical=rows * cols, pairs_total=R * C)
    return EncodedMatrix(
        g_pos=g_pos, g_neg=g_neg, scale=float(scale), rows=rows, cols=cols,
        device=device, fill=fill,
    )


def write_verify_error(enc: EncodedMatrix, W) -> float:
    """Max relative deviation between programmed and target matrix."""
    W = jnp.asarray(W)
    err = jnp.abs(enc.decode() - W)
    return float(jnp.max(err) / (jnp.max(jnp.abs(W)) + 1e-30))
