"""MELISO+-style crossbar device simulation substrate."""
from .device import DEVICES, EPIRAM, TAOX_HFOX, DeviceModel
from .encode import (
    EncodedMatrix,
    charge_write,
    encode_core,
    encode_matrix,
    write_verify_error,
)
from .energy import Ledger
from .array import CrossbarArray, analog_linear, crossbar_accel_factory
from .gpu import RTX6000, GPUModel
from .refine import refined_core, solve_crossbar_refined
from .solver import (
    CrossbarBatchSolver,
    CrossbarSolveReport,
    make_crossbar_bucket_pipeline,
    solve_crossbar_jit,
    solve_crossbar_stream,
)

__all__ = [
    "DEVICES", "EPIRAM", "TAOX_HFOX", "DeviceModel",
    "EncodedMatrix", "charge_write", "encode_core", "encode_matrix",
    "write_verify_error",
    "Ledger", "CrossbarArray", "analog_linear", "crossbar_accel_factory",
    "RTX6000", "GPUModel", "CrossbarBatchSolver", "CrossbarSolveReport",
    "make_crossbar_bucket_pipeline", "refined_core", "solve_crossbar_jit",
    "solve_crossbar_refined", "solve_crossbar_stream",
]
