"""MELISO+-style crossbar device simulation substrate."""
from .device import DEVICES, EPIRAM, TAOX_HFOX, DeviceModel
from .encode import EncodedMatrix, encode_matrix, write_verify_error
from .energy import Ledger
from .array import CrossbarArray, analog_linear, crossbar_accel_factory
from .gpu import RTX6000, GPUModel
from .solver import (
    CrossbarSolveReport,
    solve_crossbar_jit,
    solve_crossbar_stream,
)

__all__ = [
    "DEVICES", "EPIRAM", "TAOX_HFOX", "DeviceModel",
    "EncodedMatrix", "encode_matrix", "write_verify_error",
    "Ledger", "CrossbarArray", "analog_linear", "crossbar_accel_factory",
    "RTX6000", "GPUModel", "CrossbarSolveReport", "solve_crossbar_jit",
    "solve_crossbar_stream",
]
