"""Per-phase energy/latency ledger (shape of paper Tables 4-5).

The ledger accumulates WRITE (programming, once) and READ (per analog MVM)
costs for RRAM backends, and H2D/SOLVE/D2H costs for the GPU baseline.
``snapshot()``/``diff()`` let the benchmark harness split Lanczos-phase vs
PDHG-phase totals exactly like the paper's tables.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Ledger:
    # RRAM phases
    write_energy_j: float = 0.0
    write_latency_s: float = 0.0
    read_energy_j: float = 0.0
    read_latency_s: float = 0.0
    # padding share of the WRITE phase (cells programmed only because the
    # bucket/tile is larger than the logical operator — RESET pulses on
    # all-zero targets).  Already included in ``write_energy_j``; tracked
    # separately so bucketing overhead is auditable.
    write_energy_padding_j: float = 0.0
    # ECC share of the WRITE phase (replicas 1..k-1 of a k-fold
    # differential-pair replication, ``DeviceModel.ecc``).  Included in
    # ``write_energy_j``/``cells_written`` like padding is, and tracked
    # separately so redundancy overhead is auditable per instance.
    write_energy_ecc_j: float = 0.0
    # GPU phases
    h2d_energy_j: float = 0.0
    h2d_latency_s: float = 0.0
    solve_energy_j: float = 0.0
    solve_latency_s: float = 0.0
    d2h_energy_j: float = 0.0
    d2h_latency_s: float = 0.0
    # counters
    mvm_count: int = 0
    cells_written: int = 0
    cells_written_padding: int = 0
    cells_written_ecc: int = 0

    @property
    def write_energy_logical_j(self) -> float:
        return (self.write_energy_j - self.write_energy_padding_j
                - self.write_energy_ecc_j)

    @property
    def total_energy_j(self) -> float:
        return (self.write_energy_j + self.read_energy_j + self.h2d_energy_j
                + self.solve_energy_j + self.d2h_energy_j)

    @property
    def total_latency_s(self) -> float:
        return (self.write_latency_s + self.read_latency_s
                + self.h2d_latency_s + self.solve_latency_s
                + self.d2h_latency_s)

    def snapshot(self) -> "Ledger":
        return dataclasses.replace(self)

    def diff(self, earlier: "Ledger") -> "Ledger":
        out = Ledger()
        for f in dataclasses.fields(Ledger):
            setattr(out, f.name,
                    getattr(self, f.name) - getattr(earlier, f.name))
        return out

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["total_energy_j"] = self.total_energy_j
        d["total_latency_s"] = self.total_latency_s
        d["write_energy_logical_j"] = self.write_energy_logical_j
        return d
