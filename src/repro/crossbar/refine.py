"""Mixed-precision iterative refinement for the crossbar PDHG solve.

After Le Gallo et al., "Mixed-Precision In-Memory Computing"
(arXiv 1701.04279): the analog crossbar solves fast but only down to its
read-noise floor; a digital outer loop recovers full-precision answers by
repeatedly solving the RESIDUAL-CORRECTION problem on the *same
programmed conductances*.  For the LP saddle point

    min_x max_y  c'x + y'(b - Kx),   lb <= x <= ub,

substituting x = x_bar + dx, y = y_bar + dy gives (up to a constant) the
correction saddle

    min_dx max_dy  (c - K'y_bar)'dx + dy'((b - K x_bar) - K dx),
    lb - x_bar <= dx <= ub - x_bar,

i.e. the SAME operator K with shifted b/c and a shifted box — nothing is
ever reprogrammed, which is the paper's core constraint (writes are the
expensive phase; the ledger across refinement rounds shows zero
additional write cycles).  Each round:

  1. DIGITAL: compute exact residuals r_b = b - Kx, r_c = c - K'y against
     the full-precision operator (the digital co-processor's job — these
     MVMs are counted via ``engine.refine_digital_mvms`` but never
     charged to the crossbar read ledger).
  2. Scale the correction problem to unit size (s = max residual norm).
     Analog read noise is RELATIVE, so re-solving the residual system at
     its own scale is what gains digits: the absolute noise floor
     shrinks proportionally to the residual each round.
  3. ANALOG: solve the correction LP through ``engine.solve_core`` on
     the programmed operator, warm-started at dx = dy = 0 (the previous
     outer iterate IS the origin in shifted coordinates).  Every inner
     MVM is an analog read, charged to the ledger like any other solve.
  4. DIGITAL: evaluate the candidate x + s*dx, y + s*dy exactly and
     adopt it only if it improves the exact KKT merit (safeguarded
     refinement — a noisy correction can regress, and once the merit is
     at ``refine_tol`` further corrections would only pump read noise
     back in).

``refined_core`` is the traced shell (vmappable — the batched crossbar
pipeline runs it per lane); ``solve_crossbar_refined`` is the eager
single-instance driver with the energy ledger, dispatched from
``solve_crossbar_jit`` when ``opts.refine_rounds > 0``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import engine
from ..core import pdhg as pdhg_mod
from ..core.lanczos import lanczos_svd_jit, power_iteration_mv
from ..core.residuals import kkt_residuals
from ..core.symblock import build_sym_block
from ..lp.problem import StandardLP
from .device import DeviceModel, EPIRAM
from .encode import encode_matrix
from .energy import Ledger

#: guard for an exactly-zero residual (already converged): the correction
#: problem degenerates and the scale must not divide by zero
_TINY = 1e-300


def digital_merit(x, y, b, c, lb, ub, Kx, KTy):
    """Exact KKT merit from full-precision operator images."""
    return kkt_residuals(x, x, y, c, b, Kx, KTy, lb=lb, ub=ub).max


def refined_core(K_dig_fwd, K_dig_adj, K_fwd, K_adj, b, c, lb, ub, T,
                 Sigma, rho, key, static, *,
                 operator: Optional[engine.Operator] = None):
    """Digital-outer / analog-inner refinement shell (traced, vmappable).

    ``K_dig_fwd``/``K_dig_adj`` are the EXACT (full-precision) scaled
    operator blocks used only for the digital residual/merit MVMs;
    ``K_fwd``/``K_adj`` (or ``operator``) is the programmed analog
    operator every inner solve runs on — identical in every round, never
    rewritten.  ``static`` is the ``pdhg.opts_static`` tuple; entries 13
    (``refine_rounds``) and 14 (``refine_tol``) drive the shell, the rest
    is passed straight into ``engine.solve_core``.

    Returns ``(x, y, its, merit)`` where ``its`` is the per-round
    iteration-count vector (length ``refine_rounds + 1``; callers charge
    each round's analog windows to the read ledger) and ``merit`` is the
    exact digital KKT merit after refinement (``refine_rounds == 0``
    degenerates to ``solve_core`` with its in-loop analog merit).
    """
    rounds = int(static[13]) if len(static) > 13 else 0
    refine_tol = float(static[14]) if len(static) > 14 else 0.0

    x, y, it0, merit0 = engine.solve_core(
        K_fwd, K_adj, b, c, lb, ub, T, Sigma, rho, key, static,
        operator=operator)
    if rounds == 0:
        return x, y, jnp.reshape(it0, (1,)), merit0

    its = [it0]
    Kx = K_dig_fwd @ x
    KTy = K_dig_adj @ y
    merit = digital_merit(x, y, b, c, lb, ub, Kx, KTy)
    for _ in range(rounds):
        key, kr = jax.random.split(key)
        rb = b - Kx
        rc = c - KTy
        # unit-scale the correction problem: relative analog noise means
        # the absolute error floor of the inner solve tracks s downward
        s = jnp.maximum(jnp.maximum(jnp.max(jnp.abs(rb)),
                                    jnp.max(jnp.abs(rc))),
                        jnp.asarray(_TINY, b.dtype))
        dx, dy, it_r, _ = engine.solve_core(
            K_fwd, K_adj, rb / s, rc / s, (lb - x) / s, (ub - x) / s,
            T, Sigma, rho, kr, static, operator=operator,
            x0=jnp.zeros_like(x), y0=jnp.zeros_like(y))
        its.append(it_r)
        x_c = jnp.clip(x + s * dx, lb, ub)
        y_c = y + s * dy
        Kx_c = K_dig_fwd @ x_c
        KTy_c = K_dig_adj @ y_c
        merit_c = digital_merit(x_c, y_c, b, c, lb, ub, Kx_c, KTy_c)
        # safeguarded adoption: only keep an exact improvement, and stop
        # moving once the target tolerance is met
        adopt = jnp.logical_and(merit_c < merit, merit > refine_tol)
        pick = lambda cand, cur: jnp.where(adopt, cand, cur)  # noqa: E731
        x, y = pick(x_c, x), pick(y_c, y)
        Kx, KTy = pick(Kx_c, Kx), pick(KTy_c, KTy)
        merit = jnp.where(adopt, merit_c, merit)
    return x, y, jnp.stack(its), merit


# module-level jit so repeated eager-driver calls share the executable
# cache (a per-call jax.jit wrapper would recompile every solve)
_refined_core_jit = jax.jit(refined_core, static_argnums=(12,))


def solve_crossbar_refined(
    lp: StandardLP,
    opts: pdhg_mod.PDHGOptions,
    device: DeviceModel = EPIRAM,
    key: Optional[jax.Array] = None,
    ledger: Optional[Ledger] = None,
):
    """Eager driver: encode once, then the refined solve with the ledger.

    Mirrors ``solve_crossbar_jit`` (one encode of the symmetric block M,
    charged as WRITE) but runs ``refined_core`` instead of a single
    solve: the write ledger is touched exactly once — refinement rounds
    add only READ windows (plus uncharged digital residual MVMs, counted
    on the report as ``digital_mvms``).  Returns a
    ``CrossbarSolveReport``.
    """
    from .solver import CrossbarSolveReport, _charge_reads  # deferred cycle

    if key is None:
        key = jax.random.PRNGKey(opts.seed)
    ledger = ledger if ledger is not None else Ledger()

    scaled, T, Sigma = pdhg_mod.prepare(lp, opts)
    m, n = scaled.K.shape
    M = build_sym_block(scaled.K)
    enc = encode_matrix(M, device, key, ledger=ledger)
    M_prog = enc.decode()
    K_fwd = M_prog[:m, m:]
    K_adj = M_prog[m:, :m]

    if opts.norm_override is not None:
        rho = jnp.asarray(opts.norm_override, scaled.K.dtype)
        lanczos_mvms = 0
    else:
        Keff = (jnp.sqrt(Sigma)[:, None] * K_fwd * jnp.sqrt(T)[None, :])
        Msym = build_sym_block(Keff)
        if opts.norm_backend == "power":
            est = power_iteration_mv(lambda v: Msym @ v, Msym.shape[0],
                                     Msym.dtype, iters=opts.lanczos_iters)
        else:
            est = lanczos_svd_jit(Msym, k_max=opts.lanczos_iters)
        rho = engine.lemma2_margin(est, device.sigma_read)
        lanczos_mvms = opts.lanczos_iters

    static = pdhg_mod.opts_static(opts, device.sigma_read)
    x, y, its, merit = _refined_core_jit(
        scaled.K, scaled.K.T, K_fwd, K_adj, scaled.b, scaled.c, scaled.lb,
        scaled.ub, T, Sigma, rho, jax.random.PRNGKey(opts.seed + 1),
        static)

    its_np = np.asarray(its)
    pdhg_mvms = int(sum(
        engine.mvm_accounting(int(i), opts.check_every, 0,
                              restart=opts.restart)
        for i in its_np))
    _charge_reads(ledger, device, lanczos_mvms + pdhg_mvms,
                  enc.active_cells)

    x_orig = np.asarray(scaled.unscale_x(x))
    y_orig = np.asarray(scaled.unscale_y(y))
    res = kkt_residuals(
        x, x, y, scaled.c, scaled.b, scaled.K @ x, scaled.K.T @ y,
        lb=scaled.lb, ub=scaled.ub)
    merit_f = float(merit)
    if not np.isfinite(merit_f):
        status = "diverged"
    elif merit_f <= opts.tol:
        status = "optimal"
    else:
        status = "iteration_limit"
    it_total = int(its_np.sum())
    result = pdhg_mod.PDHGResult(
        status=status, x=x_orig, y=y_orig, obj=float(lp.c @ x_orig),
        iterations=it_total, residuals=res, sigma_max=float(rho),
        lanczos_iters=lanczos_mvms, mvm_calls=lanczos_mvms + pdhg_mvms,
        merit=merit_f,
    )
    return CrossbarSolveReport(
        result=result, ledger=ledger, device=device,
        lanczos_mvms=lanczos_mvms, pdhg_mvms=pdhg_mvms,
        executed_iterations=it_total,
        digital_mvms=engine.refine_digital_mvms(opts.refine_rounds),
    )
