"""RRAM device models (MELISO+/NeuroSim+-style constants).

Two technologies from the paper:
  * EpiRAM        — Choi et al., Nature Materials 17, 335 (2018) [ref 57]:
                    SiGe epitaxial RRAM; fast, low-energy analog READ but
                    relatively expensive write-verify programming.
  * TaOx-HfOx     — Wu et al., VLSI 2018 [ref 58]: engineered bilayer with
                    high programming linearity => far fewer verify pulses,
                    much lower write voltage/duration (the paper attributes
                    its consistently superior energy numbers to exactly
                    this), at slightly slower integrate+ADC read.

Constants below are calibrated so the end-to-end ledger reproduces the
ORDER OF MAGNITUDE of the paper's Tables 4-5 (per-phase energy/latency and
the 10x-5000x improvement factors over the GPU baseline); the container has
no physical hardware, so exact joules are not reproducible — the
improvement-factor structure is the reproduction target.

Noise parameters feed the solver's robustness machinery (§4): residual
programming error after write-verify (device-to-device) and cycle-to-cycle
read noise, both relative/multiplicative and unbiased (Assumption 2).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    name: str
    # --- geometry -----------------------------------------------------
    crossbar_rows: int = 64          # physical tile size (paper: 64x64)
    crossbar_cols: int = 64
    grid_rows: int = 4               # 4x4 array of tiles (paper) => 256x256
    grid_cols: int = 4
    # --- conductance programming --------------------------------------
    g_levels: int = 256              # distinguishable conductance levels
    avg_write_pulses: float = 30.0   # mean write-verify pulses per cell
    write_pulse_energy_j: float = 3.0e-6
    write_pulse_latency_s: float = 5.0e-6
    sigma_program: float = 2.0e-3    # residual relative error after verify
    # --- analog read (one MVM per tile, tiles fire in parallel) --------
    read_energy_per_cell_j: float = 2.1e-8   # V^2 * g * t_int + ADC share
    read_latency_s: float = 2.2e-5           # DAC + integrate + ADC
    sigma_read: float = 1.0e-3       # cycle-to-cycle multiplicative
    # --- converters (None = ideal; set to int bits to model quantization)
    dac_bits: int | None = None
    adc_bits: int | None = None
    # --- error correction / fault tolerance (arXiv 2508.13298) ----------
    # ``ecc`` programs k physically distinct replicas of every
    # differential pair on k parallel tile sets; reads decode the replica
    # stack per cell (``ecc_decode``: "median" is robust to a minority of
    # stuck replicas, "mean" averages programming noise down by sqrt(k)).
    # Replicas 1..k-1 are ledgered under the ``*_ecc`` fields, and every
    # replica cell draws read current on every MVM (k-fold read energy).
    ecc: int = 1                     # replication factor (1 = off)
    ecc_decode: str = "median"       # "median" | "mean"
    stuck_rate: float = 0.0          # per-cell stuck-at fault probability
    #                                  (half stuck-OFF g=0, half stuck-ON g=1)
    drift: float = 0.0               # relative conductance decay mask
    #                                  applied after programming (retention)

    @property
    def logical_rows(self) -> int:
        return self.crossbar_rows * self.grid_rows

    @property
    def logical_cols(self) -> int:
        return self.crossbar_cols * self.grid_cols


# Calibration notes (back-solved from paper Tables 4-5, gen-ip002):
#   EpiRAM  write: 0.75 J / (65*65*2 diff cells) ~ 8.9e-5 J/cell
#           at ~30 pulses/cell  => ~3e-6 J/pulse; tile-parallel latency
#           0.33 s => ~5e-6 s/pulse.
#   EpiRAM  read:  ~1.8e-4 J per logical MVM => ~2.1e-8 J/cell;
#           ~2.2e-5 s per MVM.
EPIRAM = DeviceModel(
    name="EpiRAM",
    avg_write_pulses=30.0,
    write_pulse_energy_j=3.0e-6,
    write_pulse_latency_s=5.0e-6,
    sigma_program=2.0e-3,
    read_energy_per_cell_j=2.1e-8,
    read_latency_s=2.2e-5,
    sigma_read=1.0e-3,
)

#   TaOx-HfOx write: 0.0114 J / 8450 cells ~ 1.35e-6 J/cell at ~8
#           pulses/cell => ~1.7e-7 J/pulse; latency 0.039 s => ~2.3e-6 s.
#   TaOx-HfOx read: ~8e-5 J per MVM => ~9.5e-9 J/cell; ~4.6e-5 s per MVM
#           (slower integrate+ADC, but far cheaper writes — the paper's
#           "physics of the device" advantage).
TAOX_HFOX = DeviceModel(
    name="TaOx-HfOx",
    avg_write_pulses=8.0,
    write_pulse_energy_j=1.7e-7,
    write_pulse_latency_s=2.3e-6,
    sigma_program=1.0e-3,
    read_energy_per_cell_j=9.5e-9,
    read_latency_s=4.6e-5,
    sigma_read=5.0e-4,
)

DEVICES = {d.name: d for d in (EPIRAM, TAOX_HFOX)}
