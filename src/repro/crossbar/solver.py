"""Fast (jitted) crossbar PDHG: device physics + analytic energy ledger.

The host-loop path (``core.pdhg.solve`` + ``crossbar_accel_factory``)
simulates every MVM through the tile model — maximal fidelity, but eager
per-call overhead makes 40k-iteration benchmark sweeps slow on one CPU
core.  This module runs the SAME device physics inside the jitted solver:

  1. Encode M = [[0,K],[K^T,0]] once (quantization + residual programming
     error; the K and K^T blocks are physically distinct cells and carry
     independent error) — ledgered as WRITE.
  2. Decode the two programmed blocks K_fwd (≈K) and K_adj (≈K^T) and run
     ``core.pdhg.solve_jit`` with per-MVM multiplicative read noise.
  3. Charge READ energy/latency analytically from the iteration count
     (2 MVMs per PDHG iteration + residual checks + Lanczos), identical
     cost constants to the host path.

Stream serving is DEVICE-TILE-AWARE and batched: ``CrossbarBatchSolver``
(a ``runtime.batch.BatchSolver`` subclass) buckets instances to multiples
of the physical crossbar tile, then encodes AND solves each bucket
through one vmapped compiled pipeline — programming a stacked (B, R, C)
operator array and solving all B instances in a single dispatch, with the
compiled executable cached per (bucket, batch, dtype, options, device)
signature.  Per-instance encode statistics come back from the pipeline so
each report's energy ledger is accumulated vectorized, with logical vs.
padding cells ledgered separately.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import engine
from ..core import pdhg as pdhg_mod
from ..core.pdhg import PDHGOptions, PDHGResult
from ..core.lanczos import lanczos_svd_jit
from ..core.residuals import kkt_residuals
from ..core.symblock import build_sym_block
from ..lp.problem import StandardLP
from ..runtime.batch import BatchSolver, _ceil_to, opts_static, prep_scale
from .device import DeviceModel, EPIRAM
from .encode import charge_write, encode_core, encode_matrix
from .energy import Ledger


@dataclasses.dataclass
class CrossbarSolveReport:
    result: PDHGResult
    ledger: Ledger
    device: DeviceModel
    lanczos_mvms: int
    pdhg_mvms: int
    # iterations the hardware actually EXECUTED (and the ledger charged).
    # On the batched path a vmapped while_loop runs every lane until the
    # slowest lane's check window completes, so an early-converged
    # instance executes (and pays for) more windows than
    # ``result.iterations`` reports; on single-instance paths the two
    # coincide.  With refinement this sums the executed windows of every
    # round.
    executed_iterations: int = 0
    # exact full-precision residual MVMs issued by the digital refinement
    # shell (``engine.refine_digital_mvms``) — digital co-processor work,
    # deliberately NOT charged to the analog read ledger
    digital_mvms: int = 0


def _charge_reads(ledger: Ledger, device: DeviceModel, n_mvms: int,
                  active_cells: float):
    ledger.read_energy_j += (n_mvms * active_cells
                             * device.read_energy_per_cell_j)
    ledger.read_latency_s += n_mvms * device.read_latency_s
    ledger.mvm_count += n_mvms


def solve_crossbar_jit(
    lp: StandardLP,
    opts: PDHGOptions = PDHGOptions(),
    device: DeviceModel = EPIRAM,
    key: Optional[jax.Array] = None,
    ledger: Optional[Ledger] = None,
) -> CrossbarSolveReport:
    if opts.refine_rounds > 0:
        # digital iterative-refinement shell: same encode-once contract,
        # extra analog read windows per round, zero extra writes
        from . import refine as refine_mod
        return refine_mod.solve_crossbar_refined(lp, opts, device, key,
                                                 ledger)
    if key is None:
        key = jax.random.PRNGKey(opts.seed)
    ledger = ledger if ledger is not None else Ledger()

    # Ruiz-scale on host first (Algorithm 4 step 0), then program M once.
    scaled, _T, _Sigma = pdhg_mod.prepare(lp, opts)
    m, n = scaled.K.shape
    M = build_sym_block(scaled.K)
    enc = encode_matrix(M, device, key, ledger=ledger)
    M_prog = enc.decode()
    K_fwd = M_prog[:m, m:]          # programmed K block
    K_adj = M_prog[m:, :m]          # programmed K^T block (distinct cells)

    result = pdhg_mod.solve_jit(
        lp, opts, K_fwd=K_fwd, K_adj=K_adj, sigma_read=device.sigma_read
    )
    # READ accounting: ``result.mvm_calls`` already counts Lanczos
    # (1 MVM/iter, ``result.lanczos_iters``) + PDHG (2/iter) + residual
    # checks (4 per check: x/y pair for current and averaged iterates) —
    # charge it wholesale.
    lanczos_mvms = result.lanczos_iters
    pdhg_mvms = result.mvm_calls - lanczos_mvms
    _charge_reads(ledger, device, result.mvm_calls, enc.active_cells)
    return CrossbarSolveReport(
        result=result, ledger=ledger, device=device,
        lanczos_mvms=lanczos_mvms, pdhg_mvms=pdhg_mvms,
        executed_iterations=result.iterations,
    )


# ------------------------------------------------- batched stream serving ---

def _array_dims(mb: int, nb: int, device: DeviceModel) -> Tuple[int, int]:
    """Physical array shape of the programmed symmetric block M for a
    (mb, nb) bucket: (mb+nb) rounded up to whole tiles.  With square
    tiles (the shipped devices) this is the identity, but rectangular
    tiles leave (mb+nb) mid-tile in one dimension."""
    d = mb + nb
    return (_ceil_to(d, device.crossbar_rows),
            _ceil_to(d, device.crossbar_cols))


def make_crossbar_bucket_pipeline(opts: PDHGOptions, device: DeviceModel):
    """vmapped prep + encode + solve over a stacked (B, m, n) bucket.

    Per instance: Ruiz/diagonal preconditioning, differential-pair
    programming of M (independent error on the K and K^T blocks), Lanczos
    on the PROGRAMMED operator (or ``opts.norm_override``), then the
    engine loop with the device's read noise.  ``opts.kernel`` selects
    the backends: ``"jnp"`` decodes the programmed blocks and runs the
    dense operator; ``"pallas"`` keeps the conductance pair ON DEVICE and
    issues every solve MVM through the tiled differential-pair kernel
    (``engine.crossbar_operator`` -> ``kernels.ops.crossbar_mvm``) with
    the fused update kernels.  Returns unscaled (xs, ys, its,
    merits, rhos, nz) — ``nz`` is the per-instance count of programmed
    differential pairs feeding the vectorized write ledger, and ``its``
    is the per-round iteration-count vector (length
    ``opts.refine_rounds + 1``; one entry per analog solve).

    With ``opts.refine_rounds > 0`` each lane runs the digital
    iterative-refinement shell (``crossbar.refine.refined_core``) around
    the same encode: the programmed conductance stack is reused by every
    round (zero extra writes), the exact scaled K feeds the digital
    residual MVMs, and the analog correction solves ride the same
    operator backend selection.
    """
    from .refine import refined_core   # deferred: refine imports solver

    static = opts_static(opts, device.sigma_read)

    def one(K, b, c, lb, ub, key):
        (Ks, bs, cs, lbs, ubs, T, Sigma, D1, D2) = prep_scale(
            K, b, c, lb, ub, opts)
        enc_key, solve_key = jax.random.split(key)
        M = build_sym_block(Ks)
        m, n = K.shape
        R, C = _array_dims(m, n, device)
        Mp = jnp.zeros((R, C), M.dtype).at[:m + n, :m + n].set(M)
        g_pos, g_neg, scale, nz = encode_core(
            Mp, enc_key, device.g_levels, device.sigma_program,
            ecc=device.ecc, ecc_decode=device.ecc_decode,
            stuck_rate=device.stuck_rate, drift=device.drift)
        M_prog = (g_pos - g_neg) * scale
        K_fwd = M_prog[:m, m:m + n]
        K_adj = M_prog[m:m + n, :m]
        if opts.norm_override is not None:
            rho = jnp.asarray(opts.norm_override, K.dtype)
        else:
            # operator norm of the operator actually executed (Lemma 2
            # margin widened for the noisy estimate, as in solve_jit)
            Keff = jnp.sqrt(Sigma)[:, None] * K_fwd * jnp.sqrt(T)[None, :]
            rho = engine.lemma2_margin(
                lanczos_svd_jit(build_sym_block(Keff),
                                k_max=opts.lanczos_iters),
                device.sigma_read)
        op = (engine.crossbar_operator(g_pos, g_neg, scale, m, n,
                                       sigma_read=device.sigma_read)
              if opts.kernel == "pallas" else None)   # None -> dense decode
        if opts.refine_rounds > 0:
            x, y, its, merit = refined_core(
                Ks, Ks.T, K_fwd, K_adj, bs, cs, lbs, ubs, T, Sigma, rho,
                solve_key, static, operator=op)
        else:
            x, y, it, merit = engine.solve_core(
                K_fwd, K_adj, bs, cs, lbs, ubs, T, Sigma, rho, solve_key,
                static, operator=op)
            its = jnp.reshape(it, (1,))
        return D2 * x, D1 * y, its, merit, rho, nz

    def pipeline(Ks, bs, cs, lbs, ubs, keys):
        return jax.vmap(one)(Ks, bs, cs, lbs, ubs, keys)

    return pipeline


class CrossbarBatchSolver(BatchSolver):
    """Device-tile-aware bucketing scheduler for crossbar-simulated LPs.

    Buckets snap to multiples of ``device.crossbar_rows/cols`` (whole
    physical tiles), each bucket is encoded + solved by one vmapped
    compiled executable, and the cache key carries the device model, so
    traffic mixing devices or shapes compiles at most once per
    (bucket, batch, device) signature.  ``solve_stream`` returns
    ``CrossbarSolveReport`` objects (per-instance energy ledger included;
    residuals reported in ORIGINAL coordinates).

    Sparse instances densify on entry (``supports_sparse = False``): a
    crossbar programs every physical cell of its tiles regardless of the
    operator's sparsity, so there is no memory to save device-side.
    """

    supports_sparse = False

    def __init__(self, opts: PDHGOptions = PDHGOptions(), *,
                 device: DeviceModel = EPIRAM, mesh=None,
                 batch_axes: Tuple[str, ...] = ("data",),
                 kernel: Optional[str] = None):
        super().__init__(
            opts, mesh=mesh, batch_axes=batch_axes,
            sigma_read=device.sigma_read,
            tile=(device.crossbar_rows, device.crossbar_cols),
            kernel=kernel)
        self.device = device

    def _device_signature(self):
        return self.device           # frozen dataclass -> hashable

    def _make_pipeline(self):
        return make_crossbar_bucket_pipeline(self.opts, self.device)

    def _collect(self, out, bucket, idxs, lps, results) -> None:
        xs, ys, its, merits, rhos, nzs = (np.asarray(a) for a in out)
        mb, nb = bucket
        R, C = _array_dims(mb, nb, self.device)
        pairs_total = R * C                # tile-padded physical array
        lanczos_mvms = (0 if self.opts.norm_override is not None
                        else self.opts.lanczos_iters)
        # The vmapped while_loop physically executes EVERY lane (filler
        # lanes included) until the slowest lane's check window
        # completes, so the hardware runs — and the ledger must charge —
        # the bucket-max iteration count per analog solve, not each
        # instance's own early-exit count.  ``its`` is (B, rounds + 1):
        # one column per refinement round's analog solve; iteration
        # counts advance by ``check_every`` per window, so the column max
        # is already window-quantized.
        executed = its.max(axis=0)                  # per-round, all lanes
        executed_total = int(executed.sum())
        pdhg_mvms = int(sum(
            engine.mvm_accounting(int(e), self.opts.check_every, 0,
                                  restart=self.opts.restart)
            for e in executed))
        digital_mvms = engine.refine_digital_mvms(self.opts.refine_rounds)
        for k, i in enumerate(idxs):
            lp = lps[i]
            m, n = lp.K.shape
            x, y = xs[k, :n], ys[k, :m]
            it = int(its[k].sum())
            merit = float(merits[k])
            ledger = Ledger()
            fill = charge_write(ledger, self.device, float(nzs[k]),
                                pairs_logical=(m + n) ** 2,
                                pairs_total=pairs_total)
            active_cells = (2.0 * pairs_total * fill
                            * max(1, self.device.ecc))
            _charge_reads(ledger, self.device, lanczos_mvms + pdhg_mvms,
                          active_cells)
            res = kkt_residuals(
                jnp.asarray(x), jnp.asarray(x), jnp.asarray(y),
                jnp.asarray(lp.c), jnp.asarray(lp.b),
                jnp.asarray(lp.K @ x), jnp.asarray(lp.K.T @ y),
                lb=jnp.asarray(lp.lb), ub=jnp.asarray(lp.ub))
            if not np.isfinite(merit):
                status = "diverged"     # NaN merit: blow-up, not a limit
            elif merit <= self.opts.tol:
                status = "optimal"
            else:
                status = "iteration_limit"
            result = PDHGResult(
                status=status,
                x=x, y=y, obj=float(lp.c @ x), iterations=it,
                residuals=res, sigma_max=float(rhos[k]),
                lanczos_iters=lanczos_mvms,
                mvm_calls=lanczos_mvms + pdhg_mvms,
                merit=merit,
            )
            results[i] = CrossbarSolveReport(
                result=result, ledger=ledger, device=self.device,
                lanczos_mvms=lanczos_mvms, pdhg_mvms=pdhg_mvms,
                executed_iterations=executed_total,
                digital_mvms=digital_mvms,
            )


def solve_crossbar_stream(
    lps: Sequence[StandardLP],
    opts: PDHGOptions = PDHGOptions(),
    device: DeviceModel = EPIRAM,
    *,
    mesh=None,
    solver: Optional[CrossbarBatchSolver] = None,
) -> List[CrossbarSolveReport]:
    """Serve a heterogeneous LP stream on one simulated crossbar tier.

    Instances bucket to whole physical tiles and every bucket runs
    encode -> solve as ONE vmapped compiled call (see
    ``CrossbarBatchSolver``).  Pass ``solver`` to keep the compiled
    executables warm across streams.
    """
    if solver is None:
        solver = CrossbarBatchSolver(opts, device=device, mesh=mesh)
    return solver.solve_stream(lps)
