"""Fast (jitted) crossbar PDHG: device physics + analytic energy ledger.

The host-loop path (``core.pdhg.solve`` + ``crossbar_accel_factory``)
simulates every MVM through the tile model — maximal fidelity, but eager
per-call overhead makes 40k-iteration benchmark sweeps slow on one CPU
core.  This module runs the SAME device physics inside the jitted solver:

  1. Encode M = [[0,K],[K^T,0]] once (quantization + residual programming
     error; the K and K^T blocks are physically distinct cells and carry
     independent error) — ledgered as WRITE.
  2. Decode the two programmed blocks K_fwd (≈K) and K_adj (≈K^T) and run
     ``core.pdhg.solve_jit`` with per-MVM multiplicative read noise.
  3. Charge READ energy/latency analytically from the iteration count
     (2 MVMs per PDHG iteration + residual checks + Lanczos), identical
     cost constants to the host path.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import pdhg as pdhg_mod
from ..core.pdhg import PDHGOptions, PDHGResult
from ..core.symblock import build_sym_block
from ..lp.problem import StandardLP
from ..runtime.batch import bucket_dims, pad_problem
from .device import DeviceModel, EPIRAM
from .encode import encode_matrix
from .energy import Ledger


@dataclasses.dataclass
class CrossbarSolveReport:
    result: PDHGResult
    ledger: Ledger
    device: DeviceModel
    lanczos_mvms: int
    pdhg_mvms: int


def _charge_reads(ledger: Ledger, device: DeviceModel, n_mvms: int,
                  active_cells: float):
    ledger.read_energy_j += (n_mvms * active_cells
                             * device.read_energy_per_cell_j)
    ledger.read_latency_s += n_mvms * device.read_latency_s
    ledger.mvm_count += n_mvms


def solve_crossbar_jit(
    lp: StandardLP,
    opts: PDHGOptions = PDHGOptions(),
    device: DeviceModel = EPIRAM,
    key: Optional[jax.Array] = None,
    ledger: Optional[Ledger] = None,
) -> CrossbarSolveReport:
    if key is None:
        key = jax.random.PRNGKey(opts.seed)
    ledger = ledger if ledger is not None else Ledger()

    # Ruiz-scale on host first (Algorithm 4 step 0), then program M once.
    scaled, _T, _Sigma = pdhg_mod.prepare(lp, opts)
    m, n = scaled.K.shape
    M = build_sym_block(scaled.K)
    enc = encode_matrix(M, device, key, ledger=ledger)
    M_prog = enc.decode()
    K_fwd = M_prog[:m, m:]          # programmed K block
    K_adj = M_prog[m:, :m]          # programmed K^T block (distinct cells)

    result = pdhg_mod.solve_jit(
        lp, opts, K_fwd=K_fwd, K_adj=K_adj, sigma_read=device.sigma_read
    )
    # READ accounting: Lanczos (1 MVM/iter) + PDHG (2/iter) + residual
    # checks (4 per check: x/y pair for current and averaged iterates).
    n_checks = max(1, result.iterations // max(1, opts.check_every))
    lanczos_mvms = opts.lanczos_iters
    pdhg_mvms = 2 * result.iterations + 4 * n_checks
    _charge_reads(ledger, device, lanczos_mvms + pdhg_mvms,
                  enc.active_cells)
    return CrossbarSolveReport(
        result=result, ledger=ledger, device=device,
        lanczos_mvms=lanczos_mvms, pdhg_mvms=pdhg_mvms,
    )


def solve_crossbar_stream(
    lps: Sequence[StandardLP],
    opts: PDHGOptions = PDHGOptions(),
    device: DeviceModel = EPIRAM,
) -> List[CrossbarSolveReport]:
    """Serve a heterogeneous LP stream on one simulated crossbar tier.

    Each instance is padded up to its power-of-two runtime bucket (see
    ``runtime.batch``) before encoding, so the jitted solve core is
    traced once per bucket instead of once per distinct ``(m, n)`` —
    the crossbar analogue of the batch scheduler's executable reuse.
    Padded cells still encode (lb=ub=0 pins their variables), so device
    physics and the energy ledger see the full programmed array.
    """
    reports = []
    for i, lp in enumerate(lps):
        mb, nb = bucket_dims(*lp.K.shape)
        padded = pad_problem(lp, mb, nb)
        rep = solve_crossbar_jit(padded, opts, device=device,
                                 key=jax.random.PRNGKey(opts.seed + i))
        m, n = lp.K.shape
        res = rep.result
        x = res.x[:n]
        rep.result = dataclasses.replace(
            res, x=x, y=res.y[:m], obj=float(lp.c @ x))
        reports.append(rep)
    return reports
