"""Model substrate: unified decoder LM for all assigned architectures."""
from .config import ModelConfig
from . import layers, lm, mla, moe, rwkv6, ssm
from .lm import (
    decode_step,
    forward,
    init_cache,
    init_params,
    param_shapes,
    partition_specs,
)

__all__ = [
    "ModelConfig", "layers", "lm", "mla", "moe", "rwkv6", "ssm",
    "decode_step", "forward", "init_cache", "init_params", "param_shapes",
    "partition_specs",
]
