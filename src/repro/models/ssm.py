"""Selective SSM (Mamba-style) head + Hymba parallel attn/SSM block.

Hymba (arXiv:2411.13676) fuses attention heads and mamba heads *in
parallel within the same layer*: both see the same normed input, their
outputs are normalized and mean-combined.  Attention uses a sliding
window, and the SSM branch carries unbounded context — the combination is
sub-quadratic, which is why hymba runs the long_500k cell.

The SSM here is a grouped selective scan (per-head state (N, dh)):
    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * (B_t ⊗ x_t)
    y_t = C_t · S_t + D_h * x_t
with dt, B, C data-dependent (input projections) — the mamba2 recipe minus
the depthwise conv fine print (a k=4 depthwise conv is included).
Baseline lowers as lax.scan over time; the chunked/associative variant is
a hillclimb option.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import gqa_attention, gqa_decode, gqa_params_shape, rms_norm

CONV_K = 4


def ssm_params_shape(cfg):
    d = cfg.d_model
    nh, dh, N = cfg.ssm_heads, cfg.d_head, cfg.ssm_state
    di = nh * dh
    return {
        "w_in": (d, 2 * di),          # x branch + gate z
        "conv": (CONV_K, di),         # depthwise conv
        "w_dt": (di, nh),
        "dt_bias": (nh,),
        "w_B": (d, nh * N),
        "w_C": (d, nh * N),
        "A_log": (nh,),
        "D": (nh,),
        "w_out": (di, d),
    }


def _depthwise_conv(x, w):
    """causal depthwise conv: x (B, S, di), w (K, di)."""
    B, S, di = x.shape
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):      # K is tiny and static: unrolled taps
        out = out + xp[:, i : i + S, :] * w[i]
    return out


def ssm_scan(p, x, cfg, state=None, conv_tail=None):
    """x (B, S, d) -> (y (B, S, d), (state, conv_tail)).

    state (B, nh, N, dh); conv_tail (B, CONV_K-1, di) carries the causal
    conv context across decode steps.
    """
    B, S, d = x.shape
    nh, dh, N = cfg.ssm_heads, cfg.d_head, cfg.ssm_state
    di = nh * dh
    xz = x @ p["w_in"]
    xb, z = xz[..., :di], xz[..., di:]
    if conv_tail is not None:
        xb_ext = jnp.concatenate([conv_tail, xb], axis=1)
        conv_out = _depthwise_conv(xb_ext, p["conv"])[:, -(S):, :]
        new_tail = xb_ext[:, -(CONV_K - 1):, :]
    else:
        conv_out = _depthwise_conv(xb, p["conv"])
        new_tail = xb[:, -(CONV_K - 1):, :]
    u = jax.nn.silu(conv_out)                                  # (B,S,di)
    dt = jax.nn.softplus(u @ p["w_dt"] + p["dt_bias"])         # (B,S,nh)
    Bmat = (x @ p["w_B"]).reshape(B, S, nh, N)
    Cmat = (x @ p["w_C"]).reshape(B, S, nh, N)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (nh,)
    uh = u.reshape(B, S, nh, dh)
    if state is None:
        state = jnp.zeros((B, nh, N, dh), jnp.float32)

    def step(S_prev, inp):
        u_t, dt_t, B_t, C_t = inp
        decay = jnp.exp(dt_t.astype(jnp.float32) * A)[..., None, None]
        drive = (dt_t[..., None, None] * B_t[..., :, None]
                 * u_t[..., None, :]).astype(jnp.float32)
        S_new = decay * S_prev + drive
        y_t = jnp.einsum("bhn,bhnd->bhd", C_t.astype(jnp.float32), S_new)
        return S_new, y_t

    xs = (jnp.moveaxis(uh, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bmat, 1, 0), jnp.moveaxis(Cmat, 1, 0))
    state, ys = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)                 # (B,S,nh,dh)
    y = y + uh * p["D"][:, None]
    y = (y.reshape(B, S, di) * jax.nn.silu(z))
    return y @ p["w_out"], (state, new_tail)


def ssm_scan_chunked(p, x, cfg, state=None, conv_tail=None):
    """Chunk-parallel selective scan (mamba2-style) — hillclimb 3.

    Identical math to ``ssm_scan`` (per-head scalar decay A_h), processed
    ``cfg.ssm_chunk`` timesteps at once:

        cum_t  = sum_{s<=t} dt_s * A_h                (log-decay cumsum)
        y_t    = e^{cum_t} (C_t . S_0)
                 + sum_{s<=t} e^{cum_t - cum_s} dt_s (C_t . B_s) u_s
        S_next = e^{cum_L} S_0 + sum_s e^{cum_L - cum_s} dt_s B_s (x) u_s

    The per-step (B,nh,N,dh) state read/write of the sequential scan
    becomes one (L,L) masked matmul per chunk per head — MXU food.  All
    exponents are <= 0 for s <= t, so no overflow.
    """
    B, S, d = x.shape
    L = max(1, min(cfg.ssm_chunk, S))
    if S % L != 0:
        return ssm_scan(p, x, cfg, state=state, conv_tail=conv_tail)
    nh, dh, N = cfg.ssm_heads, cfg.d_head, cfg.ssm_state
    di = nh * dh
    xz = x @ p["w_in"]
    xb, z = xz[..., :di], xz[..., di:]
    if conv_tail is not None:
        xb_ext = jnp.concatenate([conv_tail, xb], axis=1)
        conv_out = _depthwise_conv(xb_ext, p["conv"])[:, -(S):, :]
        new_tail = xb_ext[:, -(CONV_K - 1):, :]
    else:
        conv_out = _depthwise_conv(xb, p["conv"])
        new_tail = xb[:, -(CONV_K - 1):, :]
    u = jax.nn.silu(conv_out)
    dt = jax.nn.softplus(u @ p["w_dt"] + p["dt_bias"]).astype(jnp.float32)
    Bm = (x @ p["w_B"]).reshape(B, S, nh, N).astype(jnp.float32)
    Cm = (x @ p["w_C"]).reshape(B, S, nh, N).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    uh = u.reshape(B, S, nh, dh).astype(jnp.float32)
    nc = S // L
    # chunked views: (B, nc, L, ...)
    dtc = dt.reshape(B, nc, L, nh)
    Bc = Bm.reshape(B, nc, L, nh, N)
    Cc = Cm.reshape(B, nc, L, nh, N)
    uc = uh.reshape(B, nc, L, nh, dh)
    if state is None:
        state = jnp.zeros((B, nh, N, dh), jnp.float32)
    mask = jnp.tril(jnp.ones((L, L), jnp.float32))

    def chunk_step(S0, inp):
        dt_k, B_k, C_k, u_k = inp              # (B,L,nh[,N|dh])
        log_a = dt_k * A                        # (B,L,nh), <= 0
        cum = jnp.cumsum(log_a, axis=1)         # (B,L,nh)
        decay0 = jnp.exp(cum)                   # e^{cum_t}
        # inter-chunk: y_t^0 = e^{cum_t} C_t . S_0
        y0 = jnp.einsum("blhn,bhnd->blhd", C_k, S0) * decay0[..., None]
        # intra-chunk quadratic form
        G = jnp.einsum("blhn,bshn->bhls", C_k, B_k)          # (B,nh,L,L)
        ratio = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,l,s,h)
        ratio = jnp.moveaxis(ratio, 3, 1)                    # (B,nh,l,s)
        W = G * ratio * jnp.moveaxis(dt_k, 2, 1)[:, :, None, :]
        W = W * mask[None, None]
        y1 = jnp.einsum("bhls,bshd->blhd", W, u_k)
        # state propagation to chunk end
        decay_end = jnp.exp(cum[:, -1:, :] - cum)            # (B,L,nh)
        drive = jnp.einsum(
            "blh,blhn,blhd->bhnd", dt_k * decay_end, B_k, u_k)
        S_new = S0 * jnp.exp(cum[:, -1, :])[..., None, None] + drive
        return S_new, y0 + y1

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (dtc, Bc, Cc, uc))
    state, ys = jax.lax.scan(chunk_step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, nh, dh)
    y = y + uh * p["D"][:, None]
    y = (y.astype(x.dtype).reshape(B, S, di) * jax.nn.silu(z))
    return y @ p["w_out"], (state, new_tail)


def ssm_apply(p, x, cfg, state=None, conv_tail=None):
    """Dispatch: chunked when configured and applicable, else sequential."""
    if cfg.ssm_chunk and x.shape[1] > 1:
        return ssm_scan_chunked(p, x, cfg, state=state, conv_tail=conv_tail)
    return ssm_scan(p, x, cfg, state=state, conv_tail=conv_tail)


# ------------------------------------------------------------- hymba ---

def hybrid_params_shape(cfg):
    shapes = {"attn": gqa_params_shape(cfg), "ssm": ssm_params_shape(cfg)}
    shapes["attn_scale"] = (cfg.d_model,)
    shapes["ssm_scale"] = (cfg.d_model,)
    return shapes


def hybrid_block(p, x, cfg, positions=None):
    attn_out, _kv = gqa_attention(p["attn"], x, cfg, positions)
    ssm_out, _st = ssm_apply(p["ssm"], x, cfg)
    out = 0.5 * (rms_norm(attn_out, p["attn_scale"])
                 + rms_norm(ssm_out, p["ssm_scale"]))
    return out, None


def hybrid_decode(p, x, cfg, cache):
    """cache = {"attn": rolling-window KV, "state", "conv_tail"}."""
    attn_out, attn_cache = gqa_decode(p["attn"], x, cfg, cache["attn"])
    ssm_out, (state, tail) = ssm_scan(
        p["ssm"], x, cfg, state=cache["state"], conv_tail=cache["conv_tail"])
    out = 0.5 * (rms_norm(attn_out, p["attn_scale"])
                 + rms_norm(ssm_out, p["ssm_scale"]))
    return out, {"attn": attn_cache, "state": state, "conv_tail": tail}
