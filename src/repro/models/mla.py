"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Queries and keys/values are produced from low-rank latents; a small
decoupled-RoPE sub-head carries positional information.  The decode cache
stores only the compressed latent (kv_lora_rank + rope dims per token) —
the architecture's raison d'être.

  cq  = x W_dq                       (d -> q_rank),  norm
  q   = cq W_uq                      -> H x (dh + dr)
  ckv = x W_dkv                      (d -> kv_rank + dr)
        split:  latent (kv_rank, normed) | k_rope (dr, shared over heads)
  k_nope, v = latent W_ukv           -> H x (dh + dh)
  attn over [nope ; rope] dims; out proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (
    apply_rope,
    chunked_causal_attention,
    decode_attention,
    rms_norm,
    rope_angles,
)


def mla_params_shape(cfg):
    d, H, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    qr, kvr, dr = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.rope_head_dim
    return {
        "w_dq": (d, qr),
        "q_norm": (qr,),
        "w_uq": (qr, H * (dh + dr)),
        "w_dkv": (d, kvr + dr),
        "kv_norm": (kvr,),
        "w_ukv": (kvr, H * (dh + dh)),
        "wo": (H * dh, d),
    }


def _project(p, x, cfg, positions):
    B, S, d = x.shape
    H, dh = cfg.n_heads, cfg.d_head
    qr, kvr, dr = cfg.q_lora_rank, cfg.kv_lora_rank, cfg.rope_head_dim
    cq = rms_norm(x @ p["w_dq"], p["q_norm"])
    q = (cq @ p["w_uq"]).reshape(B, S, H, dh + dr)
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    ckv = x @ p["w_dkv"]
    latent = rms_norm(ckv[..., :kvr], p["kv_norm"])
    k_rope = ckv[..., kvr:].reshape(B, S, 1, dr)
    kv = (latent @ p["w_ukv"]).reshape(B, S, H, 2 * dh)
    k_nope, v = kv[..., :dh], kv[..., dh:]
    cos, sin = rope_angles(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1)
    return q_full, k_full, v, ckv


def mla_attention(p, x, cfg, positions=None):
    B, S, _ = x.shape
    pos = positions if positions is not None else jnp.arange(S)
    q, k, v, _ = _project(p, x, cfg, pos)
    o = chunked_causal_attention(q, k, v, chunk=cfg.attn_chunk)
    H, dh = cfg.n_heads, cfg.d_head
    return o.reshape(B, S, H * dh) @ p["wo"], None


def mla_decode(p, x, cfg, cache):
    """cache = {"ckv": (B, C, kvr+dr), "len": ()} — compressed per MLA."""
    B, S, d = x.shape
    assert S == 1
    H, dh = cfg.n_heads, cfg.d_head
    kvr, dr = cfg.kv_lora_rank, cfg.rope_head_dim
    pos = cache["len"]
    q, k_new, v_new, ckv = _project(p, x, cfg, pos[None])
    ckv_cache = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, pos, 0))
    # reconstruct K/V for the whole cache from latents (weight-absorbed
    # decode is the hillclimb variant; baseline reconstructs explicitly)
    C = ckv_cache.shape[1]
    latent = rms_norm(ckv_cache[..., :kvr], p["kv_norm"])
    k_rope_c = ckv_cache[..., kvr:].reshape(B, C, 1, dr)
    cos, sin = rope_angles(jnp.arange(C), dr, cfg.rope_theta)
    k_rope_c = apply_rope(k_rope_c, cos, sin)
    kv = (latent @ p["w_ukv"]).reshape(B, C, H, 2 * dh)
    k_full = jnp.concatenate(
        [kv[..., :dh], jnp.broadcast_to(k_rope_c, (B, C, H, dr))], axis=-1)
    v_full = kv[..., dh:]
    o = decode_attention(q, k_full, v_full, pos + 1)
    new_cache = {"ckv": ckv_cache, "len": pos + 1}
    return o.reshape(B, 1, H * dh) @ p["wo"], new_cache
