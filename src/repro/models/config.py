"""Model configuration shared by all assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | vlm | hybrid | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int             # 0 for attention-free (rwkv6)
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    mixer: str = "gqa"       # gqa | mla | hybrid | rwkv6
    mlp: str = "dense"       # dense | moe
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # MLA (MiniCPM3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0   # decoupled-RoPE dims per head (MLA)
    # SSM / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    window: int = 0          # sliding-window attention size (0 = full)
    # attention details
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    # misc
    tie_embeddings: bool = True
    act: str = "silu"
    dtype: str = "bfloat16"
    frontend: str = "none"   # none | vision | audio  (stub embeddings)
    # attention chunking for sub-quadratic MEMORY during long prefill
    attn_chunk: int = 512
    # SSM chunked (mamba2-style) scan: 0 = sequential lax.scan baseline,
    # N = process N timesteps per state update (hillclimb 3: turns the
    # state recurrence from memory-bound into MXU matmuls)
    ssm_chunk: int = 0
    # int8 KV cache (per-token-per-head symmetric scales): halves decode
    # cache memory+bandwidth for the MHA archs (musicgen, phi-3-vision)
    # whose 32k caches exceed a single-pod HBM budget. Opt-in.
    kv_cache_int8: bool = False

    @property
    def attention_free(self) -> bool:
        return self.mixer == "rwkv6"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid-with-SWA / linear attn)."""
        return self.mixer in ("rwkv6", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (exact for our layer definitions)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        H, Hkv, dh = self.n_heads, self.n_kv_heads, self.d_head
        n = V * d                      # embedding (tied head)
        if not self.tie_embeddings:
            n += V * d
        per_layer = 2 * d              # two RMSNorm gains
        if self.mixer == "gqa":
            per_layer += d * H * dh + 2 * d * Hkv * dh + H * dh * d
            if self.qk_norm:
                per_layer += 2 * dh
        elif self.mixer == "mla":
            qr, kvr, dr = self.q_lora_rank, self.kv_lora_rank, self.rope_head_dim
            per_layer += d * qr + qr + qr * H * (dh + dr)          # q path
            per_layer += d * (kvr + dr) + kvr                      # kv down
            per_layer += kvr * H * (dh + dh)                       # k_nope + v
            per_layer += H * dh * d                                # out
        elif self.mixer == "hybrid":
            per_layer += d * H * dh + 2 * d * Hkv * dh + H * dh * d
            sh, sd, N = self.ssm_heads, self.d_head, self.ssm_state
            di = sh * sd
            per_layer += d * 2 * di + di * 2 + di * (2 * N) + di + di * d
            per_layer += 2 * d        # extra norms for branch fusion
        elif self.mixer == "rwkv6":
            sh, dh2 = self.ssm_heads, self.d_head
            di = sh * dh2
            per_layer += 6 * d * di // (di // d if di >= d else 1) if False else 0
            per_layer += 5 * d * di + di * d   # r,k,v,g,w projections + out
            per_layer += 6 * d + 2 * 32 * d    # token-shift lerps + lora
        if self.mlp == "dense":
            per_layer += 3 * d * ff            # gated MLP (w1, w3, w2)
        else:
            E = self.n_experts
            per_layer += d * E                 # router
            per_layer += E * 3 * d * ff        # per-expert gated MLP
        n += L * per_layer + d                 # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.mlp != "moe":
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        inactive = L * (self.n_experts - self.top_k) * 3 * d * ff
        return self.param_count() - inactive
