"""Top-k routed mixture-of-experts (OLMoE 64e/top-8, Grok-1 8e/top-2).

Default path is the grouped one-hot dispatch (Shazeer-style, two einsums)
with a small group size so the dispatch tensor stays ~tens of MB/device
under SPMD — robust to the XLA partitioner for the dry-run.  Capacity is
``ceil(group_tokens * top_k / E * capacity_factor)``; overflowing tokens
are dropped (standard) and their residual stream passes through.

The scatter-based dropless path (sort by expert, dense per-expert matmul)
is the hillclimb alternative (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import act_fn

GROUP = 512   # tokens per routing group


def moe_params_shape(cfg):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": (d, E),
        "w1": (E, d, ff),
        "w3": (E, d, ff),
        "w2": (E, ff, d),
    }


def _capacity(tokens_per_group: int, top_k: int, n_experts: int,
              factor: float) -> int:
    c = math.ceil(tokens_per_group * top_k / n_experts * factor)
    return max(4, int(c))


def moe_block(p, x, cfg):
    """x (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    g = max(1, T // GROUP)
    tg = T // g
    xt = x.reshape(g, tg, d)
    logits = (xt @ p["router"]).astype(jnp.float32)        # (g, tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                  # (g, tg, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    C = _capacity(tg, k, E, cfg.capacity_factor)
    # expert one-hot per choice: (g, tg, k, E)
    oh = jax.nn.one_hot(top_i, E, dtype=jnp.float32)
    # position of each (token, choice) within its expert queue
    pos = jnp.cumsum(oh.reshape(g, tg * k, E), axis=1).reshape(
        g, tg, k, E) * oh - 1.0
    keep = (pos < C) & (oh > 0)
    pos = jnp.where(keep, pos, 0.0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
    # dispatch (g, tg, E, C) / combine with routing probs folded in
    dispatch = jnp.einsum("gske,gskec->gsec", oh * keep, pos_oh)
    combine = jnp.einsum("gske,gskec,gsk->gsec", oh * keep, pos_oh, top_p)

    xin = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), xt)
    h = act_fn(jnp.einsum("egcd,edf->egcf", xin, p["w1"]), cfg.act)
    h = h * jnp.einsum("egcd,edf->egcf", xin, p["w3"])
    out_e = jnp.einsum("egcf,efd->egcd", h, p["w2"])
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), out_e)
    return out.reshape(B, S, d)


def moe_aux_loss(p, x, cfg):
    """Load-balancing auxiliary loss (Switch-style): E * sum(f_e * p_e)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    logits = (x.reshape(-1, d) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_i = jax.lax.top_k(probs, k)[1]
    frac = jnp.mean(jax.nn.one_hot(top_i, E, dtype=jnp.float32), axis=(0, 1))
    imp = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac * imp)
