"""RWKV-6 "Finch" token mixer (arXiv:2404.05892) — attention-free.

Structure (per layer, per head of size dh):
  token shift   x_i = lerp(x_t, x_{t-1}, mu_i)   for i in {r,k,v,g,w}
  projections   r, k, v (d -> di), gate g = silu(.), decay LoRA for w
  data-dependent decay   w_t = exp(-exp(wb + tanh(x_w A) B))  in (0,1)
  WKV recurrence (state S per head, (dh, dh)):
      y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
      S_t = diag(w_t) S_{t-1} + k_t v_t^T
  head-wise group norm, gate, output projection.

O(1) state per token => rwkv6 runs the long_500k decode cell natively.
Baseline lowers the recurrence as lax.scan; the chunked formulation is a
hillclimb option.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import rms_norm

LORA_R = 32


def rwkv6_params_shape(cfg):
    d = cfg.d_model
    nh, dh = cfg.ssm_heads, cfg.d_head
    di = nh * dh
    return {
        "mu": (5, d),                # token-shift lerps for r,k,v,g,w
        "w_r": (d, di),
        "w_k": (d, di),
        "w_v": (d, di),
        "w_g": (d, di),
        "w_decay_base": (di,),
        "w_decay_A": (d, LORA_R),
        "w_decay_B": (LORA_R, di),
        "u_bonus": (di,),
        "ln_x": (di,),
        "w_o": (di, d),
    }


def _shift(x, prev):
    """x (B,S,d) -> x_{t-1} with ``prev`` (B,1,d) as the t=0 context."""
    return jnp.concatenate([prev, x[:, :-1, :]], axis=1)


def _mix(x, x_prev, mu):
    return x + (x_prev - x) * mu


def rwkv6_mix(p, x, cfg, state=None, x_tail=None):
    """x (B,S,d) -> (y, (state (B,nh,dh,dh), x_tail (B,1,d)))."""
    B, S, d = x.shape
    nh, dh = cfg.ssm_heads, cfg.d_head
    di = nh * dh
    prev = x_tail if x_tail is not None else jnp.zeros((B, 1, d), x.dtype)
    xp = _shift(x, prev)
    xr = _mix(x, xp, p["mu"][0])
    xk = _mix(x, xp, p["mu"][1])
    xv = _mix(x, xp, p["mu"][2])
    xg = _mix(x, xp, p["mu"][3])
    xw = _mix(x, xp, p["mu"][4])
    r = (xr @ p["w_r"]).reshape(B, S, nh, dh)
    k = (xk @ p["w_k"]).reshape(B, S, nh, dh)
    v = (xv @ p["w_v"]).reshape(B, S, nh, dh)
    g = jax.nn.silu(xg @ p["w_g"])
    # data-dependent decay, clamped for numerical safety
    dec = p["w_decay_base"] + jnp.tanh(xw @ p["w_decay_A"]) @ p["w_decay_B"]
    w = jnp.exp(-jnp.exp(jnp.clip(dec.astype(jnp.float32), -8.0, 2.0)))
    w = w.reshape(B, S, nh, dh)
    u = p["u_bonus"].reshape(nh, dh)
    if state is None:
        state = jnp.zeros((B, nh, dh, dh), jnp.float32)

    def step(S_prev, inp):
        r_t, k_t, v_t, w_t = [a.astype(jnp.float32) for a in inp]
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,nh,dh,dh)
        y_t = jnp.einsum("bhk,bhkv->bhv", r_t,
                         S_prev + u[..., :, None] * kv)
        S_new = w_t[..., :, None] * S_prev + kv
        return S_new, y_t

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y, p["ln_x"]) * g
    out = y @ p["w_o"]
    return out, (state, x[:, -1:, :])


def rwkv6_decode(p, x, cfg, cache):
    """Single-token step; cache = {"state", "x_tail"} — O(1) memory."""
    out, (state, tail) = rwkv6_mix(
        p, x, cfg, state=cache["state"], x_tail=cache["x_tail"])
    return out, {"state": state, "x_tail": tail}
