"""Unified decoder LM over explicit parameter pytrees.

One model definition serves all ten assigned architectures: the token
mixer (GQA / MLA / hybrid attn+SSM / RWKV6) and the channel mixer
(dense gated MLP / top-k MoE) are selected by ``ModelConfig``.  Layers are
STACKED (leading L axis) and executed with lax.scan + remat, so the HLO is
depth-independent — crucial for CPU-hosted dry-run compiles of 40-64-layer
configs.

Sharding: parameters get explicit PartitionSpecs (``partition_specs``);
activations get in-graph constraints (``_constrain``) that no-op when no
mesh is active (single-device smoke tests).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..runtime import compat
from . import mla as mla_mod
from . import moe as moe_mod
from . import rwkv6 as rwkv_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (
    gated_mlp,
    gqa_attention,
    gqa_decode,
    gqa_params_shape,
    mlp_params_shape,
    rms_norm,
)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# with_sharding_constraint / batch-axis resolution against the ambient
# mesh, portable across JAX versions (see runtime.compat).
_batch_axes = compat.batch_axes
_constrain = compat.constrain


def constrain_tokens(x):
    return _constrain(x, _batch_axes(), *([None] * (x.ndim - 1)))


# ------------------------------------------------------------- shapes ---

def mixer_params_shape(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.mixer == "gqa":
        return gqa_params_shape(cfg)
    if cfg.mixer == "mla":
        return mla_mod.mla_params_shape(cfg)
    if cfg.mixer == "hybrid":
        return ssm_mod.hybrid_params_shape(cfg)
    if cfg.mixer == "rwkv6":
        return rwkv_mod.rwkv6_params_shape(cfg)
    raise ValueError(cfg.mixer)


def mlp_params_shape_for(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.mlp == "dense":
        return mlp_params_shape(cfg)
    if cfg.mlp == "moe":
        return moe_mod.moe_params_shape(cfg)
    raise ValueError(cfg.mlp)


def layer_params_shape(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": (cfg.d_model,),
        "mixer": mixer_params_shape(cfg),
        "ln2": (cfg.d_model,),
        "mlp": mlp_params_shape_for(cfg),
    }


def padded_vocab(cfg: ModelConfig) -> int:
    """Pad the embedding row count so the vocab dim shards 16-way.

    Megatron-style: granite (49155), minicpm3 (73448), hymba (32001) are
    not divisible by the model-axis size; pad rows are ordinary learned
    rows that no label ever references (loss semantics unchanged up to the
    logsumexp over finite never-target logits).
    """
    v = cfg.vocab
    if v % 16 == 0:
        return v
    return ((v + 255) // 256) * 256


def param_shapes(cfg: ModelConfig):
    """Full-model ShapeDtypeStruct pytree (no allocation — dry-run input)."""
    dt = _dtype(cfg)
    L = cfg.n_layers
    V = padded_vocab(cfg)

    def stacked(tree):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((L, *s), dt), tree,
            is_leaf=lambda s: isinstance(s, tuple),
        )

    out = {
        "embed": jax.ShapeDtypeStruct((V, cfg.d_model), dt),
        "layers": stacked(layer_params_shape(cfg)),
        "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = jax.ShapeDtypeStruct((cfg.d_model, V), dt)
    return out


def init_params(cfg: ModelConfig, key: jax.Array):
    """Real initialization (smoke tests / examples; small configs only)."""
    shapes = param_shapes(cfg)
    leaves, treedef = jax.tree.flatten(shapes)
    keys = jax.random.split(key, len(leaves))
    flat_paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(shapes)[0]
    ]

    def init_one(path, sds, k):
        shape, dt = sds.shape, sds.dtype
        name = path.split("/")[-1]
        if name.startswith("ln") or "norm" in name or name in (
                "ln_x", "attn_scale", "ssm_scale"):
            return jnp.ones(shape, dt)
        if name in ("dt_bias", "D", "u_bonus"):
            return jnp.ones(shape, dt) * 0.5
        if name == "A_log":
            return jnp.zeros(shape, dt)
        if name == "w_decay_base":
            return jnp.full(shape, -2.0, dt)
        if name == "mu":
            return jnp.full(shape, 0.5, dt)
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    inits = [init_one(p, s, k) for p, s, k in zip(flat_paths, leaves, keys)]
    return jax.tree.unflatten(treedef, inits)


# --------------------------------------------------------- partitioning ---

_COL_PARALLEL = {"wq", "wk", "wv", "w1", "w3", "w_in", "w_B", "w_C",
                 "w_uq", "w_ukv", "w_dq", "w_dkv", "w_r", "w_k", "w_v",
                 "w_g", "w_decay_A"}
_ROW_PARALLEL = {"wo", "w2", "w_out", "w_o", "w_decay_B"}


def partition_specs(cfg: ModelConfig, mode: str = "fsdp"):
    """PartitionSpec pytree for params.

    mode "dp":   params replicated over data axes, TP over "model".
    mode "fsdp": additionally shard the non-TP major dim over data axes
                 (ZeRO-3 style; XLA inserts the all-gathers).
    MoE experts: TP over the ff dim (token-local math identical to dense
    TP); EP (experts over "model") is the hillclimb variant.
    """
    fsdp = ("pod", "data") if mode == "fsdp" else None

    def spec_for(path_name, shape, stacked):
        name = path_name
        lead = (None,) if stacked else ()
        nd = len(shape) - (1 if stacked else 0)
        if nd <= 1:
            return P(*lead, None) if nd == 1 else P(*lead)
        if name in ("w1", "w3", "w2") and nd == 3:      # MoE experts
            if name in ("w1", "w3"):
                return P(*lead, None, fsdp, "model")
            return P(*lead, None, "model", fsdp)
        if name in _COL_PARALLEL:
            return P(*lead, fsdp, "model")
        if name in _ROW_PARALLEL:
            return P(*lead, "model", fsdp)
        if name == "router":
            return P(*lead, None, None)
        if name == "conv":
            return P(*lead, None, None)
        if name == "embed":
            return P("model", fsdp)
        if name == "lm_head":
            return P(fsdp, "model")
        return P(*lead, *([None] * nd))

    shapes = param_shapes(cfg)

    def build(tree, stacked):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = build(v, stacked)
            else:
                out[k] = spec_for(k, v.shape, stacked)
        return out

    specs = {
        "embed": spec_for("embed", shapes["embed"].shape, False),
        "layers": build(shapes["layers"], True),
        "final_norm": P(None),
    }
    if "lm_head" in shapes:
        specs["lm_head"] = spec_for("lm_head", shapes["lm_head"].shape, False)
    return specs


def cache_specs(cfg: ModelConfig):
    """PartitionSpec pytree matching init_cache (stacked L leading dim).

    Batch shards over data axes.  The head-feature (last) dim shards over
    "model" rather than the kv-head dim: several assigned archs have fewer
    kv heads (starcoder2: 2, granite: 8) than the 16-way model axis, and
    dh=64..128 divides cleanly everywhere.  Slot writes
    (dynamic_update_slice over the sequence dim) stay shard-local.
    """
    dp = ("pod", "data")
    if cfg.mixer == "gqa":
        out = {
            "k": P(None, dp, None, None, "model"),
            "v": P(None, dp, None, None, "model"),
            "len": P(None),
        }
        if cfg.kv_cache_int8:
            # scales are dh-times smaller; keep them head-replicated so
            # the dequant multiply stays aligned with the dh-sharded values
            out["k_scale"] = P(None, dp, None, None)
            out["v_scale"] = P(None, dp, None, None)
        return out
    if cfg.mixer == "mla":
        # latent cache has no head dim; shard the latent dim over model
        return {"ckv": P(None, dp, None, "model"), "len": P(None)}
    if cfg.mixer == "hybrid":
        return {
            "attn": {
                "k": P(None, dp, None, None, "model"),
                "v": P(None, dp, None, None, "model"),
                "len": P(None),
            },
            "state": P(None, dp, None, None, "model"),
            "conv_tail": P(None, dp, None, "model"),
        }
    if cfg.mixer == "rwkv6":
        return {
            "state": P(None, dp, None, None, "model"),
            "x_tail": P(None, dp, None, "model"),
        }
    raise ValueError(cfg.mixer)


# -------------------------------------------------------------- forward ---

def _mixer_apply(p, x, cfg, positions=None):
    if cfg.mixer == "gqa":
        return gqa_attention(p, x, cfg, positions)[0]
    if cfg.mixer == "mla":
        return mla_mod.mla_attention(p, x, cfg, positions)[0]
    if cfg.mixer == "hybrid":
        return ssm_mod.hybrid_block(p, x, cfg, positions)[0]
    if cfg.mixer == "rwkv6":
        return rwkv_mod.rwkv6_mix(p, x, cfg)[0]
    raise ValueError(cfg.mixer)


def _mlp_apply(p, x, cfg):
    if cfg.mlp == "dense":
        return gated_mlp(p, x, cfg)
    return moe_mod.moe_block(p, x, cfg)


def _block(layer_p, h, cfg):
    ba = _batch_axes()
    h = _constrain(h, ba, None, None)
    h = h + _mixer_apply(layer_p["mixer"], rms_norm(h, layer_p["ln1"]), cfg)
    h = h + _mlp_apply(layer_p["mlp"], rms_norm(h, layer_p["ln2"]), cfg)
    return _constrain(h, ba, None, None)


def forward_hidden(params, cfg: ModelConfig, tokens=None, embeddings=None,
                   remat: bool = True,
                   remat_policy: Optional[str] = None):
    """Backbone only: tokens/embeddings -> final-norm hidden (B, S, d)."""
    if embeddings is not None:
        h = embeddings.astype(_dtype(cfg))
    else:
        h = jnp.take(params["embed"], tokens, axis=0)
    h = constrain_tokens(h)

    def body(h, layer_p):
        return _block(layer_p, h, cfg), None

    if remat:
        policy = None
        if remat_policy == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots
        elif remat_policy == "dots_no_batch":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        body = jax.checkpoint(body, policy=policy)
    h, _ = jax.lax.scan(body, h, params["layers"])
    return rms_norm(h, params["final_norm"])


def apply_head(params, h):
    """hidden (..., d) -> logits (..., V)."""
    head = params.get("lm_head")
    if head is None:
        logits = jnp.einsum("...d,vd->...v", h, params["embed"])
    else:
        logits = h @ head
    spec = [_batch_axes()] + [None] * (logits.ndim - 2) + ["model"]
    return _constrain(logits, *spec)


def forward(params, cfg: ModelConfig, tokens=None, embeddings=None,
            remat: bool = True, remat_policy: Optional[str] = None,
            last_only: bool = False):
    """tokens (B, S) int32 OR embeddings (B, S, d) -> logits (B, S, V).

    ``last_only`` computes the head projection only for the final position
    (serving prefill semantics) — on a 152k-vocab model that removes
    S-1/S of the head FLOPs and ALL the logits-sized collective traffic
    (hillclimb 2, EXPERIMENTS.md §Perf).
    """
    h = forward_hidden(params, cfg, tokens=tokens, embeddings=embeddings,
                       remat=remat, remat_policy=remat_policy)
    if last_only:
        h = h[:, -1:, :]
    return apply_head(params, h)


# --------------------------------------------------------------- decode ---

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               as_shapes: bool = False):
    """Stacked (L-leading) per-layer decode cache pytree."""
    dt = _dtype(cfg)
    L = cfg.n_layers

    def make(shape, dtype=dt):
        sds = jax.ShapeDtypeStruct((L, *shape), dtype)
        return sds if as_shapes else jnp.zeros(sds.shape, sds.dtype)

    def scalar_len():
        sds = jax.ShapeDtypeStruct((L,), jnp.int32)
        return sds if as_shapes else jnp.zeros(sds.shape, sds.dtype)

    if cfg.mixer == "gqa":
        C = min(max_len, cfg.window) if cfg.window > 0 else max_len
        if cfg.kv_cache_int8:
            return {
                "k": make((batch, C, cfg.n_kv_heads, cfg.d_head), jnp.int8),
                "v": make((batch, C, cfg.n_kv_heads, cfg.d_head), jnp.int8),
                "k_scale": make((batch, C, cfg.n_kv_heads), jnp.float32),
                "v_scale": make((batch, C, cfg.n_kv_heads), jnp.float32),
                "len": scalar_len(),
            }
        return {
            "k": make((batch, C, cfg.n_kv_heads, cfg.d_head)),
            "v": make((batch, C, cfg.n_kv_heads, cfg.d_head)),
            "len": scalar_len(),
        }
    if cfg.mixer == "mla":
        return {
            "ckv": make((batch, max_len, cfg.kv_lora_rank + cfg.rope_head_dim)),
            "len": scalar_len(),
        }
    if cfg.mixer == "hybrid":
        C = min(max_len, cfg.window) if cfg.window > 0 else max_len
        di = cfg.ssm_heads * cfg.d_head
        return {
            "attn": {
                "k": make((batch, C, cfg.n_kv_heads, cfg.d_head)),
                "v": make((batch, C, cfg.n_kv_heads, cfg.d_head)),
                "len": scalar_len(),
            },
            "state": make((batch, cfg.ssm_heads, cfg.ssm_state, cfg.d_head),
                          jnp.float32),
            "conv_tail": make((batch, ssm_mod.CONV_K - 1, di)),
        }
    if cfg.mixer == "rwkv6":
        return {
            "state": make((batch, cfg.ssm_heads, cfg.d_head, cfg.d_head),
                          jnp.float32),
            "x_tail": make((batch, 1, cfg.d_model)),
        }
    raise ValueError(cfg.mixer)


def _mixer_decode(p, x, cfg, cache):
    if cfg.mixer == "gqa":
        return gqa_decode(p, x, cfg, cache)
    if cfg.mixer == "mla":
        return mla_mod.mla_decode(p, x, cfg, cache)
    if cfg.mixer == "hybrid":
        return ssm_mod.hybrid_decode(p, x, cfg, cache)
    if cfg.mixer == "rwkv6":
        return rwkv_mod.rwkv6_decode(p, x, cfg, cache)
    raise ValueError(cfg.mixer)


def decode_step(params, cfg: ModelConfig, tokens, cache):
    """tokens (B, 1) + stacked cache -> (logits (B, V), new cache)."""
    h = jnp.take(params["embed"], tokens, axis=0)
    h = constrain_tokens(h)

    def body(h, xs):
        layer_p, layer_cache = xs
        hn = rms_norm(h, layer_p["ln1"])
        mix_out, new_cache = _mixer_decode(layer_p["mixer"], hn, cfg,
                                           layer_cache)
        h = h + mix_out
        h = h + _mlp_apply(layer_p["mlp"], rms_norm(h, layer_p["ln2"]), cfg)
        return h, new_cache

    h, new_caches = jax.lax.scan(body, h, (params["layers"], cache))
    h = rms_norm(h, params["final_norm"])
    head = params.get("lm_head")
    if head is None:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = h @ head
    return logits[:, 0, :], new_caches
