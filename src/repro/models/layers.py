"""Core layers: norms, RoPE, GQA attention (chunked prefill + decode).

Everything is pure jnp over explicit parameter pytrees (no flax): params
are dicts of arrays, layer fns are (params, x, ...) -> y, so the whole
model scans over stacked per-layer params and lowers to a single compact
HLO loop regardless of depth.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# Ambient-mesh-aware sharding annotations live in the runtime layer so
# they work on every supported JAX version (0.4.x lacks the explicit-
# sharding APIs these used to call directly).
from ..runtime.compat import batch_axes, constrain


def rms_norm(x, gain, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    scale = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * scale).astype(dt) * gain


def act_fn(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


# ---------------------------------------------------------------- RoPE ---

def rope_angles(positions, dim: int, theta: float):
    """positions (...,) -> cos/sin (..., dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, D); cos/sin (S, D/2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


# ----------------------------------------------------- chunked attention ---

def chunked_causal_attention(q, k, v, chunk: int = 512,
                             window: int = 0, scale: Optional[float] = None):
    """Flash-style online-softmax attention, O(S * chunk) memory.

    q (B, S, H, D); k/v (B, S, Hkv, D) — GQA handled by head repetition at
    the logical level (XLA CSEs the broadcast).  ``window`` > 0 restricts
    attention to a trailing window (sliding-window attention); blocks
    entirely outside every query's window are masked (their contribution
    vanishes through the online-softmax weights).
    """
    B, S, H, D = q.shape
    Dv = v.shape[-1]
    Hkv = k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    # Pin the HEAD dim to the model axis (hillclimb 2): without this,
    # head counts that don't divide the 16-way axis (qwen3: 40 q / 8 kv)
    # push GSPMD into contraction-dim sharding, which all-reduces the f32
    # score tensor on EVERY kv chunk (~1.7 TB/device for prefill_32k).
    # GSPMD pads the head dim instead (<=20% extra head compute).
    ba = batch_axes()
    q = constrain(q, ba, None, "model", None)
    k = constrain(k, ba, None, "model", None)
    v = constrain(v, ba, None, "model", None)
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    nkv = max(1, S // chunk)
    ck = S // nkv
    kc = k.reshape(B, nkv, ck, H, D)
    vc = v.reshape(B, nkv, ck, H, Dv)
    q_pos = jnp.arange(S)
    qf = (q * scale).astype(jnp.float32)

    def step(carry, blk):
        m_run, l_run, acc = carry
        kb, vb, j = blk
        kv_pos = j * ck + jnp.arange(ck)
        scores = jnp.einsum("bshd,bchd->bhsc", qf, kb.astype(jnp.float32))
        mask = q_pos[None, None, :, None] >= kv_pos[None, None, None, :]
        if window > 0:
            mask &= (q_pos[None, None, :, None] - kv_pos[None, None, None, :]
                     < window)
        scores = jnp.where(mask, scores, -1e30)
        m_new = jnp.maximum(m_run, scores.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l_run * alpha + p.sum(axis=-1)
        # NOTE (hillclimb 2, refuted): casting p@v to bf16 was tried and
        # REGRESSED both accuracy and HLO traffic (extra converts) — keep
        # the f32 chain; see EXPERIMENTS.md §Perf.
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhsc,bchd->bhsd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, H, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, H, S, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.arange(nkv)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.einsum("bhsd->bshd", out).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, window: int = 0):
    """Single-token attention against a (possibly rolling) KV cache.

    q (B, 1, H, D); caches (B, C, Hkv, D); cache_len scalar = #valid slots.
    """
    B, _, H, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = H // Hkv
    if rep > 1:
        k_cache = jnp.repeat(k_cache, rep, axis=2)
        v_cache = jnp.repeat(v_cache, rep, axis=2)
    scores = jnp.einsum(
        "bshd,bchd->bhsc", (q / np.sqrt(D)).astype(jnp.float32),
        k_cache.astype(jnp.float32))
    pos = jnp.arange(C)
    valid = pos[None, None, None, :] < cache_len
    if window > 0:
        valid &= pos[None, None, None, :] >= jnp.maximum(cache_len - window, 0)
    scores = jnp.where(valid, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhsc,bchd->bshd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


# ------------------------------------------------------------ GQA block ---

def gqa_params_shape(cfg):
    d, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    shapes = {
        "wq": (d, H * dh),
        "wk": (d, Hkv * dh),
        "wv": (d, Hkv * dh),
        "wo": (H * dh, d),
    }
    if cfg.qk_norm:
        shapes["q_norm"] = (dh,)
        shapes["k_norm"] = (dh,)
    return shapes


def gqa_attention(p, x, cfg, positions=None):
    """Full-sequence (training / prefill) GQA attention."""
    B, S, d = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (x @ p["wk"]).reshape(B, S, Hkv, dh)
    v = (x @ p["wv"]).reshape(B, S, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.rope:
        pos = positions if positions is not None else jnp.arange(S)
        cos, sin = rope_angles(pos, dh, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    o = chunked_causal_attention(q, k, v, chunk=cfg.attn_chunk,
                                 window=cfg.window)
    return o.reshape(B, S, H * dh) @ p["wo"], (k, v)


def _quantize_kv(t):
    """(B,1,Hkv,dh) -> (int8 values, per (B,1,Hkv) scale)."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def gqa_decode(p, x, cfg, cache):
    """One-token decode; cache = {"k": (B,C,Hkv,dh), "v": ..., "len": ()}.

    With ``cfg.kv_cache_int8`` the cache holds int8 values + per-token
    per-head scales (symmetric); dequantization happens at read.
    """
    B, S, d = x.shape
    assert S == 1
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ p["wq"]).reshape(B, 1, H, dh)
    k = (x @ p["wk"]).reshape(B, 1, Hkv, dh)
    v = (x @ p["wv"]).reshape(B, 1, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    pos = cache["len"]
    if cfg.rope:
        cos, sin = rope_angles(pos[None], dh, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    C = cache["k"].shape[1]
    # Rolling buffer when the cache is window-sized: every live slot is
    # inside the window by construction (RoPE phases are absolute, so dot
    # products stay relative-position-correct across wraparound).
    rolling = cfg.window > 0 and C <= cfg.window
    slot = pos % C if rolling else jnp.minimum(pos, C - 1)
    if cfg.kv_cache_int8:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        k_cache = jax.lax.dynamic_update_slice(cache["k"], kq,
                                               (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], vq,
                                               (0, slot, 0, 0))
        k_sc = jax.lax.dynamic_update_slice(
            cache["k_scale"], ks.astype(cache["k_scale"].dtype),
            (0, slot, 0))
        v_sc = jax.lax.dynamic_update_slice(
            cache["v_scale"], vs.astype(cache["v_scale"].dtype),
            (0, slot, 0))
        dt = jnp.dtype(cfg.dtype)
        k_full = (k_cache.astype(dt) * k_sc[..., None].astype(dt))
        v_full = (v_cache.astype(dt) * v_sc[..., None].astype(dt))
        o = decode_attention(q, k_full, v_full, pos + 1,
                             window=0 if rolling else cfg.window)
        new_cache = {"k": k_cache, "v": v_cache, "k_scale": k_sc,
                     "v_scale": v_sc, "len": pos + 1}
    else:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        o = decode_attention(q, k_cache, v_cache, pos + 1,
                             window=0 if rolling else cfg.window)
        new_cache = {"k": k_cache, "v": v_cache, "len": pos + 1}
    return o.reshape(B, 1, H * dh) @ p["wo"], new_cache


# ------------------------------------------------------------- gated MLP ---

def mlp_params_shape(cfg):
    d, ff = cfg.d_model, cfg.d_ff
    return {"w1": (d, ff), "w3": (d, ff), "w2": (ff, d)}


def gated_mlp(p, x, cfg):
    h = act_fn(x @ p["w1"], cfg.act) * (x @ p["w3"])
    return h @ p["w2"]
