"""Shape-bucketed batch solving of heterogeneous LP streams.

The paper frames RRAM crossbars as *shared* linear-optimization
accelerators: many independent LP instances arrive with arbitrary shapes
and must be served together.  Same-shape stacking (the old
``distributed/batch_solve.py`` contract) breaks down there — every new
``(m, n)`` would recompile.  This scheduler:

  1. rounds every instance up to a power-of-two ``(m_pad, n_pad)``
     bucket (padding is exact: extra primal coordinates are pinned at
     lb=ub=0, extra rows are all-zero with b=0, so the optimum is
     unchanged),
  2. stacks each bucket and dispatches it through a vmapped jitted PDHG
     pipeline (Ruiz + diagonal preconditioning + Lanczos + while_loop) —
     the zero-collective data-parallel path: with a mesh, instances shard
     across devices and each device solves its slice locally,
  3. caches the compiled executable per (bucket, batch, dtype, options)
     signature so repeat traffic never re-lowers, and
  4. strips padding and returns per-instance results in input order.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core import pdhg as pdhg_mod
from ..core.pdhg import PDHGOptions
from ..lp.problem import StandardLP

MIN_BUCKET = 8


# ------------------------------------------------------------- bucketing ---

def bucket_dims(m: int, n: int, min_size: int = MIN_BUCKET) -> Tuple[int, int]:
    """Round ``(m, n)`` up to the enclosing power-of-two bucket."""
    up = lambda v: max(min_size, 1 << (int(v) - 1).bit_length())  # noqa: E731
    return up(m), up(n)


def pad_problem(lp: StandardLP, m_pad: int, n_pad: int) -> StandardLP:
    """Embed ``lp`` in an (m_pad, n_pad) problem with identical optimum.

    Extra variables are pinned (lb=ub=0, c=0); extra rows are zero with
    b=0.  Any solution of the padded problem restricts to one of the
    original and vice versa.
    """
    m, n = lp.K.shape
    assert m_pad >= m and n_pad >= n, ((m, n), (m_pad, n_pad))
    K = np.zeros((m_pad, n_pad))
    K[:m, :n] = lp.K
    b = np.zeros(m_pad)
    b[:m] = lp.b
    c = np.zeros(n_pad)
    c[:n] = lp.c
    lb = np.zeros(n_pad)
    ub = np.zeros(n_pad)
    lb[:n] = lp.lb
    ub[:n] = lp.ub
    x_opt = None
    if lp.x_opt is not None:
        x_opt = np.zeros(n_pad)
        x_opt[:n] = lp.x_opt
    return StandardLP(c=c, K=K, b=b, lb=lb, ub=ub, name=lp.name,
                      x_opt=x_opt, obj_opt=lp.obj_opt)


def stack_problems(lps: Sequence[StandardLP], m: Optional[int] = None,
                   n: Optional[int] = None) -> tuple:
    """Pad a list of StandardLPs to a common shape and stack.

    Target dims default to the max over the list (the legacy
    ``distributed.batch_solve`` behaviour); buckets pass them explicitly.
    """
    m = m if m is not None else max(lp.K.shape[0] for lp in lps)
    n = n if n is not None else max(lp.K.shape[1] for lp in lps)
    padded = [pad_problem(lp, m, n) for lp in lps]
    return tuple(
        np.stack([getattr(p, f) for p in padded])
        for f in ("K", "b", "c", "lb", "ub"))


# -------------------------------------------------------------- pipeline ---

def opts_static(opts: PDHGOptions, sigma_read: float = 0.0) -> tuple:
    """The hashable option tuple ``core.pdhg._solve_jit_core`` consumes."""
    return (opts.max_iters, opts.tol, opts.eta, opts.omega, opts.gamma,
            opts.check_every, opts.restart_beta if opts.restart else 0.0,
            float(sigma_read))


def _single_solve(K, b, c, lb, ub, T, Sigma, rho, static):
    return pdhg_mod._solve_jit_core(
        K, K.T, b, c, lb, ub, T, Sigma, rho, jax.random.PRNGKey(1), static)


def _prep_one(K, b, c, lb, ub, opts: PDHGOptions):
    from ..core.lanczos import lanczos_svd_jit
    from ..core.precondition import apply_ruiz, diagonal_precondition
    from ..core.symblock import build_sym_block

    scaled = apply_ruiz(K, b, c, lb, ub, iters=opts.ruiz_iters)
    T, Sigma = diagonal_precondition(scaled.K)
    Keff = jnp.sqrt(Sigma)[:, None] * scaled.K * jnp.sqrt(T)[None, :]
    rho = lanczos_svd_jit(build_sym_block(Keff), k_max=opts.lanczos_iters)
    return (scaled.K, scaled.b, scaled.c, scaled.lb, scaled.ub, T, Sigma,
            rho, scaled.D1, scaled.D2)


def make_bucket_pipeline(opts: PDHGOptions):
    """vmapped prep + solve over a stacked (B, m, n) bucket.

    Returns (xs, ys, iterations, merits) in the ORIGINAL (unscaled)
    coordinates.  Pure function of the stacked arrays — safe to jit/AOT.
    """
    static = opts_static(opts)

    def pipeline(Ks, bs, cs, lbs, ubs):
        prepped = jax.vmap(functools.partial(_prep_one, opts=opts))(
            Ks, bs, cs, lbs, ubs)
        (Ks2, bs2, cs2, lbs2, ubs2, Ts, Sigs, rhos, D1s, D2s) = prepped
        solver = functools.partial(_single_solve, static=static)
        xs, ys, its, merits = jax.vmap(solver)(
            Ks2, bs2, cs2, lbs2, ubs2, Ts, Sigs, rhos)
        return D2s * xs, D1s * ys, its, merits

    return pipeline


# ------------------------------------------------------------- scheduler ---

@dataclasses.dataclass
class BatchItemResult:
    """Per-instance result with padding stripped."""

    name: str
    x: np.ndarray
    y: np.ndarray
    obj: float
    iterations: int
    merit: float
    converged: bool
    bucket: Tuple[int, int]

    @property
    def status(self) -> str:
        return "optimal" if self.converged else "iteration_limit"


def _ceil_to(v: int, mult: int) -> int:
    return -(-v // mult) * mult


class BatchSolver:
    """Shape-bucketing scheduler with a compiled-executable cache.

    One instance amortizes compilation across calls: the first stream
    touching a ``(bucket, batch, dtype)`` signature lowers + compiles the
    bucket pipeline (a cache MISS); every later stream with the same
    signature reuses the executable (a HIT).  ``mesh`` shards the batch
    dimension over ``batch_axes`` — zero collectives during the solve.
    """

    def __init__(self, opts: PDHGOptions = PDHGOptions(), *,
                 mesh=None, batch_axes: Tuple[str, ...] = ("data",),
                 min_bucket: int = MIN_BUCKET):
        self.opts = opts
        self.mesh = mesh
        self.batch_axes = tuple(batch_axes)
        self.min_bucket = min_bucket
        self._cache = {}
        self.cache_hits = 0
        self.cache_misses = 0

    # -- executable cache ---------------------------------------------

    def _batch_quantum(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    def _padded_batch(self, n_items: int) -> int:
        pow2 = 1 << (n_items - 1).bit_length()
        return _ceil_to(pow2, self._batch_quantum())

    def _sharding(self):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(self.batch_axes))

    def _executable(self, mb: int, nb: int, B: int, dtype):
        key = (mb, nb, B, jnp.dtype(dtype).name, opts_static(self.opts),
               None if self.mesh is None else
               (tuple(self.mesh.axis_names),
                tuple(self.mesh.devices.shape), self.batch_axes))
        hit = self._cache.get(key)
        if hit is not None:
            self.cache_hits += 1
            return hit
        self.cache_misses += 1
        sh = self._sharding()
        sds = lambda *s: jax.ShapeDtypeStruct(  # noqa: E731
            (B, *s), dtype, sharding=sh)
        args = (sds(mb, nb), sds(mb), sds(nb), sds(nb), sds(nb))
        compiled = jax.jit(make_bucket_pipeline(self.opts)).lower(
            *args).compile()
        self._cache[key] = compiled
        return compiled

    def cache_info(self) -> dict:
        return {"hits": self.cache_hits, "misses": self.cache_misses,
                "entries": len(self._cache)}

    # -- solving ------------------------------------------------------

    def solve_stream(self, lps: Sequence[StandardLP]) -> List[BatchItemResult]:
        """Solve a heterogeneous stream; results come back in input order."""
        lps = list(lps)
        dtype = jnp.dtype(self.opts.dtype)
        buckets = {}
        for i, lp in enumerate(lps):
            mb, nb = bucket_dims(*lp.K.shape, min_size=self.min_bucket)
            buckets.setdefault((mb, nb), []).append(i)

        results: List[Optional[BatchItemResult]] = [None] * len(lps)
        for (mb, nb), idxs in buckets.items():
            group = [lps[i] for i in idxs]
            B = self._padded_batch(len(group))
            # batch padding repeats the first instance; extras are dropped
            filler = [group[0]] * (B - len(group))
            stacked = stack_problems(group + filler, m=mb, n=nb)
            arrays = [jnp.asarray(a, dtype) for a in stacked]
            sh = self._sharding()
            if sh is not None:
                arrays = [jax.device_put(a, sh) for a in arrays]
            xs, ys, its, merits = self._executable(mb, nb, B, dtype)(*arrays)
            xs, ys = np.asarray(xs), np.asarray(ys)
            its, merits = np.asarray(its), np.asarray(merits)
            for k, i in enumerate(idxs):
                lp = lps[i]
                m, n = lp.K.shape
                x = xs[k, :n]
                results[i] = BatchItemResult(
                    name=lp.name, x=x, y=ys[k, :m],
                    obj=float(lp.c @ x), iterations=int(its[k]),
                    merit=float(merits[k]),
                    converged=bool(merits[k] <= self.opts.tol),
                    bucket=(mb, nb),
                )
        return results  # type: ignore[return-value]


def solve_stream(lps: Sequence[StandardLP],
                 opts: PDHGOptions = PDHGOptions(), *,
                 mesh=None, solver: Optional[BatchSolver] = None,
                 ) -> List[BatchItemResult]:
    """One-shot entry point; pass ``solver`` to keep the executable cache
    warm across calls."""
    if solver is None:
        solver = BatchSolver(opts, mesh=mesh)
    return solver.solve_stream(lps)
