"""Shape-bucketed batch solving of heterogeneous LP streams.

The paper frames RRAM crossbars as *shared* linear-optimization
accelerators: many independent LP instances arrive with arbitrary shapes
and must be served together.  Same-shape stacking (the old
``distributed/batch_solve.py`` contract) breaks down there — every new
``(m, n)`` would recompile.  This scheduler:

  1. rounds every instance up to a ``(m_pad, n_pad)`` bucket (padding is
     exact: extra primal coordinates are pinned at lb=ub=0, extra rows
     are all-zero with b=0, so the optimum is unchanged).  Buckets are
     powers of two by default, or — in device-tile mode — multiples of
     the physical crossbar tile dimensions (e.g. 64x64 EpiRAM tiles), so
     padded instances map exactly onto whole tiles and the energy ledger
     sees the true programmed array,
  2. stacks each bucket and dispatches it through a vmapped jitted PDHG
     pipeline (Ruiz + diagonal preconditioning + Lanczos + while_loop) —
     the zero-collective data-parallel path: with a mesh, instances shard
     across devices and each device solves its slice locally,
  3. caches the compiled executable per (bucket, batch, dtype, options,
     noise, device) signature so repeat traffic never re-lowers, and
  4. strips padding and returns per-instance results in input order.

Every instance gets its own PRNG key (derived from ``opts.seed`` and its
position in the stream), so iterate initialization and read-noise streams
are decorrelated across a bucket.

Past toy sizes, two more concerns take over (ROADMAP item 2):

  * **Sparse streams.**  A ``StandardLP`` whose K is a ``SparseCOO``
    routes through a dedicated sparse bucket pipeline selected by
    ``PDHGOptions.sparse_kernel``.  The default ``"ell"`` backend
    converts COO to row-blocked ELL — forward (B, m, Wf) AND adjoint
    (B, n, Wa) layouts, widths power-of-two bucketed like ``nnz_bucket``
    — so Ruiz equilibration, Pock–Chambolle diagonals, Lanczos and both
    solve MVMs are gathers + axis-1 reductions with no scatter anywhere
    (the wall-clock path; ``kernels.sparse_mvm``).  ``"bcoo"`` keeps the
    nnz-proportional COO stacking ((B, nnz) data + (B, nnz, 2) indices,
    ``engine.sparse_operator`` scatter contractions) — the
    memory-optimal path.  Neither ever materializes a dense
    (B, m_pad, n_pad) stack.
  * **Async serving.**  ``solve_stream`` submits EVERY bucket to its
    compiled executable first (JAX dispatch is asynchronous; the host
    never blocks between buckets) and only then collects results,
    preferring buckets whose device buffers are already ready.  Large
    buckets donate their stacked operator buffer to the executable
    (``jax.jit(..., donate_argnums=...)``) on backends that support
    donation, so peak device memory stays ~one bucket-stack.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import sparse as jsparse
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core import engine
from ..core.lanczos import (
    NORM_BACKENDS,
    lanczos_svd_jit_mv,
    power_iteration_mv,
)
from ..core.pdhg import PDHGOptions
from ..core.pdhg import opts_static  # noqa: F401  (canonical home; re-export)
from ..kernels.sparse_mvm import (
    coo_row_widths,
    ell_from_coo,
    ell_matvec,
    ell_width_bucket,
)
from ..lp.problem import SparseCOO, StandardLP
from . import sanitize

MIN_BUCKET = 8
MIN_NNZ_BUCKET = 16
# donate the stacked operator buffer to the executable past this size
# (on backends that implement donation; CPU silently ignores it)
DONATE_MIN_BYTES = 32 << 20
# norm-reuse serving (``BatchSolver(norm_reuse=True)``): instances whose
# (shape bucket, sparsity fingerprint) already has a cached operator-norm
# estimate run this many power-iteration refinement MVMs instead of the
# full ``opts.lanczos_iters``-step estimate
NORM_REFINE_ITERS = 8


# ------------------------------------------------------------- bucketing ---

def _ceil_to(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def bucket_dims(m: int, n: int, min_size: int = MIN_BUCKET,
                tile: Optional[Tuple[int, int]] = None) -> Tuple[int, int]:
    """Round ``(m, n)`` up to its bucket.

    Default mode rounds to the enclosing power of two.  With
    ``tile=(rows, cols)`` (device-tile mode) dims snap to multiples of the
    physical crossbar tile instead, so a bucket always fills whole tiles:
    ``bucket_dims(8, 70, tile=(64, 64)) == (64, 128)``.
    """
    if tile is not None:
        tr, tc = tile
        return _ceil_to(max(int(m), 1), tr), _ceil_to(max(int(n), 1), tc)
    up = lambda v: max(min_size, 1 << (int(v) - 1).bit_length())  # noqa: E731
    return up(m), up(n)


def nnz_bucket(nnz: int, min_size: int = MIN_NNZ_BUCKET) -> int:
    """Round a nonzero count up to its power-of-two bucket (so repeat
    sparse traffic with drifting nnz reuses compiled executables)."""
    return max(min_size, 1 << (max(int(nnz), 1) - 1).bit_length())


def pad_problem(lp: StandardLP, m_pad: int, n_pad: int) -> StandardLP:
    """Embed ``lp`` in an (m_pad, n_pad) problem with identical optimum.

    Extra variables are pinned (lb=ub=0, c=0); extra rows are zero with
    b=0.  Any solution of the padded problem restricts to one of the
    original and vice versa.  Padding is dtype-preserving (an f32 stream
    pads in f32 — the old ``np.zeros`` default doubled host memory) and
    sparse-preserving (a SparseCOO K just grows its logical shape; the
    nonzeros are never densified).
    """
    m, n = lp.K.shape
    assert m_pad >= m and n_pad >= n, ((m, n), (m_pad, n_pad))
    dt = lp.K.dtype
    if isinstance(lp.K, SparseCOO):
        K = lp.K.with_shape(m_pad, n_pad)
    else:
        K = np.zeros((m_pad, n_pad), dt)
        K[:m, :n] = lp.K
    b = np.zeros(m_pad, dt)
    b[:m] = lp.b
    c = np.zeros(n_pad, dt)
    c[:n] = lp.c
    lb = np.zeros(n_pad, dt)
    ub = np.zeros(n_pad, dt)
    lb[:n] = lp.lb
    ub[:n] = lp.ub
    x_opt = None
    if lp.x_opt is not None:
        x_opt = np.zeros(n_pad, np.asarray(lp.x_opt).dtype)
        x_opt[:n] = lp.x_opt
    return StandardLP(c=c, K=K, b=b, lb=lb, ub=ub, name=lp.name,
                      x_opt=x_opt, obj_opt=lp.obj_opt)


def stack_problems(lps: Sequence[StandardLP], m: Optional[int] = None,
                   n: Optional[int] = None) -> tuple:
    """Pad a list of StandardLPs to a common shape and DENSE-stack.

    Target dims default to the max over the list (the legacy
    ``distributed.batch_solve`` behaviour); buckets pass them explicitly.
    Sparse members are densified — sparse streams should go through
    ``stack_problems_sparse`` instead, which never materializes
    (B, m, n).
    """
    lps = [lp.densified() for lp in lps]
    m = m if m is not None else max(lp.K.shape[0] for lp in lps)
    n = n if n is not None else max(lp.K.shape[1] for lp in lps)
    padded = [pad_problem(lp, m, n) for lp in lps]
    return tuple(
        np.stack([getattr(p, f) for p in padded])
        for f in ("K", "b", "c", "lb", "ub"))


def stack_problems_sparse(lps: Sequence[StandardLP],
                          m: Optional[int] = None,
                          n: Optional[int] = None,
                          nnz: Optional[int] = None) -> tuple:
    """Stack sparse StandardLPs WITHOUT densifying K.

    Returns ``(data (B, nnz), idx (B, nnz, 2) int32, b, c, lb, ub)``.
    Shape padding is purely logical (zero rows / pinned variables, as in
    ``pad_problem``); nnz padding appends explicit zero entries at
    (0, 0), which contribute nothing to any contraction or scaling.
    """
    assert lps and all(isinstance(lp.K, SparseCOO) for lp in lps), \
        "stack_problems_sparse needs SparseCOO operators"
    m = m if m is not None else max(lp.K.shape[0] for lp in lps)
    n = n if n is not None else max(lp.K.shape[1] for lp in lps)
    nnz = nnz if nnz is not None else max(lp.K.nnz for lp in lps)
    B = len(lps)
    dt = lps[0].K.dtype
    data = np.zeros((B, nnz), dt)
    idx = np.zeros((B, nnz, 2), np.int32)
    vecs = {f: np.zeros((B, dim), dt)
            for f, dim in (("b", m), ("c", n), ("lb", n), ("ub", n))}
    for k, lp in enumerate(lps):
        # coalesce duplicates: the pipeline's scatter preconditioners
        # reduce over stored entries, so parity with the densified
        # problem requires one entry per (row, col)
        K = lp.K.coalesce()
        assert K.shape[0] <= m and K.shape[1] <= n and K.nnz <= nnz, \
            (K.shape, K.nnz, (m, n, nnz))
        data[k, :K.nnz] = K.data
        idx[k, :K.nnz, 0] = K.row
        idx[k, :K.nnz, 1] = K.col
        for f, arr in vecs.items():
            v = getattr(lp, f)
            arr[k, :v.shape[0]] = v
    return (data, idx, vecs["b"], vecs["c"], vecs["lb"], vecs["ub"])


def stack_problems_ell(lps: Sequence[StandardLP],
                       m: Optional[int] = None,
                       n: Optional[int] = None,
                       wf: Optional[int] = None,
                       wa: Optional[int] = None) -> tuple:
    """Stack sparse StandardLPs in row-blocked ELL form.

    Returns ``(data_f (B, m, wf), cols_f (B, m, wf) int32,
    data_a (B, n, wa), cols_a (B, n, wa) int32, b, c, lb, ub)``.
    The forward layout is the ELL form of K, the adjoint layout the ELL
    form of K^T — storing both keeps every pipeline reduction and both
    solve MVMs scatter-free.  ``wf``/``wa`` default to the exact max
    row/column occupancy over the list (buckets pass their power-of-two
    widths explicitly).  ELL padding slots carry (data 0, col 0), the
    same inertness contract as ``stack_problems_sparse``'s (0, 0)
    entries; explicit zero nonzeros are dropped during conversion, so
    they never widen a row.
    """
    assert lps and all(isinstance(lp.K, SparseCOO) for lp in lps), \
        "stack_problems_ell needs SparseCOO operators"
    m = m if m is not None else max(lp.K.shape[0] for lp in lps)
    n = n if n is not None else max(lp.K.shape[1] for lp in lps)
    if wf is None or wa is None:
        widths = [coo_row_widths(lp.K.row, lp.K.col, lp.K.data,
                                 lp.K.shape) for lp in lps]
        wf = wf if wf is not None else max(w[0] for w in widths)
        wa = wa if wa is not None else max(w[1] for w in widths)
    B = len(lps)
    dt = lps[0].K.dtype
    data_f = np.zeros((B, m, wf), dt)
    cols_f = np.zeros((B, m, wf), np.int32)
    data_a = np.zeros((B, n, wa), dt)
    cols_a = np.zeros((B, n, wa), np.int32)
    vecs = {f: np.zeros((B, dim), dt)
            for f, dim in (("b", m), ("c", n), ("lb", n), ("ub", n))}
    for k, lp in enumerate(lps):
        # coalesce first: ELL stores one slot per (row, col), so
        # duplicates must merge for parity with the densified problem
        K = lp.K.coalesce()
        assert K.shape[0] <= m and K.shape[1] <= n, (K.shape, (m, n))
        data_f[k], cols_f[k] = ell_from_coo(K.data, K.row, K.col,
                                            (m, n), width=wf)
        data_a[k], cols_a[k] = ell_from_coo(K.data, K.col, K.row,
                                            (n, m), width=wa)
        for f, arr in vecs.items():
            v = getattr(lp, f)
            arr[k, :v.shape[0]] = v
    return (data_f, cols_f, data_a, cols_a,
            vecs["b"], vecs["c"], vecs["lb"], vecs["ub"])


# -------------------------------------------------------------- pipeline ---

def _single_solve(K, b, c, lb, ub, T, Sigma, rho, key, static):
    # The iteration core is core.engine's; ``static[-1]`` (opts.kernel)
    # selects the jnp vs fused-Pallas update backend per executable.
    return engine.solve_core(
        K, K.T, b, c, lb, ub, T, Sigma, rho, key, static)


def prep_scale(K, b, c, lb, ub, opts: PDHGOptions):
    """Ruiz + diagonal preconditioning (Algorithm 4 step 0), vmappable.

    Returns the scaled problem, the diagonal step scalings (T, Sigma) and
    the unscaling diagonals (D1, D2).  Operator-norm estimation is NOT
    included — callers estimate rho on whichever operator they actually
    execute (exact K here, the programmed crossbar blocks in
    ``crossbar.solver``).
    """
    from ..core.precondition import apply_ruiz, diagonal_precondition

    scaled = apply_ruiz(K, b, c, lb, ub, iters=opts.ruiz_iters)
    T, Sigma = diagonal_precondition(scaled.K)
    return (scaled.K, scaled.b, scaled.c, scaled.lb, scaled.ub, T, Sigma,
            scaled.D1, scaled.D2)


def _check_norm_backend(opts: PDHGOptions) -> None:
    if opts.norm_backend not in NORM_BACKENDS:
        raise ValueError(f"unknown norm_backend {opts.norm_backend!r}; "
                         f"expected one of {NORM_BACKENDS}")


def _estimate_norm_mv(mv, dim: int, dtype, opts: PDHGOptions,
                      rho_seed=None):
    """RAW operator-norm estimate (no Lemma-2 margin) on a symmetric
    matvec, per ``opts.norm_backend``.  With a ``rho_seed`` (the
    norm-reuse serving path: a cached estimate for this sparsity
    fingerprint) only a short power refinement runs and the result is
    floored at the seed — same-pattern instances share spectra, so the
    cached maximum is already the safe bet and the refinement just
    catches genuinely hotter coefficient draws."""
    if rho_seed is not None:
        est = power_iteration_mv(mv, dim, dtype, iters=NORM_REFINE_ITERS)
        return jnp.maximum(est, jnp.asarray(rho_seed, est.dtype))
    if opts.norm_backend == "power":
        return power_iteration_mv(mv, dim, dtype,
                                  iters=opts.lanczos_iters)
    return lanczos_svd_jit_mv(mv, dim, dtype, k_max=opts.lanczos_iters)


def _prep_one(K, b, c, lb, ub, rho_seed=None, *, opts: PDHGOptions):
    from ..core.symblock import build_sym_block

    (Ks, bs, cs, lbs, ubs, T, Sigma, D1, D2) = prep_scale(
        K, b, c, lb, ub, opts)
    if opts.norm_override is not None:
        rho = jnp.asarray(opts.norm_override, Ks.dtype)
    else:
        Keff = jnp.sqrt(Sigma)[:, None] * Ks * jnp.sqrt(T)[None, :]
        M = build_sym_block(Keff)
        rho = _estimate_norm_mv(lambda v: M @ v, M.shape[0], M.dtype,
                                opts, rho_seed)
    return (Ks, bs, cs, lbs, ubs, T, Sigma, rho, D1, D2)


def make_bucket_pipeline(opts: PDHGOptions, sigma_read: float = 0.0,
                         norm_seeded: bool = False):
    """vmapped prep + solve over a stacked (B, m, n) bucket.

    ``keys`` carries one PRNG key per instance (iterate init + read-noise
    streams).  Returns (xs, ys, iterations, merits, rhos) in the ORIGINAL
    (unscaled) coordinates — ``rhos`` is the per-instance RAW norm
    estimate (pre-margin), which the norm-reuse cache records.  With
    ``norm_seeded`` the pipeline takes an extra per-instance
    ``rho_seeds`` argument and runs the short refinement instead of the
    full estimate (see ``_estimate_norm_mv``).  Pure function of the
    stacked arrays — safe to jit/AOT.
    """
    static = opts_static(opts, sigma_read)
    _check_norm_backend(opts)

    def _run(Ks, bs, cs, lbs, ubs, keys, rho_seeds=None):
        prep = functools.partial(_prep_one, opts=opts)
        if rho_seeds is None:
            prepped = jax.vmap(prep)(Ks, bs, cs, lbs, ubs)
        else:
            prepped = jax.vmap(prep)(Ks, bs, cs, lbs, ubs, rho_seeds)
        (Ks2, bs2, cs2, lbs2, ubs2, Ts, Sigs, rhos, D1s, D2s) = prepped
        rhos_used = rhos
        if opts.norm_override is None:
            # only the (noisy) estimate gets the Lemma-2 margin;
            # an explicit norm_override is trusted as-is (= solve_jit)
            rhos_used = engine.lemma2_margin(rhos, sigma_read)
        solver = functools.partial(_single_solve, static=static)
        xs, ys, its, merits = jax.vmap(solver)(
            Ks2, bs2, cs2, lbs2, ubs2, Ts, Sigs, rhos_used, keys)
        return D2s * xs, D1s * ys, its, merits, rhos

    if norm_seeded:
        def pipeline(Ks, bs, cs, lbs, ubs, keys, rho_seeds):
            return _run(Ks, bs, cs, lbs, ubs, keys, rho_seeds)
    else:
        def pipeline(Ks, bs, cs, lbs, ubs, keys):
            return _run(Ks, bs, cs, lbs, ubs, keys)

    return pipeline


# ------------------------------------------------------- sparse pipeline ---

def _coo_matvec(data, row, col, v, out_dim: int):
    """COO contraction ``out[row] += data * v[col]`` (scatter-add); the
    sparse twin of one dense MVM, vmappable and while_loop-safe."""
    return jnp.zeros(out_dim, v.dtype).at[row].add(data * v[col])


def _prep_one_sparse(data, idx, b, c, lb, ub, opts: PDHGOptions):
    """Sparse Ruiz + Pock–Chambolle diagonals on COO nonzeros.

    Mirrors ``precondition.apply_ruiz`` / ``diagonal_precondition``
    exactly (same eps, same sqrt-of-inf-norm update), but every row/col
    reduction is a scatter over the stored entries — padded zero entries
    at (0, 0) contribute nothing.  Returns the scaled nonzeros plus the
    same tuple layout as the dense ``prep_scale``.
    """
    dt = data.dtype
    m, n = b.shape[0], c.shape[0]
    row, col = idx[:, 0], idx[:, 1]
    eps = 1e-12
    D1 = jnp.ones(m, dt)
    D2 = jnp.ones(n, dt)
    d = data
    for _ in range(opts.ruiz_iters):
        ad = jnp.abs(d)
        r = jnp.sqrt(jnp.zeros(m, dt).at[row].max(ad))
        cc = jnp.sqrt(jnp.zeros(n, dt).at[col].max(ad))
        r = jnp.where(r < eps, 1.0, r)
        cc = jnp.where(cc < eps, 1.0, cc)
        D1 = D1 / r
        D2 = D2 / cc
        d = data * D1[row] * D2[col]
    bs = D1 * b
    cs = D2 * c
    lbs = jnp.where(jnp.isfinite(lb), lb / D2, lb)
    ubs = jnp.where(jnp.isfinite(ub), ub / D2, ub)
    ad = jnp.abs(d)
    T = 1.0 / jnp.maximum(jnp.zeros(n, dt).at[col].add(ad), eps)
    Sigma = 1.0 / jnp.maximum(jnp.zeros(m, dt).at[row].add(ad), eps)
    return d, bs, cs, lbs, ubs, T, Sigma, D1, D2


def make_sparse_bucket_pipeline(opts: PDHGOptions, sigma_read: float = 0.0,
                                norm_seeded: bool = False):
    """vmapped sparse prep + solve over a stacked COO bucket.

    Inputs are the ``stack_problems_sparse`` layout: (B, nnz) data,
    (B, nnz, 2) indices, plus the dense vectors and per-instance keys.
    The operator-norm estimate runs a matvec-only Lanczos (or power
    iteration, per ``opts.norm_backend``; a short seeded refinement
    with ``norm_seeded``) on the symmetric block of
    Sigma^{1/2} K T^{1/2} (two COO contractions per iteration); the
    solve itself mounts ``engine.sparse_operator`` on a BCOO built from
    the scaled nonzeros.  No dense (m, n) array ever exists on host or
    device.  Returns an extra trailing ``rhos`` (raw per-instance norm
    estimates) like ``make_bucket_pipeline``.
    """
    static = opts_static(opts, sigma_read)
    _check_norm_backend(opts)

    def one(kd, ki, b, c, lb, ub, key, rho_seed=None):
        m, n = b.shape[0], c.shape[0]
        (d, bs, cs, lbs, ubs, T, Sigma, D1, D2) = _prep_one_sparse(
            kd, ki, b, c, lb, ub, opts)
        if opts.norm_override is not None:
            rho_raw = jnp.asarray(opts.norm_override, kd.dtype)
            rho = rho_raw
        else:
            row, col = ki[:, 0], ki[:, 1]
            deff = d * jnp.sqrt(Sigma)[row] * jnp.sqrt(T)[col]

            def mv(v):         # symmetric block M' of Keff, matvec-only
                top = _coo_matvec(deff, row, col, v[m:], m)
                bot = _coo_matvec(deff, col, row, v[:m], n)
                return jnp.concatenate([top, bot])

            rho_raw = _estimate_norm_mv(mv, m + n, kd.dtype, opts,
                                        rho_seed)
            rho = engine.lemma2_margin(rho_raw, sigma_read)
        K_sp = jsparse.BCOO((d, ki), shape=(m, n))
        x, y, it, merit = engine.solve_core(
            K_sp, None, bs, cs, lbs, ubs, T, Sigma, rho, key, static)
        return D2 * x, D1 * y, it, merit, rho_raw

    if norm_seeded:
        def pipeline(Kdata, Kidx, bs, cs, lbs, ubs, keys, rho_seeds):
            return jax.vmap(one)(Kdata, Kidx, bs, cs, lbs, ubs, keys,
                                 rho_seeds)
    else:
        def pipeline(Kdata, Kidx, bs, cs, lbs, ubs, keys):
            return jax.vmap(one)(Kdata, Kidx, bs, cs, lbs, ubs, keys)

    return pipeline


# ---------------------------------------------------------- ELL pipeline ---

def _row_reduce(a, reduce_fn):
    """axis-1 reduction of an (m, W) ELL value array, total-safe at
    W == 0 (an all-zero operator's ELL form has zero width)."""
    if a.shape[1] == 0:
        return jnp.zeros(a.shape[0], a.dtype)
    return reduce_fn(a, axis=1)


def _prep_one_ell(df, cf, da, ca, b, c, lb, ub, opts: PDHGOptions):
    """Sparse Ruiz + Pock–Chambolle diagonals on ELL nonzeros.

    Mirrors ``_prep_one_sparse`` (same eps, same guard, same update
    order — the scaling diagonals come out bit-identical), but every
    row/column reduction is a vectorized axis-1 max/sum on the layout
    that already has it contiguous: row stats on the forward ELL,
    column stats on the adjoint ELL.  No scatter anywhere.  Padding
    slots (data 0, col 0) scale to 0 and never move a max or a sum.
    """
    dt = df.dtype
    eps = 1e-12
    m, n = b.shape[0], c.shape[0]
    D1 = jnp.ones(m, dt)
    D2 = jnp.ones(n, dt)
    sf, sa = df, da
    for _ in range(opts.ruiz_iters):
        r = jnp.sqrt(_row_reduce(jnp.abs(sf), jnp.max))
        cc = jnp.sqrt(_row_reduce(jnp.abs(sa), jnp.max))
        r = jnp.where(r < eps, 1.0, r)
        cc = jnp.where(cc < eps, 1.0, cc)
        D1 = D1 / r
        D2 = D2 / cc
        sf = df * D1[:, None] * D2[cf]
        sa = da * D2[:, None] * D1[ca]
    bs = D1 * b
    cs = D2 * c
    lbs = jnp.where(jnp.isfinite(lb), lb / D2, lb)
    ubs = jnp.where(jnp.isfinite(ub), ub / D2, ub)
    T = 1.0 / jnp.maximum(_row_reduce(jnp.abs(sa), jnp.sum), eps)
    Sigma = 1.0 / jnp.maximum(_row_reduce(jnp.abs(sf), jnp.sum), eps)
    return sf, sa, bs, cs, lbs, ubs, T, Sigma, D1, D2


def make_ell_bucket_pipeline(opts: PDHGOptions, sigma_read: float = 0.0,
                             norm_seeded: bool = False):
    """vmapped ELL prep + solve over a stacked ELL bucket.

    Inputs are the ``stack_problems_ell`` layout plus per-instance keys.
    The operator-norm estimate runs a matvec-only Lanczos with two ELL
    gathers per iteration; the solve mounts ``engine.sparse_ell_operator``
    (``opts.megakernel`` additionally fuses each check window into one
    ``kernels.pdhg_megakernel`` launch).  Like the COO pipeline, no
    dense (m, n) array ever exists on host or device — but unlike it,
    no iteration-path op is a scatter, which is what makes sparse win
    on wall clock and not just memory.  Returns an extra trailing
    ``rhos`` (raw per-instance norm estimates) like
    ``make_bucket_pipeline``; ``norm_seeded`` swaps the full estimate
    for the short cached-seed refinement.
    """
    static = opts_static(opts, sigma_read)
    _check_norm_backend(opts)

    def one(df, cf, da, ca, b, c, lb, ub, key, rho_seed=None):
        m, n = b.shape[0], c.shape[0]
        (sf, sa, bs, cs, lbs, ubs, T, Sigma, D1, D2) = _prep_one_ell(
            df, cf, da, ca, b, c, lb, ub, opts)
        if opts.norm_override is not None:
            rho_raw = jnp.asarray(opts.norm_override, df.dtype)
            rho = rho_raw
        else:
            rtS, rtT = jnp.sqrt(Sigma), jnp.sqrt(T)
            deff_f = sf * rtS[:, None] * rtT[cf]
            deff_a = sa * rtT[:, None] * rtS[ca]

            def mv(v):         # symmetric block M' of Keff, matvec-only
                top = ell_matvec(deff_f, cf, v[m:])
                bot = ell_matvec(deff_a, ca, v[:m])
                return jnp.concatenate([top, bot])

            rho_raw = _estimate_norm_mv(mv, m + n, df.dtype, opts,
                                        rho_seed)
            rho = engine.lemma2_margin(rho_raw, sigma_read)
        op = engine.sparse_ell_operator(sf, cf, sa, ca, sigma_read)
        if opts.megakernel and sigma_read == 0.0:
            op = op._replace(fuse=engine.make_fused_ell(
                sf, cf, sa, ca, bs, cs, lbs, ubs, T, Sigma, opts.gamma))
        x, y, it, merit = engine.solve_core(
            None, None, bs, cs, lbs, ubs, T, Sigma, rho, key, static,
            operator=op)
        return D2 * x, D1 * y, it, merit, rho_raw

    if norm_seeded:
        def pipeline(df, cf, da, ca, bs, cs, lbs, ubs, keys, rho_seeds):
            return jax.vmap(one)(df, cf, da, ca, bs, cs, lbs, ubs, keys,
                                 rho_seeds)
    else:
        def pipeline(df, cf, da, ca, bs, cs, lbs, ubs, keys):
            return jax.vmap(one)(df, cf, da, ca, bs, cs, lbs, ubs, keys)

    return pipeline


# ------------------------------------------------------------- scheduler ---

@dataclasses.dataclass
class BatchItemResult:
    """Per-instance result with padding stripped."""

    name: str
    x: np.ndarray
    y: np.ndarray
    obj: float
    iterations: int
    merit: float
    converged: bool
    bucket: Tuple[int, int]
    mvm_calls: int = 0          # device MVMs (engine.mvm_accounting)
    sparse: bool = False        # served by a sparse (ELL/COO) pipeline

    @property
    def status(self) -> str:
        # a non-finite merit means the iterate blew up — that is
        # divergence, not a clean iteration limit (converged is already
        # False: NaN <= tol compares false)
        if not np.isfinite(self.merit):
            return "diverged"
        return "optimal" if self.converged else "iteration_limit"


def _donation_supported() -> bool:
    """Buffer donation is a no-op on CPU; only claim it where XLA
    implements it (keeps executable cache keys stable per platform)."""
    try:
        return jax.local_devices()[0].platform in ("gpu", "cuda", "rocm",
                                                   "tpu")
    except Exception:                      # pragma: no cover - no devices
        return False


def _outputs_ready(out) -> bool:
    """True when every device buffer of a dispatched result is ready
    (computation finished) — drives completion-order collection."""
    return all(leaf.is_ready() for leaf in jax.tree_util.tree_leaves(out)
               if hasattr(leaf, "is_ready"))


class BatchSolver:
    """Shape-bucketing scheduler with a compiled-executable cache.

    One instance amortizes compilation across calls: the first stream
    touching a ``(bucket, batch, dtype)`` signature lowers + compiles the
    bucket pipeline (a cache MISS); every later stream with the same
    signature reuses the executable (a HIT).  ``mesh`` shards the batch
    dimension over ``batch_axes`` — zero collectives during the solve.

    ``tile`` switches bucketing to device-tile mode (multiples of the
    physical crossbar dims); ``sigma_read`` adds multiplicative per-MVM
    read noise inside the vmapped solver; ``kernel`` ("jnp" | "pallas")
    selects the engine's update backend (all three are part of the
    executable cache key — executables never cross kernels).  Subclasses
    (``crossbar.solver.CrossbarBatchSolver``) override
    ``_make_pipeline``/``_collect``/``_device_signature`` to run full
    device physics in the same bucketed harness.

    Sparse instances (``lp.is_sparse``) are bucketed separately (shape
    bucket + power-of-two nnz bucket) and served by the COO pipeline
    when the solver ``supports_sparse`` (the crossbar subclass programs
    every physical cell, so it densifies instead).  ``async_dispatch``
    submits all buckets before collecting any result (set False for
    blocking per-bucket dispatch, e.g. to bound device memory on tiny
    hosts); ``donate_min_bytes`` is the stacked-operator size beyond
    which the input buffer is donated to the executable.
    ``last_stream_stats`` records, per ``solve_stream`` call, the host
    bytes each stacking path materialized, dispatch/collect timings, and
    ``compiles`` — the number of XLA compilations the call triggered
    (``runtime.sanitize``; a warm pass over a bucket mix served before
    must report 0).  ``transfer_sanitize=True`` additionally runs every
    executable under ``sanitize.no_implicit_transfers()``, so an
    accidental per-call host<->device transfer raises instead of
    silently serializing dispatch.

    ``norm_reuse=True`` turns on the cross-instance operator-norm cache:
    every served instance's raw norm estimate is recorded under its
    (shape bucket, sparsity-pattern fingerprint) key, and a bucket whose
    instances ALL have cached estimates is served by a seeded executable
    that replaces the full ``lanczos_iters``-step estimate with a
    ``NORM_REFINE_ITERS``-step power refinement floored at the cached
    value (``_estimate_norm_mv``).  The seeded twin executable is
    compiled EAGERLY on the cold pass, so warm streams stay at zero
    compiles; the cache changes step sizes (a refined estimate instead
    of the full one), so it is opt-in — the default ``False`` path is
    bit-identical to not having the feature.
    """

    supports_sparse = True

    def __init__(self, opts: PDHGOptions = PDHGOptions(), *,
                 mesh=None, batch_axes: Tuple[str, ...] = ("data",),
                 min_bucket: int = MIN_BUCKET,
                 sigma_read: float = 0.0,
                 tile: Optional[Tuple[int, int]] = None,
                 kernel: Optional[str] = None,
                 async_dispatch: bool = True,
                 donate_min_bytes: int = DONATE_MIN_BYTES,
                 transfer_sanitize: bool = False,
                 norm_reuse: bool = False):
        if kernel is not None:
            # convenience override; the kernel choice rides in opts and
            # therefore in every executable cache signature
            opts = dataclasses.replace(opts, kernel=kernel)
        self.opts = opts
        self.mesh = mesh
        self.batch_axes = tuple(batch_axes)
        self.min_bucket = min_bucket
        self.sigma_read = float(sigma_read)
        self.tile = None if tile is None else (int(tile[0]), int(tile[1]))
        self.async_dispatch = bool(async_dispatch)
        self.donate_min_bytes = int(donate_min_bytes)
        self.transfer_sanitize = bool(transfer_sanitize)
        self.norm_reuse = bool(norm_reuse)
        self._cache = {}
        self._norm_cache: dict = {}
        self._seeded_idxs: set = set()
        self.cache_hits = 0
        self.cache_misses = 0
        self.last_stream_stats: dict = {}

    # -- subclass hooks -----------------------------------------------

    def _bucket(self, m: int, n: int) -> Tuple[int, int]:
        return bucket_dims(m, n, min_size=self.min_bucket, tile=self.tile)

    def _make_pipeline(self, norm_seeded: bool = False):
        return make_bucket_pipeline(self.opts, self.sigma_read,
                                    norm_seeded=norm_seeded)

    def _make_sparse_pipeline(self, norm_seeded: bool = False):
        return make_sparse_bucket_pipeline(self.opts, self.sigma_read,
                                           norm_seeded=norm_seeded)

    def _make_ell_pipeline(self, norm_seeded: bool = False):
        return make_ell_bucket_pipeline(self.opts, self.sigma_read,
                                        norm_seeded=norm_seeded)

    def _device_signature(self):
        """Hashable device component of the executable cache key."""
        return None

    # -- executable cache ---------------------------------------------

    def _batch_quantum(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    def _padded_batch(self, n_items: int) -> int:
        pow2 = 1 << (n_items - 1).bit_length()
        return _ceil_to(pow2, self._batch_quantum())

    def _sharding(self):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(self.batch_axes))

    def _cache_key(self, shape_sig, B: int, dtype, donate: bool):
        return (shape_sig, B, jnp.dtype(dtype).name, bool(donate),
                opts_static(self.opts, self.sigma_read),
                # prep-stage options that shape the pipeline but live
                # outside the solve-core static tuple
                (self.opts.ruiz_iters, self.opts.lanczos_iters,
                 self.opts.norm_override, self.opts.norm_backend),
                self.tile,
                self._device_signature(),
                None if self.mesh is None else
                (tuple(self.mesh.axis_names),
                 tuple(self.mesh.devices.shape), self.batch_axes))

    def _compile(self, key, pipeline, args, donate: bool):
        hit = self._cache.get(key)
        if hit is not None:
            self.cache_hits += 1
            return hit
        self.cache_misses += 1
        donate_argnums = (0,) if donate else ()
        compiled = jax.jit(pipeline, donate_argnums=donate_argnums) \
            .lower(*args).compile()
        self._cache[key] = compiled
        return compiled

    def _sds(self, shape, dt):
        return jax.ShapeDtypeStruct(shape, dt, sharding=self._sharding())

    @staticmethod
    def _key_template():
        """Shape/dtype template for one per-instance PRNG key slot.

        The constant key never produces random bits: executables are
        lowered from abstract shapes only, and the real per-instance
        keys are threaded at call time by ``_instance_keys``.
        """
        return jax.random.PRNGKey(0)  # jaxlint: disable=R2

    def _executable(self, mb: int, nb: int, B: int, dtype, *,
                    donate: bool = False, seeded: bool = False):
        sig = ("dense", mb, nb) + (("normseed",) if seeded else ())
        key = self._cache_key(sig, B, dtype, donate)
        k0 = self._key_template()
        args = (self._sds((B, mb, nb), dtype), self._sds((B, mb), dtype),
                self._sds((B, nb), dtype), self._sds((B, nb), dtype),
                self._sds((B, nb), dtype), self._sds((B, *k0.shape),
                                                     k0.dtype))
        if seeded:
            args = args + (self._sds((B,), dtype),)
        return self._compile(key, self._make_pipeline(norm_seeded=seeded)
                             if seeded else self._make_pipeline(),
                             args, donate)

    def _executable_sparse(self, mb: int, nb: int, nnz: int, B: int,
                           dtype, *, donate: bool = False,
                           seeded: bool = False):
        sig = ("sparse", mb, nb, nnz) + (("normseed",) if seeded else ())
        key = self._cache_key(sig, B, dtype, donate)
        k0 = self._key_template()
        args = (self._sds((B, nnz), dtype),
                self._sds((B, nnz, 2), jnp.int32),
                self._sds((B, mb), dtype), self._sds((B, nb), dtype),
                self._sds((B, nb), dtype), self._sds((B, nb), dtype),
                self._sds((B, *k0.shape), k0.dtype))
        if seeded:
            args = args + (self._sds((B,), dtype),)
        return self._compile(key,
                             self._make_sparse_pipeline(norm_seeded=seeded)
                             if seeded else self._make_sparse_pipeline(),
                             args, donate)

    def _executable_ell(self, mb: int, nb: int, wf: int, wa: int, B: int,
                        dtype, *, donate: bool = False,
                        seeded: bool = False):
        sig = ("ell", mb, nb, wf, wa) + (("normseed",) if seeded else ())
        key = self._cache_key(sig, B, dtype, donate)
        k0 = self._key_template()
        args = (self._sds((B, mb, wf), dtype),
                self._sds((B, mb, wf), jnp.int32),
                self._sds((B, nb, wa), dtype),
                self._sds((B, nb, wa), jnp.int32),
                self._sds((B, mb), dtype), self._sds((B, nb), dtype),
                self._sds((B, nb), dtype), self._sds((B, nb), dtype),
                self._sds((B, *k0.shape), k0.dtype))
        if seeded:
            args = args + (self._sds((B,), dtype),)
        return self._compile(key, self._make_ell_pipeline(norm_seeded=seeded)
                             if seeded else self._make_ell_pipeline(),
                             args, donate)

    def cache_info(self) -> dict:
        return {"hits": self.cache_hits, "misses": self.cache_misses,
                "entries": len(self._cache)}

    # -- cross-instance norm cache ------------------------------------

    def _norm_fingerprint(self, lp: StandardLP):
        """Norm-cache key: shape bucket + exact shape + sparsity pattern.

        Sparse instances hash their COO index arrays (blake2b-64), so an
        estimate is only ever reused across instances with the SAME
        nonzero pattern — the paper's repeated-structure setting (one
        constraint template, many coefficient draws).  Index order is
        hashed as given: a reordered but equal pattern just misses the
        cache (conservative, never wrong).  Dense instances share one
        entry per exact shape.
        """
        bucket = self._bucket(*lp.K.shape)
        if isinstance(lp.K, SparseCOO):
            h = hashlib.blake2b(digest_size=8)
            h.update(np.ascontiguousarray(
                np.asarray(lp.K.row, np.int64)).tobytes())
            h.update(np.ascontiguousarray(
                np.asarray(lp.K.col, np.int64)).tobytes())
            return (bucket, tuple(lp.K.shape), int(lp.K.nnz),
                    h.hexdigest())
        return (bucket, tuple(lp.K.shape))

    # -- solving ------------------------------------------------------

    def _instance_keys(self, idxs: Sequence[int], n_total: int,
                       B: int) -> jnp.ndarray:
        """One PRNG key per batch slot: fold the instance's position in
        the stream into ``opts.seed`` (filler slots get out-of-range
        positions, so even dropped work is decorrelated)."""
        base = jax.random.PRNGKey(self.opts.seed)
        positions = list(idxs) + [n_total + j for j in range(B - len(idxs))]
        return jax.vmap(lambda p: jax.random.fold_in(base, p))(
            jnp.asarray(positions, jnp.uint32))

    def _collect(self, out, bucket: Tuple[int, int], idxs: Sequence[int],
                 lps: Sequence[StandardLP], results: list) -> None:
        xs, ys, its, merits = out[:4]
        xs, ys = np.asarray(xs), np.asarray(ys)
        its, merits = np.asarray(its), np.asarray(merits)
        # trailing rhos (raw norm estimates) arrived with the 5-tuple
        # pipelines; tolerate legacy 4-tuples (e.g. checkpoints gathered
        # from pods running an older serialization)
        rhos = np.asarray(out[4]) if len(out) > 4 else None
        record_norms = (rhos is not None and self.norm_reuse
                        and self.opts.norm_override is None)
        for k, i in enumerate(idxs):
            lp = lps[i]
            m, n = lp.K.shape
            x = xs[k, :n]
            it = int(its[k])
            if self.opts.norm_override is not None:
                lanczos = 0
            elif i in self._seeded_idxs:
                lanczos = NORM_REFINE_ITERS
            else:
                lanczos = self.opts.lanczos_iters
            results[i] = BatchItemResult(
                name=lp.name, x=x, y=ys[k, :m],
                obj=float(lp.c @ x), iterations=it,
                merit=float(merits[k]),
                converged=bool(merits[k] <= self.opts.tol),
                bucket=bucket,
                mvm_calls=engine.mvm_accounting(
                    it, self.opts.check_every, lanczos,
                    restart=self.opts.restart),
                sparse=bool(getattr(lp, "is_sparse", False)),
            )
            if record_norms and np.isfinite(rhos[k]):
                fp = self._norm_fingerprint(lp)
                prev = self._norm_cache.get(fp)
                val = float(rhos[k])
                self._norm_cache[fp] = (val if prev is None
                                        else max(prev, val))

    def _donate(self, nbytes: int) -> bool:
        return nbytes >= self.donate_min_bytes and _donation_supported()

    def _dispatch_bucket(self, group, idxs, n_total: int,
                         mb: int, nb: int, sig, dtype,
                         stats):
        """Stack one bucket and submit it to its compiled executable.

        ``sig`` is the group's sparse signature: None for dense serving,
        a bare int nnz bucket for the COO/BCOO backend, or
        ``("ell", wf, wa)`` width buckets for the ELL backend.  Returns
        the (asynchronously dispatched) device outputs — the call never
        blocks on the solve itself.
        """
        B = self._padded_batch(len(group))
        # norm-reuse serving: a bucket is seeded only when EVERY member's
        # fingerprint already has a cached estimate (filler slots reuse
        # the first member's seed — their results are dropped anyway)
        rho_seeds = None
        if self.norm_reuse and self.opts.norm_override is None:
            cached = [self._norm_cache.get(self._norm_fingerprint(lp))
                      for lp in group]
            if all(v is not None for v in cached):
                # dtype-convert on host: jnp.asarray of a ready numpy
                # array is a pure transfer, so a first seeded pass never
                # triggers an eager convert compile (warm streams must
                # stay at zero)
                rho_seeds = jnp.asarray(np.asarray(
                    cached + [cached[0]] * (B - len(group)),
                    jax.dtypes.canonicalize_dtype(dtype)))
        seeded = rho_seeds is not None
        # batch padding repeats the first instance; extras are dropped
        filler = [group[0]] * (B - len(group))
        keys = self._instance_keys(idxs, n_total, B)
        if isinstance(sig, tuple):                       # ("ell", wf, wa)
            _, wf, wa = sig
            stacked = stack_problems_ell(group + filler, m=mb, n=nb,
                                         wf=wf, wa=wa)
            stats["sparse_stack_bytes"] += sum(a.nbytes for a in stacked)
            arrays = [jnp.asarray(a, jnp.int32) if i in (1, 3)
                      else jnp.asarray(a, dtype)
                      for i, a in enumerate(stacked)]
            donate = self._donate(arrays[0].nbytes)
            exe_fn = functools.partial(self._executable_ell, mb, nb, wf,
                                       wa, B, dtype, donate=donate)
        elif sig is not None:                            # bare int nnz
            stacked = stack_problems_sparse(group + filler, m=mb, n=nb,
                                            nnz=sig)
            stats["sparse_stack_bytes"] += sum(a.nbytes for a in stacked)
            arrays = ([jnp.asarray(stacked[0], dtype),
                       jnp.asarray(stacked[1], jnp.int32)]
                      + [jnp.asarray(a, dtype) for a in stacked[2:]])
            donate = self._donate(arrays[0].nbytes)
            exe_fn = functools.partial(self._executable_sparse, mb, nb,
                                       sig, B, dtype, donate=donate)
        else:
            group = [lp.densified() for lp in group]
            filler = [group[0]] * (B - len(group))
            stacked = stack_problems(group + filler, m=mb, n=nb)
            stats["dense_stack_bytes"] += sum(a.nbytes for a in stacked)
            arrays = [jnp.asarray(a, dtype) for a in stacked]
            donate = self._donate(arrays[0].nbytes)
            exe_fn = functools.partial(self._executable, mb, nb, B, dtype,
                                       donate=donate)
        exe = exe_fn(seeded=seeded)
        if self.norm_reuse and self.opts.norm_override is None \
                and not seeded:
            # cold pass over a new fingerprint set: compile the seeded
            # twin NOW so the warm stream that will hit the cache later
            # reports zero compiles (bench_guard --max-warm-compiles 0)
            exe_fn(seeded=True)
        if seeded:
            self._seeded_idxs.update(idxs)
            stats["norm_seeded_buckets"] += 1
        stats["donated_buckets"] += int(donate)
        sh = self._sharding()
        if sh is not None:
            arrays = [jax.device_put(a, sh) for a in arrays]
            keys = jax.device_put(keys, sh)
            if seeded:
                rho_seeds = jax.device_put(rho_seeds, sh)
        call_args = ((*arrays, keys, rho_seeds) if seeded
                     else (*arrays, keys))
        if self.transfer_sanitize:
            # inputs are on device by now (the jnp.asarray stacking above
            # is the one sanctioned upload); anything implicit past this
            # point is a serving bug
            with sanitize.no_implicit_transfers():
                return exe(*call_args)
        return exe(*call_args)

    def _sparse_signature(self, lp: StandardLP):
        """Sparse component of an instance's bucket key: the nnz bucket
        (bare int — the COO/BCOO stacking axis) or the pair of ELL width
        buckets.  Either way, one occupancy outlier never inflates (and
        never recompiles) the whole shape bucket's stack."""
        if self.opts.sparse_kernel == "ell":
            wf, wa = coo_row_widths(lp.K.row, lp.K.col, lp.K.data,
                                    lp.K.shape)
            return ("ell", ell_width_bucket(wf), ell_width_bucket(wa))
        return nnz_bucket(lp.K.nnz)

    def _group_buckets(self, lps: Sequence[StandardLP]) -> dict:
        """Group stream positions by ((m_bucket, n_bucket), sparse sig).

        Pure function of the stream (and solver config): every process
        of a multi-pod deployment derives the identical grouping, which
        is what makes coordination-free bucket routing possible."""
        buckets: dict = {}
        for i, lp in enumerate(lps):
            sp = bool(getattr(lp, "is_sparse", False)) and \
                self.supports_sparse
            sig = self._sparse_signature(lp) if sp else None
            buckets.setdefault((self._bucket(*lp.K.shape), sig),
                               []).append(i)
        return buckets

    # -- multi-pod routing hooks (runtime.cluster overrides these) ----

    def _route(self, buckets: dict) -> Tuple[dict, dict]:
        """Split buckets into (served here, served by other pods).

        The base scheduler is single-pod: everything is local."""
        return buckets, {}

    def _bucket_served(self, key, idxs: Sequence[int], out) -> None:
        """Called once per locally served bucket with its device outputs
        (after collection) — the cluster solver publishes here."""

    def _gather_remote(self, remote: dict, lps, results, stats) -> None:
        """Collect buckets served by other pods.  Single-pod: none."""
        if remote:      # pragma: no cover - _route never yields any here
            raise RuntimeError("base BatchSolver cannot gather remote "
                               f"buckets: {sorted(remote)}")

    def solve_stream(self, lps: Sequence[StandardLP]) -> List[BatchItemResult]:
        """Solve a heterogeneous stream; results come back in input order.

        Dispatch-then-collect: every locally routed bucket is stacked
        and submitted to its compiled executable before ANY result is
        pulled back (JAX dispatch is asynchronous, so device work
        overlaps host stacking of later buckets), then results are
        collected preferring buckets whose buffers are already ready.
        ``async_dispatch=False`` restores blocking per-bucket serving.
        Buckets routed to OTHER pods (``runtime.cluster``) are gathered
        after the local work completes.
        """
        lps = list(lps)
        dtype = jnp.dtype(self.opts.dtype)
        buckets = self._group_buckets(lps)
        mine, remote = self._route(buckets)

        results: List[Optional[object]] = [None] * len(lps)
        self._seeded_idxs = set()
        stats = {"n_buckets": len(buckets), "n_local_buckets": len(mine),
                 "dense_stack_bytes": 0,
                 "sparse_stack_bytes": 0, "donated_buckets": 0,
                 "norm_seeded_buckets": 0,
                 "dispatch_s": 0.0, "collect_s": 0.0, "compiles": 0}
        compiles0 = sanitize.compile_counts()["compiles"]
        t0 = time.perf_counter()
        pending = []
        for ((mb, nb), sig), idxs in mine.items():
            group = [lps[i] for i in idxs]
            out = self._dispatch_bucket(group, idxs, len(lps), mb, nb, sig,
                                        dtype, stats)
            if self.async_dispatch:
                pending.append((out, ((mb, nb), sig), idxs))
            else:
                jax.block_until_ready(out)
                self._collect(out, (mb, nb), idxs, lps, results)
                self._bucket_served(((mb, nb), sig), idxs, out)
        stats["dispatch_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        while pending:
            # completion order: prefer a bucket whose buffers are ready;
            # fall back to the oldest submission (blocking on it).
            nxt = next((p for p in pending if _outputs_ready(p[0])),
                       pending[0])
            pending.remove(nxt)
            out, key, idxs = nxt
            self._collect(out, key[0], idxs, lps, results)
            self._bucket_served(key, idxs, out)
        stats["collect_s"] = time.perf_counter() - t0
        self._gather_remote(remote, lps, results, stats)
        stats["compiles"] = (sanitize.compile_counts()["compiles"]
                             - compiles0)
        self.last_stream_stats = stats
        return results  # type: ignore[return-value]


def solve_stream(lps: Sequence[StandardLP],
                 opts: PDHGOptions = PDHGOptions(), *,
                 mesh=None, solver: Optional[BatchSolver] = None,
                 ) -> List[BatchItemResult]:
    """One-shot entry point; pass ``solver`` to keep the executable cache
    warm across calls."""
    if solver is None:
        solver = BatchSolver(opts, mesh=mesh)
    return solver.solve_stream(lps)
