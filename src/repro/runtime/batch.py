"""Shape-bucketed batch solving of heterogeneous LP streams.

The paper frames RRAM crossbars as *shared* linear-optimization
accelerators: many independent LP instances arrive with arbitrary shapes
and must be served together.  Same-shape stacking (the old
``distributed/batch_solve.py`` contract) breaks down there — every new
``(m, n)`` would recompile.  This scheduler:

  1. rounds every instance up to a ``(m_pad, n_pad)`` bucket (padding is
     exact: extra primal coordinates are pinned at lb=ub=0, extra rows
     are all-zero with b=0, so the optimum is unchanged).  Buckets are
     powers of two by default, or — in device-tile mode — multiples of
     the physical crossbar tile dimensions (e.g. 64x64 EpiRAM tiles), so
     padded instances map exactly onto whole tiles and the energy ledger
     sees the true programmed array,
  2. stacks each bucket and dispatches it through a vmapped jitted PDHG
     pipeline (Ruiz + diagonal preconditioning + Lanczos + while_loop) —
     the zero-collective data-parallel path: with a mesh, instances shard
     across devices and each device solves its slice locally,
  3. caches the compiled executable per (bucket, batch, dtype, options,
     noise, device) signature so repeat traffic never re-lowers, and
  4. strips padding and returns per-instance results in input order.

Every instance gets its own PRNG key (derived from ``opts.seed`` and its
position in the stream), so iterate initialization and read-noise streams
are decorrelated across a bucket.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..core import engine
from ..core.pdhg import PDHGOptions
from ..core.pdhg import opts_static  # noqa: F401  (canonical home; re-export)
from ..lp.problem import StandardLP

MIN_BUCKET = 8


# ------------------------------------------------------------- bucketing ---

def _ceil_to(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def bucket_dims(m: int, n: int, min_size: int = MIN_BUCKET,
                tile: Optional[Tuple[int, int]] = None) -> Tuple[int, int]:
    """Round ``(m, n)`` up to its bucket.

    Default mode rounds to the enclosing power of two.  With
    ``tile=(rows, cols)`` (device-tile mode) dims snap to multiples of the
    physical crossbar tile instead, so a bucket always fills whole tiles:
    ``bucket_dims(8, 70, tile=(64, 64)) == (64, 128)``.
    """
    if tile is not None:
        tr, tc = tile
        return _ceil_to(max(int(m), 1), tr), _ceil_to(max(int(n), 1), tc)
    up = lambda v: max(min_size, 1 << (int(v) - 1).bit_length())  # noqa: E731
    return up(m), up(n)


def pad_problem(lp: StandardLP, m_pad: int, n_pad: int) -> StandardLP:
    """Embed ``lp`` in an (m_pad, n_pad) problem with identical optimum.

    Extra variables are pinned (lb=ub=0, c=0); extra rows are zero with
    b=0.  Any solution of the padded problem restricts to one of the
    original and vice versa.
    """
    m, n = lp.K.shape
    assert m_pad >= m and n_pad >= n, ((m, n), (m_pad, n_pad))
    K = np.zeros((m_pad, n_pad))
    K[:m, :n] = lp.K
    b = np.zeros(m_pad)
    b[:m] = lp.b
    c = np.zeros(n_pad)
    c[:n] = lp.c
    lb = np.zeros(n_pad)
    ub = np.zeros(n_pad)
    lb[:n] = lp.lb
    ub[:n] = lp.ub
    x_opt = None
    if lp.x_opt is not None:
        x_opt = np.zeros(n_pad)
        x_opt[:n] = lp.x_opt
    return StandardLP(c=c, K=K, b=b, lb=lb, ub=ub, name=lp.name,
                      x_opt=x_opt, obj_opt=lp.obj_opt)


def stack_problems(lps: Sequence[StandardLP], m: Optional[int] = None,
                   n: Optional[int] = None) -> tuple:
    """Pad a list of StandardLPs to a common shape and stack.

    Target dims default to the max over the list (the legacy
    ``distributed.batch_solve`` behaviour); buckets pass them explicitly.
    """
    m = m if m is not None else max(lp.K.shape[0] for lp in lps)
    n = n if n is not None else max(lp.K.shape[1] for lp in lps)
    padded = [pad_problem(lp, m, n) for lp in lps]
    return tuple(
        np.stack([getattr(p, f) for p in padded])
        for f in ("K", "b", "c", "lb", "ub"))


# -------------------------------------------------------------- pipeline ---

def _single_solve(K, b, c, lb, ub, T, Sigma, rho, key, static):
    # The iteration core is core.engine's; ``static[-1]`` (opts.kernel)
    # selects the jnp vs fused-Pallas update backend per executable.
    return engine.solve_core(
        K, K.T, b, c, lb, ub, T, Sigma, rho, key, static)


def prep_scale(K, b, c, lb, ub, opts: PDHGOptions):
    """Ruiz + diagonal preconditioning (Algorithm 4 step 0), vmappable.

    Returns the scaled problem, the diagonal step scalings (T, Sigma) and
    the unscaling diagonals (D1, D2).  Operator-norm estimation is NOT
    included — callers estimate rho on whichever operator they actually
    execute (exact K here, the programmed crossbar blocks in
    ``crossbar.solver``).
    """
    from ..core.precondition import apply_ruiz, diagonal_precondition

    scaled = apply_ruiz(K, b, c, lb, ub, iters=opts.ruiz_iters)
    T, Sigma = diagonal_precondition(scaled.K)
    return (scaled.K, scaled.b, scaled.c, scaled.lb, scaled.ub, T, Sigma,
            scaled.D1, scaled.D2)


def _prep_one(K, b, c, lb, ub, opts: PDHGOptions):
    from ..core.lanczos import lanczos_svd_jit
    from ..core.symblock import build_sym_block

    (Ks, bs, cs, lbs, ubs, T, Sigma, D1, D2) = prep_scale(
        K, b, c, lb, ub, opts)
    if opts.norm_override is not None:
        rho = jnp.asarray(opts.norm_override, Ks.dtype)
    else:
        Keff = jnp.sqrt(Sigma)[:, None] * Ks * jnp.sqrt(T)[None, :]
        rho = lanczos_svd_jit(build_sym_block(Keff),
                              k_max=opts.lanczos_iters)
    return (Ks, bs, cs, lbs, ubs, T, Sigma, rho, D1, D2)


def make_bucket_pipeline(opts: PDHGOptions, sigma_read: float = 0.0):
    """vmapped prep + solve over a stacked (B, m, n) bucket.

    ``keys`` carries one PRNG key per instance (iterate init + read-noise
    streams).  Returns (xs, ys, iterations, merits) in the ORIGINAL
    (unscaled) coordinates.  Pure function of the stacked arrays — safe
    to jit/AOT.
    """
    static = opts_static(opts, sigma_read)

    def pipeline(Ks, bs, cs, lbs, ubs, keys):
        prepped = jax.vmap(functools.partial(_prep_one, opts=opts))(
            Ks, bs, cs, lbs, ubs)
        (Ks2, bs2, cs2, lbs2, ubs2, Ts, Sigs, rhos, D1s, D2s) = prepped
        if opts.norm_override is None:
            # only the (noisy) Lanczos estimate gets the Lemma-2 margin;
            # an explicit norm_override is trusted as-is (= solve_jit)
            rhos = engine.lemma2_margin(rhos, sigma_read)
        solver = functools.partial(_single_solve, static=static)
        xs, ys, its, merits = jax.vmap(solver)(
            Ks2, bs2, cs2, lbs2, ubs2, Ts, Sigs, rhos, keys)
        return D2s * xs, D1s * ys, its, merits

    return pipeline


# ------------------------------------------------------------- scheduler ---

@dataclasses.dataclass
class BatchItemResult:
    """Per-instance result with padding stripped."""

    name: str
    x: np.ndarray
    y: np.ndarray
    obj: float
    iterations: int
    merit: float
    converged: bool
    bucket: Tuple[int, int]
    mvm_calls: int = 0          # device MVMs (engine.mvm_accounting)

    @property
    def status(self) -> str:
        return "optimal" if self.converged else "iteration_limit"


class BatchSolver:
    """Shape-bucketing scheduler with a compiled-executable cache.

    One instance amortizes compilation across calls: the first stream
    touching a ``(bucket, batch, dtype)`` signature lowers + compiles the
    bucket pipeline (a cache MISS); every later stream with the same
    signature reuses the executable (a HIT).  ``mesh`` shards the batch
    dimension over ``batch_axes`` — zero collectives during the solve.

    ``tile`` switches bucketing to device-tile mode (multiples of the
    physical crossbar dims); ``sigma_read`` adds multiplicative per-MVM
    read noise inside the vmapped solver; ``kernel`` ("jnp" | "pallas")
    selects the engine's update backend (all three are part of the
    executable cache key — executables never cross kernels).  Subclasses
    (``crossbar.solver.CrossbarBatchSolver``) override
    ``_make_pipeline``/``_collect``/``_device_signature`` to run full
    device physics in the same bucketed harness.
    """

    def __init__(self, opts: PDHGOptions = PDHGOptions(), *,
                 mesh=None, batch_axes: Tuple[str, ...] = ("data",),
                 min_bucket: int = MIN_BUCKET,
                 sigma_read: float = 0.0,
                 tile: Optional[Tuple[int, int]] = None,
                 kernel: Optional[str] = None):
        if kernel is not None:
            # convenience override; the kernel choice rides in opts and
            # therefore in every executable cache signature
            opts = dataclasses.replace(opts, kernel=kernel)
        self.opts = opts
        self.mesh = mesh
        self.batch_axes = tuple(batch_axes)
        self.min_bucket = min_bucket
        self.sigma_read = float(sigma_read)
        self.tile = None if tile is None else (int(tile[0]), int(tile[1]))
        self._cache = {}
        self.cache_hits = 0
        self.cache_misses = 0

    # -- subclass hooks -----------------------------------------------

    def _bucket(self, m: int, n: int) -> Tuple[int, int]:
        return bucket_dims(m, n, min_size=self.min_bucket, tile=self.tile)

    def _make_pipeline(self):
        return make_bucket_pipeline(self.opts, self.sigma_read)

    def _device_signature(self):
        """Hashable device component of the executable cache key."""
        return None

    # -- executable cache ---------------------------------------------

    def _batch_quantum(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    def _padded_batch(self, n_items: int) -> int:
        pow2 = 1 << (n_items - 1).bit_length()
        return _ceil_to(pow2, self._batch_quantum())

    def _sharding(self):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(self.batch_axes))

    def _executable(self, mb: int, nb: int, B: int, dtype):
        key = (mb, nb, B, jnp.dtype(dtype).name,
               opts_static(self.opts, self.sigma_read),
               # prep-stage options that shape the pipeline but live
               # outside the solve-core static tuple
               (self.opts.ruiz_iters, self.opts.lanczos_iters,
                self.opts.norm_override),
               self.tile,
               self._device_signature(),
               None if self.mesh is None else
               (tuple(self.mesh.axis_names),
                tuple(self.mesh.devices.shape), self.batch_axes))
        hit = self._cache.get(key)
        if hit is not None:
            self.cache_hits += 1
            return hit
        self.cache_misses += 1
        sh = self._sharding()
        sds = lambda s, dt: jax.ShapeDtypeStruct(  # noqa: E731
            (B, *s), dt, sharding=sh)
        k0 = jax.random.PRNGKey(0)
        args = (sds((mb, nb), dtype), sds((mb,), dtype), sds((nb,), dtype),
                sds((nb,), dtype), sds((nb,), dtype),
                sds(k0.shape, k0.dtype))
        compiled = jax.jit(self._make_pipeline()).lower(*args).compile()
        self._cache[key] = compiled
        return compiled

    def cache_info(self) -> dict:
        return {"hits": self.cache_hits, "misses": self.cache_misses,
                "entries": len(self._cache)}

    # -- solving ------------------------------------------------------

    def _instance_keys(self, idxs: Sequence[int], n_total: int,
                       B: int) -> jnp.ndarray:
        """One PRNG key per batch slot: fold the instance's position in
        the stream into ``opts.seed`` (filler slots get out-of-range
        positions, so even dropped work is decorrelated)."""
        base = jax.random.PRNGKey(self.opts.seed)
        positions = list(idxs) + [n_total + j for j in range(B - len(idxs))]
        return jax.vmap(lambda p: jax.random.fold_in(base, p))(
            jnp.asarray(positions, jnp.uint32))

    def _collect(self, out, bucket: Tuple[int, int], idxs: Sequence[int],
                 lps: Sequence[StandardLP], results: list) -> None:
        xs, ys, its, merits = out
        xs, ys = np.asarray(xs), np.asarray(ys)
        its, merits = np.asarray(its), np.asarray(merits)
        lanczos = (0 if self.opts.norm_override is not None
                   else self.opts.lanczos_iters)
        for k, i in enumerate(idxs):
            lp = lps[i]
            m, n = lp.K.shape
            x = xs[k, :n]
            it = int(its[k])
            results[i] = BatchItemResult(
                name=lp.name, x=x, y=ys[k, :m],
                obj=float(lp.c @ x), iterations=it,
                merit=float(merits[k]),
                converged=bool(merits[k] <= self.opts.tol),
                bucket=bucket,
                mvm_calls=engine.mvm_accounting(
                    it, self.opts.check_every, lanczos),
            )

    def solve_stream(self, lps: Sequence[StandardLP]) -> List[BatchItemResult]:
        """Solve a heterogeneous stream; results come back in input order."""
        lps = list(lps)
        dtype = jnp.dtype(self.opts.dtype)
        buckets = {}
        for i, lp in enumerate(lps):
            buckets.setdefault(self._bucket(*lp.K.shape), []).append(i)

        results: List[Optional[object]] = [None] * len(lps)
        for (mb, nb), idxs in buckets.items():
            group = [lps[i] for i in idxs]
            B = self._padded_batch(len(group))
            # batch padding repeats the first instance; extras are dropped
            filler = [group[0]] * (B - len(group))
            stacked = stack_problems(group + filler, m=mb, n=nb)
            arrays = [jnp.asarray(a, dtype) for a in stacked]
            keys = self._instance_keys(idxs, len(lps), B)
            sh = self._sharding()
            if sh is not None:
                arrays = [jax.device_put(a, sh) for a in arrays]
                keys = jax.device_put(keys, sh)
            out = self._executable(mb, nb, B, dtype)(*arrays, keys)
            self._collect(out, (mb, nb), idxs, lps, results)
        return results  # type: ignore[return-value]


def solve_stream(lps: Sequence[StandardLP],
                 opts: PDHGOptions = PDHGOptions(), *,
                 mesh=None, solver: Optional[BatchSolver] = None,
                 ) -> List[BatchItemResult]:
    """One-shot entry point; pass ``solver`` to keep the executable cache
    warm across calls."""
    if solver is None:
        solver = BatchSolver(opts, mesh=mesh)
    return solver.solve_stream(lps)
