"""Runtime portability layer: device/mesh/sharding concerns + batching.

Single entry point for everything that touches JAX's (version-volatile)
device and sharding machinery:

  * ``runtime.compat``  — feature-detected shims over the JAX APIs that
    moved between 0.4.x and >=0.6 (``AxisType``, ``get_abstract_mesh``,
    ``set_mesh``/``use_mesh``, top-level ``shard_map``), plus the shared
    sharding-annotation helpers (``constrain``/``batch_axes``).
  * ``runtime.mesh``    — one ``make_mesh`` API for every mesh in the
    repo (tests, local solves, 16x16 / 2x16x16 production dry-runs) with
    a CPU multi-device fallback for tests.
  * ``runtime.batch``   — shape-bucketed batch solving of heterogeneous
    LP streams with a compiled-executable cache per bucket.
  * ``runtime.sanitize`` — compile-count guard (warm streams assert
    zero recompiles) + ``jax.transfer_guard`` wrapper for the jitted
    solve paths; the runtime twin of ``tools.jaxlint``.
  * ``runtime.cluster`` — multi-host serving: env-driven
    ``jax.distributed`` bring-up with a single-process fallback,
    deterministic per-pod bucket routing, and the
    ``ClusterBatchSolver`` routed-stream scheduler.

No module outside ``runtime.compat`` may reference the volatile
``jax.sharding`` attributes directly.
"""
from . import batch, compat, mesh, sanitize
# cluster pulls in repro.distributed (fault-tolerant transport); import
# it last so the partially initialized package already exposes the
# submodules that chain re-enters (compat via distributed.pdhg_dist)
from . import cluster
from .batch import BatchSolver, solve_stream
from .cluster import ClusterBatchSolver, init_cluster
from .compat import (
    batch_axes,
    constrain,
    get_abstract_mesh,
    set_mesh,
    shard_map,
    use_mesh,
)
from .sanitize import CompileGuard, RecompileError, no_implicit_transfers
from .mesh import (
    make_cluster_mesh,
    make_local_mesh,
    make_mesh,
    make_production_mesh,
)

__all__ = [
    "BatchSolver",
    "ClusterBatchSolver",
    "CompileGuard",
    "RecompileError",
    "batch",
    "batch_axes",
    "cluster",
    "compat",
    "constrain",
    "get_abstract_mesh",
    "init_cluster",
    "no_implicit_transfers",
    "sanitize",
    "make_cluster_mesh",
    "make_local_mesh",
    "make_mesh",
    "make_production_mesh",
    "mesh",
    "set_mesh",
    "shard_map",
    "solve_stream",
    "use_mesh",
]
