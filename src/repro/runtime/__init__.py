"""Runtime portability layer: device/mesh/sharding concerns + batching.

Single entry point for everything that touches JAX's (version-volatile)
device and sharding machinery:

  * ``runtime.compat``  — feature-detected shims over the JAX APIs that
    moved between 0.4.x and >=0.6 (``AxisType``, ``get_abstract_mesh``,
    ``set_mesh``/``use_mesh``, top-level ``shard_map``), plus the shared
    sharding-annotation helpers (``constrain``/``batch_axes``).
  * ``runtime.mesh``    — one ``make_mesh`` API for every mesh in the
    repo (tests, local solves, 16x16 / 2x16x16 production dry-runs) with
    a CPU multi-device fallback for tests.
  * ``runtime.batch``   — shape-bucketed batch solving of heterogeneous
    LP streams with a compiled-executable cache per bucket.

No module outside ``runtime.compat`` may reference the volatile
``jax.sharding`` attributes directly.
"""
from . import batch, compat, mesh
from .batch import BatchSolver, solve_stream
from .compat import (
    batch_axes,
    constrain,
    get_abstract_mesh,
    set_mesh,
    shard_map,
    use_mesh,
)
from .mesh import make_local_mesh, make_mesh, make_production_mesh

__all__ = [
    "BatchSolver",
    "batch",
    "batch_axes",
    "compat",
    "constrain",
    "get_abstract_mesh",
    "make_local_mesh",
    "make_mesh",
    "make_production_mesh",
    "mesh",
    "set_mesh",
    "shard_map",
    "solve_stream",
    "use_mesh",
]
