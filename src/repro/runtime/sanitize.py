"""Runtime sanitizers for the jitted solve paths.

Two guards turn serving-stack performance contracts from timing
inferences into hard, assertable checks:

* :class:`CompileGuard` — counts XLA traces/compilations via
  ``jax.monitoring`` duration events.  A warm ``solve_stream`` pass over
  a bucket mix it has served before must compile **zero** new
  executables; wrapping the pass in ``CompileGuard(max_compiles=0)``
  makes any silent cache miss (a forgotten ``opts_static`` field, a
  drifting shape signature) raise :class:`RecompileError` instead of
  just showing up as a latency blip.

* :func:`no_implicit_transfers` — a ``jax.transfer_guard``-based
  context: any *implicit* host<->device transfer inside (a traced
  ``float()``/``.item()``, a numpy array silently uploaded per call)
  raises immediately.  This is the runtime twin of jaxlint rule R5.

``BatchSolver.solve_stream`` reports the compile count of every pass in
``last_stream_stats["compiles"]`` and can run its executables under the
transfer guard (``BatchSolver(..., transfer_sanitize=True)``); the
benchmark surfaces the warm counts in ``BENCH_stream.json`` where
``bench_guard --max-warm-compiles 0`` gates them in CI.

One module-level listener is registered lazily and never removed —
``jax.monitoring`` has no unregister API, so guards snapshot the global
counters instead of installing their own listeners.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax

COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"

_counts = {"compiles": 0, "traces": 0}
_lock = threading.Lock()
_installed = False


def _listener(event: str, duration_secs: float, **_kw) -> None:
    if event == COMPILE_EVENT:
        with _lock:
            _counts["compiles"] += 1
    elif event == TRACE_EVENT:
        with _lock:
            _counts["traces"] += 1


def install() -> bool:
    """Register the global compile listener (idempotent).

    Returns True when the listener is active.  On a JAX without the
    monitoring API the counters simply stay at zero — guards still work,
    they just cannot detect recompiles (``supported()`` reports this).
    """
    global _installed
    if _installed:
        return True
    register = getattr(getattr(jax, "monitoring", None),
                       "register_event_duration_secs_listener", None)
    if register is None:
        return False
    register(_listener)
    _installed = True
    return True


def supported() -> bool:
    """True when compile counting is actually wired into this JAX."""
    return install()


def compile_counts() -> dict:
    """Snapshot of the process-lifetime {compiles, traces} counters."""
    install()
    with _lock:
        return dict(_counts)


class RecompileError(RuntimeError):
    """A guarded region compiled more executables than its budget."""


class CompileGuard:
    """Count traces/compiles across a ``with`` region.

    >>> with CompileGuard(max_compiles=0) as guard:
    ...     solver.solve_stream(lps)      # warm: must not compile
    >>> guard.compiles
    0

    ``max_compiles=None`` only counts; an int budget raises
    :class:`RecompileError` on exit when exceeded.
    """

    def __init__(self, max_compiles: Optional[int] = None,
                 label: str = "guarded region"):
        self.max_compiles = max_compiles
        self.label = label
        self.compiles = 0
        self.traces = 0
        self._start: Optional[dict] = None

    def __enter__(self) -> "CompileGuard":
        install()
        self._start = compile_counts()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        end = compile_counts()
        self.compiles = end["compiles"] - self._start["compiles"]
        self.traces = end["traces"] - self._start["traces"]
        if exc_type is None and self.max_compiles is not None and \
                self.compiles > self.max_compiles:
            raise RecompileError(
                f"{self.label}: {self.compiles} XLA compilation(s), "
                f"budget {self.max_compiles} — an executable cache "
                "missed (stale opts_static field? drifting shape "
                "signature?)")
        return False


@contextlib.contextmanager
def no_implicit_transfers():
    """Raise on any implicit host<->device transfer inside the region.

    Thin wrapper over ``jax.transfer_guard("disallow")`` (no-op on JAX
    versions without it).  Explicit transfers — ``jax.device_put``,
    ``np.asarray(device_array)`` on CPU — stay allowed: the guard traps
    exactly the *accidental* per-call uploads and traced host syncs
    jaxlint rule R5 flags statically.
    """
    guard = getattr(jax, "transfer_guard", None)
    if guard is None:
        yield
        return
    with guard("disallow"):
        yield
