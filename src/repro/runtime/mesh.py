"""One mesh-construction API for the whole repo.

Absorbs the logic that used to live in ``launch/mesh.py`` (production
16x16 / 2x16x16 grids) and ``launch/solve.py`` (ad-hoc local meshes):

    make_mesh({"data": 2, "model": 4})              # preferred form
    make_mesh((2, 4), ("data", "model"))            # legacy positional
    make_mesh({"data": 8}, backend="cpu")           # platform-filtered
    make_local_mesh()                               # all local devices

Device-count errors point at the CPU multi-device fallback
(``compat.request_cpu_devices`` / XLA_FLAGS) instead of XLA's opaque
reshape failure.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple, Union

import jax

from . import compat

AxesSpec = Union[Dict[str, int], Sequence[int]]


def _normalize_axes(axes: AxesSpec, names: Optional[Sequence[str]]):
    if isinstance(axes, dict):
        return tuple(axes.values()), tuple(axes.keys())
    axes = tuple(axes)
    if names is not None:
        return axes, tuple(names)
    if axes and isinstance(axes[0], (tuple, list)):  # [("data", 2), ...]
        return tuple(int(s) for _, s in axes), tuple(a for a, _ in axes)
    raise TypeError(
        "make_mesh expects a {name: size} dict, (shape, names), or a "
        f"sequence of (name, size) pairs; got {axes!r}")


def make_mesh(axes: AxesSpec, names: Optional[Sequence[str]] = None, *,
              backend: Optional[str] = None, devices=None):
    """Build a Mesh on any supported JAX, with readable capacity errors."""
    shape, axis_names = _normalize_axes(axes, names)
    needed = math.prod(shape)
    if devices is None:
        devices = jax.devices(backend) if backend is not None else None
    avail = len(devices) if devices is not None else len(jax.devices())
    if needed > avail:
        raise RuntimeError(
            f"mesh {dict(zip(axis_names, shape))} needs {needed} devices "
            f"but only {avail} are visible"
            + (f" on backend {backend!r}" if backend else "")
            + "; for CPU tests set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={needed} before the "
            "first device query (repro.runtime.compat.request_cpu_devices)")
    if devices is not None:
        devices = list(devices)[:needed]
    return compat.make_mesh(shape, axis_names, devices=devices)


def make_production_mesh(*, multi_pod: bool = False,
                         backend: Optional[str] = None):
    """16x16 = 256 chips/pod; multi_pod adds a 2-pod leading axis (512)."""
    if multi_pod:
        return make_mesh({"pod": 2, "data": 16, "model": 16},
                         backend=backend)
    return make_mesh({"data": 16, "model": 16}, backend=backend)


def make_local_mesh(axis_names: Tuple[str, str] = ("data", "model"), *,
                    backend: Optional[str] = None):
    """Near-square 2-D mesh over all visible devices (local solves)."""
    n_dev = len(jax.devices(backend) if backend else jax.devices())
    rows = max(1, n_dev // 2)
    while n_dev % rows:
        rows -= 1
    cols = n_dev // rows
    return make_mesh((rows, cols), axis_names, backend=backend)
