"""One mesh-construction API for the whole repo.

Absorbs the logic that used to live in ``launch/mesh.py`` (production
16x16 / 2x16x16 grids) and ``launch/solve.py`` (ad-hoc local meshes):

    make_mesh({"data": 2, "model": 4})              # preferred form
    make_mesh((2, 4), ("data", "model"))            # legacy positional
    make_mesh({"data": 8}, backend="cpu")           # platform-filtered
    make_local_mesh()                               # all local devices

Device-count errors point at the CPU multi-device fallback
(``compat.request_cpu_devices`` / XLA_FLAGS) instead of XLA's opaque
reshape failure.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple, Union

import jax

from . import compat

AxesSpec = Union[Dict[str, int], Sequence[int]]


def _normalize_axes(axes: AxesSpec, names: Optional[Sequence[str]]):
    if isinstance(axes, dict):
        return tuple(axes.values()), tuple(axes.keys())
    axes = tuple(axes)
    if names is not None:
        return axes, tuple(names)
    if axes and isinstance(axes[0], (tuple, list)):  # [("data", 2), ...]
        return tuple(int(s) for _, s in axes), tuple(a for a, _ in axes)
    raise TypeError(
        "make_mesh expects a {name: size} dict, (shape, names), or a "
        f"sequence of (name, size) pairs; got {axes!r}")


def make_mesh(axes: AxesSpec, names: Optional[Sequence[str]] = None, *,
              backend: Optional[str] = None, devices=None):
    """Build a Mesh on any supported JAX, with readable capacity errors."""
    shape, axis_names = _normalize_axes(axes, names)
    needed = math.prod(shape)
    if devices is None:
        devices = jax.devices(backend) if backend is not None else None
    avail = len(devices) if devices is not None else len(jax.devices())
    if needed > avail:
        raise RuntimeError(
            f"mesh {dict(zip(axis_names, shape))} needs {needed} devices "
            f"but only {avail} are visible"
            + (f" on backend {backend!r}" if backend else "")
            + "; for CPU tests set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={needed} before the "
            "first device query (repro.runtime.compat.request_cpu_devices)")
    if devices is not None:
        devices = list(devices)[:needed]
    return compat.make_mesh(shape, axis_names, devices=devices)


def _default_pod_count() -> int:
    """Pod axis = process granularity.  Single-process keeps the legacy
    2-pod dry-run grid (512 fake devices); in a real multi-process
    cluster the pod axis matches ``jax.process_count()``."""
    from . import cluster

    n = cluster.pod_count()
    return n if n > 1 else 2


def make_production_mesh(*, multi_pod: bool = False,
                         pods: Optional[int] = None,
                         grid: Tuple[int, int] = (16, 16),
                         backend: Optional[str] = None):
    """16x16 = 256 chips/pod; ``multi_pod`` adds a leading pod axis.

    The pod axis is derived from the process count (one pod per
    process; the old hard-coded 2 survives only as the single-process
    dry-run default) — override with ``pods``.  ``grid`` shrinks the
    per-pod chip grid for tests.
    """
    rows, cols = grid
    if multi_pod:
        if pods is None:
            pods = _default_pod_count()
        return make_mesh({"pod": int(pods), "data": rows, "model": cols},
                         backend=backend)
    return make_mesh({"data": rows, "model": cols}, backend=backend)


def make_cluster_mesh(axis_names: Tuple[str, ...] = ("pod", "data", "model"),
                      *, backend: Optional[str] = None):
    """Process-spanning mesh: pod axis = process granularity.

    Devices are ordered by ``(process_index, id)`` so each pod's block
    is exactly one process's addressable devices — shard placement along
    the pod axis never needs cross-process transfers at setup.  Within a
    pod the local devices form a near-square (data, model) grid.  Falls
    back to a 1-pod mesh over the local devices when single-process, so
    callers need no separate code path.
    """
    devs = sorted(jax.devices(backend) if backend else jax.devices(),
                  key=lambda d: (d.process_index, d.id))
    pods = max(1, getattr(jax, "process_count", lambda: 1)())
    per_pod = len(devs) // pods
    if per_pod * pods != len(devs):
        raise RuntimeError(
            f"{len(devs)} global devices do not divide into {pods} pods; "
            "heterogeneous pods are not supported")
    rows = max(1, per_pod // 2)
    while per_pod % rows:
        rows -= 1
    cols = per_pod // rows
    return make_mesh((pods, rows, cols), axis_names, devices=devs)


def make_local_mesh(axis_names: Tuple[str, str] = ("data", "model"), *,
                    backend: Optional[str] = None):
    """Near-square 2-D mesh over all visible devices (local solves)."""
    n_dev = len(jax.devices(backend) if backend else jax.devices())
    rows = max(1, n_dev // 2)
    while n_dev % rows:
        rows -= 1
    cols = n_dev // rows
    return make_mesh((rows, cols), axis_names, backend=backend)
