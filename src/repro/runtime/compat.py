"""Version-adaptive JAX compatibility shims (0.4.x <-> >=0.6 APIs).

The sharding surface moved a lot between JAX 0.4.x and the explicit-
sharding releases: ``jax.sharding.AxisType``, ``get_abstract_mesh``,
``set_mesh``/``use_mesh`` and top-level ``jax.shard_map`` only exist on
newer versions, while ``jax.experimental.shard_map`` (with ``check_rep``
instead of ``check_vma``) only exists on older ones.  Every feature is
detected once at import; callers use the functions below and never touch
``jax.sharding`` attributes that may be absent.

On old JAX the "ambient mesh" (what ``get_abstract_mesh`` returns on new
JAX) is emulated with a thread-local set by ``set_mesh``/``use_mesh``,
falling back to the legacy ``with mesh:`` context if one is active.
"""
from __future__ import annotations

import contextlib
import inspect
import os
import re
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# --------------------------------------------------------- feature flags ---

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
HAS_GET_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")
HAS_SET_MESH = hasattr(jax.sharding, "set_mesh")
HAS_USE_MESH = hasattr(jax.sharding, "use_mesh")
HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")
HAS_MAKE_MESH = hasattr(jax, "make_mesh")  # added in jax 0.4.35
_MAKE_MESH_TAKES_AXIS_TYPES = HAS_MAKE_MESH and (
    "axis_types" in inspect.signature(jax.make_mesh).parameters
)


def jax_version() -> Tuple[int, ...]:
    return tuple(int(p) for p in jax.__version__.split(".")[:3] if p.isdigit())


def axis_type_auto():
    """``AxisType.Auto`` on explicit-sharding JAX; None on 0.4.x."""
    return jax.sharding.AxisType.Auto if HAS_AXIS_TYPE else None


# ------------------------------------------------------------------ mesh ---

class _MeshState(threading.local):
    def __init__(self):
        self.mesh = None


_STATE = _MeshState()


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None, axis_types=None):
    """``jax.make_mesh`` that tolerates the missing ``axis_types`` kwarg.

    Pre-0.4.35 JAX has no ``jax.make_mesh`` at all; there the mesh is
    assembled directly from ``mesh_utils.create_device_mesh``.
    """
    shape, names = tuple(axis_shapes), tuple(axis_names)
    if not HAS_MAKE_MESH:
        import math

        from jax.experimental import mesh_utils

        if devices is None:
            devices = jax.devices()[:math.prod(shape)]
        grid = mesh_utils.create_device_mesh(shape, devices=list(devices))
        return jax.sharding.Mesh(grid, names)
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _MAKE_MESH_TAKES_AXIS_TYPES:
        if axis_types is None and HAS_AXIS_TYPE:
            axis_types = (axis_type_auto(),) * len(names)
        if axis_types is not None:
            kwargs["axis_types"] = axis_types
    return jax.make_mesh(shape, names, **kwargs)


def get_abstract_mesh():
    """The ambient mesh: AbstractMesh on new JAX, Mesh (or None) on old.

    Returned objects always expose ``.axis_names`` and ``.empty``; callers
    must treat both None and ``.empty`` as "no mesh".
    """
    if HAS_GET_ABSTRACT_MESH:
        return jax.sharding.get_abstract_mesh()
    if _STATE.mesh is not None:
        return _STATE.mesh
    try:  # legacy `with mesh:` context, if someone opened one
        from jax._src import mesh as _mesh_internal
        pm = _mesh_internal.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return pm
    except Exception:  # noqa: BLE001 - internals may move; absence is fine
        pass
    return None


def set_mesh(mesh) -> None:
    """Install ``mesh`` as the ambient mesh (process-wide intent)."""
    if HAS_SET_MESH:
        jax.sharding.set_mesh(mesh)
    else:
        _STATE.mesh = mesh


@contextlib.contextmanager
def use_mesh(mesh):
    """Scoped ambient mesh (restores the previous one on exit)."""
    if HAS_USE_MESH:
        with jax.sharding.use_mesh(mesh):
            yield mesh
    else:
        prev, _STATE.mesh = _STATE.mesh, mesh
        try:
            yield mesh
        finally:
            _STATE.mesh = prev


def mesh_axis_names() -> Tuple[str, ...]:
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return ()
    return tuple(mesh.axis_names)


# ------------------------------------------------- sharding annotations ---

def clean_spec(spec, names) -> P:
    """Drop spec axes absent from ``names`` (e.g. 'pod' on single-pod)."""
    clean = []
    for s in spec:
        if s is None:
            clean.append(None)
        elif isinstance(s, tuple):
            t = tuple(a for a in s if a in names)
            clean.append(t if t else None)
        else:
            clean.append(s if s in names else None)
    return P(*clean)


def constrain(x, *spec):
    """with_sharding_constraint that no-ops without an ambient mesh.

    Axes absent from the mesh are dropped; non-divisible dims are padded
    internally by GSPMD (e.g. 40 heads on a 16-way axis).  On old JAX the
    ambient mesh is concrete, so the spec is resolved to a NamedSharding
    (bare PartitionSpecs need mesh-context machinery 0.4.x lacks).
    """
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    pspec = clean_spec(spec, mesh.axis_names)
    if isinstance(mesh, jax.sharding.Mesh):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))
    return jax.lax.with_sharding_constraint(x, pspec)


def batch_axes() -> Tuple[str, ...]:
    """The data-parallel axes present on the ambient mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh_axis_names())


# ------------------------------------------------------------ shard_map ---

def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Top-level ``jax.shard_map`` or the 0.4.x experimental fallback.

    ``check_vma`` (new name) maps onto ``check_rep`` (old name).
    """
    if HAS_TOPLEVEL_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


# -------------------------------------------------- CPU device fan-out ---

def request_cpu_devices(n: int) -> bool:
    """Ask XLA for ``n`` host-platform (CPU) devices.

    Must run before the first device query in the process (the flag is
    read at backend initialization).  Returns False when the backend is
    already up, in which case the caller should re-exec in a subprocess.
    """
    flag = f"--xla_force_host_platform_device_count={n}"
    cur = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in cur:
        # rewrite a pre-existing (possibly different) count in place
        cur = re.sub(r"--xla_force_host_platform_device_count=\d+",
                     flag, cur)
        os.environ["XLA_FLAGS"] = cur
    else:
        os.environ["XLA_FLAGS"] = (cur + " " + flag).strip()
    try:
        from jax._src import xla_bridge
        return not xla_bridge.backends_are_initialized()
    except Exception:  # noqa: BLE001 - optimistically assume it took effect
        return True
