"""Multi-host serving: cluster init, per-pod bucket routing, gather.

The paper's headline configuration is *distributed* in-memory PDHG —
crossbars tiled across many chips/pods.  ``runtime.batch`` already
serves heterogeneous LP streams bucketed and data-parallel inside one
process; this module is the step to the multi-process posture:

  * ``init_cluster`` wraps ``jax.distributed.initialize`` behind
    env-driven auto-detection (``REPRO_COORDINATOR`` /
    ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID``) with a
    single-process fallback, so every existing entry point keeps
    working unchanged when the env names no cluster.
  * ``route_buckets`` assigns shape buckets to pods with a
    deterministic cost model — padded FLOPs per MVM x queue depth
    (padded batch) — via longest-processing-time greedy placement.
    Every pod computes the SAME routing table from the same stream, so
    no coordination round is needed to agree on who serves what.
  * ``ClusterBatchSolver`` extends ``BatchSolver.solve_stream``: each
    pod compiles and serves only its routed buckets; results cross
    pods through a shared-filesystem transport whose writes are the
    atomic-rename snapshots of ``distributed.fault`` (a torn write is
    never observed); collection is completion-order (whichever pod's
    bucket lands first is consumed first).  A straggler policy reroutes
    a dead/slow pod's pending buckets — read back from the routing
    manifest snapshot — onto the coordinator, so a killed worker never
    stalls the stream and (keys being derived from global stream
    positions) the rerouted results are bitwise-identical to the ones
    the worker would have produced.

Per-instance PRNG keys depend only on ``opts.seed`` and the instance's
global position in the stream, and bucket membership/padded batch are
routing-independent — therefore a routed stream is bitwise-identical to
the single-process ``BatchSolver.solve_stream`` at ``sigma_read=0``
(and, in fact, at any sigma: the noise streams are keyed, not timed).

Real multi-host CI being unavailable, ``tests/_cluster_harness.py``
spawns coordinator+worker processes over localhost against this module.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from ..distributed.fault import SolverCheckpoint, load_checkpoint, \
    save_checkpoint
from .batch import BatchSolver, nnz_bucket  # noqa: F401  (re-export)

# env vars describing the cluster (REPRO_* preferred; the JAX_* spellings
# some launchers export are honored as fallbacks)
ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"
_FALLBACK_ENV = {
    ENV_COORDINATOR: "JAX_COORDINATOR_ADDRESS",
    ENV_NUM_PROCESSES: "JAX_NUM_PROCESSES",
    ENV_PROCESS_ID: "JAX_PROCESS_ID",
}

BucketKey = Tuple[Tuple[int, int], Optional[int]]


# ---------------------------------------------------------------- init ---

@dataclasses.dataclass(frozen=True)
class ClusterInfo:
    """What ``init_cluster`` resolved: the process's place in the pod grid."""

    num_processes: int
    process_id: int
    coordinator: Optional[str]
    initialized: bool          # jax.distributed.initialize ran (this call
    #                            or a previous one in this process)

    @property
    def is_multiprocess(self) -> bool:
        return self.num_processes > 1

    @property
    def is_coordinator(self) -> bool:
        return self.process_id == 0


_INFO: Optional[ClusterInfo] = None


def _env(name: str) -> Optional[str]:
    v = os.environ.get(name)
    if v is None:
        v = os.environ.get(_FALLBACK_ENV.get(name, ""), None)
    return v


def detect_env() -> Optional[Dict[str, object]]:
    """The cluster the environment describes, or None (single process).

    A cluster needs all three of coordinator address, process count > 1
    and this process's id; anything partial is treated as "no cluster"
    (the single-process fallback) rather than an error, so plain local
    runs never trip on stray variables.
    """
    coord = _env(ENV_COORDINATOR)
    n = _env(ENV_NUM_PROCESSES)
    pid = _env(ENV_PROCESS_ID)
    if not coord or n is None or pid is None:
        return None
    try:
        n_i, pid_i = int(n), int(pid)
    except ValueError:           # stray/typo'd vars: no cluster, no crash
        return None
    if n_i <= 1:
        return None
    return {"coordinator_address": coord, "num_processes": n_i,
            "process_id": pid_i}


def init_cluster(mode: str = "auto") -> ClusterInfo:
    """Idempotent cluster bring-up with a single-process fallback.

    ``mode="auto"`` initializes ``jax.distributed`` iff the environment
    describes a multi-process cluster (``detect_env``); ``mode="off"``
    never initializes and reports a 1-process cluster regardless of env.
    Safe to call from every entry point — repeat calls return the first
    resolution.
    """
    global _INFO
    if mode not in ("auto", "off"):
        raise ValueError(f"init_cluster mode must be auto|off, got {mode!r}")
    if _INFO is not None:
        return _INFO
    if mode == "off":
        _INFO = ClusterInfo(1, 0, None, False)
        return _INFO
    spec = detect_env()
    if spec is None:
        # fallback: maybe someone else initialized jax.distributed
        n = jax.process_count()
        _INFO = ClusterInfo(n, jax.process_index(), None, n > 1)
        return _INFO
    jax.distributed.initialize(**spec)
    _INFO = ClusterInfo(int(spec["num_processes"]), int(spec["process_id"]),
                        str(spec["coordinator_address"]), True)
    return _INFO


def current_info() -> Optional[ClusterInfo]:
    return _INFO


def pod_count() -> int:
    """Pod axis granularity = process granularity (1 when single-process)."""
    if _INFO is not None:
        return max(1, _INFO.num_processes)
    return max(1, jax.process_count())


def pod_id() -> int:
    if _INFO is not None:
        return _INFO.process_id
    return jax.process_index()


def _reset_for_tests() -> None:
    global _INFO
    _INFO = None


# ------------------------------------------------------------- routing ---

def bucket_tag(key: BucketKey) -> str:
    """Stable string id of a bucket key (filenames, routing tables)."""
    (mb, nb), sig = key
    if sig is None:
        kind = "dense"
    elif isinstance(sig, tuple):            # ("ell", wf, wa)
        kind = f"ell{sig[1]}x{sig[2]}"
    else:                                   # bare int nnz bucket
        kind = f"nnz{sig}"
    return f"{mb}x{nb}-{kind}"


def bucket_cost(key: BucketKey, queue_depth: int) -> int:
    """Deterministic serving cost: padded FLOPs per MVM x queue depth.

    Dense buckets move 2*mb*nb FLOPs per MVM; COO sparse buckets
    2*nnz_bucket (scatter contractions touch stored entries only); ELL
    buckets mb*wf + nb*wa (the two gather contractions of one fwd+adj
    MVM pair, padding slots included).  ``queue_depth`` is the padded
    batch the executable will actually run — filler slots cost real
    FLOPs, so they count.
    """
    (mb, nb), sig = key
    if sig is None:
        flops_per_mvm = 2 * mb * nb
    elif isinstance(sig, tuple):            # ("ell", wf, wa)
        flops_per_mvm = mb * sig[1] + nb * sig[2]
    else:                                   # bare int nnz bucket
        flops_per_mvm = 2 * sig
    return int(flops_per_mvm) * int(queue_depth)


def route_buckets(costs: Mapping[BucketKey, int],
                  n_pods: int) -> Dict[BucketKey, int]:
    """LPT greedy assignment of buckets to pods, fully deterministic.

    Buckets sorted by (cost desc, tag asc) go to the least-loaded pod
    (ties -> lowest pod id).  Pure function of (costs, n_pods): every
    process derives the identical table with zero communication.
    """
    n_pods = max(1, int(n_pods))
    loads = [0] * n_pods
    routing: Dict[BucketKey, int] = {}
    for key in sorted(costs, key=lambda k: (-costs[k], bucket_tag(k))):
        pod = min(range(n_pods), key=lambda p: (loads[p], p))
        routing[key] = pod
        loads[pod] += costs[key]
    return routing


# ----------------------------------------------------------- transport ---

class DirectoryTransport:
    """Shared-filesystem result plane for routed streams.

    Every write goes through ``distributed.fault.save_checkpoint`` —
    write-to-temp + atomic rename — so a reader either sees a complete
    snapshot or nothing; a pod crashing mid-publish leaves at most a
    torn ``*.tmp`` that no reader ever opens.  One subdirectory per
    stream keeps repeat ``solve_stream`` calls on a warm solver from
    colliding.  Works for localhost harnesses and for any shared mount
    (NFS/GCS-fuse) in a real pod deployment.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    # -- paths --------------------------------------------------------
    def _stream_dir(self, stream: int) -> str:
        d = os.path.join(self.root, f"stream{stream:05d}")
        os.makedirs(d, exist_ok=True)
        return d

    def _bucket_path(self, stream: int, tag: str) -> str:
        return os.path.join(self._stream_dir(stream), f"bucket_{tag}.npz")

    def _manifest_path(self, stream: int) -> str:
        return os.path.join(self._stream_dir(stream), "manifest.npz")

    # -- manifest (routing snapshot) ----------------------------------
    def publish_manifest(self, stream: int, routing: Mapping[BucketKey, int],
                         meta: Optional[dict] = None) -> str:
        table = {bucket_tag(k): int(p) for k, p in routing.items()}
        return save_checkpoint(self._manifest_path(stream), stream, {},
                               {"routing": table, **(meta or {})})

    def fetch_manifest(self, stream: int) -> Optional[SolverCheckpoint]:
        path = self._manifest_path(stream)
        if not os.path.exists(path):
            return None
        return load_checkpoint(path)

    # -- bucket results -----------------------------------------------
    def publish_bucket(self, stream: int, tag: str, pod: int,
                       arrays: Mapping[str, np.ndarray],
                       meta: Optional[dict] = None) -> str:
        return save_checkpoint(
            self._bucket_path(stream, tag), stream, dict(arrays),
            {"pod": int(pod), "tag": tag, **(meta or {})})

    def try_fetch_bucket(self, stream: int,
                         tag: str) -> Optional[SolverCheckpoint]:
        path = self._bucket_path(stream, tag)
        if not os.path.exists(path):
            return None
        try:
            return load_checkpoint(path)
        except Exception:       # mid-rename on non-atomic mounts: retry later
            return None

    def pending_from_manifest(self, stream: int,
                              pods: Sequence[int]) -> List[str]:
        """Bucket tags routed to ``pods`` with no published result yet —
        the reroute worklist, read back from the fault.py snapshot."""
        ck = self.fetch_manifest(stream)
        if ck is None:
            return []
        return [tag for tag, pod in sorted(ck.meta["routing"].items())
                if pod in pods
                and not os.path.exists(self._bucket_path(stream, tag))]


# ------------------------------------------------------ cluster solver ---

class StragglerTimeout(RuntimeError):
    """A remote pod's buckets never arrived and this pod may not reroute."""


class ClusterBatchSolver(BatchSolver):
    """Per-pod bucket routing on top of the bucketed stream scheduler.

    Every pod runs the same ``solve_stream`` over the same stream:
    bucket grouping and the routing table are deterministic, so each pod
    independently serves exactly its routed buckets (compiling only
    those executables) and publishes per-bucket outputs through
    ``transport``.  Remote buckets are gathered completion-order; after
    ``straggler_timeout`` seconds (or immediately for *virtual* pods —
    routing targets beyond ``live_pods``, used to exercise routing
    single-process), the coordinator reroutes pending buckets onto
    itself and publishes them, so survivors still converge to the full
    result list.  Instance PRNG keys derive from global stream
    positions, making routed results bitwise-identical to the
    single-process path.
    """

    def __init__(self, *args, pod: Optional[int] = None,
                 n_pods: Optional[int] = None,
                 live_pods: Optional[int] = None,
                 transport: Optional[DirectoryTransport] = None,
                 straggler_timeout: float = 60.0,
                 gather_timeout: Optional[float] = None,
                 poll_interval: float = 0.05, **kwargs):
        super().__init__(*args, **kwargs)
        self.pod = pod_id() if pod is None else int(pod)
        self.n_pods = max(1, pod_count() if n_pods is None else int(n_pods))
        self.live_pods = max(1, (pod_count() if n_pods is None else
                                 min(self.n_pods, pod_count()))
                             if live_pods is None else int(live_pods))
        self._owns_transport = False
        if transport is None and self.n_pods > 1:
            tdir = os.environ.get("REPRO_TRANSPORT_DIR")
            if tdir:
                transport = DirectoryTransport(tdir)
            elif pod_count() > 1:
                # a private mkdtemp per process would mean pods silently
                # never see each other's results — fail loudly instead
                raise RuntimeError(
                    "multi-process cluster serving needs a SHARED result "
                    "plane: set REPRO_TRANSPORT_DIR to a directory every "
                    "pod can reach, or pass transport= explicitly")
            else:
                # single-process virtual pods: private scratch, cleaned
                # up per stream (nobody else ever reads it)
                transport = DirectoryTransport(
                    tempfile.mkdtemp(prefix="repro-cluster-"))
                self._owns_transport = True
        self.transport = transport
        self.straggler_timeout = float(straggler_timeout)
        self.gather_timeout = (4.0 * self.straggler_timeout
                               if gather_timeout is None
                               else float(gather_timeout))
        self.poll_interval = float(poll_interval)
        self.stream_seq = 0          # per-solver stream counter; every pod
        #                              sees the same call sequence
        self.last_routing: Dict[str, int] = {}
        self.last_costs: Dict[str, int] = {}
        self.last_bucket_sizes: Dict[str, int] = {}

    # -- routing ------------------------------------------------------

    def _route(self, buckets):
        # audit surface: the table/costs/sizes the routing actually used
        # (benchmarks and dashboards read these instead of re-deriving)
        self.last_costs = {bucket_tag(k): bucket_cost(
            k, self._padded_batch(len(idxs)))
            for k, idxs in buckets.items()}
        self.last_bucket_sizes = {bucket_tag(k): len(idxs)
                                  for k, idxs in buckets.items()}
        if self.n_pods == 1:
            self.last_routing = {bucket_tag(k): 0 for k in buckets}
            return dict(buckets), {}
        costs = {k: bucket_cost(k, self._padded_batch(len(idxs)))
                 for k, idxs in buckets.items()}
        routing = route_buckets(costs, self.n_pods)
        self.last_routing = {bucket_tag(k): p for k, p in routing.items()}
        if self.pod == 0:
            # the fault.py snapshot reroutes read pending work from
            self.transport.publish_manifest(
                self.stream_seq, routing,
                {"n_pods": self.n_pods, "live_pods": self.live_pods})
        mine = {k: v for k, v in buckets.items() if routing[k] == self.pod}
        remote = {k: v for k, v in buckets.items() if routing[k] != self.pod}
        self._remote_routing = routing
        return mine, remote

    # -- publish ------------------------------------------------------

    def _bucket_served(self, key: BucketKey, idxs, out) -> None:
        if self.n_pods == 1:
            return
        xs, ys, its, merits = (np.asarray(o) for o in out[:4])
        arrays = {"xs": xs, "ys": ys, "its": its, "merits": merits}
        if len(out) > 4:
            # raw norm estimates (5-tuple pipelines); remote consumers
            # feed them into their own norm-reuse cache on fetch
            arrays["rhos"] = np.asarray(out[4])
        self.transport.publish_bucket(
            self.stream_seq, bucket_tag(key), self.pod, arrays,
            {"idxs": list(int(i) for i in idxs)})

    # -- gather + straggler policy ------------------------------------

    def _reroute_buckets(self, pairs, lps, results, stats):
        """Serve straggler pods' buckets locally and publish them (same
        executables, same global-position keys -> identical outputs).

        Dispatch-then-collect, like the base scheduler: every rerouted
        bucket is submitted before any result is pulled back, so device
        work overlaps host stacking of the later ones.
        """
        dtype = np.dtype(self.opts.dtype)
        outs = []
        for key, idxs in pairs:
            (mb, nb), sig = key
            group = [lps[i] for i in idxs]
            outs.append((key, idxs, self._dispatch_bucket(
                group, idxs, len(lps), mb, nb, sig, dtype, stats)))
        for key, idxs, out in outs:
            jax.block_until_ready(out)
            self._collect(out, key[0], idxs, lps, results)
            self._bucket_served(key, idxs, out)
            stats["rerouted_buckets"] += 1

    def _gather_remote(self, remote, lps, results, stats) -> None:
        stats["routing"] = dict(self.last_routing)
        stats["pod"] = self.pod
        stats["n_pods"] = self.n_pods
        stats["rerouted_buckets"] = stats.get("rerouted_buckets", 0)
        stats["gather_s"] = 0.0
        if not remote:
            return
        t0 = time.perf_counter()
        pending = dict(remote)
        # virtual pods (routing targets with no live process) never
        # publish: the coordinator serves their buckets immediately
        if self.pod == 0:
            virtual = [(k, pending.pop(k)) for k in list(pending)
                       if self._remote_routing[k] >= self.live_pods]
            self._reroute_buckets(virtual, lps, results, stats)
        deadline = time.perf_counter() + self.straggler_timeout
        hard_deadline = time.perf_counter() + self.gather_timeout
        while pending:
            progress = False
            for key in sorted(pending, key=bucket_tag):
                ck = self.transport.try_fetch_bucket(self.stream_seq,
                                                     bucket_tag(key))
                if ck is None:
                    continue
                idxs = pending.pop(key)
                out = (ck.arrays["xs"], ck.arrays["ys"],
                       ck.arrays["its"], ck.arrays["merits"])
                if "rhos" in ck.arrays:     # absent from older pods
                    out = out + (ck.arrays["rhos"],)
                self._collect(out, key[0], idxs, lps, results)
                progress = True
            if progress:
                # a live-but-slow pod that keeps publishing is never a
                # straggler: the reroute deadline measures silence, so
                # its work is not duplicated while it makes progress
                deadline = time.perf_counter() + self.straggler_timeout
            if not pending:
                break
            if self.pod == 0 and time.perf_counter() > deadline:
                # straggler policy: whatever the manifest still shows as
                # unpublished gets rerouted onto the coordinator
                stalled = set(self.transport.pending_from_manifest(
                    self.stream_seq,
                    [p for p in range(self.n_pods) if p != self.pod]))
                hit = [(k, pending.pop(k))
                       for k in sorted(pending, key=bucket_tag)
                       if bucket_tag(k) in stalled]
                self._reroute_buckets(hit, lps, results, stats)
                progress = progress or bool(hit)
            if pending and time.perf_counter() > hard_deadline:
                # reachable even past the straggler deadline (e.g. a
                # bucket file that exists but never becomes readable)
                raise StragglerTimeout(
                    f"pod {self.pod}: buckets "
                    f"{[bucket_tag(k) for k in pending]} never arrived "
                    f"within {self.gather_timeout}s")
            if pending and not progress:
                time.sleep(self.poll_interval)
        stats["gather_s"] = time.perf_counter() - t0

    def solve_stream(self, lps):
        try:
            return super().solve_stream(lps)
        finally:
            if self._owns_transport:
                # private single-process scratch: nobody else ever reads
                # it, so don't let repeat streams accumulate on disk
                import shutil
                shutil.rmtree(self.transport._stream_dir(self.stream_seq),
                              ignore_errors=True)
            self.stream_seq += 1
