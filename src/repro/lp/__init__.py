"""LP problem substrate: containers, generators, ground-truth simplex."""
from .problem import INF, LPProblem, SparseCOO, StandardLP, split_standard_solution
from .generators import (
    TABLE1_SIZES,
    assignment_lp,
    crossbar_sized_lp,
    infeasible_lp,
    netlib_like,
    pagerank_lp,
    random_inequality_lp,
    random_inequality_lp_known,
    random_standard_lp,
    sparse_lp_stream,
    sparse_random_standard_lp,
    SPARSE_STREAM_SHAPES,
    table1_instance,
)
from . import mps, simplex

__all__ = [
    "INF",
    "LPProblem",
    "SparseCOO",
    "StandardLP",
    "split_standard_solution",
    "TABLE1_SIZES",
    "assignment_lp",
    "crossbar_sized_lp",
    "infeasible_lp",
    "netlib_like",
    "pagerank_lp",
    "random_inequality_lp",
    "random_inequality_lp_known",
    "random_standard_lp",
    "sparse_lp_stream",
    "sparse_random_standard_lp",
    "SPARSE_STREAM_SHAPES",
    "table1_instance",
    "simplex",
    "mps",
]
