"""LP problem substrate: containers, generators, ground-truth simplex."""
from .problem import INF, LPProblem, StandardLP, split_standard_solution
from .generators import (
    TABLE1_SIZES,
    assignment_lp,
    crossbar_sized_lp,
    infeasible_lp,
    netlib_like,
    pagerank_lp,
    random_inequality_lp,
    random_inequality_lp_known,
    random_standard_lp,
    table1_instance,
)
from . import mps, simplex

__all__ = [
    "INF",
    "LPProblem",
    "StandardLP",
    "split_standard_solution",
    "TABLE1_SIZES",
    "assignment_lp",
    "crossbar_sized_lp",
    "infeasible_lp",
    "netlib_like",
    "pagerank_lp",
    "random_inequality_lp",
    "random_inequality_lp_known",
    "random_standard_lp",
    "table1_instance",
    "simplex",
    "mps",
]
