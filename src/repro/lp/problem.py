"""LP problem containers and canonicalization.

The paper (Section 2.1) works with the general form

    min c^T x   s.t.  G x >= h,   A x = b,   l <= x_i <= u

and, "upon suitable projection", with the standard form

    min c^T x   s.t.  K x = b,    lb <= x <= ub        (eq. 3 + Alg. 4)

``LPProblem`` holds the general form; ``StandardLP`` the canonical form the
in-memory solver consumes.  Conversion introduces one slack variable per
inequality row (``G x - s = h``, ``s >= 0``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

INF = np.inf


@dataclasses.dataclass
class StandardLP:
    """min c@x  s.t.  K@x = b,  lb <= x <= ub   (host-side, float64)."""

    c: np.ndarray            # (n,)
    K: np.ndarray            # (m, n) dense
    b: np.ndarray            # (m,)
    lb: np.ndarray           # (n,)  may be -inf
    ub: np.ndarray           # (n,)  may be +inf
    # Optional metadata
    name: str = "lp"
    x_opt: Optional[np.ndarray] = None   # known optimal solution, if any
    obj_opt: Optional[float] = None      # known optimal objective, if any

    def __post_init__(self):
        self.c = np.asarray(self.c, dtype=np.float64).reshape(-1)
        self.K = np.asarray(self.K, dtype=np.float64)
        self.b = np.asarray(self.b, dtype=np.float64).reshape(-1)
        m, n = self.K.shape
        if self.lb is None:
            self.lb = np.zeros(n)
        if self.ub is None:
            self.ub = np.full(n, INF)
        self.lb = np.broadcast_to(np.asarray(self.lb, np.float64), (n,)).copy()
        self.ub = np.broadcast_to(np.asarray(self.ub, np.float64), (n,)).copy()
        assert self.c.shape == (n,), (self.c.shape, n)
        assert self.b.shape == (m,), (self.b.shape, m)

    @property
    def shape(self):
        return self.K.shape

    def objective(self, x: np.ndarray) -> float:
        return float(self.c @ x)

    def feasibility_error(self, x: np.ndarray) -> float:
        """Scaled primal feasibility error (matches paper's r_pri)."""
        r = np.linalg.norm(self.K @ x - self.b) / (1.0 + np.linalg.norm(self.b))
        box = np.linalg.norm(np.maximum(self.lb - x, 0.0)) + np.linalg.norm(
            np.maximum(x - self.ub, 0.0)
        )
        return float(r + box)


@dataclasses.dataclass
class LPProblem:
    """General form (paper eq. 1):  min c@x, Gx>=h, Ax=b, l<=x<=u."""

    c: np.ndarray
    G: Optional[np.ndarray] = None   # (m1, n)
    h: Optional[np.ndarray] = None   # (m1,)
    A: Optional[np.ndarray] = None   # (m2, n)
    b: Optional[np.ndarray] = None   # (m2,)
    lb: Optional[np.ndarray] = None
    ub: Optional[np.ndarray] = None
    name: str = "lp"

    def __post_init__(self):
        self.c = np.asarray(self.c, np.float64).reshape(-1)
        n = self.c.shape[0]
        if self.G is None:
            self.G = np.zeros((0, n))
            self.h = np.zeros((0,))
        if self.A is None:
            self.A = np.zeros((0, n))
            self.b = np.zeros((0,))
        self.G = np.asarray(self.G, np.float64)
        self.h = np.asarray(self.h, np.float64).reshape(-1)
        self.A = np.asarray(self.A, np.float64)
        self.b = np.asarray(self.b, np.float64).reshape(-1)
        if self.lb is None:
            self.lb = np.full(n, -INF)
        if self.ub is None:
            self.ub = np.full(n, INF)
        self.lb = np.broadcast_to(np.asarray(self.lb, np.float64), (n,)).copy()
        self.ub = np.broadcast_to(np.asarray(self.ub, np.float64), (n,)).copy()

    @property
    def n(self) -> int:
        return self.c.shape[0]

    @property
    def m1(self) -> int:
        return self.G.shape[0]

    @property
    def m2(self) -> int:
        return self.A.shape[0]

    def saddle_data(self):
        """K = [G; A], q = [h; b] for the saddle problem (eq. 2)."""
        K = np.concatenate([self.G, self.A], axis=0)
        q = np.concatenate([self.h, self.b], axis=0)
        return K, q, self.m1, self.m2

    def to_standard(self) -> StandardLP:
        """Equality-only canonical form: add slacks s>=0 for Gx - s = h."""
        n, m1, m2 = self.n, self.m1, self.m2
        K = np.zeros((m1 + m2, n + m1))
        K[:m1, :n] = self.G
        K[:m1, n:] = -np.eye(m1)
        K[m1:, :n] = self.A
        b = np.concatenate([self.h, self.b])
        c = np.concatenate([self.c, np.zeros(m1)])
        lb = np.concatenate([self.lb, np.zeros(m1)])
        ub = np.concatenate([self.ub, np.full(m1, INF)])
        return StandardLP(c=c, K=K, b=b, lb=lb, ub=ub, name=self.name)


def split_standard_solution(lp: LPProblem, x_std: np.ndarray) -> np.ndarray:
    """Drop slack coordinates of a standard-form solution."""
    return np.asarray(x_std)[: lp.n]
