"""LP problem containers and canonicalization.

The paper (Section 2.1) works with the general form

    min c^T x   s.t.  G x >= h,   A x = b,   l <= x_i <= u

and, "upon suitable projection", with the standard form

    min c^T x   s.t.  K x = b,    lb <= x <= ub        (eq. 3 + Alg. 4)

``LPProblem`` holds the general form; ``StandardLP`` the canonical form the
in-memory solver consumes.  Conversion introduces one slack variable per
inequality row (``G x - s = h``, ``s >= 0``).

``StandardLP.K`` may be either a dense ndarray or a host-side
``SparseCOO`` — the paper's headline workloads are large sparse LPs, and
carrying the nonzeros explicitly lets the batch scheduler pad, stack and
solve them without ever materializing an (m, n) dense matrix.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

INF = np.inf


class SparseCOO:
    """Host-side COO sparse matrix (data/row/col triplet + shape).

    Deliberately dependency-free (no scipy in the tier-1 environment) and
    minimal: exactly the surface the LP containers and the batch
    scheduler need — matvec (``@``), transpose view (``.T``), dtype
    casts, shape-growing pads, and densification on demand.  Duplicate
    indices are allowed and sum (the scatter-add convention of
    ``jax.experimental.sparse.BCOO``).
    """

    __slots__ = ("data", "row", "col", "shape")

    def __init__(self, data, row, col, shape: Tuple[int, int]):
        self.data = np.asarray(data).reshape(-1)
        if not np.issubdtype(self.data.dtype, np.floating):
            self.data = self.data.astype(np.float64)
        self.row = np.asarray(row, np.int32).reshape(-1)
        self.col = np.asarray(col, np.int32).reshape(-1)
        self.shape = (int(shape[0]), int(shape[1]))
        assert self.data.shape == self.row.shape == self.col.shape
        if self.data.size:
            assert int(self.row.max()) < self.shape[0], "row index out of range"
            assert int(self.col.max()) < self.shape[1], "col index out of range"

    # -- constructors --------------------------------------------------

    @classmethod
    def from_dense(cls, K) -> "SparseCOO":
        K = np.asarray(K)
        row, col = np.nonzero(K)
        return cls(K[row, col], row, col, K.shape)

    # -- properties ----------------------------------------------------

    @property
    def nnz(self) -> int:
        return int(self.data.size)

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def density(self) -> float:
        m, n = self.shape
        return self.nnz / max(m * n, 1)

    @property
    def T(self) -> "SparseCOO":
        return SparseCOO(self.data, self.col, self.row,
                         (self.shape[1], self.shape[0]))

    # -- ops -----------------------------------------------------------

    def __matmul__(self, x):
        x = np.asarray(x)
        assert x.ndim == 1 and x.shape[0] == self.shape[1], \
            (x.shape, self.shape)
        out = np.zeros(self.shape[0], np.result_type(self.dtype, x.dtype))
        np.add.at(out, self.row, self.data * x[self.col])
        return out

    def astype(self, dtype) -> "SparseCOO":
        return SparseCOO(self.data.astype(dtype), self.row, self.col,
                         self.shape)

    def with_shape(self, m: int, n: int) -> "SparseCOO":
        """Grow the logical shape (zero padding) without touching data."""
        assert m >= self.shape[0] and n >= self.shape[1], \
            (self.shape, (m, n))
        return SparseCOO(self.data, self.row, self.col, (m, n))

    def toarray(self) -> np.ndarray:
        out = np.zeros(self.shape, self.dtype)
        np.add.at(out, (self.row, self.col), self.data)
        return out

    def coalesce(self) -> "SparseCOO":
        """Sum duplicate (row, col) entries into one.  The batch
        pipeline's scatter preconditioners reduce over STORED entries,
        so duplicates must be merged before stacking for sparse/dense
        parity to hold."""
        flat = self.row.astype(np.int64) * self.shape[1] + self.col
        uniq, inv = np.unique(flat, return_inverse=True)
        if uniq.size == self.data.size:
            return self
        data = np.zeros(uniq.size, self.dtype)
        np.add.at(data, inv, self.data)
        row, col = np.divmod(uniq, self.shape[1])
        return SparseCOO(data, row, col, self.shape)

    def __repr__(self):
        return (f"SparseCOO(shape={self.shape}, nnz={self.nnz}, "
                f"dtype={self.dtype.name})")


@dataclasses.dataclass
class StandardLP:
    """min c@x  s.t.  K@x = b,  lb <= x <= ub   (host-side).

    ``K`` is either a dense ndarray or a ``SparseCOO``; the floating
    dtype of ``K`` is preserved (f32 streams stay f32 end-to-end — no
    silent f64 promotion) and the vector data follows it.  Non-floating
    input defaults to float64.
    """

    c: np.ndarray            # (n,)
    K: object                # (m, n) dense ndarray | SparseCOO
    b: np.ndarray            # (m,)
    lb: np.ndarray           # (n,)  may be -inf
    ub: np.ndarray           # (n,)  may be +inf
    # Optional metadata
    name: str = "lp"
    x_opt: Optional[np.ndarray] = None   # known optimal solution, if any
    obj_opt: Optional[float] = None      # known optimal objective, if any

    def __post_init__(self):
        if not isinstance(self.K, SparseCOO):
            self.K = np.asarray(self.K)
            if not np.issubdtype(self.K.dtype, np.floating):
                self.K = self.K.astype(np.float64)
        dt = self.K.dtype
        self.c = np.asarray(self.c, dtype=dt).reshape(-1)
        self.b = np.asarray(self.b, dtype=dt).reshape(-1)
        m, n = self.K.shape
        if self.lb is None:
            self.lb = np.zeros(n, dt)
        if self.ub is None:
            self.ub = np.full(n, INF, dt)
        self.lb = np.broadcast_to(np.asarray(self.lb, dt), (n,)).copy()
        self.ub = np.broadcast_to(np.asarray(self.ub, dt), (n,)).copy()
        assert self.c.shape == (n,), (self.c.shape, n)
        assert self.b.shape == (m,), (self.b.shape, m)

    @property
    def shape(self):
        return self.K.shape

    @property
    def is_sparse(self) -> bool:
        return isinstance(self.K, SparseCOO)

    @property
    def K_dense(self) -> np.ndarray:
        """Dense view of K for paths that need the full matrix (e.g.
        crossbar programming, which burns every physical cell anyway)."""
        return self.K.toarray() if self.is_sparse else self.K

    def densified(self) -> "StandardLP":
        """Copy with a dense K (identity for already-dense problems)."""
        if not self.is_sparse:
            return self
        return dataclasses.replace(self, K=self.K.toarray())

    def sparsified(self) -> "StandardLP":
        """Copy with a SparseCOO K (identity if already sparse)."""
        if self.is_sparse:
            return self
        return dataclasses.replace(self, K=SparseCOO.from_dense(self.K))

    def objective(self, x: np.ndarray) -> float:
        return float(self.c @ x)

    def feasibility_error(self, x: np.ndarray) -> float:
        """Scaled primal feasibility error (matches paper's r_pri)."""
        r = np.linalg.norm(self.K @ x - self.b) / (1.0 + np.linalg.norm(self.b))
        box = np.linalg.norm(np.maximum(self.lb - x, 0.0)) + np.linalg.norm(
            np.maximum(x - self.ub, 0.0)
        )
        return float(r + box)


@dataclasses.dataclass
class LPProblem:
    """General form (paper eq. 1):  min c@x, Gx>=h, Ax=b, l<=x<=u."""

    c: np.ndarray
    G: Optional[np.ndarray] = None   # (m1, n)
    h: Optional[np.ndarray] = None   # (m1,)
    A: Optional[np.ndarray] = None   # (m2, n)
    b: Optional[np.ndarray] = None   # (m2,)
    lb: Optional[np.ndarray] = None
    ub: Optional[np.ndarray] = None
    name: str = "lp"

    def __post_init__(self):
        self.c = np.asarray(self.c, np.float64).reshape(-1)
        n = self.c.shape[0]
        if self.G is None:
            self.G = np.zeros((0, n))
            self.h = np.zeros((0,))
        if self.A is None:
            self.A = np.zeros((0, n))
            self.b = np.zeros((0,))
        self.G = np.asarray(self.G, np.float64)
        self.h = np.asarray(self.h, np.float64).reshape(-1)
        self.A = np.asarray(self.A, np.float64)
        self.b = np.asarray(self.b, np.float64).reshape(-1)
        if self.lb is None:
            self.lb = np.full(n, -INF)
        if self.ub is None:
            self.ub = np.full(n, INF)
        self.lb = np.broadcast_to(np.asarray(self.lb, np.float64), (n,)).copy()
        self.ub = np.broadcast_to(np.asarray(self.ub, np.float64), (n,)).copy()

    @property
    def n(self) -> int:
        return self.c.shape[0]

    @property
    def m1(self) -> int:
        return self.G.shape[0]

    @property
    def m2(self) -> int:
        return self.A.shape[0]

    def saddle_data(self):
        """K = [G; A], q = [h; b] for the saddle problem (eq. 2)."""
        K = np.concatenate([self.G, self.A], axis=0)
        q = np.concatenate([self.h, self.b], axis=0)
        return K, q, self.m1, self.m2

    def to_standard(self) -> StandardLP:
        """Equality-only canonical form: add slacks s>=0 for Gx - s = h."""
        n, m1, m2 = self.n, self.m1, self.m2
        K = np.zeros((m1 + m2, n + m1))
        K[:m1, :n] = self.G
        K[:m1, n:] = -np.eye(m1)
        K[m1:, :n] = self.A
        b = np.concatenate([self.h, self.b])
        c = np.concatenate([self.c, np.zeros(m1)])
        lb = np.concatenate([self.lb, np.zeros(m1)])
        ub = np.concatenate([self.ub, np.full(m1, INF)])
        return StandardLP(c=c, K=K, b=b, lb=lb, ub=ub, name=self.name)


def split_standard_solution(lp: LPProblem, x_std: np.ndarray) -> np.ndarray:
    """Drop slack coordinates of a standard-form solution."""
    return np.asarray(x_std)[: lp.n]
