"""Minimal fixed/free-format MPS reader + writer.

The paper benchmarks MIPLIB-2017 instances distributed as .mps files; the
container is offline so generated instances stand in (generators.py), but
this reader lets the same pipeline consume the real files when present:

    lp = mps.read("gen-ip002.mps").to_standard()
    core.solve_jit(lp, ...)

Supported sections: NAME, ROWS (N/L/G/E), COLUMNS (incl. integer
markers — integrality is relaxed, matching the paper's use of LP
relaxations), RHS, RANGES, BOUNDS (UP/LO/FX/FR/BV/MI/PL).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from .problem import INF, LPProblem


def read(path: str) -> LPProblem:
    with open(path) as f:
        return parse(f.read(), name=path)


def parse(text: str, name: str = "mps") -> LPProblem:
    section = None
    obj_row = None
    row_sense: Dict[str, str] = {}
    row_order: List[str] = []
    cols: Dict[str, Dict[str, float]] = {}
    col_order: List[str] = []
    rhs: Dict[str, float] = {}
    ranges: Dict[str, float] = {}
    lbs: Dict[str, float] = {}
    ubs: Dict[str, float] = {}
    integer_mode = False

    for raw in text.splitlines():
        if not raw.strip() or raw.lstrip().startswith("*"):
            continue
        if raw[0] not in " \t":
            head = raw.split()
            section = head[0].upper()
            continue
        tok = raw.split()
        if section == "ROWS":
            sense, rname = tok[0].upper(), tok[1]
            if sense == "N":
                if obj_row is None:
                    obj_row = rname
            else:
                row_sense[rname] = sense
                row_order.append(rname)
        elif section == "COLUMNS":
            if len(tok) >= 3 and tok[1].upper() == "'MARKER'":
                integer_mode = tok[2].upper() == "'INTORG'"
                continue
            cname = tok[0]
            if cname not in cols:
                cols[cname] = {}
                col_order.append(cname)
                if integer_mode:
                    # LP relaxation: integer columns default to [0, 1]
                    # only if BOUNDS later says BV; else [0, +inf)
                    pass
            for rname, val in zip(tok[1::2], tok[2::2]):
                cols[cname][rname] = float(val)
        elif section == "RHS":
            for rname, val in zip(tok[1::2], tok[2::2]):
                rhs[rname] = float(val)
        elif section == "RANGES":
            for rname, val in zip(tok[1::2], tok[2::2]):
                ranges[rname] = float(val)
        elif section == "BOUNDS":
            btype, cname = tok[0].upper(), tok[2]
            val = float(tok[3]) if len(tok) > 3 else 0.0
            if btype == "UP":
                ubs[cname] = val
            elif btype == "LO":
                lbs[cname] = val
            elif btype == "FX":
                lbs[cname] = val
                ubs[cname] = val
            elif btype == "FR":
                lbs[cname] = -INF
            elif btype == "MI":
                lbs[cname] = -INF
            elif btype == "BV":
                lbs[cname] = 0.0
                ubs[cname] = 1.0
            elif btype == "PL":
                ubs[cname] = INF

    n = len(col_order)
    cidx = {c: j for j, c in enumerate(col_order)}
    c_vec = np.zeros(n)
    G_rows, h_vals, A_rows, b_vals = [], [], [], []
    for rname in row_order:
        sense = row_sense[rname]
        row = np.zeros(n)
        for cname, vals in cols.items():
            if rname in vals:
                row[cidx[cname]] = vals[rname]
        b = rhs.get(rname, 0.0)
        rng = ranges.get(rname)
        if sense == "G":
            G_rows.append(row)
            h_vals.append(b)
            if rng is not None:
                G_rows.append(-row)
                h_vals.append(-(b + abs(rng)))
        elif sense == "L":
            G_rows.append(-row)
            h_vals.append(-b)
            if rng is not None:
                G_rows.append(row)
                h_vals.append(b - abs(rng))
        else:  # E
            if rng is not None:
                lo, hi = min(b, b + rng), max(b, b + rng)
                G_rows.append(row)
                h_vals.append(lo)
                G_rows.append(-row)
                h_vals.append(-hi)
            else:
                A_rows.append(row)
                b_vals.append(b)
    for cname, vals in cols.items():
        if obj_row in vals:
            c_vec[cidx[cname]] = vals[obj_row]
    lb = np.array([lbs.get(c, 0.0) for c in col_order])
    ub = np.array([ubs.get(c, INF) for c in col_order])
    return LPProblem(
        c=c_vec,
        G=np.array(G_rows) if G_rows else None,
        h=np.array(h_vals) if G_rows else None,
        A=np.array(A_rows) if A_rows else None,
        b=np.array(b_vals) if A_rows else None,
        lb=lb, ub=ub, name=name,
    )


def write(lp: LPProblem, path: str, name: str = "REPRO"):
    """Write the general-form LP as free-format MPS (roundtrip support)."""
    lines = [f"NAME          {name}", "ROWS", " N  OBJ"]
    for i in range(lp.m1):
        lines.append(f" G  R{i}")
    for i in range(lp.m2):
        lines.append(f" E  E{i}")
    lines.append("COLUMNS")
    for j in range(lp.n):
        col = f"X{j}"
        if lp.c[j] != 0.0:
            lines.append(f"    {col}  OBJ  {lp.c[j]:.17g}")
        for i in range(lp.m1):
            if lp.G[i, j] != 0.0:
                lines.append(f"    {col}  R{i}  {lp.G[i, j]:.17g}")
        for i in range(lp.m2):
            if lp.A[i, j] != 0.0:
                lines.append(f"    {col}  E{i}  {lp.A[i, j]:.17g}")
    lines.append("RHS")
    for i in range(lp.m1):
        if lp.h[i] != 0.0:
            lines.append(f"    RHS  R{i}  {lp.h[i]:.17g}")
    for i in range(lp.m2):
        if lp.b[i] != 0.0:
            lines.append(f"    RHS  E{i}  {lp.b[i]:.17g}")
    lines.append("BOUNDS")
    for j in range(lp.n):
        if not np.isfinite(lp.lb[j]):
            lines.append(f" MI BND  X{j}")
        elif lp.lb[j] != 0.0:
            lines.append(f" LO BND  X{j}  {lp.lb[j]:.17g}")
        if np.isfinite(lp.ub[j]):
            lines.append(f" UP BND  X{j}  {lp.ub[j]:.17g}")
    lines.append("ENDATA")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path
