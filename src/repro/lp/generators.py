"""Synthetic LP instance generators.

The paper benchmarks LP relaxations of MIPLIB-2017 instances (Table 1).
MIPLIB binaries are not redistributable/downloadable in this offline
container, so we generate instances with the *same shapes* (m, n) and
comparable conditioning, plus classic families (assignment, PageRank LP
from the PDLP paper) and random instances *with known optimal solutions*
constructed via complementary slackness (exact ground truth without any
external solver).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from .problem import INF, LPProblem, SparseCOO, StandardLP

# (m, n) sizes from paper Table 1.  These drive the benchmark harness.
TABLE1_SIZES: Dict[str, Tuple[int, int]] = {
    "gen-ip002": (24, 41),
    "gen-ip016": (24, 28),
    "gen-ip021": (28, 35),
    "gen-ip036": (46, 29),
    "gen-ip054": (27, 30),
    "neos5": (402, 253),
    "assign1-5-8": (161, 156),
}


def random_standard_lp(
    m: int,
    n: int,
    seed: int = 0,
    density: float = 1.0,
    frac_basic: float | None = None,
    scale: float = 1.0,
) -> StandardLP:
    """Random standard-form LP with a *known* optimal solution.

    Construction (complementary slackness): pick primal ``x*`` with exactly
    ``m`` strictly-positive "basic" entries, pick any dual ``y*``, then set
    ``c = K^T y* + s`` with reduced costs ``s >= 0`` vanishing on the basic
    support.  (x*, y*) is then an optimal primal-dual pair for
    ``min c@x s.t. Kx = K x*, x >= 0``.
    """
    assert n >= m, "standard-form generator needs n >= m"
    rng = np.random.default_rng(seed)
    K = rng.normal(size=(m, n)) * scale
    if density < 1.0:
        mask = rng.random((m, n)) < density
        # keep at least one entry per row/col to avoid degenerate zero rows
        mask[np.arange(m), rng.integers(0, n, m)] = True
        mask[rng.integers(0, m, n), np.arange(n)] = True
        K = K * mask
    n_basic = m if frac_basic is None else max(1, int(round(frac_basic * n)))
    n_basic = min(n_basic, n)
    basic = rng.choice(n, size=n_basic, replace=False)
    x_opt = np.zeros(n)
    x_opt[basic] = rng.uniform(0.5, 2.0, size=n_basic)
    b = K @ x_opt
    y_opt = rng.normal(size=m)
    s = rng.uniform(0.1, 1.0, size=n)
    s[basic] = 0.0
    c = K.T @ y_opt + s
    return StandardLP(
        c=c,
        K=K,
        b=b,
        lb=np.zeros(n),
        ub=np.full(n, INF),
        name=f"rand-{m}x{n}-s{seed}",
        x_opt=x_opt,
        obj_opt=float(c @ x_opt),
    )


def sparse_random_standard_lp(
    m: int,
    n: int,
    density: float = 0.01,
    seed: int = 0,
    scale: float = 1.0,
    dtype=np.float64,
) -> StandardLP:
    """Random sparse standard-form LP with a *known* optimal solution.

    Same complementary-slackness construction as ``random_standard_lp``,
    but K is built DIRECTLY in COO form — positions sampled without ever
    allocating an (m, n) dense array, so paper-scale instances (millions
    of variables at sub-percent density) fit in nonzero-proportional host
    memory.  Coverage guarantee: at least one entry per row and per
    column (no degenerate zero rows/cols).
    """
    assert n >= m, "standard-form generator needs n >= m"
    assert 0.0 < density <= 1.0, density
    rng = np.random.default_rng(seed)
    # one guaranteed entry per row and per column ...
    flat = [rng.integers(0, n, m) + np.arange(m) * n,
            rng.integers(0, m, n) * n + np.arange(n)]
    # ... plus the remaining budget sampled with replacement and deduped
    # (collisions are rare at low density; exact nnz is not contractual)
    target = int(round(density * m * n))
    extra = max(target - m - n, 0)
    if extra:
        flat.append(rng.integers(0, m * n, extra))
    flat = np.unique(np.concatenate(flat))
    row, col = np.divmod(flat, n)
    data = (rng.normal(size=flat.size) * scale).astype(dtype)
    K = SparseCOO(data, row, col, (m, n))
    n_basic = min(m, n)
    basic = rng.choice(n, size=n_basic, replace=False)
    x_opt = np.zeros(n, dtype)
    x_opt[basic] = rng.uniform(0.5, 2.0, size=n_basic)
    b = K @ x_opt
    y_opt = rng.normal(size=m).astype(dtype)
    s = rng.uniform(0.1, 1.0, size=n).astype(dtype)
    s[basic] = 0.0
    c = (K.T @ y_opt) + s
    return StandardLP(
        c=c,
        K=K,
        b=b,
        lb=np.zeros(n, dtype),
        ub=np.full(n, INF, dtype),
        name=f"sprand-{m}x{n}-d{density:g}-s{seed}",
        x_opt=x_opt,
        obj_opt=float(c @ x_opt),
    )


# Paper-scale shapes for sparse stream serving: MIPLIB-2017-class LP
# relaxations run 1e4-1e6 nonzeros at fractions-of-a-percent density;
# these are the bucketable stand-ins the benchmarks cycle through.
SPARSE_STREAM_SHAPES: Tuple[Tuple[int, int], ...] = (
    (96, 192), (128, 256), (80, 160), (112, 224))


def sparse_lp_stream(
    n_instances: int,
    shapes: Sequence[Tuple[int, int]] = SPARSE_STREAM_SHAPES,
    density: float = 0.05,
    seed: int = 0,
    dtype=np.float64,
) -> List[StandardLP]:
    """A mixed-shape stream of sparse LPs at paper-scale densities (all
    with known optima), cycling through ``shapes`` — the sparse twin of
    the dense streams the throughput benchmark builds."""
    lps = []
    for i in range(n_instances):
        m, n = shapes[i % len(shapes)]
        lps.append(sparse_random_standard_lp(
            m, n, density=density, seed=seed + i, dtype=dtype))
    return lps


def table1_instance(name: str, seed: int = 0) -> StandardLP:
    """Instance with the same (m, n) as the named Table-1 problem.

    The MIPLIB originals are MIPs whose LP relaxations have inequality
    rows + box bounds; we generate inequality-form instances of the same
    (m, n) with a KNOWN optimum via primal-dual construction, then
    standardize (m slack columns), exactly the 'suitable projection' of
    paper §2.1.
    """
    m, n = TABLE1_SIZES[name]
    # the two larger MIPLIB instances are sparse (neos5: set-partition-
    # like rows; assign1-5-8: assignment structure, ~2 nz per column)
    density = {"neos5": 0.08, "assign1-5-8": 0.05}.get(name, 1.0)
    lp = random_inequality_lp_known(m, n, seed=seed, name=name,
                                    density=density)
    std = lp.to_standard()
    std.name = name
    # known optimum carries over (slacks don't change the objective)
    std.obj_opt = lp_known_objective(lp)
    return std


def lp_known_objective(lp: LPProblem) -> float:
    return float(getattr(lp, "_obj_opt"))


def random_inequality_lp_known(
    m: int, n: int, seed: int = 0, box: float = 10.0, name: str = "ineq",
    density: float = 1.0,
) -> LPProblem:
    """Inequality-form LP with a KNOWN optimal solution.

    KKT construction for  min c@x  s.t. Gx >= h, 0 <= x <= box:
      * choose x* with coordinates at lb / at ub / interior,
      * choose an active set of rows passing exactly through x*
        (y_i > 0 there), the rest strictly slack (y_i = 0),
      * choose bound multipliers lam_l (at lb) / lam_u (at ub),
      * stationarity fixes  c = G^T y + lam_l - lam_u.
    Complementary slackness holds by construction => x* optimal.
    """
    rng = np.random.default_rng(seed)
    G = rng.normal(size=(m, n))
    if density < 1.0:
        # MIPLIB-class constraint matrices are sparse; keep >=2 nz/row
        mask = rng.random((m, n)) < density
        mask[np.arange(m), rng.integers(0, n, m)] = True
        mask[np.arange(m), rng.integers(0, n, m)] = True
        G = G * mask
    kind = rng.choice(3, size=n, p=[0.3, 0.3, 0.4])  # 0: lb, 1: ub, 2: interior
    x_opt = np.where(
        kind == 0, 0.0, np.where(kind == 1, box, rng.uniform(0.2 * box, 0.8 * box, n))
    )
    n_active = min(m, max(1, n // 2))
    active = rng.choice(m, size=n_active, replace=False)
    Gx = G @ x_opt
    h = Gx - rng.uniform(0.5, 2.0, size=m)      # slack rows by default
    h[active] = Gx[active]                      # active rows tight at x*
    y = np.zeros(m)
    y[active] = rng.uniform(0.1, 1.0, size=n_active)
    lam_l = np.where(kind == 0, rng.uniform(0.1, 1.0, n), 0.0)
    lam_u = np.where(kind == 1, rng.uniform(0.1, 1.0, n), 0.0)
    c = G.T @ y + lam_l - lam_u
    lp = LPProblem(
        c=c, G=G, h=h, lb=np.zeros(n), ub=np.full(n, box), name=name
    )
    lp._x_opt = x_opt
    lp._obj_opt = float(c @ x_opt)
    return lp


def random_inequality_lp(
    m: int, n: int, seed: int = 0, box: float = 10.0, name: str = "ineq"
) -> LPProblem:
    """Feasible-bounded inequality-form LP:  min c@x, Gx >= h, 0<=x<=box.

    Feasibility by construction: pick interior x0 in the box, set
    h = G x0 - margin (margin > 0).  Bounded by the box constraints.
    """
    rng = np.random.default_rng(seed)
    G = rng.normal(size=(m, n))
    x0 = rng.uniform(0.25 * box, 0.75 * box, size=n)
    margin = rng.uniform(0.1, 1.0, size=m)
    h = G @ x0 - margin
    c = rng.normal(size=n)
    return LPProblem(
        c=c, G=G, h=h, lb=np.zeros(n), ub=np.full(n, box), name=name
    )


def assignment_lp(n_agents: int, seed: int = 0) -> StandardLP:
    """Assignment-problem LP (totally unimodular => LP optimum is integral).

    min sum_ij C_ij x_ij  s.t. rows sum to 1, cols sum to 1, x >= 0.
    Ground truth computable exactly by brute force for small n (tests) or
    simplex.  Shape: m = 2*n_agents rows, n = n_agents^2 variables.
    """
    rng = np.random.default_rng(seed)
    n = n_agents
    C = rng.uniform(0.0, 1.0, size=(n, n))
    nv = n * n
    K = np.zeros((2 * n, nv))
    for i in range(n):
        K[i, i * n : (i + 1) * n] = 1.0           # agent i assigned once
        K[n + i, i::n] = 1.0                      # task i assigned once
    b = np.ones(2 * n)
    return StandardLP(
        c=C.reshape(-1),
        K=K,
        b=b,
        lb=np.zeros(nv),
        ub=np.ones(nv),
        name=f"assign-{n}",
    )


def pagerank_lp(n: int, seed: int = 0, damping: float = 0.85, deg: int = 4) -> StandardLP:
    """PageRank as an LP (PDLP paper, §6 'a very large PageRank instance').

    Find x >= 0 with (I - damping * P^T) x = (1-damping)/n * 1 where P is a
    column-stochastic random-graph transition matrix; objective min sum(x)
    (any feasible point is the PageRank vector, unique).
    """
    rng = np.random.default_rng(seed)
    P = np.zeros((n, n))
    for j in range(n):
        outs = rng.choice(n, size=min(deg, n), replace=False)
        P[outs, j] = 1.0 / len(outs)
    K = np.eye(n) - damping * P
    b = np.full(n, (1.0 - damping) / n)
    c = np.ones(n)
    return StandardLP(
        c=c, K=K, b=b, lb=np.zeros(n), ub=np.full(n, INF),
        name=f"pagerank-{n}",
    )


def netlib_like(m: int, n: int, seed: int = 0, cond: float = 1e3) -> StandardLP:
    """Random LP with controlled condition number of K (tests preconditioning).

    K = U diag(logspace) V^T restricted to (m, n); known optimum as in
    random_standard_lp.
    """
    rng = np.random.default_rng(seed)
    k = min(m, n)
    U, _ = np.linalg.qr(rng.normal(size=(m, k)))
    V, _ = np.linalg.qr(rng.normal(size=(n, k)))
    sv = np.logspace(0, np.log10(cond), k)[::-1]
    K = (U * sv) @ V.T
    basic = rng.choice(n, size=m, replace=False)
    x_opt = np.zeros(n)
    x_opt[basic] = rng.uniform(0.5, 2.0, size=m)
    b = K @ x_opt
    y_opt = rng.normal(size=m)
    s = rng.uniform(0.1, 1.0, size=n)
    s[basic] = 0.0
    c = K.T @ y_opt + s
    return StandardLP(
        c=c, K=K, b=b, lb=np.zeros(n), ub=np.full(n, INF),
        name=f"netlib-like-{m}x{n}-c{cond:g}",
        x_opt=x_opt, obj_opt=float(c @ x_opt),
    )


def infeasible_lp(m: int = 8, n: int = 12, seed: int = 0) -> StandardLP:
    """Primal-infeasible instance: contradictory duplicated rows."""
    rng = np.random.default_rng(seed)
    base = random_standard_lp(m - 1, n, seed=seed)
    K = np.concatenate([base.K, base.K[-1:]], axis=0)
    b = np.concatenate([base.b, base.b[-1:] + 1.0])  # same row, different rhs
    return StandardLP(
        c=base.c, K=K, b=b, lb=np.zeros(n), ub=np.full(n, INF),
        name=f"infeasible-{m}x{n}",
    )


def crossbar_sized_lp(seed: int = 0) -> StandardLP:
    """An instance that exactly fills the paper's 256x256 logical crossbar.

    m + n = 256 (M is (m+n) x (m+n)); we use m=96, n=160.
    """
    return random_standard_lp(96, 160, seed=seed)
