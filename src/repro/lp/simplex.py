"""Dense two-phase simplex — the offline stand-in for Gurobi ground truth.

Solves   min c@x  s.t.  K@x = b, x >= 0   (standard form) with Bland's rule
(anti-cycling).  Box-bounded problems are reduced to this form by variable
shifting and upper-bound slack rows.  Intended for the small/medium
benchmark instances (Table 1 sizes); the iterative solvers are the ones
that scale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .problem import INF, StandardLP


@dataclasses.dataclass
class SimplexResult:
    status: str                      # "optimal" | "infeasible" | "unbounded" | "iteration_limit"
    x: Optional[np.ndarray] = None
    obj: Optional[float] = None
    iters: int = 0
    y: Optional[np.ndarray] = None   # dual solution (from final basis)


def _simplex_core(c, K, b, max_iters: int) -> SimplexResult:
    """Revised simplex with explicit basis inverse refresh, Bland's rule.

    Assumes rows of K are linearly independent after Phase 1 cleanup.
    """
    m, n = K.shape
    # Phase 1: artificial variables
    sign = np.where(b < 0, -1.0, 1.0)
    K1 = np.concatenate([K * sign[:, None], np.eye(m)], axis=1)
    b1 = b * sign
    c1 = np.concatenate([np.zeros(n), np.ones(m)])
    basis = list(range(n, n + m))
    res = _primal_iterate(c1, K1, b1, basis, max_iters)
    if res is None:
        return SimplexResult(status="iteration_limit")
    basis, xB, iters1 = res
    phase1_obj = float(c1[basis] @ xB)
    if phase1_obj > 1e-7 * (1.0 + abs(b).sum()):
        return SimplexResult(status="infeasible", iters=iters1)
    # Drive remaining artificials out of the basis where possible
    for pos, j in enumerate(list(basis)):
        if j >= n:
            B = K1[:, basis]
            Binv = np.linalg.pinv(B)
            row = Binv[pos] @ K1[:, :n]
            cand = np.where(np.abs(row) > 1e-9)[0]
            cand = [int(q) for q in cand if q not in basis]
            if cand:
                basis[pos] = cand[0]
    # Phase 2 on original columns (artificials pinned at zero)
    K2 = K1[:, :n].copy()
    # any still-basic artificial has xB == 0: replace col with zero col kept via K1
    basis2 = basis
    use_cols = K1 if any(j >= n for j in basis2) else K2
    c2 = np.concatenate([c, np.full(m, 1e9)]) if use_cols is K1 else c
    res2 = _primal_iterate(c2, use_cols, b1, basis2, max_iters)
    if res2 is None:
        return SimplexResult(status="iteration_limit", iters=iters1)
    basis2, xB2, iters2 = res2
    x = np.zeros(use_cols.shape[1])
    x[basis2] = xB2
    if any(j >= n and x[j] > 1e-7 for j in basis2):
        return SimplexResult(status="infeasible", iters=iters1 + iters2)
    x = x[:n]
    # undo row sign flips is unnecessary for x; duals need sign restore
    B = use_cols[:, basis2]
    yT = np.linalg.solve(B.T, np.asarray(c2)[basis2])
    y = yT * sign
    # check unbounded flag propagated via sentinel
    return SimplexResult(
        status="optimal", x=x, obj=float(c @ x), iters=iters1 + iters2, y=y
    )


def _primal_iterate(c, K, b, basis, max_iters):
    """Primal simplex iterations with Bland's rule.  Returns (basis, xB, it)."""
    m, n = K.shape
    basis = list(basis)
    for it in range(max_iters):
        B = K[:, basis]
        try:
            Binv = np.linalg.inv(B)
        except np.linalg.LinAlgError:
            Binv = np.linalg.pinv(B)
        xB = Binv @ b
        # numerical cleanup
        xB = np.where(np.abs(xB) < 1e-11, 0.0, xB)
        y = np.linalg.solve(B.T, np.asarray(c)[basis]) if True else None
        reduced = c - K.T @ y
        reduced[basis] = 0.0
        entering = -1
        for j in range(n):  # Bland: smallest index with negative reduced cost
            if reduced[j] < -1e-9 and j not in basis:
                entering = j
                break
        if entering < 0:
            return basis, xB, it
        d = Binv @ K[:, entering]
        pos = d > 1e-11
        if not np.any(pos):
            # unbounded below — signal with None basis
            return basis, xB, it  # caller treats huge-cost artificials; fine for bounded gens
        ratios = np.where(pos, xB / np.where(pos, d, 1.0), np.inf)
        leave_pos = int(np.argmin(ratios))
        # Bland tie-break: smallest basis index among ties
        tie = np.where(np.isclose(ratios, ratios[leave_pos], rtol=0, atol=1e-12))[0]
        leave_pos = int(min(tie, key=lambda p: basis[p]))
        basis[leave_pos] = entering
    return None


def solve_standard(c, K, b, max_iters: int = 20000) -> SimplexResult:
    c = np.asarray(c, np.float64)
    K = np.asarray(K, np.float64)
    b = np.asarray(b, np.float64)
    return _simplex_core(c, K, b, max_iters)


def solve(lp: StandardLP, max_iters: int = 20000) -> SimplexResult:
    """Solve a box-bounded StandardLP by reduction to x >= 0 form.

    x = lb + x',  0 <= x' <= ub - lb.  Finite upper bounds add slack rows
    x' + s = ub - lb.  Free variables (lb=-inf) are split x' = x+ - x-.
    """
    c, K, b, lb, ub = lp.c, lp.K, lp.b, lp.lb, lp.ub
    m, n = K.shape
    cols = []          # mapping: list of (kind, idx) per new var
    c_new = []
    K_cols = []
    shift = np.where(np.isfinite(lb), lb, 0.0)
    b_eff = b - K @ shift
    ub_rows = []       # (new_var_index, bound_value)
    for j in range(n):
        if np.isfinite(lb[j]):
            c_new.append(c[j])
            K_cols.append(K[:, j])
            cols.append(("pos", j))
            if np.isfinite(ub[j]):
                ub_rows.append((len(c_new) - 1, ub[j] - lb[j]))
        else:
            # free variable: split
            c_new.extend([c[j], -c[j]])
            K_cols.append(K[:, j])
            K_cols.append(-K[:, j])
            cols.append(("free+", j))
            cols.append(("free-", j))
            if np.isfinite(ub[j]):
                raise NotImplementedError("(-inf, u] bounds not needed here")
    nv = len(c_new)
    K_new = np.stack(K_cols, axis=1) if nv else np.zeros((m, 0))
    # upper-bound slack rows
    if ub_rows:
        extra = np.zeros((len(ub_rows), nv + len(ub_rows)))
        K_full = np.zeros((m + len(ub_rows), nv + len(ub_rows)))
        K_full[:m, :nv] = K_new
        b_full = np.concatenate([b_eff, np.zeros(len(ub_rows))])
        for r, (jv, bound) in enumerate(ub_rows):
            K_full[m + r, jv] = 1.0
            K_full[m + r, nv + r] = 1.0
            b_full[m + r] = bound
        c_full = np.concatenate([c_new, np.zeros(len(ub_rows))])
    else:
        K_full, b_full, c_full = K_new, b_eff, np.asarray(c_new)
    res = solve_standard(c_full, K_full, b_full, max_iters=max_iters)
    if res.status != "optimal":
        return res
    x = np.array(shift, copy=True)
    xi = res.x
    k = 0
    for kind, j in cols:
        if kind == "pos":
            x[j] = shift[j] + xi[k]
            k += 1
        elif kind == "free+":
            x[j] = xi[k] - xi[k + 1]
            k += 2
    return SimplexResult(
        status="optimal", x=x, obj=float(lp.c @ x), iters=res.iters,
        y=res.y[:m] if res.y is not None else None,
    )
