"""Pallas megakernel: k fused PDHG half-iterations per launch.

The engine's while_loop body runs ``check_every`` half-iterations per
residual check; on small buckets the per-iteration launch/dispatch cost
dominates the two tiny MVMs.  This kernel hoists the whole
check-interval window into ONE ``pallas_call``: operator and iterate
state stay resident in VMEM while a ``fori_loop`` replays the exact
``engine.pdhg_step`` algebra ``n_steps`` times, emitting the final
state plus the ergodic sums the restart block needs.  The residual /
restart check stays OUTSIDE the kernel — ``check_every`` already
delimits the fusion window, so fused and unfused loops visit the same
check points on the same iterates.

Noiseless only (``sigma_read == 0``): per-MVM read-noise keys can't be
split inside the kernel, and the engine only mounts the fused path when
no noise is configured.  Two operand layouts share one step loop:

    dense — K (m, n) and K^T (n, m) as VMEM blocks, MXU matmuls
    ell   — forward + adjoint ELL (data, cols) pairs, row gathers

Vectors travel as (d, 1) columns and scalars as (1, 1) blocks, the
kernel-package convention.  tau/sigma enter as (1, 1) runtime operands
and are RETURNED with the state: the ``strongly_convex`` θ-schedule
updates them inside the window (the in-kernel ``fori_loop`` replays the
same recurrence as ``pdhg_step``), while ``step_rule="adaptive"``
changes them only BETWEEN windows (at check boundaries, in the engine) —
either way the window stays one launch and nothing retraces.

Because the loop advances ``check_every`` half-iterations per launch,
``PDHGResult.iterations`` from any fused or stepped jit path is
quantized to multiples of ``check_every`` — exits are only observed at
check boundaries (see ``engine.mvm_accounting``).  On CPU this runs
interpreted (slow, validation only); the win is compiled Mosaic on a
real accelerator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .interpret import resolve_interpret


def _run_steps(fwd, adj, b, c, lb, ub, T, Sigma, gamma, n_steps,
               x, x_prev, x_bar, y, tau, sigma):
    """``n_steps`` of engine.pdhg_step on (d, 1) columns, accumulating
    the ergodic sums.  The algebra (order included) mirrors
    ``core.engine.pdhg_step`` exactly — keep the two in sync."""
    init = (x, x_prev, x_bar, y, tau, sigma,
            jnp.zeros_like(x), jnp.zeros_like(y))

    def step(_, carry):
        x, x_prev, x_bar, y, tau, sigma, xs, ys = carry
        Kxbar = fwd(x_bar)
        y_n = y + sigma * Sigma * (b - Kxbar)
        KTy = adj(y_n)
        theta_n = 1.0 / jnp.sqrt(1.0 + 2.0 * gamma * tau)
        x_n = jnp.clip(x - tau * T * (c - KTy), lb, ub)
        x_bar_n = x_n + theta_n * (x_n - x)
        return (x_n, x, x_bar_n, y_n, theta_n * tau, sigma / theta_n,
                xs + x_n, ys + y_n)

    return jax.lax.fori_loop(0, n_steps, step, init)


def _write(outs, results):
    for ref, val in zip(outs, results):
        ref[...] = val.astype(ref.dtype)


def _dense_kernel(K_ref, Ka_ref, b_ref, c_ref, lb_ref, ub_ref, T_ref,
                  S_ref, x_ref, xp_ref, xb_ref, y_ref, tau_ref, sig_ref,
                  *outs, n_steps, gamma):
    K = K_ref[...]
    Ka = Ka_ref[...]
    acc_dt = jnp.promote_types(K.dtype, jnp.float32)

    def mv(M, v):
        return jax.lax.dot_general(
            M, v, dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=acc_dt).astype(v.dtype)

    results = _run_steps(
        lambda v: mv(K, v), lambda v: mv(Ka, v),
        b_ref[...], c_ref[...], lb_ref[...], ub_ref[...],
        T_ref[...], S_ref[...], gamma, n_steps,
        x_ref[...], xp_ref[...], xb_ref[...], y_ref[...],
        tau_ref[...], sig_ref[...])
    _write(outs, results)


def _ell_kernel(df_ref, cf_ref, da_ref, ca_ref, b_ref, c_ref, lb_ref,
                ub_ref, T_ref, S_ref, x_ref, xp_ref, xb_ref, y_ref,
                tau_ref, sig_ref, *outs, n_steps, gamma):
    df, cf = df_ref[...], cf_ref[...]
    da, ca = da_ref[...], ca_ref[...]
    acc_dt = jnp.promote_types(df.dtype, jnp.float32)

    def mv(d, cols, v):
        g = jnp.take(v[:, 0], cols, axis=0)
        return jnp.sum((d * g).astype(acc_dt),
                       axis=1).reshape(-1, 1).astype(v.dtype)

    results = _run_steps(
        lambda v: mv(df, cf, v), lambda v: mv(da, ca, v),
        b_ref[...], c_ref[...], lb_ref[...], ub_ref[...],
        T_ref[...], S_ref[...], gamma, n_steps,
        x_ref[...], xp_ref[...], xb_ref[...], y_ref[...],
        tau_ref[...], sig_ref[...])
    _write(outs, results)


def _fused_call(kernel, operands, state_cols, m, n, dt, interpret):
    """Single-program pallas_call: every operand is one whole-array
    block (the megakernel's point is no grid, no HBM round-trips)."""
    out_shape = [
        jax.ShapeDtypeStruct((n, 1), dt),    # x
        jax.ShapeDtypeStruct((n, 1), dt),    # x_prev
        jax.ShapeDtypeStruct((n, 1), dt),    # x_bar
        jax.ShapeDtypeStruct((m, 1), dt),    # y
        jax.ShapeDtypeStruct((1, 1), dt),    # tau
        jax.ShapeDtypeStruct((1, 1), dt),    # sigma
        jax.ShapeDtypeStruct((n, 1), dt),    # x ergodic sum
        jax.ShapeDtypeStruct((m, 1), dt),    # y ergodic sum
    ]
    return pl.pallas_call(
        kernel, out_shape=out_shape, interpret=interpret,
    )(*operands, *state_cols)


def _cols(b, c, lb, ub, T, Sigma, x, x_prev, x_bar, y, tau, sigma, dt):
    col = lambda a: jnp.asarray(a, dt).reshape(-1, 1)  # noqa: E731
    return ([col(a) for a in (b, c, lb, ub, T, Sigma)],
            [col(a) for a in (x, x_prev, x_bar, y)]
            + [jnp.asarray(a, dt).reshape(1, 1) for a in (tau, sigma)])


def _unpack(out, m, n):
    x, x_prev, x_bar, y, tau, sigma, xs, ys = out
    return (x[:, 0], x_prev[:, 0], x_bar[:, 0], y[:, 0],
            tau[0, 0], sigma[0, 0], xs[:, 0], ys[:, 0])


def fused_dense_steps(K, K_adj, b, c, lb, ub, T, Sigma,
                      x, x_prev, x_bar, y, tau, sigma, *,
                      n_steps: int, gamma: float, interpret=None):
    """k fused dense PDHG half-steps; K (m, n), K_adj (n, m).  Returns
    ``(x, x_prev, x_bar, y, tau, sigma, x_sum, y_sum)`` as 1-D/scalars.
    """
    m, n = K.shape
    dt = K.dtype
    vecs, state = _cols(b, c, lb, ub, T, Sigma, x, x_prev, x_bar, y,
                        tau, sigma, dt)
    kernel = functools.partial(_dense_kernel, n_steps=int(n_steps),
                               gamma=float(gamma))
    out = _fused_call(kernel, [K, K_adj] + vecs, state, m, n, dt,
                      resolve_interpret(interpret))
    return _unpack(out, m, n)


def fused_ell_steps(data_f, cols_f, data_a, cols_a, b, c, lb, ub, T,
                    Sigma, x, x_prev, x_bar, y, tau, sigma, *,
                    n_steps: int, gamma: float, interpret=None):
    """k fused ELL PDHG half-steps; forward ELL of K (m, Wf) plus the
    separately stored ELL of K^T (n, Wa).  Same returns as the dense
    variant."""
    m, n = data_f.shape[0], data_a.shape[0]
    dt = data_f.dtype
    vecs, state = _cols(b, c, lb, ub, T, Sigma, x, x_prev, x_bar, y,
                        tau, sigma, dt)
    kernel = functools.partial(_ell_kernel, n_steps=int(n_steps),
                               gamma=float(gamma))
    out = _fused_call(kernel, [data_f, cols_f, data_a, cols_a] + vecs,
                      state, m, n, dt, resolve_interpret(interpret))
    return _unpack(out, m, n)
