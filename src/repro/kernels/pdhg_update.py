"""Pallas TPU kernels: fused PDHG vector updates.

Each PDHG half-iteration performs several elementwise passes over the
primal/dual vectors (extrapolation, preconditioned gradient step, box
projection).  Unfused, every pass is an HBM read+write of the full vector;
fused, each vector streams through VMEM exactly once per half-iteration —
a pure memory-roofline win (the vectors are the ONLY per-iteration HBM
traffic once M is device-resident, mirroring the paper's encode-once
design where only vectors move).

primal:  x_new = clip(x − τ·T⊙(c − KTy), lb, ub)
         x_bar = x_new + θ·(x_new − x)           (extrapolation for k+1)
dual:    y_new = y + σ·Σ⊙(b − Kxbar)

Scalars (τ, θ, σ) ride in as (1,1) blocks pinned to block (0,0) — they
are runtime OPERANDS, not compile-time constants, so the carried
``PDHGState.tau``/``sigma`` may change between iterations (the
``strongly_convex`` θ-schedule every step, ``adaptive`` rebalancing at
check boundaries) without retracing or recompiling these kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .interpret import resolve_interpret

BLOCK = 256


def _primal_kernel(x_ref, kty_ref, c_ref, t_ref, lb_ref, ub_ref,
                   tau_ref, theta_ref, xn_ref, xb_ref):
    tau = tau_ref[0, 0]
    theta = theta_ref[0, 0]
    x = x_ref[...]
    step = x - tau * t_ref[...] * (c_ref[...] - kty_ref[...])
    x_new = jnp.clip(step, lb_ref[...], ub_ref[...])
    xn_ref[...] = x_new
    xb_ref[...] = x_new + theta * (x_new - x)


def _dual_kernel(y_ref, kxbar_ref, b_ref, sig_ref, sigma_ref, yn_ref):
    sigma = sigma_ref[0, 0]
    yn_ref[...] = y_ref[...] + sigma * sig_ref[...] * (b_ref[...] - kxbar_ref[...])


def _col(a):
    return a.reshape(-1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def primal_update_padded(x, kty, c, T, lb, ub, tau, theta, *,
                         interpret: bool | None = None):
    """Inputs are (N, 1) with N % BLOCK == 0; tau/theta are (1, 1).

    ``interpret=None`` auto-detects the backend (interpreted on CPU,
    compiled Mosaic on real TPU) via ``kernels.interpret``."""
    N = x.shape[0]
    assert N % BLOCK == 0
    grid = (N // BLOCK,)
    vec = pl.BlockSpec((BLOCK, 1), lambda i: (i, 0))
    scl = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        _primal_kernel,
        grid=grid,
        in_specs=[vec, vec, vec, vec, vec, vec, scl, scl],
        out_specs=[vec, vec],
        out_shape=[jax.ShapeDtypeStruct((N, 1), x.dtype)] * 2,
        interpret=resolve_interpret(interpret),
    )(x, kty, c, T, lb, ub, tau, theta)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dual_update_padded(y, kxbar, b, Sigma, sigma, *,
                       interpret: bool | None = None):
    """Inputs are (M, 1) with M % BLOCK == 0; sigma is (1, 1).

    ``interpret=None`` auto-detects the backend like
    ``primal_update_padded``."""
    M = y.shape[0]
    assert M % BLOCK == 0
    grid = (M // BLOCK,)
    vec = pl.BlockSpec((BLOCK, 1), lambda i: (i, 0))
    scl = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        _dual_kernel,
        grid=grid,
        in_specs=[vec, vec, vec, vec, scl],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((M, 1), y.dtype),
        interpret=resolve_interpret(interpret),
    )(y, kxbar, b, Sigma, sigma)
