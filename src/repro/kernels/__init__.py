# Pallas TPU kernels for the paper's compute hot-spots:
#   crossbar_mvm  — the analog MVM (the operation the paper accelerates),
#                   as a tiled differential-pair MXU matmul.
#   pdhg_update   — fused primal/dual vector updates (single VMEM pass).
# Validated in interpret=True mode on CPU against ref.py oracles.
from . import crossbar_mvm, ops, pdhg_update, ref

__all__ = ["crossbar_mvm", "ops", "pdhg_update", "ref"]
