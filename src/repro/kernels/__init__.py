# Pallas TPU kernels for the paper's compute hot-spots:
#   crossbar_mvm  — the analog MVM (the operation the paper accelerates),
#                   as a tiled differential-pair MXU matmul.
#   pdhg_update   — fused primal/dual vector updates (single VMEM pass).
# Validated in interpret=True mode on CPU against ref.py oracles; every
# entry point auto-detects interpret mode through kernels.interpret.
# Solvers reach these through core.engine's operator/update backends
# (PDHGOptions.kernel = "pallas").
from . import crossbar_mvm, interpret, ops, pdhg_update, ref
from .interpret import interpret_default

__all__ = ["crossbar_mvm", "interpret", "interpret_default", "ops",
           "pdhg_update", "ref"]
