"""Pure-jnp oracles for every Pallas kernel (shape-for-shape identical)."""
from __future__ import annotations

import jax.numpy as jnp


def crossbar_mvm_ref(g_pos, g_neg, v, gain):
    """w = gain ⊙ ((G+ − G−) @ v); v (C,1), gain (R,1) -> (R,1)."""
    return gain * ((g_pos - g_neg) @ v)


def primal_update_ref(x, kty, c, T, lb, ub, tau, theta):
    tau = jnp.asarray(tau).reshape(())
    theta = jnp.asarray(theta).reshape(())
    x_new = jnp.clip(x - tau * T * (c - kty), lb, ub)
    x_bar = x_new + theta * (x_new - x)
    return x_new, x_bar


def dual_update_ref(y, kxbar, b, Sigma, sigma):
    sigma = jnp.asarray(sigma).reshape(())
    return y + sigma * Sigma * (b - kxbar)
