"""Jit'd public wrappers around the Pallas kernels.

Handle padding to tile boundaries, column-vector reshapes, and the
interpret-mode switch (interpret=True on CPU — the container's validation
mode; compiled Mosaic on real TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import crossbar_mvm as _xbar
from . import pdhg_update as _upd
from .interpret import interpret_default as _interpret_default


def _pad_to(a, mult, axis):
    size = a.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(a, pad)


def crossbar_mvm(g_pos, g_neg, v, scale, noise, interpret=None):
    """w = scale * (1 + noise) ⊙ ((G+ − G−) @ v)  with arbitrary (R, C).

    noise: per-row multiplicative read-noise sample, shape (R,).
    """
    if interpret is None:
        interpret = _interpret_default()
    R, C = g_pos.shape
    gp = _pad_to(_pad_to(g_pos, _xbar.TILE_R, 0), _xbar.TILE_C, 1)
    gn = _pad_to(_pad_to(g_neg, _xbar.TILE_R, 0), _xbar.TILE_C, 1)
    vp = _pad_to(v.reshape(-1, 1), _xbar.TILE_C, 0)
    gain = scale * (1.0 + noise)
    gainp = _pad_to(gain.reshape(-1, 1), _xbar.TILE_R, 0)
    out = _xbar.crossbar_mvm_padded(gp, gn, vp, gainp, interpret=interpret)
    return out[:R, 0]


def primal_update(x, kty, c, T, lb, ub, tau, theta, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    n = x.shape[0]
    cols = [_pad_to(a.reshape(-1, 1), _upd.BLOCK, 0)
            for a in (x, kty, c, T, lb, ub)]
    tau2 = jnp.asarray(tau, x.dtype).reshape(1, 1)
    theta2 = jnp.asarray(theta, x.dtype).reshape(1, 1)
    x_new, x_bar = _upd.primal_update_padded(
        *cols, tau2, theta2, interpret=interpret
    )
    return x_new[:n, 0], x_bar[:n, 0]


def dual_update(y, kxbar, b, Sigma, sigma, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    m = y.shape[0]
    cols = [_pad_to(a.reshape(-1, 1), _upd.BLOCK, 0)
            for a in (y, kxbar, b, Sigma)]
    sig2 = jnp.asarray(sigma, y.dtype).reshape(1, 1)
    out = _upd.dual_update_padded(*cols, sig2, interpret=interpret)
    return out[:m, 0]
