"""Pallas TPU kernel: tiled differential-pair crossbar MVM.

TPU-native adaptation of the paper's analog MVM (DESIGN.md §2): the
64x64 analog crossbar tile becomes a VMEM block feeding the MXU.  MXU
matmul tiles are 128x128, so we pack a 2x2 grid of logical 64x64
crossbars per block — the BlockSpec index maps are the digital analogue of
the paper's "broadcast input voltages to every tile, sum currents along
grid rows".

    w = gain ⊙ ((G+ − G−) @ v)

  G+/G− : (R, C) normalized conductances, VMEM-tiled (TR, TC) blocks
  v     : (C, 1) input "voltages", tiled (TC, 1), broadcast down each
          block row of the grid (the crossbar input broadcast)
  gain  : (R, 1) per-row output scaling — encodes BOTH the conductance
          scale s and the multiplicative cycle-to-cycle read noise
          (1 + sigma*xi), applied once at the final accumulation step
  accumulation over the column-tile grid dimension = the analog current
  summation along a crossbar grid row.

Grid iteration order on TPU is row-major with the LAST axis innermost, so
for grid (i, j) all column tiles j of a row-block i run back-to-back and
the output block stays resident in VMEM across the accumulation — no
HBM round-trips for partial sums.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .interpret import resolve_interpret

# 128 = MXU tile edge; each block packs a 2x2 grid of 64x64 crossbars.
TILE_R = 128
TILE_C = 128


def _mvm_kernel(gp_ref, gn_ref, v_ref, gain_ref, out_ref, *, n_col_tiles):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = gp_ref[...] - gn_ref[...]                     # (TR, TC) in VMEM
    # accumulate at least f32 (MXU native), never BELOW the tile dtype —
    # x64 interpret-mode validation must not round through f32
    part = jax.lax.dot_general(
        g, v_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.promote_types(g.dtype, jnp.float32),
    )                                                  # (TR, 1)
    out_ref[...] += part.astype(out_ref.dtype)

    @pl.when(j == n_col_tiles - 1)
    def _finish():
        out_ref[...] *= gain_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def crossbar_mvm_padded(g_pos, g_neg, v, gain, *,
                        interpret: bool | None = None):
    """MVM on tile-aligned inputs: R, C multiples of (TILE_R, TILE_C).

    v: (C, 1); gain: (R, 1).  Returns (R, 1).  ``interpret=None``
    auto-detects the backend via ``kernels.interpret``.
    """
    R, C = g_pos.shape
    assert R % TILE_R == 0 and C % TILE_C == 0, (R, C)
    n_row_tiles = R // TILE_R
    n_col_tiles = C // TILE_C
    kernel = functools.partial(_mvm_kernel, n_col_tiles=n_col_tiles)
    return pl.pallas_call(
        kernel,
        grid=(n_row_tiles, n_col_tiles),
        in_specs=[
            pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j)),   # G+
            pl.BlockSpec((TILE_R, TILE_C), lambda i, j: (i, j)),   # G-
            pl.BlockSpec((TILE_C, 1), lambda i, j: (j, 0)),        # v
            pl.BlockSpec((TILE_R, 1), lambda i, j: (i, 0)),        # gain
        ],
        out_specs=pl.BlockSpec((TILE_R, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), g_pos.dtype),
        interpret=resolve_interpret(interpret),
    )(g_pos, g_neg, v, gain)
