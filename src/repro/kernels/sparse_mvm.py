"""Row-blocked ELL sparse MVM kernel (Pallas) + host-side COO->ELL.

The sparse COO path is memory-optimal but loses on wall clock: every
MVM is a scatter-add over (nnz,) gathers, which XLA CPU serializes, and
every Ruiz/Pock-Chambolle reduction is another scatter.  ELL
(ELLPACK) trades a bounded amount of padding for fully vectorized
row-major access:

    data (m, W) float   row i's nonzero values, zero-padded to width W
    cols (m, W) int32   matching column indices (padding points at 0)

so one MVM is a dense gather + axis-1 reduction,

    w[i] = sum_j data[i, j] * v[cols[i, j]]

with no scatter anywhere.  Padding entries carry data == 0, so whatever
``cols`` says for them (index 0 by convention) contributes nothing —
exactly the inertness contract of ``stack_problems_sparse``'s (0, 0)
padding.  The row dimension blocks in ``ROW_BLOCK`` chunks aligned with
the crossbar tile edge (``crossbar_mvm.TILE_R``), so an ELL operator
occupies the same logical row tiling as the programmed array it models.

Two execution paths, one rule (``kernels.interpret``): on CPU the
vectorized gather/segment-sum jnp expression IS the kernel (running the
Pallas kernel interpreted would only add overhead); on an accelerator
backend the row-blocked Pallas kernel runs compiled, the input vector
resident in VMEM across all row blocks.  ``use_pallas=True`` forces the
Pallas kernel (interpreted on CPU) for parity testing.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .interpret import resolve_interpret

# Row-block edge: matches crossbar_mvm.TILE_R so ELL row blocks and
# crossbar tiles describe the same physical row partitioning.
ROW_BLOCK = 128
# Smallest ELL width bucket (power-of-two bucketing, like nnz_bucket).
MIN_ELL_WIDTH = 4


# ------------------------------------------------------ host conversion ---

def ell_width_bucket(width: int, min_size: int = MIN_ELL_WIDTH) -> int:
    """Round an ELL width up to its power-of-two bucket so repeat sparse
    traffic with drifting row occupancy reuses compiled executables
    (the ELL twin of ``runtime.batch.nnz_bucket``)."""
    return max(min_size, 1 << (max(int(width), 1) - 1).bit_length())


def coo_row_widths(row, col, data, shape: Tuple[int, int]) -> Tuple[int, int]:
    """(max nonzeros per row, max nonzeros per column) of a COO triplet,
    counting only true nonzeros — explicit zeros (nnz padding at (0, 0)
    included) never widen the ELL form."""
    data = np.asarray(data).reshape(-1)
    keep = data != 0
    row = np.asarray(row).reshape(-1)[keep]
    col = np.asarray(col).reshape(-1)[keep]
    m, n = shape
    wf = int(np.bincount(row, minlength=max(m, 1)).max()) if m else 0
    wa = int(np.bincount(col, minlength=max(n, 1)).max()) if n else 0
    return wf, wa


def ell_from_coo(data, row, col, shape: Tuple[int, int],
                 width: Optional[int] = None):
    """Host-side COO -> ELL conversion (numpy).

    Drops explicit zero entries first (they carry no information and
    would only widen rows), then packs each row's nonzeros
    left-justified in column-sorted order.  Returns ``(ell_data (m, W),
    ell_cols (m, W) int32)`` with ``W = width`` (must cover the widest
    row) or the exact max row width when ``width`` is None.  Rows with
    no nonzeros — including every row of an all-zero K — come back fully
    padded (data 0, cols 0), which the matvec treats as inert.
    """
    m, n = int(shape[0]), int(shape[1])
    data = np.asarray(data).reshape(-1)
    keep = data != 0
    data = data[keep]
    row = np.asarray(row, np.int64).reshape(-1)[keep]
    col = np.asarray(col, np.int64).reshape(-1)[keep]
    order = np.lexsort((col, row))
    data, row, col = data[order], row[order], col[order]
    counts = np.bincount(row, minlength=max(m, 1))[:max(m, 1)]
    w_need = int(counts.max()) if m else 0
    W = w_need if width is None else int(width)
    assert W >= w_need, (W, w_need)
    ell_data = np.zeros((m, W), data.dtype)
    ell_cols = np.zeros((m, W), np.int32)
    if data.size:
        # position of each entry within its row (entries are row-sorted)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        pos = np.arange(data.size) - np.repeat(starts, counts)
        ell_data[row, pos] = data
        ell_cols[row, pos] = col
    return ell_data, ell_cols


# ------------------------------------------------------------- reference ---

def ell_matvec_ref(data, cols, v):
    """Vectorized gather/segment-sum ELL matvec — the CPU/interpret
    path.  One (m, W) gather + one axis-1 reduction; no scatter."""
    if data.shape[1] == 0:
        return jnp.zeros(data.shape[0], v.dtype)
    return jnp.sum(data * jnp.take(v, cols, axis=0), axis=1)


# --------------------------------------------------------- Pallas kernel ---

def _ell_kernel(d_ref, c_ref, v_ref, out_ref):
    d = d_ref[...]                                   # (ROW_BLOCK, W)
    c = c_ref[...]
    v = v_ref[...][:, 0]                             # (n,) resident in VMEM
    g = jnp.take(v, c, axis=0)                       # row-block gather
    # accumulate at least f32, never BELOW the data dtype (matches
    # crossbar_mvm: x64 interpret validation must not round through f32)
    acc_dt = jnp.promote_types(d.dtype, jnp.float32)
    w = jnp.sum((d * g).astype(acc_dt), axis=1)
    out_ref[...] = w.reshape(-1, 1).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ell_matvec_padded(data, cols, v, *, interpret: bool | None = None):
    """Row-blocked Pallas ELL matvec on row-aligned inputs.

    data/cols: (R, W) with R a multiple of ``ROW_BLOCK``; v: (n, 1).
    Returns (R, 1).  The full input vector is a VMEM-resident block for
    every grid step ("broadcast the input voltages"), each grid step
    owns one row block — the sparse analogue of ``crossbar_mvm``'s
    row-tile accumulation, with the column loop replaced by the gather.
    """
    R, W = data.shape
    assert R % ROW_BLOCK == 0, (R, ROW_BLOCK)
    n = v.shape[0]
    return pl.pallas_call(
        _ell_kernel,
        grid=(R // ROW_BLOCK,),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, W), lambda i: (i, 0)),   # data
            pl.BlockSpec((ROW_BLOCK, W), lambda i: (i, 0)),   # cols
            pl.BlockSpec((n, 1), lambda i: (0, 0)),           # v (full)
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, 1), data.dtype),
        interpret=resolve_interpret(interpret),
    )(data, cols, v)


# ------------------------------------------------------------ public API ---

def _pad_rows(a, mult):
    size = a.shape[0]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return a
    return jnp.pad(a, ((0, target - size), (0, 0)))


def ell_matvec(data, cols, v, *, interpret=None,
               use_pallas: Optional[bool] = None):
    """``w = ELL(data, cols) @ v`` with arbitrary (m, W).

    ``use_pallas=None`` auto-selects: the vectorized jnp gather path on
    CPU (where Pallas would run interpreted anyway), the row-blocked
    Pallas kernel on accelerator backends.  ``use_pallas=True`` forces
    the Pallas kernel — interpreted on CPU — for parity validation.
    """
    if use_pallas is None:
        use_pallas = not resolve_interpret(interpret)
    if not use_pallas or data.shape[1] == 0:
        return ell_matvec_ref(data, cols, v)
    m = data.shape[0]
    dp = _pad_rows(data, ROW_BLOCK)
    cp = _pad_rows(cols, ROW_BLOCK)
    out = ell_matvec_padded(dp, cp, v.reshape(-1, 1),
                            interpret=resolve_interpret(interpret))
    return out[:m, 0]
