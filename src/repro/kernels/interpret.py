"""Shared interpret-mode auto-detection for every Pallas entry point.

One rule for the whole kernel package: interpreted on CPU (the
container's validation mode), compiled Mosaic on a real TPU backend.
Both the high-level ``ops`` wrappers and the low-level ``*_padded``
kernels default through here, so a real-TPU caller of either API never
silently runs interpreted.
"""
from __future__ import annotations

import jax


def interpret_default() -> bool:
    return jax.default_backend() == "cpu"


def resolve_interpret(interpret) -> bool:
    """``None`` -> backend auto-detection; anything else passes through."""
    return interpret_default() if interpret is None else bool(interpret)
