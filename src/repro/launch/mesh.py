"""Production mesh construction (NEVER touches jax device state on import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a 2-pod leading axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_mesh(shape, axes):
    """General mesh helper for tests/examples (e.g. (2, 4) on 8 CPUs)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )
