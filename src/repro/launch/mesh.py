"""Back-compat shim: mesh construction moved to ``repro.runtime.mesh``.

Kept so existing imports (tests, examples, benchmarks) keep working;
new code should import from ``repro.runtime`` directly.  NEVER touches
jax device state on import.
"""
from __future__ import annotations

from ..runtime.mesh import (  # noqa: F401
    make_local_mesh,
    make_mesh,
    make_production_mesh,
)
