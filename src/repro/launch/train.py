"""End-to-end training driver.

Runs real steps (smoke-scale configs on this CPU container; the same code
path drives the production mesh on hardware): data pipeline -> jitted
train_step -> checkpoint manager, with crash-safe snapshots and restart.

  PYTHONPATH=src python -m repro.launch.train \
      --arch granite-3-8b --smoke --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..distributed.fault import CheckpointManager
from ..train.checkpoint import load_train_state, save_train_state
from ..train.data import DataConfig, Prefetcher
from ..train.train_step import TrainConfig, init_opt_state, make_train_step
from ..models import lm as lm_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(lr=args.lr, remat=True)
    params = lm_mod.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = init_opt_state(params, tcfg)
    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
        if args.resume and mgr.latest():
            start_step, hp, ho, _meta = load_train_state(mgr.latest())
            params = jax.tree.map(
                lambda a, b: jnp.asarray(b, a.dtype), params, hp)
            opt_state = jax.tree.map(
                lambda a, b: jnp.asarray(b, a.dtype), opt_state, ho)
            print(f"resumed from step {start_step}")
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    dcfg = DataConfig(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq,
                      seed=args.seed,
                      embeddings_dim=cfg.d_model
                      if cfg.frontend in ("vision", "audio") else 0)
    data = Prefetcher(dcfg, start_step=start_step)
    losses = []
    t0 = time.perf_counter()
    try:
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % args.log_every == 0:
                dt = (time.perf_counter() - t0) / args.log_every
                tok_s = args.batch * args.seq / dt
                print(f"step {step+1}: loss={losses[-1]:.4f} "
                      f"{dt*1e3:.0f} ms/step {tok_s:.0f} tok/s", flush=True)
                t0 = time.perf_counter()
            if mgr is not None:
                path = mgr.maybe_save(
                    step + 1,
                    {**{f"params/{k}": v for k, v in _flat(params)},
                     **{f"opt/{k}": v for k, v in _flat(opt_state)}},
                )
                if path:
                    print(f"checkpoint -> {path}", flush=True)
    finally:
        data.close()
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


def _flat(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flat(v, f"{prefix}{k}/")
    else:
        yield prefix[:-1], np.asarray(tree)


if __name__ == "__main__":
    main()
