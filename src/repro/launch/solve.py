"""LP solving driver — the paper's workload as a service.

  PYTHONPATH=src python -m repro.launch.solve --instance gen-ip002 \
      --backend taox          # crossbar-simulated (device physics + ledger)
  PYTHONPATH=src python -m repro.launch.solve --instance rand:64x128 \
      --backend exact         # jitted dense PDHG
  PYTHONPATH=src python -m repro.launch.solve --instance rand:96x160 \
      --backend distributed   # shard_map PDHG on all local devices
"""
from __future__ import annotations

import argparse

import jax

from ..core.pdhg import PDHGOptions, solve_jit
from ..crossbar import EPIRAM, TAOX_HFOX, solve_crossbar_jit
from ..lp import (
    TABLE1_SIZES,
    pagerank_lp,
    random_standard_lp,
    table1_instance,
)


def load_instance(spec: str, seed: int = 0):
    if spec in TABLE1_SIZES:
        return table1_instance(spec, seed=seed)
    if spec.startswith("rand:"):
        m, n = spec[5:].split("x")
        return random_standard_lp(int(m), int(n), seed=seed)
    if spec.startswith("pagerank:"):
        return pagerank_lp(int(spec.split(":")[1]), seed=seed)
    raise ValueError(f"unknown instance {spec!r}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--instance", default="gen-ip002")
    ap.add_argument("--backend", default="exact",
                    choices=["exact", "epiram", "taox", "distributed"])
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--max-iters", type=int, default=40000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    jax.config.update("jax_enable_x64", True)
    lp = load_instance(args.instance, seed=args.seed)
    opts = PDHGOptions(max_iters=args.max_iters, tol=args.tol,
                       check_every=100)
    if args.backend == "exact":
        res = solve_jit(lp, opts)
        led = None
    elif args.backend in ("epiram", "taox"):
        dev = EPIRAM if args.backend == "epiram" else TAOX_HFOX
        rep = solve_crossbar_jit(lp, opts, device=dev)
        res, led = rep.result, rep.ledger
    else:
        from ..distributed.pdhg_dist import solve_dist
        n_dev = len(jax.devices())
        rows = max(1, n_dev // 2)
        cols = max(1, n_dev // rows)
        mesh = jax.make_mesh(
            (rows, cols), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2)
        res = solve_dist(lp, mesh, opts)
        led = None

    print(f"instance={lp.name} shape={lp.K.shape} backend={args.backend}")
    print(f"status={res.status} iters={res.iterations} "
          f"sigma_max={res.sigma_max:.6f}")
    print(f"objective={res.obj:.6f}"
          + (f" (known optimum {lp.obj_opt:.6f}, "
             f"rel err {abs(res.obj-lp.obj_opt)/max(abs(lp.obj_opt),1e-12):.2e})"
             if lp.obj_opt is not None else ""))
    if led is not None:
        print(f"energy: write={led.write_energy_j:.4f}J "
              f"read={led.read_energy_j:.4f}J | latency: "
              f"write={led.write_latency_s:.4f}s read={led.read_latency_s:.4f}s")
    return res


if __name__ == "__main__":
    main()
