"""LP solving driver — the paper's workload as a service.

  PYTHONPATH=src python -m repro.launch.solve --instance gen-ip002 \
      --backend taox          # crossbar-simulated (device physics + ledger)
  PYTHONPATH=src python -m repro.launch.solve --instance rand:64x128 \
      --backend exact         # jitted dense PDHG
  PYTHONPATH=src python -m repro.launch.solve --instance rand:96x160 \
      --backend distributed   # shard_map PDHG on all local devices
  PYTHONPATH=src python -m repro.launch.solve --backend batch \
      --instances rand:8x14,rand:10x18,rand:24x40   # bucketed stream
  PYTHONPATH=src python -m repro.launch.solve --backend batch \
      --device epiram --instances rand:8x14,rand:10x18,rand:24x40
      # device-tile-aware bucketed stream through the crossbar simulator
  PYTHONPATH=src python -m repro.launch.solve --backend batch --sparse \
      --instances sprand:96x192:0.05,sprand:128x256:0.02
      # sparse COO stream: nonzero-proportional memory, async dispatch
  REPRO_COORDINATOR=host0:9876 REPRO_NUM_PROCESSES=2 REPRO_PROCESS_ID=0 \
  PYTHONPATH=src python -m repro.launch.solve --backend batch \
      --cluster auto --instances rand:8x14,rand:10x18,rand:24x40
      # multi-host serving: per-pod bucket routing + straggler reroute
"""
from __future__ import annotations

import argparse

import jax

from ..core.pdhg import PDHGOptions, solve_jit
from ..crossbar import (
    EPIRAM,
    TAOX_HFOX,
    solve_crossbar_jit,
    solve_crossbar_stream,
)
from ..lp import (
    TABLE1_SIZES,
    pagerank_lp,
    random_standard_lp,
    sparse_random_standard_lp,
    table1_instance,
)
from ..runtime import BatchSolver
from ..runtime.mesh import make_local_mesh


def load_instance(spec: str, seed: int = 0):
    if spec in TABLE1_SIZES:
        return table1_instance(spec, seed=seed)
    if spec.startswith("rand:"):
        m, n = spec[5:].split("x")
        return random_standard_lp(int(m), int(n), seed=seed)
    if spec.startswith("sprand:"):
        # sprand:MxN[:density] — COO-native sparse instance
        parts = spec[7:].split(":")
        m, n = parts[0].split("x")
        density = float(parts[1]) if len(parts) > 1 else 0.05
        return sparse_random_standard_lp(int(m), int(n), density=density,
                                         seed=seed)
    if spec.startswith("pagerank:"):
        return pagerank_lp(int(spec.split(":")[1]), seed=seed)
    raise ValueError(f"unknown instance {spec!r}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--instance", default="gen-ip002")
    ap.add_argument("--instances", default=None,
                    help="comma-separated specs for --backend batch")
    ap.add_argument("--backend", default="exact",
                    choices=["exact", "epiram", "taox", "distributed",
                             "batch"])
    ap.add_argument("--device", default="none",
                    choices=["none", "epiram", "taox"],
                    help="with --backend batch: serve the stream through "
                         "the device-tile-aware crossbar simulator")
    ap.add_argument("--sparse", action="store_true",
                    help="with --backend batch: serve the stream through "
                         "the sparse COO pipeline (instances loaded as "
                         "sprand: specs are sparse already; dense specs "
                         "are converted).  Memory is proportional to "
                         "nonzeros — no dense (B, m, n) stack exists")
    ap.add_argument("--sync", action="store_true",
                    help="with --backend batch: block per bucket instead "
                         "of the default submit-all-then-collect async "
                         "dispatch")
    ap.add_argument("--cluster", default="off", choices=["auto", "off"],
                    help="multi-host serving: 'auto' initializes "
                         "jax.distributed from REPRO_COORDINATOR/"
                         "REPRO_NUM_PROCESSES/REPRO_PROCESS_ID (falling "
                         "back to single-process when unset) and routes "
                         "buckets across pods; 'off' serves everything "
                         "in-process")
    ap.add_argument("--pods", type=int, default=None,
                    help="route buckets across N pods (default: the "
                         "detected process count).  N beyond the live "
                         "process count creates virtual pods whose "
                         "buckets the coordinator reroutes — a single-"
                         "process way to exercise the routing table")
    ap.add_argument("--kernel", default="jnp", choices=["jnp", "pallas"],
                    help="engine update backend: reference jnp vector "
                         "algebra or the fused Pallas kernels (interpret "
                         "mode auto-detected; on the crossbar batch path "
                         "'pallas' also routes every MVM through the "
                         "differential-pair crossbar kernel)")
    from ..core.engine import STEP_RULES
    from ..core.lanczos import NORM_BACKENDS

    ap.add_argument("--step-rule", default="fixed", choices=STEP_RULES,
                    help="'fixed' = classic constant steps; 'adaptive' = "
                         "data-driven primal-weight init + PDLP-style "
                         "rebalancing at restarts + down-only step "
                         "safeguard (boundary-only, megakernel-safe); "
                         "'strongly_convex' = accelerated theta schedule "
                         "(requires --gamma > 0)")
    ap.add_argument("--gamma", type=float, default=0.0,
                    help="strong-convexity modulus for "
                         "--step-rule strongly_convex")
    ap.add_argument("--norm-backend", default="lanczos",
                    choices=NORM_BACKENDS,
                    help="jitted operator-norm estimator seeding the "
                         "step sizes")
    ap.add_argument("--norm-reuse", action="store_true",
                    help="with --backend batch: reuse operator-norm "
                         "estimates across stream passes, keyed by "
                         "(shape bucket, sparsity fingerprint) — repeat "
                         "instances pay a short power-iteration refine "
                         "instead of the full Lanczos run")
    ap.add_argument("--refine-rounds", type=int, default=0,
                    help="crossbar backends only: digital iterative-"
                         "refinement rounds — each re-solves the "
                         "residual-correction LP on the SAME programmed "
                         "conductances (shifted b/c, zero extra write "
                         "cycles), recovering exact-path accuracy from "
                         "noisy analog reads")
    ap.add_argument("--refine-tol", type=float, default=0.0,
                    help="stop adopting refinement corrections once the "
                         "exact digital KKT merit reaches this "
                         "(default 0 = refine for all rounds)")
    ap.add_argument("--ecc", type=int, default=1,
                    help="crossbar backends only: k-fold differential-"
                         "pair replication with median decode — tolerates "
                         "stuck cells/drift at k-fold write+read energy, "
                         "ledgered separately under the *_ecc fields")
    ap.add_argument("--tol", type=float, default=1e-6)
    ap.add_argument("--max-iters", type=int, default=40000)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    crossbar_backend = (args.backend in ("epiram", "taox")
                        or (args.backend == "batch"
                            and args.device != "none"))
    if (args.refine_rounds or args.refine_tol or args.ecc != 1) \
            and not crossbar_backend:
        ap.error("--refine-rounds/--refine-tol/--ecc only apply to the "
                 "crossbar backends (--backend epiram/taox or "
                 "--backend batch --device ...): refinement re-reads the "
                 "programmed array and ECC replicates its cells — exact "
                 "digital paths have neither")
    if args.ecc < 1:
        ap.error("--ecc must be >= 1 (1 = replication off)")
    if args.device != "none" and args.backend != "batch":
        ap.error("--device only applies to --backend batch "
                 "(use --backend epiram/taox for single instances)")
    if (args.sparse or args.sync) and args.backend != "batch":
        ap.error("--sparse/--sync only apply to --backend batch")
    if args.sparse and args.device != "none":
        ap.error("--sparse does not combine with --device: a crossbar "
                 "programs every physical cell, so device streams are "
                 "served densely")
    if args.kernel != "jnp" and args.backend == "distributed":
        ap.error("--kernel pallas is not wired into the shard_map path "
                 "(the distributed engine runs the psum-tiled operator "
                 "with jnp updates)")
    if args.pods is not None and args.backend != "batch":
        ap.error("--pods only applies to --backend batch (distributed "
                 "spans processes through the global mesh directly)")
    if (args.cluster != "off" or args.pods is not None) \
            and args.device != "none":
        ap.error("--cluster/--pods do not combine with --device: the "
                 "crossbar batch path is single-process")

    from ..runtime import cluster as cluster_mod

    info = cluster_mod.init_cluster(args.cluster)
    jax.config.update("jax_enable_x64", True)
    opts = PDHGOptions(max_iters=args.max_iters, tol=args.tol,
                       check_every=100, seed=args.seed,
                       kernel=args.kernel, step_rule=args.step_rule,
                       gamma=args.gamma, norm_backend=args.norm_backend,
                       refine_rounds=args.refine_rounds,
                       refine_tol=args.refine_tol)

    def crossbar_device(name: str):
        import dataclasses as _dc
        dev = EPIRAM if name == "epiram" else TAOX_HFOX
        if args.ecc != 1:
            dev = _dc.replace(dev, ecc=args.ecc)
        return dev
    if args.norm_reuse and (args.backend != "batch"
                            or args.device != "none"):
        ap.error("--norm-reuse only applies to --backend batch without "
                 "--device (single solves estimate the norm once by "
                 "construction; the crossbar stream programs every cell "
                 "per instance, so there is nothing to reuse)")
    if args.backend == "batch":
        specs = (args.instances or args.instance).split(",")
        lps = [load_instance(s.strip(), seed=args.seed + i)
               for i, s in enumerate(specs)]
        if args.device != "none":
            dev = crossbar_device(args.device)
            reports = solve_crossbar_stream(lps, opts, device=dev)
            for lp, rep in zip(lps, reports):
                r, led = rep.result, rep.ledger
                line = (f"instance={lp.name} shape={lp.K.shape} "
                        f"device={dev.name} status={r.status} "
                        f"iters={r.iterations} objective={r.obj:.6f}")
                if lp.obj_opt is not None:
                    rel = abs(r.obj - lp.obj_opt) / max(abs(lp.obj_opt),
                                                        1e-12)
                    line += (f" (known optimum {lp.obj_opt:.6f}, "
                             f"rel err {rel:.2e})")
                line += (f" | write={led.write_energy_j:.4f}J "
                         f"(padding {led.write_energy_padding_j:.4f}J"
                         + (f", ecc {led.write_energy_ecc_j:.4f}J"
                            if dev.ecc > 1 else "")
                         + f") read={led.read_energy_j:.4f}J")
                if args.refine_rounds:
                    line += (f" | refine: rounds={args.refine_rounds} "
                             f"executed_iters={rep.executed_iterations} "
                             f"digital_mvms={rep.digital_mvms}")
                print(line)
            return reports
        if args.sparse:
            lps = [lp.sparsified() for lp in lps]
        n_pods = args.pods if args.pods is not None else info.num_processes
        if n_pods > 1 or info.is_multiprocess:
            from ..runtime import ClusterBatchSolver
            solver = ClusterBatchSolver(opts, async_dispatch=not args.sync,
                                        n_pods=n_pods,
                                        norm_reuse=args.norm_reuse)
        else:
            solver = BatchSolver(opts, async_dispatch=not args.sync,
                                 norm_reuse=args.norm_reuse)
        results = solver.solve_stream(lps)
        for lp, r in zip(lps, results):
            line = (f"instance={r.name} shape={lp.K.shape} "
                    f"bucket={r.bucket} status={r.status} "
                    f"iters={r.iterations} objective={r.obj:.6f}")
            if r.sparse:
                line += f" sparse(nnz={lp.K.nnz})"
            if lp.obj_opt is not None:
                rel = abs(r.obj - lp.obj_opt) / max(abs(lp.obj_opt), 1e-12)
                line += f" (known optimum {lp.obj_opt:.6f}, rel err {rel:.2e})"
            print(line)
        st = solver.last_stream_stats
        print(f"stream: buckets={st['n_buckets']} "
              f"dispatch={st['dispatch_s']:.3f}s "
              f"collect={st['collect_s']:.3f}s "
              f"host_stack_bytes=dense:{st['dense_stack_bytes']}"
              f"/sparse:{st['sparse_stack_bytes']}")
        if "routing" in st:
            print(f"cluster: pod={st['pod']}/{st['n_pods']} "
                  f"local_buckets={st['n_local_buckets']} "
                  f"rerouted={st['rerouted_buckets']} "
                  f"routing={st['routing']}")
        return results

    lp = load_instance(args.instance, seed=args.seed)
    if args.backend == "exact":
        res = solve_jit(lp, opts)
        led = None
    elif args.backend in ("epiram", "taox"):
        dev = crossbar_device(args.backend)
        rep = solve_crossbar_jit(lp, opts, device=dev)
        res, led = rep.result, rep.ledger
        if args.refine_rounds:
            print(f"refine: rounds={args.refine_rounds} "
                  f"executed_iters={rep.executed_iterations} "
                  f"digital_mvms={rep.digital_mvms} "
                  f"cells_written={led.cells_written} (all pre-refinement; "
                  f"rounds add READ windows only)")
        if dev.ecc > 1:
            print(f"ecc: k={dev.ecc} decode={dev.ecc_decode} "
                  f"write_ecc={led.write_energy_ecc_j:.4f}J "
                  f"cells_ecc={led.cells_written_ecc}")
    else:
        if args.cluster != "off":
            # shard_map over the process-spanning global mesh
            from ..distributed.pdhg_dist import solve_dist_auto
            res = solve_dist_auto(lp, opts, cluster=args.cluster)
        else:
            from ..distributed.pdhg_dist import solve_dist
            mesh = make_local_mesh()
            res = solve_dist(lp, mesh, opts)
        led = None

    print(f"instance={lp.name} shape={lp.K.shape} backend={args.backend}")
    print(f"status={res.status} iters={res.iterations} "
          f"sigma_max={res.sigma_max:.6f}")
    print(f"objective={res.obj:.6f}"
          + (f" (known optimum {lp.obj_opt:.6f}, "
             f"rel err {abs(res.obj-lp.obj_opt)/max(abs(lp.obj_opt),1e-12):.2e})"
             if lp.obj_opt is not None else ""))
    if led is not None:
        print(f"energy: write={led.write_energy_j:.4f}J "
              f"read={led.read_energy_j:.4f}J | latency: "
              f"write={led.write_latency_s:.4f}s read={led.read_latency_s:.4f}s")
    return res


if __name__ == "__main__":
    main()
