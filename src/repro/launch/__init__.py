"""Launchers: mesh construction, multi-pod dry-run, train/solve drivers.

NOTE: ``dryrun`` must be imported/run as the FIRST jax-touching module of
its process (it sets XLA_FLAGS for 512 host devices at import).  Do not
import it from library code.
"""
from .mesh import make_mesh, make_production_mesh

__all__ = ["make_mesh", "make_production_mesh"]
