from repro.runtime.compat import request_cpu_devices
assert request_cpu_devices(512), \
    "JAX backend initialized before repro.launch.dryrun import"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. assembles the step function (train_step / prefill / serve_step for LM
     archs; the distributed PDHG step for the paper's own LP configs),
  3. jits with explicit in/out shardings and lowers against
     ShapeDtypeStruct inputs (zero allocation),
  4. compiles, prints memory_analysis() (proves it fits) and
     cost_analysis() (FLOPs/bytes), parses collective traffic from the
     partitioned HLO (trip-count-scaled), and
  5. writes a JSON artifact under experiments/dryrun/ that the roofline
     harness (benchmarks/roofline.py) consumes.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k
  python -m repro.launch.dryrun --arch lp_256k --shape dist_step
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import dataclasses
import json
import os
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import (
    ARCH_NAMES,
    LP_CONFIGS,
    SHAPES,
    cell_supported,
    get_config,
    input_specs,
)
from ..runtime import compat
from ..runtime.mesh import make_production_mesh
from ..launch import hlo as hlo_mod
from ..models import lm as lm_mod
from ..train.serve_step import make_prefill_step, make_serve_step
from ..train.train_step import TrainConfig, make_train_step, opt_state_shapes

# v5e-class roofline constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # B/s
ICI_BW = 50e9              # B/s per link


def _sanitize(mesh, spec: P) -> P:
    """Drop spec axes absent from the mesh (e.g. 'pod' on single-pod)."""
    names = mesh.axis_names
    clean = []
    for s in spec:
        if s is None:
            clean.append(None)
        elif isinstance(s, tuple):
            t = tuple(a for a in s if a in names)
            clean.append(t if t else None)
        else:
            clean.append(s if s in names else None)
    return P(*clean)


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _sanitize(mesh, s)), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def _dp_axes(mesh, batch: int):
    """Largest data-parallel axis set that divides the global batch."""
    names = mesh.axis_names
    full = tuple(a for a in ("pod", "data") if a in names)
    size = int(np.prod([mesh.shape[a] for a in full])) if full else 1
    if full and batch % size == 0:
        return full
    if "data" in names and batch % mesh.shape["data"] == 0:
        return ("data",)
    return ()


def _retarget_dp(spec_tree, dp):
    """Replace ('pod','data') batch axes in a spec tree with ``dp``."""
    def fix(spec):
        clean = []
        for s in spec:
            if s == ("pod", "data"):
                clean.append(dp if dp else None)
            else:
                clean.append(s)
        return P(*clean)

    return jax.tree.map(fix, spec_tree, is_leaf=lambda s: isinstance(s, P))


def _batch_specs(batch_sds, dp=("pod", "data")):
    specs = {}
    for k, v in batch_sds.items():
        if k in ("tokens", "labels"):
            specs[k] = P(dp if dp else None, None)
        elif k == "embeddings":
            specs[k] = P(dp if dp else None, None, None)
        else:
            raise ValueError(k)
    return specs


def _opt_specs(param_specs, optimizer: str = "adamw"):
    if optimizer == "adamw":
        return {
            "m": param_specs,
            "v": param_specs,
            "step": P(),
        }
    # adafactor: factored second moments — row drops the last dim,
    # col drops the second-to-last (mirrors train.optimizer.adafactor_init)
    def fac(spec: P):
        if len(spec) >= 2:
            return {
                "row": P(*spec[:-1]),
                "col": P(*spec[:-2], spec[-1]),
            }
        return {"v": P(*spec)}

    f = jax.tree.map(fac, param_specs,
                     is_leaf=lambda s: isinstance(s, P))
    return {"f": f, "step": P()}


def lower_lm_cell(arch: str, shape_name: str, mesh, sharding_mode="fsdp",
                  tcfg: TrainConfig = TrainConfig(microbatch=8),
                  cfg_overrides=None, prefill_last_only=True):
    """microbatch=8: gradient accumulation bounds activation residency
    (global 256-batch -> 32-sample microbatches); the production-memory
    default.  ``cfg_overrides`` (dict of ModelConfig fields) and
    ``prefill_last_only`` are the hillclimb knobs."""
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"skipped": reason}
    params_sds = lm_mod.param_shapes(cfg)
    pspecs = lm_mod.partition_specs(cfg, mode=sharding_mode)
    dp = _dp_axes(mesh, shape.global_batch)
    if shape.kind == "train":
        step = make_train_step(cfg, tcfg)
        opt_sds = opt_state_shapes(params_sds, tcfg)
        batch_sds = input_specs(cfg, shape)
        bspecs = _batch_specs(batch_sds, dp)
        ospecs = _opt_specs(pspecs, tcfg.optimizer)
        in_sh = (_ns(mesh, pspecs), _ns(mesh, ospecs),
                 _ns(mesh, bspecs))
        out_sh = (_ns(mesh, pspecs), _ns(mesh, ospecs),
                  NamedSharding(mesh, P()))
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
        args = (params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, last_only=prefill_last_only)
        batch_sds = input_specs(cfg, shape)
        bspecs = _batch_specs(batch_sds, dp)
        in_sh = (_ns(mesh, pspecs), _ns(mesh, bspecs))
        out_sh = NamedSharding(mesh, _sanitize(mesh, P(dp, "model")))
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        args = (params_sds, batch_sds)
    else:  # decode
        step = make_serve_step(cfg)
        ins = input_specs(cfg, shape)
        cspecs = _retarget_dp(lm_mod.cache_specs(cfg), dp)
        in_sh = (_ns(mesh, pspecs),
                 NamedSharding(mesh, _sanitize(mesh, P(dp, None))),
                 _ns(mesh, cspecs))
        out_sh = (NamedSharding(mesh, _sanitize(mesh, P(dp))),
                  NamedSharding(mesh, _sanitize(mesh, P(dp, "model"))),
                  _ns(mesh, cspecs))
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(2,))
        args = (params_sds, ins["tokens"], ins["cache"])
    return _compile_and_analyze(fn, args, mesh, cfg=cfg, shape=shape)


def lower_lp_cell(lp_name: str, mesh, n_inner: int = 64):
    from ..distributed.pdhg_dist import make_dist_step
    from ..distributed.sharding import axis_size, col_axes, padded_dim, row_axes

    lpc = LP_CONFIGS[lp_name]
    Rax, Cax = row_axes(mesh), col_axes(mesh)
    R, C = axis_size(mesh, Rax), axis_size(mesh, Cax)
    m, n = padded_dim(lpc.m, R), padded_dim(lpc.n, C)
    dt = jnp.dtype(lpc.dtype)
    tdt = jnp.dtype(lpc.tile_dtype)
    step = make_dist_step(mesh, n_inner=n_inner)
    sds = lambda *s: jax.ShapeDtypeStruct(s, dt)  # noqa: E731
    args = (jax.ShapeDtypeStruct((m, n), tdt),   # device-resident K tiles
            sds(m), sds(n), sds(n), sds(n), sds(n), sds(m),
            sds(n), sds(n), sds(m), jax.ShapeDtypeStruct((), dt),
            jax.ShapeDtypeStruct((), dt))
    specs = (P(Rax, Cax), P(Rax), P(Cax), P(Cax), P(Cax), P(Cax), P(Rax),
             P(Cax), P(Cax), P(Rax), P(), P())
    in_sh = tuple(NamedSharding(mesh, s) for s in specs)
    out_sh = tuple(NamedSharding(mesh, s)
                   for s in (P(Cax), P(Cax), P(Rax), P(), P()))
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
    return _compile_and_analyze(fn, args, mesh, lp=lpc, n_inner=n_inner)


def _compile_and_analyze(fn, args, mesh, cfg=None, shape=None, lp=None,
                         n_inner=None):
    t0 = time.perf_counter()
    lowered = fn.lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    text = compiled.as_text()
    colls = hlo_mod.parse_collectives(text)
    est = hlo_mod.estimate_costs(text)
    n_chips = mesh.devices.size
    out = {
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_chips": int(n_chips),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_bytes": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      + mem.output_size_in_bytes
                                      - mem.alias_size_in_bytes),
        },
        "xla_cost": {
            "flops_per_device_static": float(cost.get("flops", -1.0)),
            "bytes_accessed_static": float(cost.get("bytes accessed", -1.0)),
        },
        "hlo_estimate": est.as_dict(),       # trip-scaled, per device
        "collectives": colls.as_dict(),      # trip-scaled, per device
    }
    if cfg is not None:
        n_params = cfg.param_count()
        n_active = cfg.active_param_count()
        tokens = (shape.global_batch * shape.seq_len
                  if shape.kind != "decode" else shape.global_batch)
        mult = 6.0 if shape.kind == "train" else 2.0
        out["model"] = {
            "arch": cfg.name,
            "shape": shape.name,
            "kind": shape.kind,
            "params": n_params,
            "active_params": n_active,
            "tokens_per_step": tokens,
            "model_flops": mult * n_active * tokens,
        }
    if lp is not None:
        # 2 MVMs per PDHG iteration over the (m, n) tile grid
        out["model"] = {
            "arch": lp.name,
            "shape": f"dist_step_x{n_inner}",
            "kind": "lp",
            "model_flops": 2.0 * 2.0 * lp.m * lp.n * n_inner,
        }
    # roofline terms (seconds) — spec formulas, HLO totals = per_device*chips
    flops_total = est.flops * n_chips
    bytes_total = est.bytes * n_chips
    out["roofline"] = {
        "compute_s": flops_total / (n_chips * PEAK_FLOPS),
        "memory_s": bytes_total / (n_chips * HBM_BW),
        "collective_s": colls.total_bytes * n_chips / (n_chips * ICI_BW),
    }
    terms = out["roofline"]
    out["roofline"]["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    if cfg is not None or lp is not None:
        mf = out["model"]["model_flops"]
        out["roofline"]["model_flops_ratio"] = (
            mf / flops_total if flops_total > 0 else 0.0)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             sharding_mode: str = "fsdp", verbose: bool = True,
             cfg_overrides=None, prefill_last_only=True, tag_suffix="",
             tcfg=None):
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}_{shape_name}_{mesh_name}{tag_suffix}"
    path = os.path.join(out_dir, f"{tag}.json")
    mesh = make_production_mesh(multi_pod=multi_pod)
    compat.set_mesh(mesh)
    try:
        if arch in LP_CONFIGS:
            result = lower_lp_cell(arch, mesh)
        else:
            result = lower_lm_cell(
                arch, shape_name, mesh, sharding_mode=sharding_mode,
                cfg_overrides=cfg_overrides,
                prefill_last_only=prefill_last_only,
                **({"tcfg": tcfg} if tcfg is not None else {}))
        result["cell"] = tag
        result["sharding_mode"] = sharding_mode
        if cfg_overrides:
            result["cfg_overrides"] = cfg_overrides
        result["prefill_last_only"] = prefill_last_only
        status = "SKIP" if "skipped" in result else "OK"
    except Exception as e:  # noqa: BLE001
        result = {"cell": tag, "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
        status = "FAIL"
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    if verbose:
        if status == "OK":
            mem = result["memory"]["peak_per_device_bytes"] / 2**30
            rf = result["roofline"]
            print(f"[{status}] {tag}: peak/dev={mem:.2f}GiB "
                  f"compute={rf['compute_s']:.3e}s "
                  f"memory={rf['memory_s']:.3e}s "
                  f"collective={rf['collective_s']:.3e}s "
                  f"bottleneck={rf['bottleneck']} "
                  f"(compile {result['compile_s']:.0f}s)", flush=True)
        elif status == "SKIP":
            print(f"[{status}] {tag}: {result['skipped']}", flush=True)
        else:
            print(f"[{status}] {tag}: {result['error']}", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id or lp config (lp_crossbar/lp_64k/lp_256k)")
    ap.add_argument("--shape", default="train_4k",
                    help="train_4k|prefill_32k|decode_32k|long_500k|dist_step")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--sharding", default="fsdp", choices=["fsdp", "dp"])
    ap.add_argument("--out", default="experiments/dryrun")
    # hillclimb knobs
    ap.add_argument("--ssm-chunk", type=int, default=None,
                    help="override ModelConfig.ssm_chunk")
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--prefill-naive", action="store_true",
                    help="materialize full (B,S,V) logits in prefill")
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache for decode cells")
    ap.add_argument("--microbatch", type=int, default=8)
    ap.add_argument("--tag-suffix", default="")
    args = ap.parse_args()
    overrides = {}
    if args.ssm_chunk is not None:
        overrides["ssm_chunk"] = args.ssm_chunk
    if args.attn_chunk is not None:
        overrides["attn_chunk"] = args.attn_chunk
    if args.kv_int8:
        overrides["kv_cache_int8"] = True

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                cells.append((arch, shape))
        for lp in LP_CONFIGS:
            cells.append((lp, "dist_step"))
    else:
        cells.append((args.arch, args.shape))
    for multi_pod in meshes:
        for arch, shape in cells:
            run_cell(arch, shape, multi_pod, args.out,
                     sharding_mode=args.sharding,
                     cfg_overrides=overrides or None,
                     prefill_last_only=not args.prefill_naive,
                     tag_suffix=args.tag_suffix,
                     tcfg=TrainConfig(optimizer=args.optimizer,
                                      microbatch=args.microbatch))


if __name__ == "__main__":
    main()
