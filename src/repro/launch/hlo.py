"""HLO-text analysis: collective traffic extraction for the roofline.

``cost_analysis()`` has no collective-bytes entry, so we parse the
compiled (post-SPMD) HLO and sum the output-shape bytes of every
collective op.  Conventions (documented in EXPERIMENTS.md §Roofline):

  all-reduce        : 2x output bytes   (ring = reduce-scatter + all-gather)
  all-gather        : 1x output bytes   (bytes received per device ~ output)
  reduce-scatter    : 1x output bytes   (per-device receive volume)
  all-to-all        : 1x output bytes
  collective-permute: 1x output bytes

Bytes are PER DEVICE (SPMD: every device executes the same program).
Collectives inside while/scan bodies are scaled by the loop trip count
(XLA annotates ``known_trip_count`` on lowered scans), nested loops
multiply — so a per-layer all-reduce in a 40-layer scan counts 40x.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^=]*?\)|\S+?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.-]+)\s+\(.*->")
_WHILE_RE = re.compile(r"\bwhile\(")
_BODY_RE = re.compile(r"body=%?([\w.-]+)")
_TRIP_RE = re.compile(r'known_trip_count=\{"?n"?[:=]"?(\d+)"?\}'
                      r'|"known_trip_count":\{"n":"(\d+)"\}')

_MULTIPLIER = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def shape_bytes(shape_str: str) -> int:
    """bytes of 'f32[128,64]' or a tuple '(f32[8], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(text: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines.

    Computation headers sit at column 0:
      %region_0.2 (arg: (s32[], f32[...])) -> (...) {
      ENTRY %main.42 (...) -> ... {
    """
    comps: Dict[str, List[str]] = {}
    current = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            current = m.group(1)
            comps[current] = []
            continue
        if current is not None:
            if line.strip() == "}":
                current = None
                continue
            comps[current].append(line)
    return comps


def propagate_multipliers(nodes, edges) -> Dict[str, float]:
    """Fixed-point trip-count propagation over a loop-nesting graph.

    ``nodes`` are computation/region identifiers; ``edges`` are
    ``(parent, body, trip)`` triples meaning *parent executes body trip
    times per own execution*.  Returns node -> total execution
    multiplier (nested loops multiply).  Shared between the HLO-text
    parser here and the jaxpr walker in ``tools/traceaudit``."""
    mult: Dict[str, float] = {name: 1.0 for name in nodes}
    # loops nest at most a few levels; fixed-point iterate
    for _ in range(max(8, len(edges) + 1)):
        changed = False
        for parent, body, trip in edges:
            new = mult.get(parent, 1.0) * trip
            if body in mult and abs(mult[body] - new) > 1e-9:
                mult[body] = new
                changed = True
        if not changed:
            break
    return mult


def _loop_multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """computation -> execution multiplier from enclosing loop trip counts."""
    # find (parent_comp, body_comp, trip) triples
    edges: List[Tuple[str, str, float]] = []
    for name, lines in comps.items():
        for line in lines:
            if not _WHILE_RE.search(line):
                continue
            bm = _BODY_RE.search(line)
            if not bm:
                continue
            tm = _TRIP_RE.search(line)
            trip = 1.0
            if tm:
                trip = float(tm.group(1) or tm.group(2))
            edges.append((name, bm.group(1), trip))
    return propagate_multipliers(comps, edges)


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]              # static instruction counts
    bytes_by_kind: Dict[str, float]     # trip-scaled, multiplier-weighted
    static_bytes: float                 # unscaled single-execution bytes

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    def as_dict(self):
        return {
            "counts": self.counts,
            "bytes_by_kind": self.bytes_by_kind,
            "total_bytes": self.total_bytes,
            "static_bytes": self.static_bytes,
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    comps = _split_computations(hlo_text)
    mults = _loop_multipliers(comps)
    counts: Dict[str, int] = {}
    by_kind: Dict[str, float] = {}
    static_total = 0.0
    for name, lines in comps.items():
        scale = mults.get(name, 1.0)
        for line in lines:
            m = _COLL_RE.search(line)
            if not m or "-done" in line:
                continue
            shape_str, kind = m.group(1), m.group(2)
            b = shape_bytes(shape_str) * _MULTIPLIER[kind]
            counts[kind] = counts.get(kind, 0) + 1
            by_kind[kind] = by_kind.get(kind, 0.0) + b * scale
            static_total += b
    return CollectiveStats(counts=counts, bytes_by_kind=by_kind,
                           static_bytes=static_total)


def scan_trip_counts(hlo_text: str) -> List[int]:
    out = []
    for m in _TRIP_RE.finditer(hlo_text):
        out.append(int(m.group(1) or m.group(2)))
    return out


# ----------------------------------------------------- trip-scaled costs ---
#
# XLA's HloCostAnalysis visits while bodies exactly ONCE (verified in this
# container: a 10-step scan reports the same flops as its body), so the
# roofline needs its own trip-scaled counts from the partitioned HLO.

_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.-]+)\s*=\s*"
                       r"((?:\([^=]*?\)|\S+?))\s+([\w-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call", "custom-call", "copy-start", "copy-done",
    "all-reduce-start", "all-reduce-done", "all-gather-start",
    "all-gather-done",
}


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class CostEstimate:
    flops: float            # trip-scaled per-device dot/conv flops
    bytes: float            # trip-scaled per-device instruction IO bytes

    def as_dict(self):
        return {"flops": self.flops, "bytes": self.bytes}


def estimate_costs(hlo_text: str) -> CostEstimate:
    """Trip-scaled per-device flops (dot ops) + IO bytes from HLO text."""
    comps = _split_computations(hlo_text)
    mults = _loop_multipliers(comps)
    # name -> output shape string (instruction names are globally unique)
    shapes: Dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            m = _INSTR_RE.match(line)
            if m:
                shapes[m.group(1)] = m.group(2)
    # computations containing an in-place dynamic-update-slice: fusions
    # calling them alias their big buffer operand (XLA updates in place),
    # so actual traffic is the update slice, not the buffer.  (scan carry
    # stashes and grad-of-scan accumulators are all this pattern)
    dus_comps = set()
    for name, lines in comps.items():
        for line in lines:
            if "dynamic-update-slice(" in line:
                dus_comps.add(name)
                break
    calls_re = re.compile(r"calls=%?([\w.-]+)")
    flops = 0.0
    io_bytes = 0.0
    for name, lines in comps.items():
        scale = mults.get(name, 1.0)
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            out_shape, op = m.group(2), m.group(3)
            # operand list = first paren group AFTER the op name
            rest = line[m.end():]
            depth, args = 1, []
            for ch in rest:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                args.append(ch)
            arg_str = "".join(args)
            operands = _OPERAND_RE.findall(arg_str)
            if op == "dot":
                out_elems = 1
                for d in _shape_dims(out_shape):
                    out_elems *= d
                k = 1
                cm = _CONTRACT_RE.search(line)
                if cm and operands:
                    lhs_dims = _shape_dims(shapes.get(operands[0], ""))
                    for ci in (cm.group(1).split(",") if cm.group(1)
                               else []):
                        if ci and int(ci) < len(lhs_dims):
                            k *= lhs_dims[int(ci)]
                flops += 2.0 * out_elems * k * scale
            if op in _SKIP_BYTES_OPS:
                continue
            out_b = shape_bytes(out_shape)
            in_place = op == "dynamic-update-slice"
            if op == "fusion":
                cm2 = calls_re.search(line)
                if cm2 and cm2.group(1) in dus_comps:
                    in_place = True
            operand_bytes = [shape_bytes(shapes.get(o, ""))
                             for o in operands]
            if in_place:
                # aliased buffer update: traffic = the non-buffer operands
                # (read, clipped) + an equal-sized write; the aliased
                # buffer itself is not rewritten.
                small = [min(ob, out_b) for ob in operand_bytes
                         if ob < out_b]
                b = 2.0 * float(sum(small))
            else:
                b = float(out_b)
                for ob in operand_bytes:
                    if op == "dot":
                        b += ob        # matmul truly reads both operands
                    else:
                        # fusions often slice loop-invariant operands:
                        # actual reads bounded by the fusion output scale
                        b += min(ob, out_b)
            io_bytes += b * scale
    return CostEstimate(flops=flops, bytes=io_bytes)
