"""repro: distributed in-memory PDHG for large-scale LPs (+ LM substrate).

Reproduction + TPU-native extension of "From GPUs to RRAMs: Distributed
In-Memory Primal-Dual Hybrid Gradient Method for Solving Large-Scale
Linear Optimization Problems" (CS.DC 2025).  See DESIGN.md.
"""
__version__ = "1.0.0"
