# The paper's primary contribution: distributed in-memory PDHG for LPs.
from . import engine
from .engine import (
    JNP_UPDATES,
    Operator,
    PDHGState,
    Updates,
    accel_operator,
    crossbar_operator,
    dense_operator,
    make_updates,
    mvm_accounting,
    pdhg_loop,
    pdhg_step,
    sharded_operator,
)
from .symblock import (
    MODE_AX,
    MODE_ATY,
    MODE_FULL,
    Accel,
    as_dense,
    build_sym_block,
    encode_exact,
    encode_noisy,
    matmul_accel,
    scaled_accel,
)
from .lanczos import LanczosResult, lanczos_svd, lanczos_svd_jit, power_iteration
from .precondition import (
    ScaledProblem,
    apply_ruiz,
    diagonal_precondition,
    ruiz_rescale,
)
from .residuals import KKTResiduals, kkt_residuals, relative_error
from .noise import NOISELESS, NoiseModel
from .theory import (
    SafeCoupling,
    lemma2_worst_case,
    safe_coupling,
    spectral_ratio,
    theorem1_envelope,
    theorem2_envelope,
)
from .pdhg import PDHGOptions, PDHGResult, prepare, solve, solve_jit
from .infeasibility import Certificate, check_farkas, difference_ray

__all__ = [
    "engine", "JNP_UPDATES", "Operator", "PDHGState", "Updates",
    "accel_operator", "crossbar_operator", "dense_operator", "make_updates",
    "mvm_accounting", "pdhg_loop", "pdhg_step", "sharded_operator",
    "MODE_AX", "MODE_ATY", "MODE_FULL", "Accel", "as_dense",
    "build_sym_block", "encode_exact", "encode_noisy", "matmul_accel",
    "scaled_accel", "LanczosResult", "lanczos_svd", "lanczos_svd_jit",
    "power_iteration", "ScaledProblem", "apply_ruiz",
    "diagonal_precondition", "ruiz_rescale", "KKTResiduals",
    "kkt_residuals", "relative_error", "NOISELESS", "NoiseModel",
    "SafeCoupling", "lemma2_worst_case", "safe_coupling", "spectral_ratio",
    "theorem1_envelope", "theorem2_envelope", "PDHGOptions", "PDHGResult",
    "prepare", "solve", "solve_jit", "Certificate", "check_farkas",
    "difference_ray",
]
