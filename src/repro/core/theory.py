"""Executable forms of the paper's theoretical guarantees.

Lemma 2 (safe coupling): if the norm estimate satisfies |L^ - L| <= delta_bar*L
and tau*sigma = theta / L^2 with theta in (0, (1-delta_bar)^2), then
tau*sigma*L^2 < 1 — PDHG's convergence condition holds despite the noisy
estimate.

Theorem 1 (noisy Lanczos):  E|theta_k - L| <= C rho^{kappa(k-1)} + k eps_max
Theorem 2 (noisy PDHG):     E[gap(z_bar_K)] <= C0/K + delta/sqrt(K)

The bound evaluators below are used by tests/test_theory.py to check the
empirical estimators against these envelopes.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class SafeCoupling:
    tau: float
    sigma: float
    theta: float          # safety margin used
    satisfied: bool       # tau*sigma*L_hat^2-based guarantee holds


def safe_coupling(
    L_hat: float,
    delta_bar: float = 0.0,
    eta: float = 0.95,
    omega: float = 1.0,
) -> SafeCoupling:
    """Step sizes from a noisy norm estimate (Lemma 2).

    theta = eta^2 must lie in (0, (1 - delta_bar)^2)  =>  eta < 1 - delta_bar.
    tau = eta/(omega L^),  sigma = eta*omega/L^  =>  tau*sigma = eta^2/L^2.
    """
    if not (0.0 <= delta_bar < 1.0):
        raise ValueError("delta_bar must be in [0, 1)")
    eta_eff = min(eta, (1.0 - delta_bar) * 0.999)
    theta = eta_eff**2
    tau = eta_eff / (omega * L_hat)
    sigma = eta_eff * omega / L_hat
    satisfied = theta < (1.0 - delta_bar) ** 2
    return SafeCoupling(tau=tau, sigma=sigma, theta=theta, satisfied=satisfied)


def lemma2_worst_case(L: float, L_hat: float, tau: float, sigma: float,
                      delta_bar: float) -> Tuple[float, bool]:
    """Check tau*sigma*L^2 <= theta/(1-delta_bar)^2 < 1 for the true L."""
    lhs = tau * sigma * L * L
    theta = tau * sigma * L_hat * L_hat
    bound = theta / (1.0 - delta_bar) ** 2
    return lhs, bool(lhs <= bound + 1e-12 and bound < 1.0)


def theorem1_envelope(k: np.ndarray, C: float, rho: float, kappa: int,
                      eps_max: float) -> np.ndarray:
    """Pointwise Ritz-error envelope  C rho^{kappa(k-1)} + k eps_max."""
    k = np.asarray(k, dtype=np.float64)
    return C * rho ** (kappa * (k - 1.0)) + k * eps_max


def theorem2_envelope(K: np.ndarray, C0: float, delta: float) -> np.ndarray:
    """Ergodic-gap envelope  C0/K + delta/sqrt(K)."""
    K = np.asarray(K, dtype=np.float64)
    return C0 / K + delta / np.sqrt(K)


def spectral_ratio(M_eigs: np.ndarray) -> Tuple[float, int]:
    """rho = lambda_{p+1}/lambda_1 and multiplicity p of the top eigenvalue."""
    lam = np.sort(np.abs(np.asarray(M_eigs)))[::-1]
    lam1 = lam[0]
    p = int(np.sum(np.isclose(lam, lam1, rtol=1e-10)))
    rho = lam[p] / lam1 if p < lam.size else 0.0
    return float(rho), p
