"""Enhanced PDHG for LPs on in-memory accelerators (paper Algorithm 4).

Iteration (sign convention of eq. 7; Algorithm 4 lists the equivalent
negated-dual form — we keep eq. 7's so the KKT conditions (9)-(11) read
canonically: K^T y <= c etc.):

    theta_k = 1 / sqrt(1 + 2*gamma*tau)        # deterministic adaptation
    tau    <- theta_k * tau;   sigma <- sigma / theta_k    # tau*sigma const
    x_bar  = x_k + theta_k (x_k - x_{k-1})     # momentum extrapolation
    y_{k+1} = y_k + sigma * Sigma ⊙ (b - K x_bar)          # 1 device MVM
    x_{k+1} = proj_[lb,ub]( x_k - tau * T ⊙ (c - K^T y_{k+1}) )  # 1 device MVM

Exactly two device MVMs per iteration, both against the SAME encoded
symmetric block M (Algorithm 2 modes A@x and AT@y); all proximal and
vector algebra stays on the host.  No K / K^T reprogramming ever happens
after the single encode (Algorithm 1).

Two drivers:
  * ``solve``      — host loop over an arbitrary ``Accel`` (crossbar sim
                     with energy ledger, noise keys, restart logic,
                     infeasibility detection, residual history).
  * ``solve_jit``  — jax.lax.while_loop, fully jitted on a dense K
                     (the performance/distributed path).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..lp.problem import StandardLP
from . import engine
from . import precondition as precond_mod
from .lanczos import (
    NORM_BACKENDS,
    lanczos_svd,
    lanczos_svd_jit,
    power_iteration_mv,
)
from .noise import NOISELESS, NoiseModel
from .residuals import KKTResiduals, kkt_residuals
from .symblock import (
    build_sym_block,
    encode_exact,
    encode_noisy,
    scaled_accel,
)


@dataclasses.dataclass
class PDHGOptions:
    max_iters: int = 20000
    tol: float = 1e-6
    eta: float = 0.95              # safety margin (paper: eta ~ 0.95)
    omega: float = 1.0             # primal weight (tau = eta/(omega L), sigma = eta omega/L)
    gamma: float = 0.0             # Nesterov acceleration parameter (>=0)
    ruiz_iters: int = 10
    use_diag_precond: bool = True
    lanczos_iters: int = 64
    lanczos_tol: float = 1e-8
    check_every: int = 64
    restart: bool = True
    restart_beta: float = 0.5      # restart when merit(avg) < beta * merit at last restart
    infeasibility_detection: bool = True
    seed: int = 0
    dtype: np.dtype = np.float64
    track_history: bool = False
    norm_override: Optional[float] = None  # skip Lanczos (reuse across runs)
    kernel: str = "jnp"            # update backend: "jnp" | "pallas" (fused)
    sparse_kernel: str = "ell"     # sparse operator backend: "ell"
    #                                (row-blocked ELL gather kernel; the
    #                                wall-clock path) | "bcoo" (COO/BCOO
    #                                scatter; the memory-optimal path)
    megakernel: bool = False       # fuse each check_every window into ONE
    #                                kernel launch (noiseless paths only)
    step_rule: str = "fixed"       # step-size schedule: "fixed" (constant
    #                                tau/sigma; requires gamma == 0) |
    #                                "adaptive" (data-driven primal-weight
    #                                init, PDLP-style rebalancing at
    #                                restart events, and a down-only
    #                                Malitsky-Pock-flavored step-scale
    #                                safeguard — all at check boundaries
    #                                only, so fused windows stay one
    #                                launch; requires gamma == 0) |
    #                                "strongly_convex" (the accelerated
    #                                theta_k = 1/sqrt(1+2*gamma*tau)
    #                                schedule; requires gamma > 0)
    norm_backend: str = "lanczos"  # operator-norm estimator on the jitted
    #                                prep paths: "lanczos" (Algorithm 3) |
    #                                "power" (symmetric-block power
    #                                iteration; same MVM count/charge)
    refine_rounds: int = 0         # digital iterative-refinement rounds
    #                                around the crossbar solve
    #                                (crossbar.refine): each round
    #                                re-solves the residual-correction LP
    #                                on the SAME programmed conductances
    #                                (shifted b/c only — zero extra write
    #                                cycles), recovering digital-grade
    #                                accuracy from noisy analog reads
    refine_tol: float = 0.0        # stop adopting corrections once the
    #                                exact (digital) KKT merit is at or
    #                                below this — avoids pumping read
    #                                noise back into a converged iterate
    #                                (0.0 = refine for all rounds)


@dataclasses.dataclass
class PDHGResult:
    status: str                 # "optimal" | "iteration_limit" |
    #                             "diverged" | "primal_infeasible"
    x: np.ndarray               # solution in ORIGINAL (unscaled) coordinates
    y: np.ndarray
    obj: float
    iterations: int
    residuals: KKTResiduals
    sigma_max: float            # operator-norm estimate used
    lanczos_iters: int
    mvm_calls: int              # total device MVMs issued (energy ledger)
    history: Optional[list] = None
    restarts: int = 0
    certificate: Optional[object] = None   # Farkas cert when diverged
    merit: Optional[float] = None  # in-loop merit at exit (jitted paths:
    #                                computed with the same noisy device
    #                                MVMs the solve used; ``residuals`` is
    #                                the noiseless post-hoc evaluation)


def _project(x, lb, ub):
    return jnp.clip(x, lb, ub)


def prepare(lp: StandardLP, opts: PDHGOptions):
    """Step 0 of Algorithm 4: scaling, preconditioning (host).

    Densifies a sparse K — the single-instance paths are dense; sparse
    problems stream through ``runtime.batch``'s sparse pipeline instead.
    """
    dt = opts.dtype
    scaled = precond_mod.apply_ruiz(
        jnp.asarray(lp.K_dense, dt), jnp.asarray(lp.b, dt), jnp.asarray(lp.c, dt),
        jnp.asarray(lp.lb, dt), jnp.asarray(lp.ub, dt),
        iters=opts.ruiz_iters,
    )
    if opts.use_diag_precond:
        T, Sigma = precond_mod.diagonal_precondition(scaled.K)
    else:
        m, n = scaled.K.shape
        T = jnp.ones(n, dt)
        Sigma = jnp.ones(m, dt)
    return scaled, T, Sigma


def solve(
    lp: StandardLP,
    opts: PDHGOptions = PDHGOptions(),
    accel_factory: Optional[Callable] = None,
    noise: NoiseModel = NOISELESS,
    on_iteration: Optional[Callable] = None,
) -> PDHGResult:
    """Algorithm 4 host driver over an arbitrary accelerator backend.

    accel_factory(K_scaled) -> Accel.  Default: exact dense backend.
    ``noise`` only applies to the default backends; a crossbar backend
    brings its own device physics.
    """
    opts_static(opts)    # shared option validation (step_rule/kernel/...)
    scaled, T, Sigma = prepare(lp, opts)
    m, n = scaled.K.shape
    key = jax.random.PRNGKey(opts.seed)

    if accel_factory is None:
        if noise.kind == "none":
            accel = encode_exact(scaled.K)
        else:
            accel = encode_noisy(scaled.K, noise.apply)
    else:
        accel = accel_factory(scaled.K)
    use_keys = noise.kind != "none" or accel.name.startswith("crossbar")

    # ---- Step 1: operator-norm estimation on the PRECONDITIONED operator.
    # M' = D M D with D = diag(sqrt(Sigma), sqrt(T)) is the symmetric block
    # of Sigma^{1/2} K T^{1/2}; Lanczos on M' (host-side scaling wrap, no
    # device rewrite) yields rho = ||Sigma^{1/2} K T^{1/2}||_2, and the
    # convergence condition for diagonal steps (tau T, sigma Sigma) is
    # tau*sigma*rho^2 < 1 (Lemma 2 with L := rho).
    if opts.norm_override is not None:
        rho = float(opts.norm_override)
        lanczos_iters = 0
    else:
        wrapped = scaled_accel(accel, jnp.sqrt(Sigma), jnp.sqrt(T))
        key, sub = jax.random.split(key)
        lres = lanczos_svd(
            wrapped, k_max=opts.lanczos_iters, tol=opts.lanczos_tol,
            key=sub, reorthogonalize=True, noise_keys=use_keys,
        )
        rho = lres.sigma_max
        lanczos_iters = lres.iterations

    tau = opts.eta / (opts.omega * rho)
    sigma = opts.eta * opts.omega / rho
    adaptive = opts.step_rule == "adaptive"
    w_lo = w_hi = None
    adapt_prev = None               # previous boundary (x, y, Kx, KTy)
    if adaptive:
        # data-driven primal-weight init + trust region (engine math)
        tau, sigma = engine.adaptive_omega_init(
            jnp.asarray(tau, scaled.K.dtype),
            jnp.asarray(sigma, scaled.K.dtype),
            scaled.b, scaled.c, T, Sigma)
        w0 = jnp.sqrt(sigma / tau)
        w_lo = w0 / engine.ADAPT_OMEGA_CLIP
        w_hi = w0 * engine.ADAPT_OMEGA_CLIP

    # ---- Step 2: initialization (paper: projected Gaussian start).
    key, kx, ky = jax.random.split(key, 3)
    x = _project(jax.random.normal(kx, (n,), dtype=scaled.K.dtype),
                 scaled.lb, scaled.ub)
    y = jax.random.normal(ky, (m,), dtype=scaled.K.dtype)
    # running ergodic sums for restarts / averaged iterate
    x_sum = jnp.zeros_like(x)
    y_sum = jnp.zeros_like(y)
    avg_len = 0
    merit_at_restart = np.inf
    n_restarts = 0

    history = [] if opts.track_history else None
    status = "iteration_limit"
    res = None
    it = 0

    # The per-iteration math is the engine's — this driver only owns the
    # Python-level control flow (history, callbacks, infeasibility exit).
    op = engine.accel_operator(accel)
    upd = engine.make_updates(opts.kernel)
    state = engine.init_state(x, y, tau, sigma, opts.gamma)
    adapt_anchor = (state.x, state.y)   # restart anchor for omega updates
    del x, y, tau, sigma

    for it in range(opts.max_iters):
        if use_keys:
            key, k1, k2 = jax.random.split(key, 3)
        else:
            k1 = k2 = None
        state = engine.pdhg_step(op, upd, scaled.b, scaled.c, scaled.lb,
                                 scaled.ub, T, Sigma, opts.gamma, state,
                                 k1, k2)

        x_sum = x_sum + state.x
        y_sum = y_sum + state.y
        avg_len += 1

        if (it + 1) % opts.check_every == 0 or it == opts.max_iters - 1:
            if use_keys:
                key, k3, k4 = jax.random.split(key, 3)
            else:
                k3 = k4 = None
            Kx = op.fwd(state.x, k3)
            KTy_c = op.adj(state.y, k4)
            res = kkt_residuals(
                state.x, state.x_prev, state.y, scaled.c, scaled.b, Kx,
                KTy_c, lb=scaled.lb, ub=scaled.ub,
            )
            merit = float(res.max)
            if history is not None:
                history.append(
                    {"iter": it + 1, "merit": merit, **res.as_dict(),
                     "obj": float(jnp.vdot(scaled.c, state.x))}
                )
            if on_iteration is not None:
                on_iteration(it + 1, merit, accel)
            if not np.isfinite(merit):
                # NaN/inf merit: the iterate blew up.  NaN fails every
                # comparison below, so without this check the loop would
                # run to the iteration limit and report it as such.
                status = "diverged"
                break
            if merit <= opts.tol:
                status = "optimal"
                break
            if opts.infeasibility_detection and merit > 1e8:
                status = "diverged"
                break
            Kx_b, KTy_b = Kx, KTy_c   # images of the iterate carried on
            if opts.restart and avg_len > 0:
                # fresh keys: reusing k3/k4 here would correlate the read
                # noise between the current- and averaged-iterate checks
                if use_keys:
                    key, k5, k6 = jax.random.split(key, 3)
                else:
                    k5 = k6 = None
                x_avg = x_sum / avg_len
                y_avg = y_sum / avg_len
                Kxa = op.fwd(x_avg, k5)
                KTya = op.adj(y_avg, k6)
                res_avg = kkt_residuals(
                    x_avg, x_avg, y_avg, scaled.c, scaled.b, Kxa, KTya,
                    lb=scaled.lb, ub=scaled.ub,
                )
                merit_avg = float(res_avg.max)
                if merit_avg < opts.restart_beta * merit_at_restart:
                    # restart from the (better) averaged iterate
                    if merit_avg < merit:
                        state = engine.restart_state(state, x_avg, y_avg)
                        Kx_b, KTy_b = Kxa, KTya
                    merit_at_restart = min(merit_avg, merit)
                    x_sum = jnp.zeros_like(state.x)
                    y_sum = jnp.zeros_like(state.y)
                    avg_len = 0
                    n_restarts += 1
                    if adaptive:
                        # primal-weight rebalance rides restart events
                        rx, ry = adapt_anchor
                        tau_n, sigma_n = engine.adaptive_omega_update(
                            state.tau, state.sigma,
                            state.x - rx, state.y - ry, T, Sigma,
                            w_lo, w_hi, jnp.asarray(True))
                        state = state._replace(tau=tau_n, sigma=sigma_n)
                        adapt_anchor = (state.x, state.y)
            if adaptive:
                # boundary-only down-only scale safeguard; the math lives
                # in the engine, and K(dx)/K^T(dy) come from the check
                # MVMs by linearity
                if adapt_prev is not None:
                    px, py, pKx, pKTy = adapt_prev
                    tau_n, sigma_n = engine.adaptive_shrink(
                        state.tau, state.sigma, opts.eta,
                        state.x - px, state.y - py,
                        Kx_b - pKx, KTy_b - pKTy,
                        T, Sigma, jnp.asarray(True))
                    state = state._replace(tau=tau_n, sigma=sigma_n)
                adapt_prev = (state.x, state.y, Kx_b, KTy_b)

    x_orig = np.asarray(scaled.unscale_x(state.x))
    y_orig = np.asarray(scaled.unscale_y(state.y))
    if res is None:
        Kx = op.fwd(state.x)
        KTy_c = op.adj(state.y)
        res = kkt_residuals(state.x, state.x, state.y, scaled.c, scaled.b,
                            Kx, KTy_c, lb=scaled.lb, ub=scaled.ub)
    certificate = None
    if status == "diverged" and opts.infeasibility_detection:
        # PDHG's dual iterate diverges along a Farkas ray on primal-
        # infeasible instances [51]; the diagonal rescaling preserves
        # certificates (K~^T y~ <= 0 <=> K^T (D1 y~) <= 0 for D2 > 0).
        from .infeasibility import check_farkas

        cert = check_farkas(np.asarray(lp.K), np.asarray(lp.b), y_orig,
                            tol=1e-5)
        if cert.kind != "none":
            status = "primal_infeasible"
            certificate = cert
    return PDHGResult(
        status=status,
        x=x_orig,
        y=y_orig,
        obj=float(lp.c @ x_orig),
        iterations=it + 1,
        residuals=res,
        sigma_max=rho,
        lanczos_iters=lanczos_iters,
        mvm_calls=accel.stats["mvm_calls"],
        history=history,
        restarts=n_restarts,
        certificate=certificate,
        merit=float(res.max),
    )


# --------------------------------------------------------------------------
# Fully-jitted dense solver (performance path; the iteration core itself
# lives in ``core.engine`` — this is the option plumbing around it).
# --------------------------------------------------------------------------

# PDHGOptions fields that deliberately stay OUT of the compiled-executable
# cache key (``tools.jaxlint`` rule R1 cross-checks this allowlist against
# the dataclass fields and the ``opts_static`` tuple below — adding an
# option without deciding its cache-key fate is a lint error).
# ``ruiz_iters``/``lanczos_iters``/``norm_override``/``norm_backend``
# ride in ``runtime.batch``'s separate prep-signature tuple (the norm
# estimate is a prep-stage input to the solve executable, not part of
# its trace); ``lanczos_tol``/
# ``use_diag_precond``/``infeasibility_detection`` only steer the host
# solve path; ``seed``/``track_history`` are runtime data; ``dtype`` is
# already encoded by every traced array shape.
DYNAMIC_FIELDS = (
    "ruiz_iters", "use_diag_precond", "lanczos_iters", "lanczos_tol",
    "infeasibility_detection", "seed", "dtype", "track_history",
    "norm_override", "norm_backend",
)


def opts_static(opts: PDHGOptions, sigma_read: float = 0.0) -> tuple:
    """The hashable option tuple ``engine.solve_core`` consumes
    (positional unpack — keep in sync with the head of that function, and
    nowhere else: ``solve_jit``, ``runtime.batch`` and
    ``crossbar.solver`` all build it through here; fields that
    deliberately stay out of the tuple are declared in
    ``DYNAMIC_FIELDS`` and the pairing is machine-checked by jaxlint
    rule R1).  ``opts.kernel``,
    ``opts.restart``, ``opts.sparse_kernel``, ``opts.megakernel`` and
    ``opts.step_rule`` are
    part of the tuple, so compiled-executable caches keyed on it never
    serve one backend's executable to another (a step-rule change is a
    different trace and must never reuse an executable compiled for
    another rule).  ``opts.restart`` rides
    as an explicit static boolean — the old encoding (restart off ==
    ``restart_beta 0.0``) only worked because ``0.0 * inf`` is NaN and
    NaN comparisons are false inside the jitted body."""
    if opts.kernel not in engine.KERNELS:
        raise ValueError(f"unknown update kernel {opts.kernel!r}; "
                         f"expected one of {engine.KERNELS}")
    if opts.sparse_kernel not in engine.SPARSE_KERNELS:
        raise ValueError(f"unknown sparse kernel {opts.sparse_kernel!r}; "
                         f"expected one of {engine.SPARSE_KERNELS}")
    if opts.megakernel and float(sigma_read) > 0.0:
        raise ValueError("megakernel mode is noiseless-only: per-MVM "
                         "read-noise keys cannot be split inside a fused "
                         "launch (sigma_read must be 0)")
    if opts.step_rule not in engine.STEP_RULES:
        raise ValueError(f"unknown step_rule {opts.step_rule!r}; expected "
                         f"one of {engine.STEP_RULES}")
    if opts.step_rule == "strongly_convex" and not opts.gamma > 0.0:
        raise ValueError("step_rule='strongly_convex' is the accelerated "
                         "theta_k schedule and requires gamma > 0")
    if opts.step_rule != "strongly_convex" and opts.gamma != 0.0:
        raise ValueError(f"gamma > 0 drives the strongly-convex schedule; "
                         f"set step_rule='strongly_convex' explicitly "
                         f"(got gamma={opts.gamma} with "
                         f"step_rule={opts.step_rule!r})")
    if opts.refine_rounds < 0:
        raise ValueError(f"refine_rounds must be >= 0 "
                         f"(got {opts.refine_rounds})")
    # refine_rounds/refine_tol ride in the static tuple (entries 13/14):
    # the refinement shell unrolls one analog solve per round, so a
    # different round count is a different trace and must never reuse an
    # executable compiled for another.  solve_core itself ignores them.
    return (opts.max_iters, opts.tol, opts.eta, opts.omega, opts.gamma,
            opts.check_every, opts.restart_beta, float(sigma_read),
            opts.kernel, bool(opts.restart), opts.sparse_kernel,
            bool(opts.megakernel), opts.step_rule,
            int(opts.refine_rounds), float(opts.refine_tol))


# Backwards-compatible alias: the dense jit core now lives in the engine.
_solve_jit_core = engine.solve_core


def solve_jit(
    lp: StandardLP,
    opts: PDHGOptions = PDHGOptions(),
    K_fwd=None,
    K_adj=None,
    sigma_read: float = 0.0,
    transfer_sanitize: bool = False,
) -> PDHGResult:
    """Jitted dense-K solver: Ruiz + PC precond + Lanczos + while_loop.

    ``K_fwd``/``K_adj`` override the operator actually *executed* (e.g. the
    decoded programmed crossbar blocks, already in the Ruiz-scaled frame);
    preconditioning and residual scaling still derive from the nominal K.
    ``sigma_read`` adds multiplicative per-MVM read noise inside the loop.
    ``transfer_sanitize`` runs the jitted iteration core under
    ``runtime.sanitize.no_implicit_transfers()`` — every input is device
    resident by then, so any implicit transfer the solve triggers is a
    bug and raises (host-side prep/result extraction stay unguarded:
    those transfers are the sanctioned ones).
    """
    scaled, T, Sigma = prepare(lp, opts)
    Kf = scaled.K if K_fwd is None else jnp.asarray(K_fwd, scaled.K.dtype)
    Ka = Kf.T if K_adj is None else jnp.asarray(K_adj, scaled.K.dtype)
    if opts.norm_backend not in NORM_BACKENDS:
        raise ValueError(f"unknown norm_backend {opts.norm_backend!r}; "
                         f"expected one of {NORM_BACKENDS}")
    if opts.norm_override is not None:
        rho = jnp.asarray(opts.norm_override, scaled.K.dtype)
    else:
        Keff = jnp.sqrt(Sigma)[:, None] * Kf * jnp.sqrt(T)[None, :]
        M = build_sym_block(Keff)
        if opts.norm_backend == "power":
            rho = power_iteration_mv(lambda v: M @ v, M.shape[0], M.dtype,
                                     iters=opts.lanczos_iters)
        else:
            rho = lanczos_svd_jit(M, k_max=opts.lanczos_iters)
        rho = engine.lemma2_margin(rho, sigma_read)
    static = opts_static(opts, sigma_read)
    core = jax.jit(engine.solve_core, static_argnums=(10,))
    core_args = (
        Kf, Ka, scaled.b, scaled.c, scaled.lb, scaled.ub, T, Sigma, rho,
        jax.random.PRNGKey(opts.seed + 1), static,
    )
    if transfer_sanitize:
        from ..runtime import sanitize
        with sanitize.no_implicit_transfers():
            x, y, it, merit = core(*core_args)
    else:
        x, y, it, merit = core(*core_args)
    x_orig = np.asarray(scaled.unscale_x(x))
    y_orig = np.asarray(scaled.unscale_y(y))
    res = kkt_residuals(
        x, x, y, scaled.c, scaled.b, scaled.K @ x, scaled.K.T @ y,
        lb=scaled.lb, ub=scaled.ub,
    )
    it_i = int(it)
    lanczos_mvms = 0 if opts.norm_override is not None else opts.lanczos_iters
    merit_f = float(merit)
    # a non-finite merit exits the while_loop (NaN > tol is false) —
    # report it as divergence, not as a clean iteration limit
    if not np.isfinite(merit_f):
        status = "diverged"
    elif merit_f <= opts.tol:
        status = "optimal"
    else:
        status = "iteration_limit"
    return PDHGResult(
        status=status,
        x=x_orig, y=y_orig, obj=float(lp.c @ x_orig),
        iterations=it_i, residuals=res, sigma_max=float(rho),
        lanczos_iters=lanczos_mvms,
        mvm_calls=engine.mvm_accounting(it_i, opts.check_every,
                                        lanczos_mvms,
                                        restart=opts.restart),
        merit=merit_f,
    )
