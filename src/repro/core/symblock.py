"""Symmetric block-matrix formulation (paper Algorithms 1 & 2).

    M = [[0_{m x m}, K       ],
         [K^T,       0_{n x n}]]

is encoded to the accelerator ONCE; every MVM the solver needs is a single
device MVM against M with mode-dependent zero padding / slicing:

    full : w = M @ u                       (Lanczos)
    A@x  : t = K @ x    = (M @ [0; x])[:m]  (dual step)
    AT@y : s = K^T @ y  = (M @ [y; 0])[m:]  (primal step)

``Accel`` abstracts *where* the single MVM runs: exact jnp, noisy-model,
Pallas crossbar kernel, MELISO+ crossbar simulation, or the shard_map
distributed backend.  Each backend only has to provide ``mvm_full``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

MODE_FULL = "full"
MODE_AX = "A@x"
MODE_ATY = "AT@y"


def build_sym_block(K) -> jnp.ndarray:
    """Algorithm 1 (BUILDSYMBLOCK), host step: M from K (m x n)."""
    K = jnp.asarray(K)
    m, n = K.shape
    top = jnp.concatenate([jnp.zeros((m, m), K.dtype), K], axis=1)
    bot = jnp.concatenate([K.T, jnp.zeros((n, n), K.dtype)], axis=1)
    return jnp.concatenate([top, bot], axis=0)


@dataclasses.dataclass
class Accel:
    """Encoded accelerator handle (result of Algorithm 1 step 2).

    mvm_full: v (m+n,) -> M @ v.  May be stochastic (device noise); the
    caller threads an explicit PRNG key when the backend needs one.
    """

    mvm_full: Callable[..., jnp.ndarray]
    m: int
    n: int
    name: str = "exact"
    # Number of device MVMs issued (host-side bookkeeping for the energy
    # ledger; incremented by matmul_accel).
    stats: Optional[dict] = None

    def __post_init__(self):
        if self.stats is None:
            self.stats = {"mvm_calls": 0}


def encode_exact(K, dtype=None) -> Accel:
    """Reference backend: encode M as a dense jnp array, exact arithmetic."""
    K = jnp.asarray(K, dtype=dtype)
    m, n = K.shape
    M = build_sym_block(K)

    def mvm(v, key=None):
        return M @ v

    return Accel(mvm_full=mvm, m=m, n=n, name="exact")


def encode_noisy(K, noise_apply, dtype=None) -> Accel:
    """Backend with an explicit MVM perturbation model (Assumptions 1-4).

    noise_apply(key, w) -> w_noisy, applied to the exact product. Models
    \\tilde{M} v = M v + zeta with E[zeta] = 0.
    """
    K = jnp.asarray(K, dtype=dtype)
    m, n = K.shape
    M = build_sym_block(K)

    def mvm(v, key=None):
        w = M @ v
        if key is None:
            return w
        return noise_apply(key, w)

    return Accel(mvm_full=mvm, m=m, n=n, name="noisy")


def matmul_accel(accel: Accel, u, mode: str, key=None) -> jnp.ndarray:
    """Algorithm 2 (MATMULACCEL): pad -> single device MVM -> slice."""
    m, n = accel.m, accel.n
    u = jnp.asarray(u)
    if mode == MODE_FULL:
        v = u
    elif mode == MODE_AX:
        v = jnp.concatenate([jnp.zeros((m,), u.dtype), u])
    elif mode == MODE_ATY:
        v = jnp.concatenate([u, jnp.zeros((n,), u.dtype)])
    else:
        raise ValueError(f"unknown mode {mode!r}")
    w = accel.mvm_full(v, key) if key is not None else accel.mvm_full(v)
    accel.stats["mvm_calls"] += 1
    if mode == MODE_FULL:
        return w
    if mode == MODE_AX:
        return w[:m]          # t = K x
    return w[m:]              # s = K^T y


def scaled_accel(accel: Accel, row_scale, col_scale, name=None) -> Accel:
    """Diagonal similarity wrap: M' = D M D with D = diag(row_scale, col_scale).

    Used to evaluate the *preconditioned* operator norm
    ||Sigma^{1/2} K T^{1/2}||_2 without reprogramming the device:
    diag(Sigma^{1/2}, T^{1/2}) M diag(Sigma^{1/2}, T^{1/2}) is exactly the
    symmetric block of Sigma^{1/2} K T^{1/2}.  Host-side vector scaling only
    — consistent with the encode-once constraint.
    """
    d = jnp.concatenate([jnp.asarray(row_scale), jnp.asarray(col_scale)])

    def mvm(v, key=None):
        w = accel.mvm_full(d * v, key) if key is not None else accel.mvm_full(d * v)
        return d * w

    return Accel(
        mvm_full=mvm, m=accel.m, n=accel.n,
        name=name or f"scaled({accel.name})", stats=accel.stats,
    )


def as_dense(accel: Accel) -> np.ndarray:
    """Materialize M by probing (test helper; O(m+n) MVMs)."""
    dim = accel.m + accel.n
    eye = jnp.eye(dim)
    cols = [np.asarray(accel.mvm_full(eye[:, i])) for i in range(dim)]
    return np.stack(cols, axis=1)
