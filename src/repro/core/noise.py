"""MVM perturbation models (paper §4, Assumptions 1-4).

The analog accelerator returns  M v + zeta  where zeta is
  * independent across iterations (Assumption 1),
  * zero-mean / unbiased (Assumption 2),
  * bounded (Assumption 3) with finite variance (Assumption 4).

We provide the two families the paper analyzes:
  multiplicative: w_i * (1 + sigma * g_i)   — models conductance C2C/D2D
                  variability scaling with the signal,
  additive:       w + sigma * scale * g     — models thermal/electronic
                  read noise independent of the signal.

Gaussians are truncated at ``clip`` std-devs so Assumption 3 (bounded)
holds exactly; truncation at +-c of a symmetric density keeps zero mean.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class NoiseModel:
    kind: str = "none"            # "none" | "multiplicative" | "additive"
    sigma: float = 0.0            # relative noise scale
    clip: float = 4.0             # truncation (std devs) => bounded noise

    def apply(self, key, w):
        if self.kind == "none" or self.sigma == 0.0:
            return w
        g = jax.random.normal(key, w.shape, dtype=w.dtype)
        g = jnp.clip(g, -self.clip, self.clip)
        if self.kind == "multiplicative":
            return w * (1.0 + self.sigma * g)
        if self.kind == "additive":
            # scale to the RMS of the clean product so sigma is relative
            scale = jnp.linalg.norm(w) / jnp.sqrt(jnp.asarray(w.size, w.dtype))
            return w + self.sigma * scale * g
        raise ValueError(self.kind)

    def bound_delta(self, typical_norm: float = 1.0) -> float:
        """delta of Assumption 3 for step-size safety margins (Lemma 2)."""
        return float(self.sigma * self.clip * typical_norm)


NOISELESS = NoiseModel()


def make_apply(model: NoiseModel) -> Callable:
    return model.apply
