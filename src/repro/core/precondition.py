"""Host-side model preparation (paper §2.3 / Algorithm 4 Step 0).

* Ruiz rescaling [48]: iterative row/col infinity-norm equilibration,
  K~ = D1 K D2.  Improves conditioning before anything touches the device.
* Pock–Chambolle diagonal preconditioning [49]: per-coordinate step
  diagonals T (primal, length n) and Sigma (dual, length m) with
  T_j = 1 / sum_i |K_ij|^{2-a},  Sigma_i = 1 / sum_j |K_ij|^a  (a = 1),
  which guarantee ||Sigma^{1/2} K T^{1/2}||_2 <= 1.

Both are pure host/vector operations: they never force a device rewrite of
the encoded M (the diagonal scalings commute through Algorithm 2 as
elementwise multiplies on the streamed vectors).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass
class ScaledProblem:
    """Ruiz-rescaled problem data (Algorithm 4 lines 2-4)."""

    K: jnp.ndarray       # D1 K D2
    b: jnp.ndarray       # D1 b
    c: jnp.ndarray       # D2 c
    lb: jnp.ndarray      # D2^{-1} lb
    ub: jnp.ndarray      # D2^{-1} ub
    D1: jnp.ndarray      # (m,) row scaling diag
    D2: jnp.ndarray      # (n,) col scaling diag

    def unscale_x(self, x):
        return self.D2 * x

    def unscale_y(self, y):
        return self.D1 * y


def ruiz_rescale(K, iters: int = 10, eps: float = 1e-12) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Ruiz equilibration: returns (D1, D2) with D1 K D2 ~ unit inf-norms."""
    K = jnp.asarray(K)
    m, n = K.shape
    D1 = jnp.ones(m, K.dtype)
    D2 = jnp.ones(n, K.dtype)
    Kw = K
    for _ in range(iters):
        r = jnp.sqrt(jnp.max(jnp.abs(Kw), axis=1))
        c = jnp.sqrt(jnp.max(jnp.abs(Kw), axis=0))
        r = jnp.where(r < eps, 1.0, r)
        c = jnp.where(c < eps, 1.0, c)
        D1 = D1 / r
        D2 = D2 / c
        Kw = K * D1[:, None] * D2[None, :]
    return D1, D2


def apply_ruiz(K, b, c, lb, ub, iters: int = 10) -> ScaledProblem:
    K = jnp.asarray(K)
    b = jnp.asarray(b, K.dtype)
    c = jnp.asarray(c, K.dtype)
    lb = jnp.asarray(lb, K.dtype)
    ub = jnp.asarray(ub, K.dtype)
    D1, D2 = ruiz_rescale(K, iters=iters)
    Ks = K * D1[:, None] * D2[None, :]
    # x = D2 x~  =>  bounds on x~ are D2^{-1}-scaled; +-inf preserved.
    lbs = jnp.where(jnp.isfinite(lb), lb / D2, lb)
    ubs = jnp.where(jnp.isfinite(ub), ub / D2, ub)
    return ScaledProblem(K=Ks, b=D1 * b, c=D2 * c, lb=lbs, ub=ubs, D1=D1, D2=D2)


def diagonal_precondition(K, alpha: float = 1.0, eps: float = 1e-12):
    """Pock–Chambolle diagonals: (T primal (n,), Sigma dual (m,))."""
    K = jnp.asarray(K)
    absK = jnp.abs(K)
    col = jnp.sum(absK ** (2.0 - alpha), axis=0)   # per primal coordinate
    row = jnp.sum(absK ** alpha, axis=1)           # per dual coordinate
    T = 1.0 / jnp.maximum(col, eps)
    Sigma = 1.0 / jnp.maximum(row, eps)
    return T, Sigma
