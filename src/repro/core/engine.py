"""One PDHG iteration engine with pluggable operator / update backends.

The paper's core claim is that an enhanced-PDHG iteration reduces to two
device MVMs plus cheap vector algebra.  This module is the SINGLE home of
that half-iteration — extrapolation, dual MVM+update, primal MVM+update,
and the check-interval residual/restart block — shared verbatim by every
solver path in the repo:

    core.pdhg.solve        host loop      accel_operator   (Accel handles)
    core.pdhg.solve_jit    while_loop     dense_operator
    runtime.batch          vmapped        dense_operator
    crossbar.solver        vmapped        dense_operator | crossbar_operator
    distributed.pdhg_dist  shard_map      sharded_operator

Two orthogonal backend axes parameterize the engine:

  * **operator backend** (``Operator``): where the two device MVMs run —
    dense ``jnp`` matmuls with optional multiplicative read noise, sparse
    BCOO/BCSR contractions over the stored nonzeros (same noise hooks;
    the paper-scale sparse workload class), the differential-pair Pallas
    crossbar kernel (``kernels.ops.crossbar_mvm`` against the single
    programmed symmetric block M), a shard_map psum-tiled operator over
    a device mesh, or a host-side ``Accel`` handle (crossbar simulation
    with an energy ledger).
  * **update backend** (``Updates``): how the proximal vector algebra
    runs — reference ``jnp`` (one expression per update) or the fused
    Pallas kernels (``kernels.ops.primal_update`` / ``dual_update``, one
    VMEM pass per vector), selected by ``PDHGOptions.kernel`` with
    interpret-mode auto-detection from ``kernels.ops._interpret_default``.

Iteration state is carried in the *pre-extrapolated* form: ``x_bar`` for
iteration k is computed at the END of iteration k-1 (fused into the
primal update — exactly what the Pallas kernel emits), and ``tau/sigma``
already include iteration k's deterministic-adaptation factor theta_k.
This is algebraically identical to Algorithm 4's ordering: theta_{k}
depends only on tau_{k-1}, which is known when iteration k-1 retires.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .residuals import kkt_residuals
from .symblock import MODE_AX, MODE_ATY, matmul_accel

KERNELS = ("jnp", "pallas")
SPARSE_KERNELS = ("ell", "bcoo")
STEP_RULES = ("fixed", "adaptive", "strongly_convex")

# Adaptive step-rule tuning (``step_rule="adaptive"``): log-space
# smoothing weight for the PDLP primal-weight updates, and the trust
# region confining the weight around its data-driven initial value.
# Rebalancing happens ONLY at check boundaries (weight moves at restart
# events, the down-only scale safeguard at every boundary), so within a
# ``check_every`` window tau/sigma are constants and the fused
# megakernel window stays a single launch.  The step-scale product
# sqrt(tau*sigma) is never grown past the global-norm value: for the
# bilinear saddle dynamics tau*sigma*rho^2 <= eta^2 is NECESSARY (the
# dual is unconstrained, so overshoot diverges along the top singular
# pair) — adaptivity lives entirely in the primal/dual SPLIT of the
# budget plus the downside safeguard.
ADAPT_SMOOTH = 0.5         # exp(s*log(target) + (1-s)*log(old))
ADAPT_OMEGA_CLIP = 1024.0  # omega confined to [omega0/1024, omega0*1024]
_ADAPT_TINY = 1e-30        # degenerate-movement / div-by-zero guard


# ---------------------------------------------------------------- state ---

class PDHGState(NamedTuple):
    """Carried PDHG iterate (a pytree; safe in lax loops and shard_map).

    ``tau``/``sigma`` are the CURRENT iteration's step sizes (theta_k
    already applied); ``x_bar`` is the current iteration's extrapolated
    point; ``x_prev`` feeds the r_iter residual at check time.
    """

    x: jax.Array
    x_prev: jax.Array
    x_bar: jax.Array
    y: jax.Array
    tau: jax.Array
    sigma: jax.Array


class Operator(NamedTuple):
    """The two device MVMs of one iteration.  ``fwd(v, key) ~ K v`` (dual
    step), ``adj(v, key) ~ K^T v`` (primal step); ``key`` seeds per-MVM
    read noise and may be ``None`` on noiseless backends.

    ``fuse(state, n_steps) -> (state', x_sum, y_sum)`` is the optional
    megakernel hook: one launch running ``n_steps`` full PDHG half-steps
    (the check-interval fusion window) and returning the new state plus
    the window's ergodic sums.  ``pdhg_loop`` uses it in place of the
    per-step ``fori_loop`` when present; only noiseless backends mount
    it (no per-MVM keys can be split inside the kernel)."""

    fwd: Callable
    adj: Callable
    name: str = "dense"
    fuse: Optional[Callable] = None


class Updates(NamedTuple):
    """The proximal vector algebra of one iteration.

    primal(x, kty, c, T, lb, ub, tau, theta) -> (x_new, x_bar_next)
    dual(y, kxbar, b, Sigma, sigma)          -> y_new
    """

    primal: Callable
    dual: Callable
    name: str = "jnp"


# ---------------------------------------------------- operator backends ---

def _read_noise(w, key, sigma_read):
    """Multiplicative cycle-to-cycle read noise, truncated at 4 sigma so
    Assumption 3 (bounded perturbation) holds exactly."""
    g = jnp.clip(jax.random.normal(key, w.shape, w.dtype), -4.0, 4.0)
    return w * (1.0 + sigma_read * g)


def dense_operator(K_fwd, K_adj, sigma_read: float = 0.0) -> Operator:
    """Dense jnp backend.  On an ideal device ``K_adj == K_fwd.T``; on a
    programmed crossbar the two blocks of M are physically distinct cells
    and carry independent programming error."""

    def fwd(v, key=None):
        w = K_fwd @ v
        if sigma_read > 0.0:
            w = _read_noise(w, key, sigma_read)
        return w

    def adj(v, key=None):
        w = K_adj @ v
        if sigma_read > 0.0:
            w = _read_noise(w, key, sigma_read)
        return w

    return Operator(fwd, adj, "dense")


def sparse_operator(K_sp, sigma_read: float = 0.0) -> Operator:
    """Sparse jnp backend over a ``jax.experimental.sparse`` matrix
    (BCOO or BCSR): the two MVMs contract only the stored nonzeros, so
    paper-scale sparse LPs never materialize a dense K on device.  The
    read-noise hook matches ``dense_operator`` exactly — a crossbar only
    programs the nonzero conductances, and cycle-to-cycle noise rides on
    the accumulated currents either way.

    The adjoint is a transpose VIEW taken once at trace time (BCSR drops
    to BCOO for it — BCSR has no native transpose); no index shuffling
    happens inside the iteration.
    """
    from jax.experimental import sparse as jsparse  # deferred

    K_adj = (K_sp.to_bcoo() if isinstance(K_sp, jsparse.BCSR) else K_sp).T

    def fwd(v, key=None):
        w = K_sp @ v
        if sigma_read > 0.0:
            w = _read_noise(w, key, sigma_read)
        return w

    def adj(v, key=None):
        w = K_adj @ v
        if sigma_read > 0.0:
            w = _read_noise(w, key, sigma_read)
        return w

    return Operator(fwd, adj, "sparse")


def sparse_ell_operator(data_f, cols_f, data_a, cols_a,
                        sigma_read: float = 0.0,
                        use_pallas: Optional[bool] = None) -> Operator:
    """Row-blocked ELL backend (``kernels.sparse_mvm``): the forward MVM
    contracts the ELL form of K (data_f/cols_f, (m, Wf)), the adjoint a
    separately stored ELL of K^T (data_a/cols_a, (n, Wa)) — both are
    gather + axis-1 reductions, no scatter anywhere in the iteration.
    The read-noise hook matches ``dense_operator`` exactly.

    ``use_pallas=None`` auto-selects the vectorized jnp gather path on
    CPU and the Pallas kernel on accelerators; pass True to force the
    Pallas kernel (interpreted on CPU) for parity validation."""
    from ..kernels import sparse_mvm as _ell  # deferred: keep core light

    def fwd(v, key=None):
        w = _ell.ell_matvec(data_f, cols_f, v, use_pallas=use_pallas)
        if sigma_read > 0.0:
            w = _read_noise(w, key, sigma_read)
        return w

    def adj(v, key=None):
        w = _ell.ell_matvec(data_a, cols_a, v, use_pallas=use_pallas)
        if sigma_read > 0.0:
            w = _read_noise(w, key, sigma_read)
        return w

    return Operator(fwd, adj, "sparse_ell")


def accel_operator(accel) -> Operator:
    """Host-loop backend over an encoded ``symblock.Accel`` handle (MVM
    stats feed the energy ledger; the backend brings its own physics)."""

    def fwd(v, key=None):
        return matmul_accel(accel, v, MODE_AX, key=key)

    def adj(v, key=None):
        return matmul_accel(accel, v, MODE_ATY, key=key)

    return Operator(fwd, adj, f"accel({accel.name})")


def crossbar_operator(g_pos, g_neg, scale, m: int, n: int,
                      sigma_read: float = 0.0, interpret=None) -> Operator:
    """Differential-pair Pallas backend against the SINGLE programmed
    symmetric block M (Algorithm 2): both MVM modes are zero-padded reads
    of the same (R, C) conductance array, exactly the paper's access
    pattern.  Read noise is a per-row multiplicative sample folded into
    the kernel's output gain."""
    from ..kernels import ops  # deferred: keep core import-light

    R, C = g_pos.shape

    def _mvm(v_full, key):
        if sigma_read > 0.0:
            noise = sigma_read * jnp.clip(
                jax.random.normal(key, (R,), v_full.dtype), -4.0, 4.0)
        else:
            noise = jnp.zeros((R,), v_full.dtype)
        return ops.crossbar_mvm(g_pos, g_neg, v_full, scale, noise,
                                interpret=interpret)

    def fwd(x, key=None):
        v = jnp.zeros((C,), x.dtype).at[m:m + n].set(x)
        return _mvm(v, key)[:m]

    def adj(y, key=None):
        v = jnp.zeros((C,), y.dtype).at[:m].set(y)
        return _mvm(v, key)[m:m + n]

    return Operator(fwd, adj, "crossbar")


def sharded_operator(K_loc, row_axis, col_axis) -> Operator:
    """shard_map psum-tiled backend: each device owns a static (m_loc,
    n_loc) tile of K; ``fwd`` psums partial products over the column
    axis ("sum the currents along a crossbar grid row"), ``adj`` over the
    row axes.  Tiles may be a narrower dtype than the vectors (bf16
    "conductances"); accumulation is at least f32 and never *below* the
    tile dtype (f64 tiles accumulate in f64)."""
    acc_dt = jnp.promote_types(K_loc.dtype, jnp.float32)

    def fwd(v, key=None):
        w = jax.lax.dot_general(
            K_loc, v.astype(K_loc.dtype),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=acc_dt,
        )
        return jax.lax.psum(w.astype(v.dtype), col_axis)

    def adj(v, key=None):
        w = jax.lax.dot_general(
            K_loc, v.astype(K_loc.dtype),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=acc_dt,
        )
        return jax.lax.psum(w.astype(v.dtype), row_axis)

    return Operator(fwd, adj, "sharded")


# ------------------------------------------------- megakernel (fused) ---

def make_fused_dense(K_fwd, K_adj, b, c, lb, ub, T, Sigma, gamma,
                     interpret=None) -> Callable:
    """``Operator.fuse`` hook for the dense backend: one
    ``kernels.pdhg_megakernel`` launch per check-interval window.
    Noiseless only — the caller guarantees ``sigma_read == 0``."""
    from ..kernels import pdhg_megakernel as _mega  # deferred

    def fuse(state: PDHGState, n_steps: int):
        (x, x_prev, x_bar, y, tau, sigma, xs, ys) = _mega.fused_dense_steps(
            K_fwd, K_adj, b, c, lb, ub, T, Sigma,
            state.x, state.x_prev, state.x_bar, state.y,
            state.tau, state.sigma,
            n_steps=int(n_steps), gamma=float(gamma), interpret=interpret)
        return (PDHGState(x=x, x_prev=x_prev, x_bar=x_bar, y=y,
                          tau=tau, sigma=sigma), xs, ys)

    return fuse


def make_fused_ell(data_f, cols_f, data_a, cols_a, b, c, lb, ub, T,
                   Sigma, gamma, interpret=None) -> Callable:
    """``Operator.fuse`` hook for the ELL backend (same contract as
    ``make_fused_dense``, operands in ELL form)."""
    from ..kernels import pdhg_megakernel as _mega  # deferred

    def fuse(state: PDHGState, n_steps: int):
        (x, x_prev, x_bar, y, tau, sigma, xs, ys) = _mega.fused_ell_steps(
            data_f, cols_f, data_a, cols_a, b, c, lb, ub, T, Sigma,
            state.x, state.x_prev, state.x_bar, state.y,
            state.tau, state.sigma,
            n_steps=int(n_steps), gamma=float(gamma), interpret=interpret)
        return (PDHGState(x=x, x_prev=x_prev, x_bar=x_bar, y=y,
                          tau=tau, sigma=sigma), xs, ys)

    return fuse


# ------------------------------------------------------ update backends ---

def _primal_jnp(x, kty, c, T, lb, ub, tau, theta):
    x_new = jnp.clip(x - tau * T * (c - kty), lb, ub)
    return x_new, x_new + theta * (x_new - x)


def _dual_jnp(y, kxbar, b, Sigma, sigma):
    return y + sigma * Sigma * (b - kxbar)


JNP_UPDATES = Updates(_primal_jnp, _dual_jnp, "jnp")


def make_updates(kernel: str = "jnp", interpret=None) -> Updates:
    """Update-backend factory keyed by ``PDHGOptions.kernel``.

    ``interpret=None`` auto-detects per ``kernels.ops._interpret_default``
    (interpreted on CPU, compiled Mosaic on real TPU)."""
    if kernel == "jnp":
        return JNP_UPDATES
    if kernel == "pallas":
        from ..kernels import ops  # deferred: keep core import-light

        def primal(x, kty, c, T, lb, ub, tau, theta):
            return ops.primal_update(x, kty, c, T, lb, ub, tau, theta,
                                     interpret=interpret)

        def dual(y, kxbar, b, Sigma, sigma):
            return ops.dual_update(y, kxbar, b, Sigma, sigma,
                                   interpret=interpret)

        return Updates(primal, dual, "pallas")
    raise ValueError(f"unknown update kernel {kernel!r}; expected "
                     f"{KERNELS}")


# ------------------------------------------------------------ iteration ---

def init_state(x0, y0, tau0, sigma0, gamma) -> PDHGState:
    """Enter engine state: apply iteration 1's theta to (tau0, sigma0)
    and seed the extrapolation at x_bar_1 = x0 (x_prev = x0)."""
    tau0 = jnp.asarray(tau0, x0.dtype)
    sigma0 = jnp.asarray(sigma0, x0.dtype)
    theta1 = 1.0 / jnp.sqrt(1.0 + 2.0 * gamma * tau0)
    return PDHGState(x=x0, x_prev=x0, x_bar=x0, y=y0,
                     tau=theta1 * tau0, sigma=sigma0 / theta1)


def pdhg_step(op: Operator, upd: Updates, b, c, lb, ub, T, Sigma, gamma,
              state: PDHGState, k1=None, k2=None) -> PDHGState:
    """ONE enhanced-PDHG iteration (paper Algorithm 4, eq. 7 signs).

        y_{k+1} = y_k + sigma_k Sigma (b - K x_bar_k)        # device MVM 1
        x_{k+1} = proj(x_k - tau_k T (c - K^T y_{k+1}))      # device MVM 2
        theta_{k+1} = 1/sqrt(1 + 2 gamma tau_k)
        x_bar_{k+1} = x_{k+1} + theta_{k+1} (x_{k+1} - x_k)  # fused above
        tau_{k+1} = theta_{k+1} tau_k; sigma_{k+1} = sigma_k / theta_{k+1}

    ``k1``/``k2`` seed the two MVMs' read noise (``None`` on noiseless
    backends).  All step math lives HERE — no caller re-implements it.
    """
    Kxbar = op.fwd(state.x_bar, k1)
    y_n = upd.dual(state.y, Kxbar, b, Sigma, state.sigma)
    KTy = op.adj(y_n, k2)
    theta_n = 1.0 / jnp.sqrt(1.0 + 2.0 * gamma * state.tau)
    x_n, x_bar_n = upd.primal(state.x, KTy, c, T, lb, ub, state.tau, theta_n)
    return PDHGState(x=x_n, x_prev=state.x, x_bar=x_bar_n, y=y_n,
                     tau=theta_n * state.tau, sigma=state.sigma / theta_n)


def restart_state(state: PDHGState, x_new, y_new) -> PDHGState:
    """Adopt a restart point: x = x_prev = x_bar = x_new (momentum reset),
    keeping the tau/sigma schedule running."""
    return state._replace(x=x_new, x_prev=x_new, x_bar=x_new, y=y_new)


def adaptive_omega_init(tau0, sigma0, b, c, T, Sigma,
                        xsum=jnp.sum, ysum=jnp.sum):
    """Data-driven primal-weight initialization (the PDLP heuristic in
    the preconditioned metric): scale the primal weight
    ``omega = sqrt(sigma/tau)`` by ``sqrt(|T^1/2 c| / |Sigma^1/2 b|)``,
    the expected dual/primal movement ratio of the very first iterations
    (the dual residual is driven by ``Sigma^1/2 b``, the primal one by
    ``T^1/2 c``).  On scale-imbalanced instances — objective and rhs in
    mismatched units, which Ruiz equilibration of K cannot see — this
    alone recovers most of the adaptive win.  Composes with the user's
    ``opts.omega`` (multiplies it).  ``xsum``/``ysum`` reduce
    primal/dual vectors (the distributed path passes psum wrappers, so
    every shard derives the same global weight)."""
    dt = b.dtype
    tiny = jnp.asarray(_ADAPT_TINY, dt)
    nc2 = xsum(T * c * c)
    nb2 = ysum(Sigma * b * b)
    w = (jnp.maximum(nc2, tiny) / jnp.maximum(nb2, tiny)) ** 0.25
    w = jnp.clip(w, 1.0 / ADAPT_OMEGA_CLIP, ADAPT_OMEGA_CLIP)
    ok = jnp.logical_and(nc2 > tiny, nb2 > tiny)
    w = jnp.where(jnp.logical_and(ok, jnp.isfinite(w)), w, 1.0)
    return tau0 / w, sigma0 * w


def adaptive_shrink(tau, sigma, eta, dx, dy, Kdx, KTdy, T, Sigma, ok,
                    xsum=jnp.sum, ysum=jnp.sum):
    """Down-only local step-scale safeguard for ``step_rule="adaptive"``
    (Malitsky–Pock-flavored, backtracking free), applied at every check
    boundary with zero extra MVMs (``Kdx``/``KTdy`` come from the check
    MVMs by linearity: ``K dx = K x_new - K x_old``).

    The Rayleigh quotient along the window's movement,
    ``rho_loc^2 = (|S^1/2 K dx|^2 + |T^1/2 K^T dy|^2)
                  / (|T^-1/2 dx|^2 + |S^-1/2 dy|^2)``,
    is a LOWER bound on the true preconditioned operator norm — so
    whenever ``sqrt(tau*sigma) * rho_loc > eta`` the Lemma 2 coupling is
    provably violated (the Lanczos/power estimate was too small, e.g.
    few iterations or heavy read noise) and the scale is shrunk to
    ``eta / rho_loc``.  The product is NEVER grown: for the bilinear
    saddle dynamics ``tau*sigma*rho^2 <= 1`` is necessary, not just
    sufficient — any sustained overshoot diverges along the top singular
    pair, so there is no safe upside, only this downside protection.
    Identity when the estimate was sound.  Gated by ``ok`` (a valid
    previous boundary exists) and finiteness.
    """
    dt = dx.dtype
    tiny = jnp.asarray(_ADAPT_TINY, dt)
    ndx2 = xsum(dx * dx / T)
    ndy2 = ysum(dy * dy / Sigma)
    nK2 = ysum(Sigma * Kdx * Kdx) + xsum(T * KTdy * KTdy)
    mv2 = ndx2 + ndy2
    rho_loc = jnp.sqrt(nK2 / jnp.maximum(mv2, tiny))
    g = jnp.sqrt(tau * sigma)
    s = jnp.minimum(jnp.asarray(1.0, dt),
                    jnp.asarray(eta, dt) / jnp.maximum(rho_loc * g, tiny))
    ok = jnp.logical_and(ok, jnp.logical_and(mv2 > tiny, jnp.isfinite(s)))
    s = jnp.where(ok, s, 1.0)
    return tau * s, sigma * s


def adaptive_omega_update(tau, sigma, dx, dy, T, Sigma, w_lo, w_hi, ok,
                          xsum=jnp.sum, ysum=jnp.sum):
    """PDLP primal-weight rebalancing, applied at RESTART events only
    (restarts land on check boundaries, so the fused window stays one
    launch).  ``dx``/``dy`` are the movement since the previous restart
    anchor; the weight ``omega = sqrt(sigma/tau)`` is pulled toward the
    dual/primal movement ratio ``|dy|_S^-1/2 / |dx|_T^-1/2`` with
    PDLP's log-space smoothing (``ADAPT_SMOOTH``) and clipped to
    ``[w_lo, w_hi]`` (a trust region around the initial weight).
    Restart cadence matters: at raw window cadence the ratio chases its
    own effect (a bigger sigma moves the dual more, which asks for a
    bigger sigma — positive feedback); between restarts the movement
    reflects genuine progress scale.  The product tau*sigma (the Lemma 2
    budget) is preserved exactly."""
    dt = dx.dtype
    tiny = jnp.asarray(_ADAPT_TINY, dt)
    ndx2 = xsum(dx * dx / T)
    ndy2 = ysum(dy * dy / Sigma)
    ok = jnp.logical_and(ok, jnp.logical_and(ndx2 > tiny, ndy2 > tiny))
    w_old = jnp.sqrt(sigma / tau)
    ratio = jnp.sqrt(ndy2 / jnp.maximum(ndx2, tiny))
    w_new = jnp.exp(ADAPT_SMOOTH * jnp.log(jnp.maximum(ratio, tiny))
                    + (1.0 - ADAPT_SMOOTH) * jnp.log(
                        jnp.maximum(w_old, tiny)))
    w_new = jnp.clip(w_new, w_lo, w_hi)
    g = jnp.sqrt(tau * sigma)
    ok = jnp.logical_and(ok, jnp.isfinite(w_new))
    return (jnp.where(ok, g / w_new, tau),
            jnp.where(ok, g * w_new, sigma))


# ----------------------------------------------------------------- loop ---

def draw_init(key, m: int, n: int, lb, ub, dtype):
    """Paper's projected-Gaussian start; returns (key', x0, y0).  Every
    jitted path draws through here so backends share inits bit-for-bit."""
    key, kx, ky = jax.random.split(key, 3)
    x0 = jnp.clip(jax.random.normal(kx, (n,), dtype), lb, ub)
    y0 = jax.random.normal(ky, (m,), dtype)
    return key, x0, y0


def pdhg_loop(op: Operator, upd: Updates, b, c, lb, ub, T, Sigma,
              x0, y0, tau0, sigma0, key, *,
              max_iters: int, tol: float, gamma: float, check_every: int,
              restart_beta: float, restart: bool = True,
              step_rule: str = "fixed", eta: float = 0.95,
              xsum_fn: Optional[Callable] = None,
              ysum_fn: Optional[Callable] = None,
              residual_fn: Optional[Callable] = None):
    """The jitted solve loop every non-host path runs: ``check_every``
    fused iterations per ``lax.while_loop`` body, then one residual check
    on the current AND ergodic-average iterates with a PDLP-style
    adaptive restart.

    Check MVMs go through the SAME (possibly noisy) operator backend as
    the solve — 4 device MVMs per check with fresh keys (k3/k4 current,
    k5/k6 averaged; reusing them would correlate read noise between the
    two residual evaluations), matching the host driver and the energy
    ledger's 4-MVMs-per-check charge.  ``restart=False`` (a STATIC
    Python bool) removes the entire averaged-iterate block from the
    trace: no ergodic-average residual MVMs (checks drop to 2 MVMs —
    ``mvm_accounting`` mirrors this) and the averaged iterate is never
    adopted.  With noiseless operators the surviving iterates are
    bit-for-bit those of ``restart_beta = 0.0`` with restarts on, minus
    that trick's reliance on ``0.0 * inf == NaN`` comparing false.

    When ``op.fuse`` is mounted (megakernel mode), each check-interval
    window runs as ONE fused launch instead of ``check_every`` stepped
    launches; the check itself stays out here, so fused and unfused
    loops visit the same check points on the same iterates.

    ``step_rule`` is a STATIC Python string (one of ``STEP_RULES``):

      * ``"fixed"`` (default) and ``"strongly_convex"`` trace the exact
        loop this function has always traced — ``"strongly_convex"`` is
        just the explicit, validated opt-in for ``gamma > 0``'s
        accelerated ``theta_k`` schedule (the theta math lives in
        ``pdhg_step`` and is carried in tau/sigma either way; with
        ``gamma == 0`` every theta is exactly 1.0 and "fixed" is
        bitwise-identical to the historical behavior).
      * ``"adaptive"`` = PDLP-style primal-weight adaptation on top of
        the same loop: (a) ``adaptive_omega_init`` rescales
        (tau0, sigma0) from the problem data before the first iterate;
        (b) ``adaptive_omega_update`` rebalances the primal weight at
        RESTART events from the movement since the previous restart
        anchor (carried in the loop state); (c) ``adaptive_shrink``
        applies a down-only step-scale safeguard at every boundary from
        the window's Rayleigh quotient (reusing the check MVMs by
        linearity — zero extra MVMs).  tau/sigma move ONLY at check
        boundaries, so the fused megakernel window is untouched and
        stays one launch.  ``eta`` is the Lemma 2 safety factor the
        safeguard enforces; ``xsum_fn``/``ysum_fn`` let the distributed
        path psum every rebalance reduction.  With ``restart=False``
        only (a) and (c) are active.

    ``residual_fn(x, x_prev, y, Kx, KTy) -> scalar merit`` defaults to
    the dense KKT residual max; the distributed path passes its
    psum-reduced variant.  Returns ``(x, y, iterations, merit)``.
    """
    if step_rule not in STEP_RULES:
        raise ValueError(f"unknown step_rule {step_rule!r}; expected one "
                         f"of {STEP_RULES}")
    adaptive = step_rule == "adaptive"
    xsum = jnp.sum if xsum_fn is None else xsum_fn
    ysum = jnp.sum if ysum_fn is None else ysum_fn
    if residual_fn is None:
        def residual_fn(x, x_prev, y, Kx, KTy):
            return kkt_residuals(x, x_prev, y, c, b, Kx, KTy,
                                 lb=lb, ub=ub).max

    dt = x0.dtype
    if adaptive:
        tau0, sigma0 = adaptive_omega_init(
            jnp.asarray(tau0, dt), jnp.asarray(sigma0, dt),
            b, c, T, Sigma, xsum, ysum)
        w0 = jnp.sqrt(sigma0 / tau0)
        w_lo = w0 / jnp.asarray(ADAPT_OMEGA_CLIP, dt)
        w_hi = w0 * jnp.asarray(ADAPT_OMEGA_CLIP, dt)
    state0 = init_state(x0, y0, tau0, sigma0, gamma)

    def half_iter(_, carry):
        state, xs, ys, cnt, rk = carry
        rk, k1, k2 = jax.random.split(rk, 3)
        state = pdhg_step(op, upd, b, c, lb, ub, T, Sigma, gamma,
                          state, k1, k2)
        return (state, xs + state.x, ys + state.y, cnt + 1.0, rk)

    def body(loop):
        if adaptive:
            (state, it, merit, xs, ys, cnt, m_restart, rk,
             ax, ay, aKx, aKTy, aok, rx, ry) = loop
        else:
            state, it, merit, xs, ys, cnt, m_restart, rk = loop
        if op.fuse is not None:
            # megakernel window: one fused launch, no per-step keys
            # (fused backends are noiseless, so none are consumed)
            state, dxs, dys = op.fuse(state, check_every)
            xs, ys = xs + dxs, ys + dys
            cnt = cnt + jnp.asarray(check_every, cnt.dtype)
        else:
            state, xs, ys, cnt, rk = jax.lax.fori_loop(
                0, check_every, half_iter, (state, xs, ys, cnt, rk))
        rk, k3, k4 = jax.random.split(rk, 3)
        Kx = op.fwd(state.x, k3)
        KTy = op.adj(state.y, k4)
        merit = residual_fn(state.x, state.x_prev, state.y, Kx, KTy)
        Kx_c, KTy_c = Kx, KTy
        if restart:
            x_avg = xs / jnp.maximum(cnt, 1.0)
            y_avg = ys / jnp.maximum(cnt, 1.0)
            rk, k5, k6 = jax.random.split(rk, 3)
            Kxa = op.fwd(x_avg, k5)
            KTya = op.adj(y_avg, k6)
            merit_avg = residual_fn(x_avg, x_avg, y_avg, Kxa, KTya)
            do_restart = merit_avg < restart_beta * m_restart
            use_avg = jnp.logical_or(
                jnp.logical_and(do_restart, merit_avg < merit),
                merit_avg <= tol,  # adopt the average if it satisfies tol
            )
            pick = lambda a, cur: jnp.where(use_avg, a, cur)  # noqa: E731
            state = state._replace(
                x=pick(x_avg, state.x), x_prev=pick(x_avg, state.x_prev),
                x_bar=pick(x_avg, state.x_bar), y=pick(y_avg, state.y))
            m_restart = jnp.where(do_restart,
                                  jnp.minimum(merit_avg, merit), m_restart)
            xs = jnp.where(do_restart, jnp.zeros_like(xs), xs)
            ys = jnp.where(do_restart, jnp.zeros_like(ys), ys)
            cnt = jnp.where(do_restart, 0.0, cnt)
            # the carried merit must be the merit of the iterate actually
            # CARRIED: min(merit, merit_avg) used to adopt the averaged
            # iterate's (lower) merit even when the state kept the
            # current iterate, so exits reported a residual the returned
            # solution does not satisfy.
            merit = jnp.where(use_avg, merit_avg, merit)
            if adaptive:
                # operator images of the iterate actually carried — by
                # linearity, no extra MVMs beyond the check's
                Kx_c, KTy_c = pick(Kxa, Kx), pick(KTya, KTy)
                tau_n, sigma_n = adaptive_omega_update(
                    state.tau, state.sigma, state.x - rx, state.y - ry,
                    T, Sigma, w_lo, w_hi, do_restart, xsum, ysum)
                state = state._replace(tau=tau_n, sigma=sigma_n)
                rx = jnp.where(do_restart, state.x, rx)
                ry = jnp.where(do_restart, state.y, ry)
        if adaptive:
            tau_n, sigma_n = adaptive_shrink(
                state.tau, state.sigma, eta,
                state.x - ax, state.y - ay, Kx_c - aKx, KTy_c - aKTy,
                T, Sigma, aok, xsum, ysum)
            state = state._replace(tau=tau_n, sigma=sigma_n)
            return (state, it + check_every, merit, xs, ys, cnt,
                    m_restart, rk, state.x, state.y, Kx_c, KTy_c,
                    jnp.asarray(True), rx, ry)
        return (state, it + check_every, merit, xs, ys, cnt, m_restart, rk)

    def cond(loop):
        it, merit = loop[1], loop[2]
        return jnp.logical_and(it < max_iters, merit > tol)

    init = (state0, jnp.asarray(0, jnp.int32), jnp.asarray(jnp.inf, dt),
            jnp.zeros_like(x0), jnp.zeros_like(y0), jnp.asarray(0.0, dt),
            jnp.asarray(jnp.inf, dt), key)
    if adaptive:
        # window baselines for the first boundary are placeholders
        # (aok=False masks them until a boundary has been recorded);
        # the restart anchors (rx, ry) start at the true initial iterate.
        init = init + (x0, y0, jnp.zeros_like(y0), jnp.zeros_like(x0),
                       jnp.asarray(False), x0, y0)
    state, it, merit = jax.lax.while_loop(cond, body, init)[:3]
    return state.x, state.y, it, merit


# ----------------------------------------------------- jit core + ledger ---

def solve_core(K_fwd, K_adj, b, c, lb, ub, T, Sigma, rho, key, static, *,
               operator: Optional[Operator] = None, x0=None, y0=None):
    """The jitted solve core (formerly ``pdhg._solve_jit_core``).

    ``static`` is the hashable tuple from ``pdhg.opts_static``:
    (max_iters, tol, eta, omega, gamma, check_every, restart_beta,
    sigma_read, kernel).  ``sigma_read`` > 0 adds multiplicative
    cycle-to-cycle read noise per MVM — residual checks included —
    and ``kernel`` selects the update backend (jnp | pallas).

    ``operator`` swaps the MVM backend (e.g. the differential-pair
    crossbar kernel or the row-blocked ELL operator) in place of the
    default dense one — ``K_fwd``/``K_adj`` may then be ``None``; the
    step-size initialization, init draws, and option plumbing stay HERE
    either way (problem dims come from ``b``/``c``).  ``K_fwd`` may be
    a ``jax.experimental.sparse`` matrix (BCOO/BCSR): the default
    operator is then ``sparse_operator`` and ``K_adj`` is ignored (the
    adjoint is a transpose view of the same nonzeros).

    Trailing static entries past the original 9 are optional (older
    9-tuples keep their exact semantics): ``restart`` (explicit restart
    gate, default True), ``sparse_kernel`` (executable-cache
    discriminator for the sparse backend — the stacking layer picks the
    operator), ``megakernel`` (fuse each check window into one launch;
    auto-mounted on the dense backend at ``sigma_read == 0``),
    ``step_rule`` (one of ``STEP_RULES``, default ``"fixed"`` — see
    ``pdhg_loop``).  Entries 13/14 (``refine_rounds``/``refine_tol``)
    belong to the digital refinement shell around this core
    (``crossbar.refine``) and are ignored here.

    ``x0``/``y0`` warm-start the loop (both or neither); by default the
    paper's projected-Gaussian init is drawn from ``key``.  The
    refinement shell passes zeros — the correction LP's origin IS the
    previous outer iterate in shifted coordinates.
    """
    (max_iters, tol, eta, omega, gamma, check_every, restart_beta,
     sigma_read, kernel) = static[:9]
    # ``static`` is the jit static-arg tuple — plain Python values at
    # trace time, so these bool() calls never touch the device
    restart = bool(static[9]) if len(static) > 9 else True  # jaxlint: disable=R5
    megakernel = bool(static[11]) if len(static) > 11 else False  # jaxlint: disable=R5
    step_rule = str(static[12]) if len(static) > 12 else "fixed"
    m, n = b.shape[0], c.shape[0]
    # an all-zero operator (degenerate but legal: the optimum is just the
    # box projection of -c's direction) has rho = 0; unguarded it makes
    # tau0 = inf and NaNs the very first update
    rho = jnp.maximum(rho, jnp.asarray(1e-12, b.dtype))
    tau0 = eta / (omega * rho)
    sigma0 = eta * omega / rho
    if x0 is None:
        key, x0, y0 = draw_init(key, m, n, lb, ub, b.dtype)
    if operator is None:
        if hasattr(K_fwd, "todense"):   # JAXSparse (BCOO/BCSR), not ndarray
            operator = sparse_operator(K_fwd, sigma_read)
        else:
            operator = dense_operator(K_fwd, K_adj, sigma_read)
    if (megakernel and operator.fuse is None and sigma_read == 0.0
            and operator.name == "dense"):
        operator = operator._replace(fuse=make_fused_dense(
            K_fwd, K_adj, b, c, lb, ub, T, Sigma, gamma))
    return pdhg_loop(
        operator, make_updates(kernel),
        b, c, lb, ub, T, Sigma, x0, y0, tau0, sigma0, key,
        max_iters=max_iters, tol=tol, gamma=gamma, check_every=check_every,
        restart_beta=restart_beta, restart=restart,
        step_rule=step_rule, eta=eta,
    )


def lemma2_margin(rho, sigma_read: float):
    """Widen a NOISY operator-norm estimate so the step-size coupling
    tau*sigma*rho^2 < 1 (Lemma 2) holds for the TRUE norm despite the
    read noise in the Lanczos MVMs.  Identity when noiseless; callers
    skip it entirely under ``opts.norm_override`` (a trusted norm)."""
    if sigma_read <= 0.0:
        return rho
    return rho / (1.0 - min(4.0 * sigma_read, 0.5))


# Per-window accounting pieces.  These three are the GROUND TRUTH the
# trace-level audit (tools/traceaudit) independently reproduces by
# counting MVM-bearing primitives in the jaxpr of every solver path —
# change any of them and the audit fails until the traced computation
# (or TRACE_BASELINE.json) agrees again.

#: MVMs per PDHG half-iteration pair: one forward (K @ x_bar) for the
#: dual update + one adjoint (K^T @ y) for the primal update.
MVMS_PER_ITERATION = 2


def mvms_per_check(restart: bool = True) -> int:
    """MVMs charged per residual check: an x/y pair for the current
    iterate, plus a second pair for the averaged iterate when restarts
    are enabled (with ``restart=False`` the averaged pair is never
    evaluated)."""
    return 4 if restart else 2


def mvm_window_budget(check_every: int, restart: bool = True) -> int:
    """MVMs per while_loop body execution (one check window): the
    ``check_every`` fused/stepped PDHG iterations plus the residual
    check.  ``step_rule="adaptive"`` rebalances from already-computed
    quantities and adds exactly zero — the traceaudit budget checker
    asserts this per path."""
    return MVMS_PER_ITERATION * check_every + mvms_per_check(restart)


def mvm_accounting(iterations: int, check_every: int,
                   lanczos_iters: int, restart: bool = True) -> int:
    """Device-MVM total for the energy ledger, shared by every jitted
    path: norm estimation (1 MVM per Lanczos/power iteration; 0 under
    ``norm_override``) + PDHG (``MVMS_PER_ITERATION``/iter) + residual
    checks (``mvms_per_check(restart)`` each).

    ``iterations`` on EVERY jitted path — stepped fori_loop and fused
    megakernel alike — advances by ``check_every`` per while_loop body,
    so reported iteration counts (and therefore this charge) quantize to
    ``check_every`` multiples: convergence mid-window is only observed
    at the next boundary, and the work (and energy) for the full window
    was genuinely spent.  Megakernel and stepped paths agree exactly —
    a test pins this (``tests/test_step_rules.py``)."""
    n_checks = max(1, iterations // max(1, check_every))
    return (lanczos_iters + MVMS_PER_ITERATION * iterations
            + mvms_per_check(restart) * n_checks)


def refine_digital_mvms(refine_rounds: int) -> int:
    """Exact (digital, full-precision) MVMs the iterative-refinement
    shell (``crossbar.refine``) issues OUTSIDE the analog while loops:
    one (Kx, K^Ty) baseline pair before the first round plus one
    candidate-evaluation pair per round.  These run on the digital
    co-processor against the exact operator — they are NOT analog reads
    and are never charged to the crossbar read ledger; the traceaudit
    budget analyzer uses this count to tell sanctioned digital residual
    MVMs apart from unledgered analog reads leaking out of the loop."""
    return 0 if refine_rounds <= 0 else 2 + 2 * refine_rounds


def refine_window_factor(refine_rounds: int) -> int:
    """Number of analog while-loop solves a refined path runs (the
    original solve plus one correction solve per round) — each is a full
    ``pdhg_loop`` whose windows charge ``mvm_window_budget`` MVMs.  The
    traceaudit budget analyzer multiplies the per-window budget by this
    when auditing refined paths."""
    return 1 + max(0, refine_rounds)
