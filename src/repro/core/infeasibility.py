"""Infeasibility certificates from PDHG iterate sequences (paper §2.3).

Following Applegate et al. [51], the difference sequence
d_k = z_{k+1} - z_k (and the normalized average iterate) converges to a
ray whose dual part is a Farkas certificate when the primal is infeasible:

    y with  K^T y <= 0 (componentwise, on coordinates with finite lb only;
                        here: standard form x >= 0)  and  b^T y > 0
    certifies  {x >= 0 : Kx = b} = empty.

We expose a checker over a candidate ray; the host solver feeds it the
difference iterate when divergence is detected.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Certificate:
    kind: str            # "primal_infeasible" | "none"
    violation: float     # max(K^T y)_+ (should be ~0 for a valid cert)
    improvement: float   # b^T y (should be > 0)
    y_ray: np.ndarray | None = None


def check_farkas(K, b, y_ray, tol: float = 1e-6) -> Certificate:
    """Is y_ray a (normalized) Farkas certificate of primal infeasibility?"""
    y = np.asarray(y_ray, dtype=np.float64)
    nrm = np.linalg.norm(y)
    if nrm < tol:
        return Certificate("none", np.inf, 0.0)
    y = y / nrm
    KTy = np.asarray(K).T @ y
    violation = float(np.maximum(KTy, 0.0).max(initial=0.0))
    improvement = float(np.asarray(b) @ y)
    ok = violation <= tol * 10 and improvement > tol
    return Certificate(
        "primal_infeasible" if ok else "none",
        violation=violation,
        improvement=improvement,
        y_ray=y,
    )


def difference_ray(z_hist: np.ndarray) -> np.ndarray:
    """Average difference direction 2*avg(z_k - z_0)/(k+1) (paper §2.3)."""
    z_hist = np.asarray(z_hist)
    k = z_hist.shape[0] - 1
    if k < 1:
        return np.zeros_like(z_hist[0])
    zbar = (z_hist[-1] - z_hist[0]) / 2.0
    return 2.0 * zbar / (k + 1)
