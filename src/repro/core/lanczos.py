"""Operator-norm estimation (paper §3.2, Algorithm 3).

Power iteration (eq. 8) is the classical choice; the paper adopts the
Lanczos iteration on the symmetric block M because it is markedly more
robust to analog MVM noise (Theorem 1: the ergodic Ritz estimate obeys
O(1/K) + O(K * eps_max)).  Proposition 1: lambda_max(M) == sigma_max(K),
so a Lanczos run on M estimates ||K||_2 directly with ONE device MVM per
iteration.

Two implementations:
  * ``lanczos_svd``      — host loop over an arbitrary Accel backend
                           (crossbar sim, energy ledger, noise keys).
  * ``lanczos_svd_jit``  — fixed-iteration lax.scan, fully jittable
                           (used by the distributed/perf paths).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .symblock import MODE_FULL, Accel, matmul_accel

# norm estimators selectable by ``PDHGOptions.norm_backend`` on the
# jitted prep paths; both cost ONE symmetric-block MVM per iteration, so
# the energy ledger charges them identically
NORM_BACKENDS = ("lanczos", "power")


@dataclasses.dataclass
class LanczosResult:
    sigma_max: float          # estimated dominant singular value of K
    iterations: int
    alphas: np.ndarray
    betas: np.ndarray
    ritz_history: np.ndarray  # largest Ritz value after each iteration
    ergodic_estimate: float   # mean of ritz_history (Theorem 1 estimator)


def lanczos_svd(
    accel: Accel,
    k_max: int = 64,
    tol: float = 1e-8,
    key: Optional[jax.Array] = None,
    reorthogonalize: bool = True,
    noise_keys: bool = False,
) -> LanczosResult:
    """Algorithm 3 (LanczosSVD) on the encoded symmetric block M.

    One full-vector device MVM per iteration.  ``reorthogonalize`` applies
    full re-orthogonalization against all previous basis vectors (the
    paper's Lemma 1 setting, essential under device noise).
    """
    dim = accel.m + accel.n
    if key is None:
        # deliberate: the default start vector must be reproducible so
        # norm estimates (and thus step sizes) are stable run-to-run
        key = jax.random.PRNGKey(0)  # jaxlint: disable=R2
    key, sub = jax.random.split(key)
    v = jax.random.normal(sub, (dim,))
    v = v / jnp.linalg.norm(v)
    v_prev = jnp.zeros_like(v)
    beta = 0.0
    alphas, betas, ritz_hist = [], [], []
    basis = [v]
    for j in range(k_max):
        if noise_keys:
            key, sub = jax.random.split(key)
            w = matmul_accel(accel, v, MODE_FULL, key=sub)
        else:
            w = matmul_accel(accel, v, MODE_FULL)
        w = w - beta * v_prev
        alpha = float(jnp.vdot(v, w))
        w = w - alpha * v
        if reorthogonalize:
            for q in basis:
                w = w - jnp.vdot(q, w) * q
        beta_next = float(jnp.linalg.norm(w))
        alphas.append(alpha)
        betas.append(beta_next)
        T = _tridiag(alphas, betas[:-1])
        ritz = float(np.max(np.abs(np.linalg.eigvalsh(T))))
        ritz_hist.append(ritz)
        # breakdown when beta hits the requested tol OR the fp-roundoff
        # floor of the working dtype (an absolute 1e-10 can never trigger
        # in f32, where residual norms bottom out around eps * ||M||)
        eps_floor = 8.0 * float(jnp.finfo(w.dtype).eps) * max(ritz, 1.0)
        if beta_next < max(tol, eps_floor):
            break
        v_prev = v
        v = w / beta_next
        basis.append(v)
        beta = beta_next
    ritz_hist = np.asarray(ritz_hist)
    return LanczosResult(
        sigma_max=float(ritz_hist[-1]),
        iterations=len(alphas),
        alphas=np.asarray(alphas),
        betas=np.asarray(betas),
        ritz_history=ritz_hist,
        ergodic_estimate=float(ritz_hist.mean()),
    )


def _tridiag(alphas, betas) -> np.ndarray:
    k = len(alphas)
    T = np.zeros((k, k))
    T[np.arange(k), np.arange(k)] = alphas
    if k > 1:
        T[np.arange(k - 1), np.arange(1, k)] = betas
        T[np.arange(1, k), np.arange(k - 1)] = betas
    return T


def lanczos_svd_jit_mv(matvec, dim: int, dtype, k_max: int = 32,
                       key=None) -> jnp.ndarray:
    """Jitted fixed-iteration Lanczos on an arbitrary symmetric matvec.

    The operator enters only through ``matvec(v) -> M v`` — sparse
    pipelines pass a BCOO/COO contraction over the symmetric block M
    here and never build M densely.  Returns the largest |Ritz value| of
    the k_max-step tridiagonalization; no early exit (fixed cost).
    """
    if key is None:
        # deliberate: reproducible default start vector (see lanczos_svd)
        key = jax.random.PRNGKey(0)  # jaxlint: disable=R2
    v0 = jax.random.normal(key, (dim,), dtype=dtype)
    v0 = v0 / jnp.linalg.norm(v0)

    def step(carry, _):
        v_prev, v, beta = carry
        w = matvec(v)
        w = w - beta * v_prev
        alpha = jnp.vdot(v, w)
        w = w - alpha * v
        beta_next = jnp.linalg.norm(w)
        v_next = jnp.where(beta_next > 1e-30, w / beta_next, w)
        return (v, v_next, beta_next), (alpha, beta_next)

    (_, _, _), (alphas, betas) = jax.lax.scan(
        step, (jnp.zeros_like(v0), v0, jnp.asarray(0.0, dtype)),
        None, length=k_max,
    )
    T = jnp.diag(alphas) + jnp.diag(betas[:-1], 1) + jnp.diag(betas[:-1], -1)
    return jnp.max(jnp.abs(jnp.linalg.eigvalsh(T)))


def lanczos_svd_jit(M: jnp.ndarray, k_max: int = 32, key=None) -> jnp.ndarray:
    """Jitted fixed-iteration Lanczos on a dense symmetric M.

    Returns the largest |Ritz value| of the k_max-step tridiagonalization.
    No early exit (fixed cost) — used inside jitted solver pipelines and
    the distributed dry-run.
    """
    return lanczos_svd_jit_mv(lambda v: M @ v, M.shape[0], M.dtype,
                              k_max=k_max, key=key)


def power_iteration_mv(matvec, dim: int, dtype, iters: int = 64,
                       key=None, v0=None) -> jnp.ndarray:
    """Jitted fixed-iteration power method on an arbitrary symmetric
    matvec — the ``norm_backend="power"`` twin of ``lanczos_svd_jit_mv``
    (same call shape, same one-MVM-per-iteration ledger charge).

    On the symmetric block M of K the spectrum comes in +/-sigma pairs
    (Proposition 1), so the iterate itself may oscillate between the two
    dominant eigenvector signs — but the Rayleigh growth factor
    ``||M v_k||`` still converges to sigma_max(K), which is what is
    returned.  ``v0`` optionally overrides the start vector; by default a
    fresh reproducible draw is used (the norm-reuse refinement path keeps
    the default — only the scalar estimate is cached, not the direction).
    """
    if key is None:
        # deliberate: reproducible default start vector (see lanczos_svd)
        key = jax.random.PRNGKey(0)  # jaxlint: disable=R2
    if v0 is None:
        v0 = jax.random.normal(key, (dim,), dtype=dtype)
    v0 = v0 / jnp.maximum(jnp.linalg.norm(v0), 1e-30)

    def body(v, _):
        w = matvec(v)
        nw = jnp.linalg.norm(w)
        return w / jnp.maximum(nw, 1e-30), nw

    _, norms = jax.lax.scan(body, v0, None, length=iters)
    return norms[-1]


def power_iteration(
    K: jnp.ndarray, iters: int = 100, key=None
) -> jnp.ndarray:
    """Two-sided power iteration baseline (eq. 8): ||K||_2 estimate."""
    m, n = K.shape
    if key is None:
        # deliberate: reproducible default start vector (see lanczos_svd)
        key = jax.random.PRNGKey(0)  # jaxlint: disable=R2
    v = jax.random.normal(key, (n,), dtype=K.dtype)
    v = v / jnp.linalg.norm(v)

    def body(v, _):
        w = K.T @ (K @ v)
        nw = jnp.linalg.norm(w)
        return w / nw, nw

    v, norms = jax.lax.scan(body, v, None, length=iters)
    return jnp.sqrt(norms[-1])
