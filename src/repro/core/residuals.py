"""KKT residuals and stopping rule (paper §3.3, eqs. 9-11).

r_pri  = ||K x - b|| / (1 + ||b||)
r_dual = ||c - K^T y - lambda|| / (1 + ||c||),   lambda = [c - K^T y]_+
r_iter = ||[x_k - x_{k+1}]_+|| / (1 + ||x_{k+1}||)
r_gap  = |c^T x - b^T y| / (1 + |c^T x| + |b^T y|)

Note: the paper's r_gap formula prints "K^T y" where the scalar duality
pairing b^T y is meant (a K^T y is a vector); we use the standard LP
duality gap b^T y, which is what the denominators' pattern implies.

All residuals reuse the two per-iteration MVM products where possible; a
convergence check therefore costs at most 2 extra device MVMs and is only
run every ``check_every`` iterations (host-level, per the paper).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass
class KKTResiduals:
    r_pri: jnp.ndarray
    r_dual: jnp.ndarray
    r_iter: jnp.ndarray
    r_gap: jnp.ndarray

    @property
    def max(self):
        return jnp.maximum(
            jnp.maximum(self.r_pri, self.r_dual),
            jnp.maximum(self.r_iter, self.r_gap),
        )

    def converged(self, tol: float):
        return self.max <= tol

    def as_dict(self):
        return {
            "r_pri": float(self.r_pri),
            "r_dual": float(self.r_dual),
            "r_iter": float(self.r_iter),
            "r_gap": float(self.r_gap),
        }


def kkt_residuals(
    x, x_prev, y, c, b, Kx, KTy, lb=None, ub=None
) -> KKTResiduals:
    """Compute the four residuals from already-available MVM products.

    ``Kx``  : K @ x        (current primal iterate)
    ``KTy`` : K^T @ y      (current dual iterate)
    ``lb``/``ub``: finite bounds tighten the dual residual via bound
    multipliers; with lb=0, ub=inf this reduces exactly to the paper's
    lambda = [c - K^T y]_+.
    """
    reduced = c - KTy
    if lb is None and ub is None:
        lam_lo = jnp.maximum(reduced, 0.0)
        lam_hi = jnp.zeros_like(reduced)
        lam = lam_lo
        lb_fin = ub_fin = None
    else:
        # Bound multipliers: lambda_lb >= 0 active at finite lb,
        # lambda_ub >= 0 active at finite ub; residual is the part of the
        # reduced cost not attributable to either.
        has_lb = jnp.isfinite(lb) if lb is not None else jnp.zeros_like(reduced, bool)
        has_ub = jnp.isfinite(ub) if ub is not None else jnp.zeros_like(reduced, bool)
        lam_lo = jnp.where(has_lb, jnp.maximum(reduced, 0.0), 0.0)
        lam_hi = jnp.where(has_ub, jnp.maximum(-reduced, 0.0), 0.0)
        lam = lam_lo - lam_hi
        lb_fin = jnp.where(has_lb, lb, 0.0)
        ub_fin = jnp.where(has_ub, ub, 0.0)
    r_pri = jnp.linalg.norm(Kx - b) / (1.0 + jnp.linalg.norm(b))
    r_dual = jnp.linalg.norm(reduced - lam) / (1.0 + jnp.linalg.norm(c))
    r_iter = jnp.linalg.norm(jnp.maximum(x_prev - x, 0.0)) / (
        1.0 + jnp.linalg.norm(x)
    )
    pobj = jnp.vdot(c, x)
    # Bounds-aware dual objective: b^T y + lb^T lam_lo - ub^T lam_hi.
    # (The paper prints |c^T x - K^T y|; with x >= 0 / no finite ub this is
    # the classical b^T y gap — the general form is required for the box-
    # bounded Table-1 relaxations.)
    dobj = jnp.vdot(b, y)
    if lb_fin is not None:
        dobj = dobj + jnp.vdot(lb_fin, lam_lo) - jnp.vdot(ub_fin, lam_hi)
    r_gap = jnp.abs(pobj - dobj) / (1.0 + jnp.abs(pobj) + jnp.abs(dobj))
    return KKTResiduals(r_pri=r_pri, r_dual=r_dual, r_iter=r_iter, r_gap=r_gap)


def relative_error(z, z_star):
    """Paper eq. 13: Delta_rel = |z - z*| / |z| (z = ground truth)."""
    return abs(z - z_star) / max(abs(z), 1e-300)
