"""Solver-as-a-service: many independent LPs solved data-parallel.

The second use of the "pod"/"data" axes (DESIGN.md §4): a *batch* of
problem instances (same padded shape) is sharded across devices and each
device runs the dense jitted PDHG core locally — zero collectives during
the solve, embarrassingly parallel, linear scaling.  This is the serving
configuration for LP-as-a-service workloads (the paper's framing of RRAM
arrays as shared linear-optimization accelerators).

The stacked-batch pipeline itself lives in ``repro.runtime.batch`` (one
bucket of the shape-bucketing scheduler IS this path); this module keeps
the explicit same-shape API for callers that already stacked their
problems.  Heterogeneous streams should use ``runtime.solve_stream``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..core.pdhg import PDHGOptions
from ..runtime.batch import make_bucket_pipeline
from ..runtime.batch import stack_problems  # noqa: F401  (re-export)


def solve_batch(
    Ks, bs, cs, lbs, ubs,
    mesh: Mesh,
    opts: PDHGOptions = PDHGOptions(),
    batch_axes: Tuple[str, ...] = ("data",),
) -> dict:
    """Solve a stacked batch of standard-form LPs.

    Ks: (B, m, n); bs: (B, m); cs/lbs/ubs: (B, n).  B must be a multiple of
    the product of ``batch_axes`` sizes.  Preconditioning (Ruiz + PC + the
    Lanczos norm) runs vmapped per instance.
    """
    pipeline = make_bucket_pipeline(opts)
    batch_sharding = NamedSharding(mesh, P(batch_axes))
    args = [jax.device_put(jnp.asarray(a), batch_sharding)
            for a in (Ks, bs, cs, lbs, ubs)]
    B = args[0].shape[0]
    keys = jax.device_put(
        jax.random.split(jax.random.PRNGKey(opts.seed), B), batch_sharding)
    xs, ys, its, merits, _rhos = jax.jit(pipeline)(*args, keys)
    return {
        "x": np.asarray(xs),
        "y": np.asarray(ys),
        "iterations": np.asarray(its),
        "merit": np.asarray(merits),
        "converged": np.asarray(merits) <= opts.tol,
    }
