"""Solver-as-a-service: many independent LPs solved data-parallel.

The second use of the "pod"/"data" axes (DESIGN.md §4): a *batch* of
problem instances (same padded shape) is sharded across devices and each
device runs the dense jitted PDHG core locally — zero collectives during
the solve, embarrassingly parallel, linear scaling.  This is the serving
configuration for LP-as-a-service workloads (the paper's framing of RRAM
arrays as shared linear-optimization accelerators).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..core import pdhg as pdhg_mod
from ..core.pdhg import PDHGOptions


def _single_solve(K, b, c, lb, ub, T, Sigma, rho, opts_static):
    x, y, it, merit = pdhg_mod._solve_jit_core(
        K, K.T, b, c, lb, ub, T, Sigma, rho, jax.random.PRNGKey(1),
        opts_static,
    )
    return x, y, it, merit


def solve_batch(
    Ks, bs, cs, lbs, ubs,
    mesh: Mesh,
    opts: PDHGOptions = PDHGOptions(),
    batch_axes: Tuple[str, ...] = ("data",),
) -> dict:
    """Solve a stacked batch of standard-form LPs.

    Ks: (B, m, n); bs: (B, m); cs/lbs/ubs: (B, n).  B must be a multiple of
    the product of ``batch_axes`` sizes.  Preconditioning (Ruiz + PC + the
    Lanczos norm) runs vmapped per instance.
    """
    Ks = jnp.asarray(Ks)
    B = Ks.shape[0]

    def prep_one(K, b, c, lb, ub):
        from ..core.lanczos import lanczos_svd_jit
        from ..core.precondition import apply_ruiz, diagonal_precondition
        from ..core.symblock import build_sym_block
        scaled = apply_ruiz(K, b, c, lb, ub, iters=opts.ruiz_iters)
        T, Sigma = diagonal_precondition(scaled.K)
        Keff = jnp.sqrt(Sigma)[:, None] * scaled.K * jnp.sqrt(T)[None, :]
        rho = lanczos_svd_jit(build_sym_block(Keff), k_max=opts.lanczos_iters)
        return (scaled.K, scaled.b, scaled.c, scaled.lb, scaled.ub, T,
                Sigma, rho, scaled.D1, scaled.D2)

    opts_static = (opts.max_iters, opts.tol, opts.eta, opts.omega,
                   opts.gamma, opts.check_every,
                   opts.restart_beta if opts.restart else 0.0, 0.0)

    def pipeline(Ks, bs, cs, lbs, ubs):
        prepped = jax.vmap(prep_one)(Ks, bs, cs, lbs, ubs)
        (Ks2, bs2, cs2, lbs2, ubs2, Ts, Sigs, rhos, D1s, D2s) = prepped
        solver = functools.partial(_single_solve, opts_static=opts_static)
        xs, ys, its, merits = jax.vmap(solver)(
            Ks2, bs2, cs2, lbs2, ubs2, Ts, Sigs, rhos)
        return D2s * xs, D1s * ys, its, merits

    batch_sharding = NamedSharding(mesh, P(batch_axes))
    args = [jax.device_put(a, batch_sharding)
            for a in (Ks, bs, cs, lbs, ubs)]
    xs, ys, its, merits = jax.jit(pipeline)(*args)
    return {
        "x": np.asarray(xs),
        "y": np.asarray(ys),
        "iterations": np.asarray(its),
        "merit": np.asarray(merits),
        "converged": np.asarray(merits) <= opts.tol,
    }


def stack_problems(lps) -> tuple:
    """Pad a list of StandardLPs to a common shape and stack."""
    m = max(lp.K.shape[0] for lp in lps)
    n = max(lp.K.shape[1] for lp in lps)
    Ks, bs, cs, lbs, ubs = [], [], [], [], []
    for lp in lps:
        mi, ni = lp.K.shape
        K = np.zeros((m, n))
        K[:mi, :ni] = lp.K
        b = np.zeros(m)
        b[:mi] = lp.b
        c = np.zeros(n)
        c[:ni] = lp.c
        lb = np.zeros(n)
        ub = np.zeros(n)           # padding pinned at 0
        lb[:ni] = lp.lb
        ub[:ni] = lp.ub
        Ks.append(K); bs.append(b); cs.append(c); lbs.append(lb); ubs.append(ub)
    return tuple(np.stack(a) for a in (Ks, bs, cs, lbs, ubs))
