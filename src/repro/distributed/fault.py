"""Fault tolerance for long-running distributed solves/training.

Design targets (1000+ node posture, DESIGN.md §4):
  * snapshot every N iterations to host storage, atomic rename so a crash
    mid-write never corrupts the last good checkpoint;
  * restart is bit-deterministic: PDHG state is (x, x_prev, y, tau, sigma,
    iter, prng) — restoring it reproduces the exact iterate stream;
  * elastic remesh: checkpoints are stored UNSHARDED (host numpy), so a
    restore can target a different mesh shape — re-placement is just
    device_put with the new sharding (tested 8 -> 4 devices; the same
    code path covers 512 -> 256 after pod loss);
  * straggler/step mitigation hooks: a snapshot is a valid PDHG state, so
    a slow/failed worker group can be dropped and the solve resumed on the
    survivors without algorithmic penalty (PDHG is memoryless beyond one
    iterate pair).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass
class SolverCheckpoint:
    step: int
    arrays: Dict[str, np.ndarray]
    meta: Dict[str, Any]


def save_checkpoint(path: str, step: int, arrays: Dict[str, Any],
                    meta: Optional[Dict[str, Any]] = None) -> str:
    """Atomic snapshot: write to tmp file in the same dir, then rename."""
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    host = {k: np.asarray(v) for k, v in arrays.items()}
    payload = dict(host)
    payload["__meta__"] = np.frombuffer(
        json.dumps({"step": step, **(meta or {})}).encode(), dtype=np.uint8
    )
    fd, tmp = tempfile.mkstemp(
        dir=os.path.dirname(os.path.abspath(path)) or ".", suffix=".tmp"
    )
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)          # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_checkpoint(path: str) -> SolverCheckpoint:
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        arrays = {k: z[k] for k in z.files if k != "__meta__"}
    step = int(meta.pop("step"))
    return SolverCheckpoint(step=step, arrays=arrays, meta=meta)


def reshard(arrays: Dict[str, np.ndarray], mesh: Mesh,
            specs: Dict[str, P]) -> Dict[str, jax.Array]:
    """Place host arrays onto a (possibly different) mesh — elastic restore."""
    out = {}
    for k, v in arrays.items():
        spec = specs.get(k, P())
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out


class CheckpointManager:
    """Rotating checkpoint files + crash-consistent latest pointer."""

    def __init__(self, directory: str, keep: int = 3, every: int = 1000):
        self.directory = directory
        self.keep = keep
        self.every = every
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, arrays: Dict[str, Any],
                   meta: Optional[Dict[str, Any]] = None) -> Optional[str]:
        if step % self.every != 0:
            return None
        path = os.path.join(self.directory, f"ckpt_{step:012d}.npz")
        save_checkpoint(path, step, arrays, meta)
        self._gc()
        return path

    def latest(self) -> Optional[str]:
        files = sorted(
            f for f in os.listdir(self.directory)
            if f.startswith("ckpt_") and f.endswith(".npz")
        )
        return os.path.join(self.directory, files[-1]) if files else None

    def _gc(self):
        files = sorted(
            f for f in os.listdir(self.directory)
            if f.startswith("ckpt_") and f.endswith(".npz")
        )
        for f in files[: -self.keep]:
            os.unlink(os.path.join(self.directory, f))
