"""Distribution runtime: sharded PDHG, batched solves, fault tolerance."""
from .sharding import (
    axis_size,
    col_axes,
    named_sharding,
    pad_to_multiple,
    padded_dim,
    row_axes,
)
from .pdhg_dist import (
    DistProblem,
    make_dist_step,
    shard_problem,
    solve_dist,
    solve_dist_auto,
)
from .batch_solve import solve_batch, stack_problems
from .fault import (
    CheckpointManager,
    SolverCheckpoint,
    load_checkpoint,
    reshard,
    save_checkpoint,
)
from .compression import compressed_psum, dequantize_int8, quantize_int8

__all__ = [
    "axis_size", "col_axes", "named_sharding", "pad_to_multiple",
    "padded_dim", "row_axes", "DistProblem", "make_dist_step",
    "shard_problem", "solve_dist", "solve_dist_auto", "solve_batch",
    "stack_problems",
    "CheckpointManager", "SolverCheckpoint", "load_checkpoint", "reshard",
    "save_checkpoint", "compressed_psum", "dequantize_int8",
    "quantize_int8",
]
