"""Distributed in-memory PDHG via shard_map (DESIGN.md §4).

The device mesh is the crossbar grid: each device owns a static tile of
the Ruiz-scaled constraint matrix K (equivalently, of the symmetric block
M — K row/col tiles and their transposes are the SAME buffers read both
ways, so the encode-once property survives sharding).  Per iteration:

  dual step   K @ x_bar : local (m_loc, n_loc) @ (n_loc,) then
              psum over the COLUMN axis ("model")     — "sum the currents"
  primal step K^T @ y   : local transpose-read then
              psum over the ROW axes ("pod","data")

Vectors are the only thing that ever moves (two small psums per
iteration); K is written once at setup.  This is the paper's
communication pattern mapped onto jax.lax collectives.

The iteration math itself is ``core.engine``'s (shared with the jit /
batch / crossbar paths); this module contributes the psum-tiled operator
backend's data layout and the psum-reduced KKT merit.

Exposes:
  * ``make_dist_step``  — jitted k-iteration step (dry-run / roofline unit)
  * ``solve_dist``      — full solver: pad, shard, engine loop with KKT
                          checks + adaptive restarts, unscale.
  * ``solve_dist_auto`` — ``solve_dist`` over the cluster-global mesh
                          (``runtime.cluster`` + ``make_cluster_mesh``):
                          multi-process deployments shard_map over ALL
                          pods' devices; single-process falls back to
                          the local mesh.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..core import engine
from ..core import pdhg as pdhg_mod
from ..core.pdhg import PDHGOptions, PDHGResult
from ..core.residuals import kkt_residuals
from ..lp.problem import StandardLP
from ..runtime import compat
from .sharding import axis_size, col_axes, pad_to_multiple, row_axes


def _l2sq(v):
    return jnp.sum(v * v)


def _dist_kkt_max(x, x_prev, y, c, b, Kx, KTy, lb, ub, Rax, Cax):
    """max KKT residual, computed from local blocks + scalar psums.

    x-like vectors are sharded over Cax, y-like over Rax.  Every sum is a
    local reduction followed by a psum over the owning axis, so the result
    is identical on all devices (drives collective-free control flow).
    """
    sum_c = lambda v: jax.lax.psum(v, Cax)      # noqa: E731
    sum_r = lambda v: jax.lax.psum(v, Rax)      # noqa: E731
    reduced = c - KTy
    has_lb = jnp.isfinite(lb)
    has_ub = jnp.isfinite(ub)
    lam_lo = jnp.where(has_lb, jnp.maximum(reduced, 0.0), 0.0)
    lam_hi = jnp.where(has_ub, jnp.maximum(-reduced, 0.0), 0.0)
    lam = lam_lo - lam_hi
    nrm_b = jnp.sqrt(sum_r(_l2sq(b)))
    nrm_c = jnp.sqrt(sum_c(_l2sq(c)))
    r_pri = jnp.sqrt(sum_r(_l2sq(Kx - b))) / (1.0 + nrm_b)
    r_dual = jnp.sqrt(sum_c(_l2sq(reduced - lam))) / (1.0 + nrm_c)
    r_iter = jnp.sqrt(sum_c(_l2sq(jnp.maximum(x_prev - x, 0.0)))) / (
        1.0 + jnp.sqrt(sum_c(_l2sq(x))))
    pobj = sum_c(jnp.vdot(c, x))
    dobj = sum_r(jnp.vdot(b, y)) + sum_c(
        jnp.vdot(jnp.where(has_lb, lb, 0.0), lam_lo)
        - jnp.vdot(jnp.where(has_ub, ub, 0.0), lam_hi))
    r_gap = jnp.abs(pobj - dobj) / (1.0 + jnp.abs(pobj) + jnp.abs(dobj))
    return jnp.maximum(jnp.maximum(r_pri, r_dual), jnp.maximum(r_iter, r_gap))


@dataclasses.dataclass
class DistProblem:
    """Padded + device-laid-out problem data (the 'encoded' state)."""

    K: jax.Array         # (m_pad, n_pad) sharded P(Rax, Cax)
    b: jax.Array         # (m_pad,)  P(Rax)
    c: jax.Array         # (n_pad,)  P(Cax)
    lb: jax.Array
    ub: jax.Array
    T: jax.Array
    Sigma: jax.Array
    m: int               # original dims
    n: int
    mesh: Mesh


def shard_problem(scaled, T, Sigma, mesh: Mesh,
                  tile_dtype=None) -> DistProblem:
    """Pad to mesh multiples and place blocks (the encode-once step).

    Padding semantics: extra primal coordinates are pinned (lb=ub=0) and
    extra rows have b=0 with zero K rows, so padding never changes the
    optimum.  ``tile_dtype`` downcasts the device-resident K tiles
    (hillclimb 1: bf16 "conductances"); vectors keep the solve dtype.
    """
    Rax, Cax = row_axes(mesh), col_axes(mesh)
    R, C = axis_size(mesh, Rax), axis_size(mesh, Cax)
    m, n = scaled.K.shape
    Kp = pad_to_multiple(pad_to_multiple(scaled.K, R, 0), C, 1)
    if tile_dtype is not None:
        Kp = Kp.astype(tile_dtype)
    bp = pad_to_multiple(scaled.b, R, 0)
    cp = pad_to_multiple(scaled.c, C, 0)
    lbp = pad_to_multiple(scaled.lb, C, 0)
    ubp = pad_to_multiple(scaled.ub, C, 0)   # pad ub with 0 => pinned vars
    Tp = pad_to_multiple(T, C, 0, value=1.0)
    Sigp = pad_to_multiple(Sigma, R, 0, value=1.0)
    put = lambda a, spec: jax.device_put(a, NamedSharding(mesh, spec))  # noqa: E731
    return DistProblem(
        K=put(Kp, P(Rax, Cax)),
        b=put(bp, P(Rax)),
        c=put(cp, P(Cax)),
        lb=put(lbp, P(Cax)),
        ub=put(ubp, P(Cax)),
        T=put(Tp, P(Cax)),
        Sigma=put(Sigp, P(Rax)),
        m=m, n=n, mesh=mesh,
    )


def make_dist_step(mesh: Mesh, n_inner: int = 1, gamma: float = 0.0):
    """k-iteration distributed PDHG step (the dry-run/roofline unit).

    Returns a function  (K, b, c, lb, ub, T, Sigma, x, x_bar, y, tau,
    sigma) -> (x, x_bar, y, tau, sigma)  running ``n_inner`` engine
    iterations over the psum-tiled operator backend.  State is the
    engine's carried form: ``x_bar`` is the next iteration's extrapolated
    point and ``tau``/``sigma`` already include its theta factor (with
    ``gamma=0`` — the dry-run default — these coincide with the raw step
    sizes).
    """
    Rax, Cax = row_axes(mesh), col_axes(mesh)

    def local_fn(K, b, c, lb, ub, T, Sig, x, x_bar, y, tau, sigma):
        op = engine.sharded_operator(K, Rax, Cax)
        state = engine.PDHGState(x=x, x_prev=x, x_bar=x_bar, y=y,
                                 tau=tau, sigma=sigma)
        state = jax.lax.fori_loop(
            0, n_inner,
            lambda i, s: engine.pdhg_step(op, engine.JNP_UPDATES, b, c,
                                          lb, ub, T, Sig, gamma, s),
            state)
        return state.x, state.x_bar, state.y, state.tau, state.sigma

    vec_r, vec_c = P(Rax), P(Cax)
    return compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(Rax, Cax), vec_r, vec_c, vec_c, vec_c, vec_c, vec_r,
                  vec_c, vec_c, vec_r, P(), P()),
        out_specs=(vec_c, vec_c, vec_r, P(), P()),
        check_vma=False,
    )


def solve_dist(
    lp: StandardLP,
    mesh: Mesh,
    opts: PDHGOptions = PDHGOptions(),
    tile_dtype=None,
) -> PDHGResult:
    """Full distributed solve (host prep -> shard -> jitted while_loop)."""
    scaled, T, Sigma = pdhg_mod.prepare(lp, opts)
    if opts.norm_override is not None:
        rho = float(opts.norm_override)
    else:
        from ..core.lanczos import (
            NORM_BACKENDS, lanczos_svd_jit, power_iteration_mv)
        from ..core.symblock import build_sym_block
        if opts.norm_backend not in NORM_BACKENDS:
            raise ValueError(f"unknown norm_backend {opts.norm_backend!r}; "
                             f"expected one of {NORM_BACKENDS}")
        Keff = jnp.sqrt(Sigma)[:, None] * scaled.K * jnp.sqrt(T)[None, :]
        M = build_sym_block(Keff)
        if opts.norm_backend == "power":
            rho = float(power_iteration_mv(lambda v: M @ v, M.shape[0],
                                           M.dtype,
                                           iters=opts.lanczos_iters))
        else:
            rho = float(lanczos_svd_jit(M, k_max=opts.lanczos_iters))
        if tile_dtype is not None:
            rho = rho / (1.0 - 0.05)   # Lemma-2 margin for tile rounding
    prob = shard_problem(scaled, T, Sigma, mesh, tile_dtype=tile_dtype)
    Rax, Cax = row_axes(mesh), col_axes(mesh)
    n_pad = prob.c.shape[0]
    m_pad = prob.b.shape[0]
    dt = prob.b.dtype   # vector dtype (tiles may be bf16)

    def local_solve(K, b, c, lb, ub, T, Sig):
        # deterministic init: every device draws the FULL vector then
        # slices its block => identical draws to the single-device solver
        # (same PRNGKey(seed+1) threading as ``solve_jit``; on an
        # unpadded 1-device mesh the iterates coincide bit-for-bit).
        key, kx, ky = jax.random.split(jax.random.PRNGKey(opts.seed + 1), 3)
        ci = jax.lax.axis_index(Cax)
        ri = jax.lax.axis_index(Rax)
        nloc, mloc = c.shape[0], b.shape[0]
        x0f = jax.random.normal(kx, (n_pad,), dt)
        y0f = jax.random.normal(ky, (m_pad,), dt)
        x0 = jnp.clip(jax.lax.dynamic_slice(x0f, (ci * nloc,), (nloc,)),
                      lb, ub)
        y0 = jax.lax.dynamic_slice(y0f, (ri * mloc,), (mloc,))
        op = engine.sharded_operator(K, Rax, Cax)

        def residual_fn(x, x_prev, y, Kx, KTy):
            return _dist_kkt_max(x, x_prev, y, c, b, Kx, KTy, lb, ub,
                                 Rax, Cax)

        # the adaptive rebalance reduces x-like vectors over the column
        # axis and y-like over the rows, exactly like the merit's norms;
        # padded coordinates are pinned (dx = dy = 0) so they never bias
        # the movement ratios
        xsum_fn = lambda v: jax.lax.psum(jnp.sum(v), Cax)   # noqa: E731
        ysum_fn = lambda v: jax.lax.psum(jnp.sum(v), Rax)   # noqa: E731

        return engine.pdhg_loop(
            op, engine.JNP_UPDATES, b, c, lb, ub, T, Sig,
            x0, y0, opts.eta / (opts.omega * rho),
            opts.eta * opts.omega / rho, key,
            max_iters=opts.max_iters, tol=opts.tol, gamma=opts.gamma,
            check_every=opts.check_every,
            restart_beta=opts.restart_beta, restart=opts.restart,
            step_rule=opts.step_rule, eta=opts.eta,
            xsum_fn=xsum_fn, ysum_fn=ysum_fn,
            residual_fn=residual_fn,
        )

    vec_r, vec_c = P(Rax), P(Cax)
    solve_fn = jax.jit(compat.shard_map(
        local_solve,
        mesh=mesh,
        in_specs=(P(Rax, Cax), vec_r, vec_c, vec_c, vec_c, vec_c, vec_r),
        out_specs=(vec_c, vec_r, P(), P()),
        check_vma=False,
    ))
    x, y, it, merit = solve_fn(prob.K, prob.b, prob.c, prob.lb, prob.ub,
                               prob.T, prob.Sigma)
    x = np.asarray(x)[: prob.n]
    y = np.asarray(y)[: prob.m]
    x_orig = np.asarray(scaled.D2) * x
    y_orig = np.asarray(scaled.D1) * y
    # Post-hoc noiseless KKT residuals on the UNSCALED solution, one per
    # component (as every other path reports them) — the in-loop scalar
    # merit only drives the status and ``result.merit``; stuffing it into
    # all four fields made ``residuals.as_dict()`` claim
    # r_pri == r_dual == r_iter == r_gap.
    res_obj = kkt_residuals(
        jnp.asarray(x_orig), jnp.asarray(x_orig), jnp.asarray(y_orig),
        jnp.asarray(lp.c), jnp.asarray(lp.b),
        jnp.asarray(lp.K @ x_orig), jnp.asarray(lp.K.T @ y_orig),
        lb=jnp.asarray(lp.lb), ub=jnp.asarray(lp.ub))
    it_i = int(it)
    lanczos_mvms = 0 if opts.norm_override is not None else opts.lanczos_iters
    merit_f = float(merit)
    if not np.isfinite(merit_f):
        status = "diverged"          # NaN exits the loop; report it truly
    elif merit_f <= opts.tol:
        status = "optimal"
    else:
        status = "iteration_limit"
    return PDHGResult(
        status=status,
        x=x_orig, y=y_orig, obj=float(lp.c @ x_orig),
        iterations=it_i, residuals=res_obj, sigma_max=rho,
        lanczos_iters=lanczos_mvms,
        mvm_calls=engine.mvm_accounting(it_i, opts.check_every,
                                        lanczos_mvms,
                                        restart=opts.restart),
        merit=merit_f,
    )


def solve_dist_auto(
    lp: StandardLP,
    opts: PDHGOptions = PDHGOptions(),
    cluster: str = "auto",
    tile_dtype=None,
) -> PDHGResult:
    """``solve_dist`` over the process-spanning global mesh.

    Brings the cluster up through ``runtime.cluster.init_cluster``
    (env-driven, idempotent, single-process fallback) and shard_maps
    over ``make_cluster_mesh()`` — in a multi-process deployment the
    pod axis is one process per pod and every psum crosses the
    interconnect; single-process this degrades to the local-devices
    mesh, so every existing entry point keeps working unchanged.
    """
    from ..runtime import cluster as cluster_mod
    from ..runtime.mesh import make_cluster_mesh, make_local_mesh

    info = cluster_mod.init_cluster(cluster)
    mesh = make_cluster_mesh() if info.is_multiprocess else make_local_mesh()
    return solve_dist(lp, mesh, opts, tile_dtype=tile_dtype)
