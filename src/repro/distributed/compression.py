"""Quantized collectives — bandwidth compression for the critical path.

PDHG's per-iteration collectives move the (small) iterate vectors; LM
training's move (large) gradients.  Both benefit from int8 compression
when the interconnect is the binding roofline term:

  compressed_psum: two-phase — (1) psum the per-shard max-abs (tiny),
  (2) quantize locally to int8 against the GLOBAL scale, psum in int32
  (bit-exact associative), dequantize.  Unbiasedness comes from symmetric
  stochastic rounding, which keeps the solver's Assumption-2 guarantees.

This is the TPU analogue of the paper's low-precision analog aggregation:
current summation on crossbar columns is intrinsically "compressed" by
ADC resolution; here the ADC is the int8 cast.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp


def _stochastic_round(x, key):
    floor = jnp.floor(x)
    frac = x - floor
    return floor + (jax.random.uniform(key, x.shape) < frac)


def compressed_psum(x, axis_names, key=None, bits: int = 8):
    """Unbiased quantized psum over ``axis_names`` (inside shard_map)."""
    qmax = 2.0 ** (bits - 1) - 1.0
    # global scale (exact small collective)
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_names)
    scale = jnp.maximum(amax, 1e-30) / qmax
    q = x / scale
    if key is not None:
        q = _stochastic_round(q, key)
    else:
        q = jnp.round(q)
    q = jnp.clip(q, -qmax, qmax).astype(jnp.int32)
    s = jax.lax.psum(q, axis_names)
    return s.astype(x.dtype) * scale


def quantize_int8(x):
    """Standalone (de)quantization pair for gradient compression tests."""
    qmax = 127.0
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale
