"""Mesh/sharding vocabulary shared by the LP solver and the LM stack.

Axis conventions (DESIGN.md §4):
  single pod : mesh (16, 16) with axes ("data", "model")
  multi-pod  : mesh (2, 16, 16) with axes ("pod", "data", "model")

For the distributed PDHG solver the device grid IS the crossbar grid:
row-blocks of the symmetric block M live on the "data" axis (and "pod",
when present), col-blocks on "model".  A K x product is a local tile
matmul + psum over the column axis — the digital twin of the paper's
"sum the output currents along a crossbar grid row".
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def named_sharding(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def row_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes carrying row-blocks of M/K ("pod" folds into rows when present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def col_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("model",)


def axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def pad_to_multiple(x, mult: int, axis: int = 0, value: float = 0.0):
    import jax.numpy as jnp

    size = x.shape[axis]
    target = math.ceil(size / mult) * mult
    if target == size:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad, constant_values=value)


def padded_dim(size: int, parts: int) -> int:
    return math.ceil(size / parts) * parts
