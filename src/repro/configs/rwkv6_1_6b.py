"""rwkv6-1.6b [ssm] — 24L d=2048 (attention-free) ff=7168 vocab=65536.

[arXiv:2404.05892; unverified]  RWKV-6 "Finch": data-dependent decay
linear-attention recurrence, 32 heads of size 64.  O(1) state per token
=> runs the long_500k cell natively.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_head=64,
    d_ff=7168,
    vocab=65536,
    mixer="rwkv6",
    ssm_heads=32,
    rope=False,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=0, n_kv_heads=0, d_head=16, d_ff=160, vocab=227,
        mixer="rwkv6", ssm_heads=4, rope=False, dtype="float32",
        attn_chunk=16,
    )
