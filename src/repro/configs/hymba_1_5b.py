"""hymba-1.5b [hybrid] — 32L d=1600 25H (GQA kv=5) ff=5504 vocab=32001,
ssm_state=16.  [arXiv:2411.13676; hf]

Parallel attention + mamba heads in every layer; attention is
sliding-window (the published model keeps 3 global-attention layers —
we use SWA throughout, noted in DESIGN.md), SSM carries global context.
Sub-quadratic => runs the long_500k cell.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab=32001,
    mixer="hybrid",
    ssm_state=16,
    ssm_heads=25,
    window=2048,
    rope=True,
    ssm_chunk=128,   # hillclimb 3: chunk-parallel selective scan (12x memory term)
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-smoke", family="hybrid", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=160, vocab=157,
        mixer="hybrid", ssm_state=4, ssm_heads=4, window=16, rope=True,
        dtype="float32", attn_chunk=16,
    )
