"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from typing import Callable, Dict

from ..models.config import ModelConfig
from . import (
    granite_3_8b,
    grok_1_314b,
    hymba_1_5b,
    minicpm3_4b,
    musicgen_large,
    olmoe_1b_7b,
    phi_3_vision_4_2b,
    qwen3_14b,
    rwkv6_1_6b,
    starcoder2_3b,
)

_MODULES = {
    "granite-3-8b": granite_3_8b,
    "starcoder2-3b": starcoder2_3b,
    "qwen3-14b": qwen3_14b,
    "minicpm3-4b": minicpm3_4b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "grok-1-314b": grok_1_314b,
    "phi-3-vision-4.2b": phi_3_vision_4_2b,
    "hymba-1.5b": hymba_1_5b,
    "musicgen-large": musicgen_large,
    "rwkv6-1.6b": rwkv6_1_6b,
}

ARCH_NAMES = list(_MODULES.keys())


def get_config(name: str) -> ModelConfig:
    return _MODULES[name].CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _MODULES[name].smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {k: m.CONFIG for k, m in _MODULES.items()}
