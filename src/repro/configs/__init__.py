"""Selectable configs: 10 assigned architectures + the paper's LP configs."""
from .registry import ARCH_NAMES, all_configs, get_config, get_smoke_config
from .shapes import SHAPES, ShapeSpec, cell_supported, input_specs
from .pdhg_paper import LP_CONFIGS, LPConfig

__all__ = [
    "ARCH_NAMES", "all_configs", "get_config", "get_smoke_config",
    "SHAPES", "ShapeSpec", "cell_supported", "input_specs",
    "LP_CONFIGS", "LPConfig",
]
