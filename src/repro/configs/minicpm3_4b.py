"""minicpm3-4b [dense] — 62L d=2560 40H (kv=40) ff=6400 vocab=73448, MLA.

[hf:openbmb/MiniCPM3-4B; hf]  Multi-head Latent Attention with
q_lora_rank=768, kv_lora_rank=256, decoupled RoPE head dim 32 (the
published MiniCPM3 latent dims).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_head=64,
    d_ff=6400,
    vocab=73448,
    mixer="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    rope_head_dim=32,
    rope=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_head=16, d_ff=160, vocab=251,
        mixer="mla", q_lora_rank=24, kv_lora_rank=16, rope_head_dim=8,
        rope=True, dtype="float32", attn_chunk=16,
    )
