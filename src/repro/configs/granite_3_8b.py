"""granite-3-8b [dense] — 40L d=4096 32H (GQA kv=8) ff=12800 vocab=49155.

[hf:ibm-granite/granite-3.0-2b-base; hf]  GQA, RoPE.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=12800,
    vocab=49155,
    mixer="gqa",
    rope=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=1, d_head=16, d_ff=160, vocab=211,
        mixer="gqa", rope=True, dtype="float32", attn_chunk=16,
    )
