"""olmoe-1b-7b [moe] — 16L d=2048 16H (kv=16) ff=1024 vocab=50304,
MoE 64 experts top-8.  [arXiv:2409.02060; hf]
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1024,
    vocab=50304,
    mixer="gqa",
    mlp="moe",
    n_experts=64,
    top_k=8,
    rope=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_head=16, d_ff=32, vocab=223,
        mixer="gqa", mlp="moe", n_experts=8, top_k=2, rope=True,
        dtype="float32", attn_chunk=16,
    )
