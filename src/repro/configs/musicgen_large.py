"""musicgen-large [audio] — 48L d=2048 32H (kv=32) ff=8192 vocab=2048.

[arXiv:2306.05284; hf]  Decoder-only transformer over EnCodec tokens.
Per the assignment, the EnCodec frontend is a stub: train/prefill cells
consume precomputed frame embeddings; decode cells emit EnCodec-codebook
token ids (vocab 2048).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_head=64,
    d_ff=8192,
    vocab=2048,
    mixer="gqa",
    rope=True,          # sinusoidal in the original; RoPE as positional core
    frontend="audio",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke", family="audio", n_layers=2, d_model=48,
        n_heads=4, n_kv_heads=4, d_head=12, d_ff=128, vocab=128,
        mixer="gqa", rope=True, frontend="audio", dtype="float32",
        attn_chunk=16,
    )
