"""The paper's own workload configs: distributed in-memory PDHG LPs.

Three scales for the dry-run of the paper technique itself (the LM archs
are the assigned pool; THIS is the paper's native workload):

  lp_crossbar : m+n = 256   — exactly the paper's 4x4 x 64x64 logical array
  lp_64k      : K 65,536^2  — one pod, dense f32 tiles (16 GB sharded)
  lp_256k     : K 262,144^2 — multi-pod scale (256 GB of tiles over 512
                chips = 0.5 GB/chip; vectors are KB-scale)

Cells lower ``make_dist_step`` (check_every PDHG iterations between KKT
checks) on the production mesh.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LPConfig:
    name: str
    m: int
    n: int
    n_inner: int = 64          # iterations per lowered step
    dtype: str = "float32"     # iterate vectors
    tile_dtype: str = "float32"  # device-resident K tiles (the "conductances")


LP_CONFIGS = {
    "lp_crossbar": LPConfig("lp_crossbar", m=96, n=160),
    "lp_64k": LPConfig("lp_64k", m=32768, n=32768),
    "lp_256k": LPConfig("lp_256k", m=131072, n=131072),
    # Beyond-paper variant: bf16 tiles — the TPU analogue of conductance
    # quantization, justified by the paper's own Theorem-2 robustness
    # (see EXPERIMENTS.md §Perf hillclimb 1).
    "lp_256k_bf16": LPConfig("lp_256k_bf16", m=131072, n=131072,
                             tile_dtype="bfloat16"),
}
