"""Assigned input shapes (one set, shared by all 10 LM architectures).

  train_4k    : seq 4,096  x global_batch 256   -> train_step
  prefill_32k : seq 32,768 x global_batch 32    -> prefill (forward, no grad)
  decode_32k  : seq 32,768 x global_batch 128   -> serve_step (1 new token,
                KV cache of seq_len)
  long_500k   : seq 524,288 x global_batch 1    -> serve_step; ONLY for
                sub-quadratic archs (hymba, rwkv6) — full-attention archs
                skip per the assignment spec (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models import lm as lm_mod
from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(supported?, reason).  The only skips are long_500k on quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 512k decode cell skipped "
                       "per assignment spec (needs sub-quadratic attention)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every step input (no allocation).

    [vlm]/[audio] train/prefill cells feed precomputed frontend embeddings
    (the modality frontend is a stub per the assignment); decode cells feed
    token ids of the backbone vocab.
    """
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if shape.kind == "train":
        if cfg.frontend in ("vision", "audio"):
            return {
                "embeddings": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt),
                "labels": tok,
            }
        return {"tokens": tok, "labels": tok}
    if shape.kind == "prefill":
        if cfg.frontend in ("vision", "audio"):
            return {"embeddings": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)}
        return {"tokens": tok}
    if shape.kind == "decode":
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "cache": lm_mod.init_cache(cfg, B, S, as_shapes=True),
        }
    raise ValueError(shape.kind)
