"""starcoder2-3b [dense] — 30L d=3072 24H (GQA kv=2) ff=12288 vocab=49152.

[arXiv:2402.19173; hf]  GQA, RoPE.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_head=128,
    d_ff=12288,
    vocab=49152,
    mixer="gqa",
    rope=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke", family="dense", n_layers=2, d_model=48,
        n_heads=6, n_kv_heads=2, d_head=8, d_ff=192, vocab=199,
        mixer="gqa", rope=True, dtype="float32", attn_chunk=16,
    )
