"""phi-3-vision-4.2b [vlm] — 32L d=3072 32H (kv=32) ff=8192 vocab=32064.

[hf:microsoft/Phi-3-vision-128k-instruct; hf]  phi3-mini backbone + CLIP
frontend.  Per the assignment, ONLY the transformer backbone is modeled;
the CLIP tower is a stub — train/prefill cells consume precomputed patch
embeddings from ``input_specs()``.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_head=96,
    d_ff=8192,
    vocab=32064,
    mixer="gqa",
    rope=True,
    frontend="vision",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b-smoke", family="vlm", n_layers=2, d_model=48,
        n_heads=4, n_kv_heads=4, d_head=12, d_ff=128, vocab=173,
        mixer="gqa", rope=True, frontend="vision", dtype="float32",
        attn_chunk=16,
    )
