"""qwen3-14b [dense] — 40L d=5120 40H (GQA kv=8) ff=17408 vocab=151936.

[hf:Qwen/Qwen3-8B; hf]  qk_norm, GQA, RoPE.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=17408,
    vocab=151936,
    mixer="gqa",
    qk_norm=True,
    rope=True,
    rope_theta=1000000.0,
    attn_chunk=1024,  # hillclimb 2: fewer flash passes at 32k (+10% memory term)
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_head=16, d_ff=224, vocab=307,
        mixer="gqa", qk_norm=True, rope=True, dtype="float32", attn_chunk=16,
    )
