"""Developer tooling (not shipped with ``repro``): jaxlint static analysis."""
