"""CLI: ``python -m tools.traceaudit``.

Traces the full supported path matrix on tiny shapes (CPU, x64) and
runs the four analyzers; exits 1 on any finding.  ``--update-baseline``
regenerates ``TRACE_BASELINE.json`` instead of diffing against it (for
PRs that intentionally change traced structure — commit the new file
with the change that explains it).  ``--json`` emits machine-readable
findings; ``--diff-out`` additionally writes the human report to a file
(the CI job uploads it as an artifact on failure).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# pin the platform BEFORE anything imports jax: the audit is CPU-only
# by construction (structure, not performance)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from . import (  # noqa: E402
    BASELINE_PATH,
    audit_paths,
    load_baseline,
    save_baseline,
    supported_paths,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.traceaudit",
        description="trace-level audit of every solver path "
                    "(see tools/traceaudit/__init__.py)")
    ap.add_argument("--paths", default=None,
                    help="comma-separated substrings; audit only path "
                         "names matching ANY of them (default: all)")
    ap.add_argument("--list-paths", action="store_true",
                    help="print the supported path matrix and exit")
    ap.add_argument("--update-baseline", action="store_true",
                    help="regenerate TRACE_BASELINE.json from the "
                         "current traces instead of diffing")
    ap.add_argument("--baseline", default=str(BASELINE_PATH),
                    help="baseline file (default: the committed one)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as a JSON array "
                         "(file/line/rule/message) for CI annotation")
    ap.add_argument("--diff-out", default=None,
                    help="also write the human-readable report to this "
                         "file (CI uploads it on failure)")
    args = ap.parse_args(argv)

    specs = supported_paths()
    if args.list_paths:
        for s in specs:
            print(s.name)
        return 0
    full_matrix = args.paths is None
    if args.paths:
        frags = [f.strip() for f in args.paths.split(",") if f.strip()]
        specs = [s for s in specs
                 if any(f in s.name for f in frags)]
        if not specs:
            ap.error(f"no supported path matches {frags}")

    import jax
    jax.config.update("jax_enable_x64", True)

    if args.update_baseline:
        records, findings, _ = audit_paths(specs)
        hard = [f for f in findings if f.analyzer != "fingerprint"]
        if hard:
            for f in hard:
                print(f, file=sys.stderr)
            print("traceaudit: refusing to write a baseline over "
                  f"{len(hard)} non-fingerprint finding(s)",
                  file=sys.stderr)
            return 1
        if not full_matrix:
            print("traceaudit: --update-baseline requires the full "
                  "matrix (drop --paths)", file=sys.stderr)
            return 1
        save_baseline(records, args.baseline)
        print(f"traceaudit: wrote {args.baseline} "
              f"({len(records)} paths, jax {jax.__version__})")
        return 0

    baseline = load_baseline(args.baseline)
    if baseline is None:
        print(f"traceaudit: no baseline at {args.baseline} — run "
              "--update-baseline and commit it", file=sys.stderr)
        return 1
    records, findings, notes = audit_paths(specs, baseline, full_matrix)

    report_lines = [str(f) for f in findings]
    if args.as_json:
        print(json.dumps(
            [{"file": f.path, "line": 0, "rule": f.analyzer,
              "message": f.message} for f in findings], indent=2))
    else:
        for line in report_lines:
            print(line)
    for note in notes:
        print(f"note: {note}", file=sys.stderr)
    if args.diff_out and findings:
        with open(args.diff_out, "w") as fh:
            fh.write("\n".join(report_lines) + "\n")
    n = len(findings)
    print(f"traceaudit: {n} finding{'s' if n != 1 else ''} across "
          f"{len(records)} traced paths", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
