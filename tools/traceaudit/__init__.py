"""traceaudit: static analysis over TRACED computations.

``tools/jaxlint`` reads source; this tool reads what the source
actually becomes.  It traces every supported solver path combo
(operator backend x update kernel x step_rule x sparse_kernel x
megakernel on/off, plus the mixed-precision refinement shells) via
``jax.make_jaxpr`` on tiny shapes, then runs
four analyzers over each jaxpr:

budget       The primitive-budget checker walks the jaxpr into
             ``while``/``scan``/``cond``/``pjit``/``shard_map``/
             ``pallas_call`` bodies (trip-count scaling shared with
             ``launch/hlo.py``'s HLO walker), counts MVM-bearing
             primitives (rank>=2 ``dot_general``, ``bcoo_dot_general``,
             the ELL row-gather), and asserts the count per check
             window equals ``core.engine.mvm_window_budget`` — and that
             NOTHING MVM-shaped runs outside the loop.  This is the
             energy ledger's formula re-derived from the actual trace:
             the ledger lied twice before (the ``2*it`` undercount, the
             noisy-check charge) and ``step_rule="adaptive"``'s "zero
             extra MVMs" claim was prose until now.

dtype        Flags silent float narrowing (``convert_element_type``
             f64 -> f32 anywhere in the trace — the paths are traced in
             f64, so every narrowing is a demotion someone wrote) and
             mixed-precision accumulation (a dot whose output dtype is
             narrower than its widest float operand).

effects      No host callbacks or device transfers inside the hot loop:
             ``pure_callback``/``debug_callback``/``io_callback``/
             ``infeed``/``outfeed``/``device_put`` under a ``while``
             body would synchronize every iteration.

fingerprint  Canonicalizes each path's jaxpr (structural rendering with
             no variable names), hashes it, and diffs against the
             committed ``TRACE_BASELINE.json``.  Unexplained drift
             fails CI with a primitive-histogram diff; PRs that
             intentionally change traced structure rerun with
             ``--update-baseline`` and commit the new file.  Hash drift
             is only a hard failure when ``jax.__version__`` matches
             the baseline's (lowering details move between releases);
             budget/dtype/effects gate regardless of version.

Run as ``python -m tools.traceaudit`` (CPU-only: the module forces
``JAX_PLATFORMS=cpu`` and x64 before tracing).  No module-level jax
import — the CLI must win the import race to pin the platform.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent.parent
BASELINE_PATH = Path(__file__).resolve().parent / "TRACE_BASELINE.json"
BASELINE_SCHEMA = "traceaudit/v1"

# tiny trace shapes: structure is shape-independent (the walker never
# reads dimension VALUES except loop trips), so the cheapest legal
# shapes trace fastest.  K has zeros so the ELL pattern is non-trivial.
TRACE_M, TRACE_N = 4, 3
CHECK_EVERY = 4
MAX_ITERS = 8
GAMMA_SC = 0.1          # strongly_convex requires gamma > 0

ANALYZERS = ("budget", "dtype", "effects", "fingerprint")


def _ensure_import_paths() -> None:
    for p in (str(REPO_ROOT / "src"), str(REPO_ROOT)):
        if p not in sys.path:
            sys.path.insert(0, p)


# ------------------------------------------------------- path registry ---

@dataclasses.dataclass(frozen=True)
class PathSpec:
    """One supported solver path combo; ``name`` is the stable id used
    in TRACE_BASELINE.json and in findings."""
    backend: str          # dense | ell | bcoo | crossbar | sharded
    kernel: str           # jnp | pallas  (update kernel)
    step_rule: str        # fixed | adaptive | strongly_convex
    megakernel: bool
    restart: bool
    refine: int = 0       # iterative-refinement rounds (0 = plain solve)

    @property
    def name(self) -> str:
        base = (f"{self.backend}/{self.kernel}/{self.step_rule}"
                f"/mega{int(self.megakernel)}/restart{int(self.restart)}")
        # suffix only when nonzero so pre-refinement baseline names are
        # stable across the matrix extension
        return base + (f"/refine{self.refine}" if self.refine else "")

    @property
    def gamma(self) -> float:
        return GAMMA_SC if self.step_rule == "strongly_convex" else 0.0


def supported_paths() -> List[PathSpec]:
    """The full combo matrix.  Constraints mirror the engine's:
    megakernel exists for dense/ell only (the fusable operand layouts),
    the distributed path always uses jnp updates, and the restart=False
    variant is audited once per backend on the canonical combo (restart
    is orthogonal to kernel/step_rule in the trace — it only toggles
    the averaged-iterate check block)."""
    paths: List[PathSpec] = []
    for backend in ("dense", "ell", "bcoo", "crossbar", "sharded"):
        kernels = ("jnp",) if backend == "sharded" else ("jnp", "pallas")
        megas = (False, True) if backend in ("dense", "ell") else (False,)
        for kernel in kernels:
            for rule in ("fixed", "adaptive", "strongly_convex"):
                for mega in megas:
                    paths.append(PathSpec(backend, kernel, rule, mega,
                                          True))
        paths.append(PathSpec(backend, "jnp", "fixed", False, False))
    # mixed-precision refinement shells (crossbar.refine.refined_core):
    # the analog-operator mount the batched pipeline uses, plus the dense
    # self-mount solve_crossbar_refined runs — each inner solve is one
    # more while loop on the SAME operator, so budgets scale by
    # engine.refine_window_factor and the digital residual MVMs land
    # outside the loops (engine.refine_digital_mvms)
    for refine in (1, 2):
        paths.append(PathSpec("crossbar", "jnp", "fixed", False, True,
                              refine))
    paths.append(PathSpec("dense", "jnp", "fixed", False, True, 1))
    return paths


# ------------------------------------------------------------- tracing ---

_TRACE_CACHE: Dict[str, object] = {}


def _problem(jnp):
    import numpy as np
    Kd = np.array([[1.0, 0.0, 2.0],
                   [0.0, 3.0, 0.0],
                   [4.0, 0.0, 5.0],
                   [0.0, 6.0, 7.0]])
    assert Kd.shape == (TRACE_M, TRACE_N)
    dt = jnp.float64
    m, n = TRACE_M, TRACE_N
    return dict(
        Kd=Kd, K=jnp.asarray(Kd, dt),
        b=jnp.ones(m, dt), c=jnp.ones(n, dt),
        lb=jnp.zeros(n, dt), ub=jnp.ones(n, dt),
        T=jnp.ones(n, dt), Sigma=jnp.ones(m, dt),
        rho=jnp.asarray(2.0, dt), dt=dt)


def _make_operator(spec: PathSpec, prob, engine):
    """Mount the operator exactly the way the serving paths do."""
    import jax.numpy as jnp
    Kd, dt = prob["Kd"], prob["dt"]
    m, n = TRACE_M, TRACE_N
    if spec.backend == "ell":
        import numpy as np
        from repro.kernels.sparse_mvm import ell_from_coo
        rows, cols = np.nonzero(Kd)
        vals = Kd[rows, cols]
        df, cf = ell_from_coo(vals, rows, cols, (m, n))
        da, ca = ell_from_coo(vals, cols, rows, (n, m))
        df, da = jnp.asarray(df, dt), jnp.asarray(da, dt)
        cf, ca = jnp.asarray(cf), jnp.asarray(ca)
        op = engine.sparse_ell_operator(df, cf, da, ca)
        if spec.megakernel:      # mounted as runtime/batch.py mounts it
            op = op._replace(fuse=engine.make_fused_ell(
                df, cf, da, ca, prob["b"], prob["c"], prob["lb"],
                prob["ub"], prob["T"], prob["Sigma"], spec.gamma))
        return op
    if spec.backend == "crossbar":
        gp = jnp.maximum(prob["K"], 0.0)
        gn = jnp.maximum(-prob["K"], 0.0)
        R = C = m + n
        gpf = jnp.zeros((R, C), dt).at[:m, m:].set(gp)
        gnf = jnp.zeros((R, C), dt).at[:m, m:].set(gn)
        return engine.crossbar_operator(gpf, gnf, jnp.asarray(1.0, dt),
                                        m, n)
    return None                  # dense / bcoo: solve_core self-mounts


def _static_tuple(spec: PathSpec):
    from repro.core.pdhg import PDHGOptions, opts_static
    opts = PDHGOptions(
        max_iters=MAX_ITERS, check_every=CHECK_EVERY,
        kernel=spec.kernel, step_rule=spec.step_rule,
        megakernel=spec.megakernel, restart=spec.restart,
        gamma=spec.gamma, refine_rounds=spec.refine,
        sparse_kernel="bcoo" if spec.backend == "bcoo" else "ell")
    return opts_static(opts)


def _trace_refined(spec: PathSpec, prob, engine, operator_override=None):
    """Refined paths: ``crossbar.refine.refined_core`` — digital exact
    operator blocks for the residual MVMs, the backend's analog mount for
    every inner solve (crossbar paths mount ``crossbar_operator`` the way
    the batched pipeline does; dense self-mounts like the eager
    ``solve_crossbar_refined`` driver)."""
    import functools

    import jax

    from repro.crossbar.refine import refined_core

    static = _static_tuple(spec)
    key = jax.random.PRNGKey(0)
    operator = (operator_override if operator_override is not None
                else _make_operator(spec, prob, engine))
    fn = (refined_core if operator is None else
          functools.partial(refined_core, operator=operator))
    K = prob["K"]
    return jax.make_jaxpr(fn, static_argnums=(12,))(
        K, K.T, K, K.T, prob["b"], prob["c"], prob["lb"], prob["ub"],
        prob["T"], prob["Sigma"], prob["rho"], key, static)


def _trace_sharded(spec: PathSpec, prob):
    """The distributed path: ``pdhg_loop`` under ``shard_map`` on a
    1-device ("data", "model") mesh with psum reduction hooks, the
    structure ``distributed/pdhg_dist.solve_dist`` runs per pod."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import engine
    from repro.distributed.sharding import col_axes, row_axes
    from repro.runtime import compat

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    rax, cax = row_axes(mesh), col_axes(mesh)
    key = jax.random.PRNGKey(0)

    def local_solve(K, b, c, lb, ub, T, Sigma):
        op = engine.sharded_operator(K, rax, cax)
        k2, x0, y0 = engine.draw_init(key, b.shape[0], c.shape[0],
                                      lb, ub, b.dtype)
        xsum = lambda v: jax.lax.psum(jnp.sum(v), cax)   # noqa: E731
        ysum = lambda v: jax.lax.psum(jnp.sum(v), rax)   # noqa: E731
        return engine.pdhg_loop(
            op, engine.JNP_UPDATES, b, c, lb, ub, T, Sigma, x0, y0,
            0.1, 0.1, k2, max_iters=MAX_ITERS, tol=1e-6,
            gamma=spec.gamma, check_every=CHECK_EVERY, restart_beta=0.5,
            restart=spec.restart, step_rule=spec.step_rule,
            xsum_fn=xsum, ysum_fn=ysum)

    fn = compat.shard_map(
        local_solve, mesh=mesh,
        in_specs=(P(rax, cax), P(rax), P(cax), P(cax), P(cax), P(cax),
                  P(rax)),
        out_specs=(P(cax), P(rax), P(), P()), check_vma=False)
    return jax.make_jaxpr(fn)(prob["K"], prob["b"], prob["c"],
                              prob["lb"], prob["ub"], prob["T"],
                              prob["Sigma"])


def trace_path(spec: PathSpec, operator_override=None):
    """Trace one path combo to a ClosedJaxpr (cached per name unless an
    override operator is injected — the test hook for seeded lies)."""
    _ensure_import_paths()
    if operator_override is None and spec.name in _TRACE_CACHE:
        return _TRACE_CACHE[spec.name]

    import functools

    import jax
    import jax.numpy as jnp

    prev_x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        from repro.core import engine
        prob = _problem(jnp)
        if spec.backend == "sharded":
            jaxpr = _trace_sharded(spec, prob)
        elif spec.refine > 0:
            jaxpr = _trace_refined(spec, prob, engine, operator_override)
        else:
            static = _static_tuple(spec)
            key = jax.random.PRNGKey(0)
            operator = (operator_override if operator_override is not None
                        else _make_operator(spec, prob, engine))
            if spec.backend == "bcoo" and operator_override is None:
                from jax.experimental import sparse as jsparse
                K_fwd, K_adj = jsparse.BCOO.fromdense(prob["K"]), None
            elif operator is None:
                K_fwd, K_adj = prob["K"], prob["K"].T
            else:
                K_fwd = K_adj = None
            fn = (engine.solve_core if operator is None else
                  functools.partial(engine.solve_core, operator=operator))
            jaxpr = jax.make_jaxpr(fn, static_argnums=(10,))(
                K_fwd, K_adj, prob["b"], prob["c"], prob["lb"],
                prob["ub"], prob["T"], prob["Sigma"], prob["rho"],
                key, static)
    finally:
        jax.config.update("jax_enable_x64", prev_x64)
    if operator_override is None:
        _TRACE_CACHE[spec.name] = jaxpr
    return jaxpr


# ------------------------------------------------------- jaxpr walking ---

def _subjaxprs(eqn):
    """(param_name, jaxpr) pairs for every sub-jaxpr an eqn carries —
    pjit/scan/while/cond bodies, shard_map/pallas_call kernels."""
    for pname in sorted(eqn.params):
        val = eqn.params[pname]
        vals = val if isinstance(val, (list, tuple)) else [val]
        for i, sub in enumerate(vals):
            if hasattr(sub, "eqns"):
                yield f"{pname}{i}", sub
            elif hasattr(sub, "jaxpr") and hasattr(sub.jaxpr, "eqns"):
                yield f"{pname}{i}", sub.jaxpr


def build_regions(jaxpr) -> Tuple[Dict[str, dict], List[tuple]]:
    """Flatten a jaxpr into loop-nesting regions.

    Returns ``(regions, edges)``: ``regions[rid]`` holds the region's
    eqns and whether it executes under a ``while`` body (the hot-loop
    "window"); ``edges`` are ``(parent, child, trip)`` triples feeding
    ``launch.hlo.propagate_multipliers`` — a ``scan`` body's trip is its
    static ``length``, everything else is 1 (a ``while`` trip is
    unknowable statically, which is exactly why budgets are PER WINDOW).
    """
    regions: Dict[str, dict] = {}
    edges: List[tuple] = []

    def visit(jx, rid: str, window: bool) -> None:
        regions[rid] = {"eqns": list(jx.eqns), "window": window}
        for i, eqn in enumerate(jx.eqns):
            name = eqn.primitive.name
            trip = 1.0
            if name == "scan":
                trip = float(eqn.params.get("length", 1))
            child_window = window or name == "while"
            for pname, sub in _subjaxprs(eqn):
                crid = f"{rid}/{i}.{name}.{pname}"
                edges.append((rid, crid, trip))
                visit(sub, crid, child_window)

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, "root", False)
    return regions, edges


def _region_multipliers(regions, edges):
    from repro.launch.hlo import propagate_multipliers
    return propagate_multipliers(regions, edges)


def classify_mvm(eqn) -> Optional[str]:
    """MVM-bearing primitive classes (None for everything else):

    dot     ``dot_general`` with an operand of rank >= 2 (rank-1 pairs
            are the residual/merit vdots — NOT operator applications)
    bcoo    any ``bcoo_dot_general`` variant (BCOO SpMV)
    gather  the ELL row gather: rank-1 source indexed to a rank-2
            (rows x width) block — ``ell_matvec``'s take expression
    """
    name = eqn.primitive.name
    if name == "dot_general":
        if max(v.aval.ndim for v in eqn.invars) >= 2:
            return "dot"
    if name.startswith("bcoo_dot_general"):
        return "bcoo"
    if name == "gather":
        if (eqn.invars[0].aval.ndim == 1
                and eqn.outvars[0].aval.ndim == 2):
            return "gather"
    return None


def count_mvms(jaxpr) -> Dict[str, float]:
    """Trip-scaled MVM counts split into ``outside`` (per solve) and
    ``per_window`` (per while-body execution)."""
    _ensure_import_paths()
    regions, edges = build_regions(jaxpr)
    mults = _region_multipliers(regions, edges)
    out = {"outside": 0.0, "per_window": 0.0}
    for rid, reg in regions.items():
        n = sum(1 for e in reg["eqns"] if classify_mvm(e))
        if not n:
            continue
        bucket = "per_window" if reg["window"] else "outside"
        out[bucket] += n * mults[rid]
    return out


def primitive_histogram(jaxpr) -> Dict[str, float]:
    """Trip-scaled primitive counts across all regions (the
    human-readable axis of the structural fingerprint diff)."""
    _ensure_import_paths()
    regions, edges = build_regions(jaxpr)
    mults = _region_multipliers(regions, edges)
    hist: Dict[str, float] = {}
    for rid, reg in regions.items():
        for eqn in reg["eqns"]:
            name = eqn.primitive.name
            hist[name] = hist.get(name, 0.0) + mults[rid]
    return hist


# --------------------------------------------------------- analyzers ---

@dataclasses.dataclass(frozen=True)
class Finding:
    path: str            # solver path name (the audit's "file")
    analyzer: str        # budget | dtype | effects | fingerprint
    message: str

    def __str__(self) -> str:
        return f"{self.path}: {self.analyzer} {self.message}"


def check_budget(spec: PathSpec, counts: Dict[str, float],
                 check_every: int = CHECK_EVERY) -> List[Finding]:
    """The ledger cross-check: traced per-window MVMs must equal
    ``engine.mvm_window_budget`` and nothing MVM-shaped may run outside
    the loop (norm estimation is ledgered separately and is not part of
    ``solve_core``)."""
    _ensure_import_paths()
    from repro.core import engine
    findings = []
    window_factor = engine.refine_window_factor(spec.refine)
    expected = (window_factor
                * engine.mvm_window_budget(check_every, spec.restart))
    got = counts["per_window"]
    if got != expected:
        findings.append(Finding(
            spec.name, "budget",
            f"per-window MVM count {got:g} != "
            f"{window_factor}*mvm_window_budget "
            f"{expected} (= {window_factor} analog solve(s) x "
            f"({engine.MVMS_PER_ITERATION}*{check_every} iterations + "
            f"{engine.mvms_per_check(spec.restart)} check)) "
            "— the energy ledger and the traced computation disagree"))
    expected_outside = engine.refine_digital_mvms(spec.refine)
    if counts["outside"] != expected_outside:
        findings.append(Finding(
            spec.name, "budget",
            f"{counts['outside']:g} MVM-bearing primitive(s) outside "
            f"the while loops, expected {expected_outside} "
            "(refine_digital_mvms: the refinement shell's exact residual"
            "/candidate MVMs run digitally outside the analog loops; "
            "anything beyond that is an unledgered device read)"))
    return findings


def check_adaptive_delta(records) -> List[Finding]:
    """PR 8's claim, made mechanical: for every (backend, kernel,
    megakernel, restart) family, ``adaptive`` must trace to EXACTLY the
    fixed rule's per-window MVM count."""
    by_family: Dict[tuple, dict] = {}
    for rec in records:
        s = rec.spec
        fam = (s.backend, s.kernel, s.megakernel, s.restart, s.refine)
        by_family.setdefault(fam, {})[s.step_rule] = rec
    findings = []
    for fam, rules in by_family.items():
        if "fixed" not in rules or "adaptive" not in rules:
            continue
        fx = rules["fixed"].counts["per_window"]
        ad = rules["adaptive"].counts["per_window"]
        if fx != ad:
            findings.append(Finding(
                rules["adaptive"].spec.name, "budget",
                f"adaptive step rule adds {ad - fx:+g} MVMs per window "
                f"vs fixed ({ad:g} vs {fx:g}) — the rule is specified "
                "to rebalance from already-computed quantities at zero "
                "extra MVM cost"))
    return findings


def _float_itemsize(dtype) -> Optional[int]:
    import numpy as np
    d = np.dtype(dtype)
    return d.itemsize if np.issubdtype(d, np.floating) else None


def check_dtype(spec_name: str, jaxpr) -> List[Finding]:
    """Silent float narrowing + mixed-precision accumulation.  Paths
    are traced in f64, so ANY float-narrowing convert is a demotion
    written in code (weak-type promotion never narrows)."""
    _ensure_import_paths()
    regions, _ = build_regions(jaxpr)
    findings = []
    for rid, reg in regions.items():
        for eqn in reg["eqns"]:
            name = eqn.primitive.name
            if name == "convert_element_type":
                src = _float_itemsize(eqn.invars[0].aval.dtype)
                dst = _float_itemsize(eqn.outvars[0].aval.dtype)
                if src is not None and dst is not None and dst < src:
                    findings.append(Finding(
                        spec_name, "dtype",
                        f"silent float narrowing "
                        f"{eqn.invars[0].aval.dtype} -> "
                        f"{eqn.outvars[0].aval.dtype} in {rid}"))
            elif name == "dot_general":
                ins = [_float_itemsize(v.aval.dtype) for v in eqn.invars]
                ins = [i for i in ins if i is not None]
                out_sz = _float_itemsize(eqn.outvars[0].aval.dtype)
                if ins and out_sz is not None and out_sz < max(ins):
                    findings.append(Finding(
                        spec_name, "dtype",
                        f"mixed-precision accumulation: dot output "
                        f"{eqn.outvars[0].aval.dtype} narrower than its "
                        f"operands in {rid}"))
    return findings


# host-sync / host-callback primitives that must never run per-iteration
EFFECT_DENYLIST = frozenset({
    "pure_callback", "debug_callback", "io_callback", "infeed",
    "outfeed", "device_put", "copy_to_host_async",
})


def check_effects(spec_name: str, jaxpr) -> List[Finding]:
    _ensure_import_paths()
    regions, _ = build_regions(jaxpr)
    findings = []
    for rid, reg in regions.items():
        if not reg["window"]:
            continue
        for eqn in reg["eqns"]:
            if eqn.primitive.name in EFFECT_DENYLIST:
                findings.append(Finding(
                    spec_name, "effects",
                    f"{eqn.primitive.name} inside the hot loop ({rid}) "
                    "— host round-trips per iteration serialize the "
                    "solve"))
    return findings


# ------------------------------------------------ structural fingerprint ---

def _canon_value(v) -> Optional[str]:
    """Deterministic, machine-independent rendering of a param value;
    None when the value may embed paths/object identity (those params
    are named but not valued in the canonical form)."""
    if v is None or isinstance(v, (bool, int, float, str, bytes)):
        return repr(v)
    if isinstance(v, (list, tuple)):
        parts = [_canon_value(x) for x in v]
        if any(p is None for p in parts):
            return None
        return "(" + ",".join(parts) + ")"
    import numpy as np
    try:
        if isinstance(v, np.dtype) or (isinstance(v, type)
                                       and issubclass(v, np.generic)):
            return str(np.dtype(v))
        if isinstance(v, np.generic):
            return repr(v.item())
    except Exception:
        pass
    return None


def canonical_render(jaxpr) -> str:
    """Structural dump with NO variable names: each eqn renders as
    ``prim[params] in_avals -> out_avals`` with sub-jaxprs indented
    beneath it.  Stable under alpha-renaming by construction."""
    lines: List[str] = []

    def aval_str(v):
        s = str(v.aval)
        if hasattr(v, "val"):         # Literal: the value is structure
            return f"{s}={v.val!r}"
        return s

    def visit(jx, depth):
        pad = "  " * depth
        for eqn in jx.eqns:
            params = []
            for k in sorted(eqn.params):
                if any(True for _ in _subjaxprs_of_value(eqn.params[k])):
                    params.append(f"{k}=<jaxpr>")
                    continue
                cv = _canon_value(eqn.params[k])
                params.append(f"{k}={cv}" if cv is not None
                              else f"{k}=<{type(eqn.params[k]).__name__}>")
            ins = ",".join(aval_str(v) for v in eqn.invars)
            outs = ",".join(str(v.aval) for v in eqn.outvars)
            lines.append(f"{pad}{eqn.primitive.name}"
                         f"[{';'.join(params)}] {ins} -> {outs}")
            for pname, sub in _subjaxprs(eqn):
                lines.append(f"{pad} <{pname}>")
                visit(sub, depth + 1)

    def _subjaxprs_of_value(val):
        vals = val if isinstance(val, (list, tuple)) else [val]
        for sub in vals:
            if hasattr(sub, "eqns") or (hasattr(sub, "jaxpr")
                                        and hasattr(sub.jaxpr, "eqns")):
                yield sub

    visit(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, 0)
    return "\n".join(lines)


def fingerprint(jaxpr) -> str:
    return hashlib.sha256(canonical_render(jaxpr).encode()).hexdigest()


# ------------------------------------------------------------ baseline ---

def load_baseline(path=BASELINE_PATH) -> Optional[dict]:
    p = Path(path)
    if not p.exists():
        return None
    return json.loads(p.read_text())


def histogram_diff(old: Dict[str, float], new: Dict[str, float]) -> str:
    lines = []
    for prim in sorted(set(old) | set(new)):
        a, b = old.get(prim, 0.0), new.get(prim, 0.0)
        if a != b:
            lines.append(f"    {prim}: {a:g} -> {b:g} ({b - a:+g})")
    if not lines:
        return ("    primitive histogram identical — drift is at the "
                "param/dtype/ordering level")
    return "\n".join(lines)


@dataclasses.dataclass
class PathRecord:
    spec: PathSpec
    counts: Dict[str, float]
    histogram: Dict[str, float]
    fingerprint: str


def analyze_path(spec: PathSpec, jaxpr) -> PathRecord:
    return PathRecord(spec=spec, counts=count_mvms(jaxpr),
                      histogram=primitive_histogram(jaxpr),
                      fingerprint=fingerprint(jaxpr))


def compare_to_baseline(records: List[PathRecord], baseline: dict,
                        full_matrix: bool) -> Tuple[List[Finding],
                                                    List[str]]:
    """Findings (hard failures) + notes (version-skew soft warnings).

    ``full_matrix`` gates the missing/stale-entry checks: a filtered
    run cannot judge baseline completeness."""
    import jax
    findings: List[Finding] = []
    notes: List[str] = []
    same_version = baseline.get("jax_version") == jax.__version__
    if not same_version:
        notes.append(
            f"baseline traced under jax {baseline.get('jax_version')}, "
            f"running {jax.__version__}: fingerprint drift reported as "
            "notes, not failures (budget/dtype/effects still gate)")
    base_paths = baseline.get("paths", {})
    for rec in records:
        base = base_paths.get(rec.spec.name)
        if base is None:
            findings.append(Finding(
                rec.spec.name, "fingerprint",
                "path missing from TRACE_BASELINE.json — new path? "
                "rerun with --update-baseline and commit the result"))
            continue
        if base["fingerprint"] != rec.fingerprint:
            diff = histogram_diff(base.get("primitives", {}),
                                  rec.histogram)
            msg = ("traced structure drifted from baseline; "
                   "primitive-level diff:\n" + diff +
                   "\n    intentional? rerun with --update-baseline "
                   "and commit the new TRACE_BASELINE.json")
            if same_version:
                findings.append(Finding(rec.spec.name, "fingerprint",
                                        msg))
            else:
                notes.append(f"{rec.spec.name}: {msg}")
    if full_matrix:
        audited = {r.spec.name for r in records}
        for name in sorted(set(base_paths) - audited):
            findings.append(Finding(
                name, "fingerprint",
                "baseline entry no longer matches any supported path — "
                "stale; rerun with --update-baseline"))
    return findings, notes


def make_baseline(records: List[PathRecord]) -> dict:
    import jax
    return {
        "schema": BASELINE_SCHEMA,
        "jax_version": jax.__version__,
        "trace_shape": [TRACE_M, TRACE_N],
        "check_every": CHECK_EVERY,
        "paths": {
            rec.spec.name: {
                "fingerprint": rec.fingerprint,
                "mvms": rec.counts,
                "primitives": {k: rec.histogram[k]
                               for k in sorted(rec.histogram)},
            } for rec in sorted(records, key=lambda r: r.spec.name)
        },
    }


def save_baseline(records: List[PathRecord],
                  path=BASELINE_PATH) -> None:
    Path(path).write_text(json.dumps(make_baseline(records), indent=1)
                          + "\n")


# --------------------------------------------------------------- audit ---

def audit_paths(specs: List[PathSpec],
                baseline: Optional[dict] = None,
                full_matrix: bool = False):
    """Trace + analyze each spec.  Returns (records, findings, notes)."""
    records: List[PathRecord] = []
    findings: List[Finding] = []
    for spec in specs:
        jaxpr = trace_path(spec)
        rec = analyze_path(spec, jaxpr)
        records.append(rec)
        findings.extend(check_budget(spec, rec.counts))
        findings.extend(check_dtype(spec.name, jaxpr))
        findings.extend(check_effects(spec.name, jaxpr))
    findings.extend(check_adaptive_delta(records))
    notes: List[str] = []
    if baseline is not None:
        bf, notes = compare_to_baseline(records, baseline, full_matrix)
        findings.extend(bf)
    return records, findings, notes
