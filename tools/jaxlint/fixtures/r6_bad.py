"""R6 fixture (BAD): pragmas that outlived their findings.

The first pragma was written when the line still used ``time.time()``;
the timing was later fixed but the suppression was carried along, where
it would silently license the next real R3 on that line.  The second
names a rule id that never existed — a typo that has been suppressing
nothing (and reviewers assumed it was load-bearing).
"""
import time


def bench(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0  # jaxlint: disable=R3


TOPK = 10  # jaxlint: disable=R9
