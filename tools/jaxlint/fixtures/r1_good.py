"""R1 fixture (GOOD): every option field is either consumed by
``opts_static`` (part of the executable cache key) or declared dynamic
in ``DYNAMIC_FIELDS``."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PDHGOptions:
    max_iters: int = 1000
    tol: float = 1e-6
    kernel: str = "jnp"
    sparse_kernel: str = "ell"
    seed: int = 0

# fields that deliberately do NOT enter the compiled-executable cache key
DYNAMIC_FIELDS = ("seed",)


def opts_static(opts):
    return (opts.max_iters, opts.tol, opts.kernel, opts.sparse_kernel)
