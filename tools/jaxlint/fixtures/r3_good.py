"""R3 fixture (GOOD): durations use the monotonic ``perf_counter``;
absolute wall-clock stamps (no subtraction) remain fine."""
import time


def bench(fn):
    t0 = time.perf_counter()
    fn()
    wall = time.perf_counter() - t0
    return wall


def poll(ready, budget_s=60.0):
    # absolute deadline comparison, not a duration subtraction: quiet
    deadline = time.time() + budget_s
    while time.time() < deadline:
        if ready():
            return True
    return False
