"""R4 fixture (BAD): Python control flow on a traced value.  The real
seed bug: ``restart_beta = 0.0`` encoded "no restart" and the jitted
comparison only *appeared* to work because ``0.0 * inf`` is NaN and NaN
comparisons are false — a trace-time accident, not a decision."""
import jax
import jax.numpy as jnp


@jax.jit
def pdhg_residual_loop(x, tol):
    residual = jnp.linalg.norm(x)
    while jnp.max(residual) > tol:       # TracerBoolConversionError
        x = x * 0.5
        residual = jnp.linalg.norm(x)
    if jnp.sum(x) > 0:                   # ditto for `if`
        x = -x
    return x
