"""R7 fixture (GOOD): every timing window synchronizes before the
closing read — either ``jax.block_until_ready`` around the result (a
no-op on host values, so always safe) or the array method.  The
host-only window at the bottom shows the pragma policy: nothing async
inside, justification on the line."""
import time

import jax


def bench_wrapped(solver, batch):
    t0 = time.perf_counter()
    out = jax.block_until_ready(solver.solve_stream(batch))
    return out, time.perf_counter() - t0


def bench_method(solver, batch):
    t0 = time.perf_counter()
    out = solver.solve_stream(batch)
    out.block_until_ready()
    return out, time.perf_counter() - t0


def bench_parse(path):
    t0 = time.perf_counter()
    rows = path.read_text().splitlines()
    # host-only parse, nothing dispatched to a device
    return rows, time.perf_counter() - t0  # jaxlint: disable=R7
