"""R1 fixture (BAD): the PR 6 bug — ``sparse_kernel`` added to the
options dataclass without an ``opts_static`` entry, so executables
compiled for the ELL backend could be served cache-hits meant for BCOO.
The allowlist exists but nobody decided ``sparse_kernel``'s fate."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PDHGOptions:
    max_iters: int = 1000
    tol: float = 1e-6
    kernel: str = "jnp"
    sparse_kernel: str = "ell"      # <- forgotten by opts_static below
    seed: int = 0

DYNAMIC_FIELDS = ("seed",)


def opts_static(opts):
    # "keep in sync ... and nowhere else" — the comment-enforced
    # invariant this rule mechanizes
    return (opts.max_iters, opts.tol, opts.kernel)
