"""R3 fixture (BAD): the timing pattern PR 6 fixed in
``stream_throughput.py`` but missed in four other files — wall-clock
``time.time()`` feeding a duration subtraction.  An NTP step makes the
reported duration negative or garbage."""
import time


def bench(fn):
    t0 = time.time()
    fn()
    wall = time.time() - t0        # duration from non-monotonic clock
    return wall
