"""R4 fixture (GOOD): the same loop expressed with ``lax.while_loop``
and ``jnp.where`` — control flow staged into the computation graph."""
import jax
import jax.numpy as jnp
from jax import lax


@jax.jit
def pdhg_residual_loop(x, tol):
    def cond(x):
        return jnp.linalg.norm(x) > tol

    def body(x):
        return x * 0.5

    x = lax.while_loop(cond, body, x)
    return jnp.where(jnp.sum(x) > 0, -x, x)


def host_driver(x, tol):
    # NOT traced (no jit decorator, not an entry point): Python control
    # flow on concrete values is fine here.
    if jnp.sum(x) > 0:
        return -x
    return x
