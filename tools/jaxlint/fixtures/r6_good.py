"""R6 fixture (GOOD): every pragma is live — the rule it disables
really fires on that line — and the one deliberate exception carries
``R6`` itself (the self-suppression escape hatch for pragmas that are
only conditionally live, e.g. kept for a config the default lint run
does not exercise)."""
import time


def poll_wall_clock(fn):
    # deliberate wall-clock duration: this harness reports NTP-visible
    # time on purpose, justification documented here (pragma is LIVE)
    t0 = time.time()
    fn()
    return time.time() - t0  # jaxlint: disable=R3


# R2 only fires here under a config whose prng_allow excludes this tree;
# the R6 entry keeps the default run from calling the pragma stale.
SEED_NOTE = "PRNGKey(7)"  # jaxlint: disable=R2,R6
