"""R7 fixture (BAD): the benchmark-timing bug class — JAX dispatch is
asynchronous, so a ``perf_counter`` window that never synchronizes
times the ENQUEUE of the work, not the work.  Both windows here close
without any ``block_until_ready``; the reported "speedup" of the warm
path is fiction (the device is still solving when the clock stops)."""
import time


def bench_solver(solver, batch):
    t0 = time.perf_counter()
    cold = solver.solve_stream(batch)
    cold_s = time.perf_counter() - t0

    t1 = time.perf_counter()
    warm = solver.solve_stream(batch)
    warm_s = time.perf_counter() - t1
    return cold, warm, cold_s, warm_s
