"""R5 fixture (GOOD): the merit stays a device value end-to-end; the
caller decides when (if ever) to materialize it on the host."""
import jax
import jax.numpy as jnp


@jax.jit
def merit_check(x, y):
    merit = jnp.linalg.norm(x) + jnp.linalg.norm(y)
    gap = x @ y
    return merit + gap + jnp.sum(x)


def collect(results):
    # Host materialization OUTSIDE the traced function is the correct
    # place for it (and float(name) on a bare name is quiet anyway).
    merit = results[0]
    return float(merit)
