"""R2 fixture (GOOD): caller key threaded through; distinct subkeys per
draw via ``split``."""
import jax


def _solve_jit_core(A, b, key):
    return jax.random.normal(key, b.shape)


def restart_check(x_avg, y_avg, k3):
    ka, kb = jax.random.split(k3)
    nx = jax.random.normal(ka, x_avg.shape)
    ny = jax.random.normal(kb, y_avg.shape)
    return nx, ny


def sequential_refresh(key, shape):
    key, sub = jax.random.split(key)
    a = jax.random.normal(sub, shape)
    key, sub = jax.random.split(key)      # rebinding refreshes 'sub'
    b = jax.random.normal(sub, shape)
    return a + b
