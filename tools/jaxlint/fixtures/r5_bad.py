"""R5 fixture (BAD): implicit device->host syncs inside a traced
hot-path function — each ``.item()`` / ``float()`` / ``np.asarray``
blocks async dispatch and round-trips through the host, destroying the
latency win batched serving exists for."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def merit_check(x, y):
    merit = float(jnp.linalg.norm(x) + jnp.linalg.norm(y))  # host sync
    gap = (x @ y).item()                                    # host sync
    host = np.asarray(x)                                    # host copy
    return merit + gap + host.sum()
