"""R2 fixture (BAD): both real PR 2 bugs.

(a) ``_solve_jit_core`` ignores its caller-threaded key in favour of a
    hardcoded ``PRNGKey(0)`` — every instance in a batch drew identical
    restart noise.
(b) ``k3`` feeds two normal draws with no intervening split — the
    averaged-iterate MVM perturbations were perfectly correlated.
"""
import jax


def _solve_jit_core(A, b, key):
    key = jax.random.PRNGKey(0)          # (a) caller key discarded
    return jax.random.normal(key, b.shape)


def restart_check(x_avg, y_avg, k3):
    nx = jax.random.normal(k3, x_avg.shape)
    ny = jax.random.normal(k3, y_avg.shape)   # (b) k3 reused, no split
    return nx, ny
