"""CLI: ``python -m tools.jaxlint [paths...]``.

Walks ``*.py`` under each path (default: ``src tests benchmarks``),
prints findings as ``path:line: RULE message`` (or a JSON array of
``{"file", "line", "rule", "message"}`` objects under ``--json``), and
exits 1 when any undisabled finding remains.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import DEFAULT_CONFIG, RULE_IDS, RULE_SUMMARIES, Config, \
    iter_python_files, lint_paths


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.jaxlint",
        description="repo-specific JAX static analysis "
                    "(see tools/jaxlint/__init__.py for the rules)")
    ap.add_argument("paths", nargs="*",
                    default=["src", "tests", "benchmarks"],
                    help="files or directories to lint "
                         "(default: src tests benchmarks)")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids to run "
                         f"(default: all of {','.join(RULE_IDS)})")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as a JSON array "
                         "(file/line/rule/message) for CI annotation")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in RULE_IDS:
            print(f"{rid}  {RULE_SUMMARIES[rid]}")
        return 0

    cfg = DEFAULT_CONFIG
    if args.select:
        selected = frozenset(r.strip().upper()
                             for r in args.select.split(",") if r.strip())
        unknown = selected - set(RULE_IDS)
        if unknown:
            ap.error(f"unknown rule ids: {sorted(unknown)}")
        cfg = Config(select=selected)

    files = iter_python_files(args.paths)
    findings = lint_paths(args.paths, cfg)
    if args.as_json:
        print(json.dumps(
            [{"file": f.path, "line": f.line, "rule": f.rule,
              "message": f.message} for f in findings],
            indent=2))
    else:
        for f in findings:
            print(f)
    n = len(findings)
    print(f"jaxlint: {n} finding{'s' if n != 1 else ''} "
          f"in {len(files)} files", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
