"""jaxlint: repo-specific static analysis for the PDHG serving stack.

Every rule is seeded by a real bug this repo shipped and later fixed —
the linter turns each one-off review catch into a mechanical check
(TDO-CIM's argument: compiler-level detection scales, hand-auditing
does not).  Pure stdlib ``ast`` — no third-party dependencies, so the
CI lint job needs no JAX install.

Rules
-----
R1  cache-key completeness.  A module defining a ``*Options`` dataclass
    together with an ``opts_static`` builder must account for EVERY
    option field: either the field is consumed by ``opts_static`` (and
    therefore part of every compiled-executable cache key) or it is
    listed in an explicit module-level ``DYNAMIC_FIELDS`` allowlist.
    Seeded by: ``sparse_kernel``, ``megakernel`` and ``restart`` each
    shipped without an ``opts_static`` entry, so executables compiled
    for one backend could be served to another.

R2  PRNG discipline.  (a) ``jax.random.PRNGKey(<const>)`` outside
    allowlisted test/example trees — a hardcoded key silently
    correlates every stream drawn from it.  (b) The same key variable
    feeding two random draws without an intervening
    ``split``/``fold_in`` rebinding.  Seeded by: ``_solve_jit_core``
    ignoring its caller key in favour of ``PRNGKey(0)``, and the host
    restart check reusing k3/k4 for the averaged-iterate MVMs.

R3  non-monotonic timing.  ``time.time()`` feeding a duration
    subtraction — wall-clock time is not monotonic (NTP steps make
    durations negative or garbage); durations must use
    ``time.perf_counter()``.  Seeded by: the PR 6 benchmark-timing
    sweep that fixed ``stream_throughput.py`` but missed four other
    files.

R4  tracer-hostile control flow.  Python ``if``/``while`` whose test
    contains a ``jnp``-rooted expression inside a function that is
    jit/vmap/shard_map-traced — under tracing this either raises a
    ``TracerBoolConversionError`` or silently bakes in a trace-time
    constant.  Seeded by: the ``restart_beta = 0.0`` encoding whose
    jitted comparison only worked because ``0.0 * inf`` is NaN and NaN
    comparisons are false.

R5  host-sync in hot paths.  ``.item()``, ``numpy.asarray``/``array``,
    or ``float()``/``int()``/``bool()`` over a device expression inside
    a traced function of a designated hot-path file — each is an
    implicit device->host sync that destroys async dispatch (and is
    exactly what the runtime transfer sanitizer traps at run time).

R6  stale pragma.  A ``# jaxlint: disable=RX`` on a line where rule RX
    no longer fires is itself a finding — suppressions must stay
    justified, and a pragma that outlives its finding silently licenses
    the next real instance of the bug.  Pragmas naming unknown rule ids
    are flagged too.  ``disable=R6`` on the same line self-suppresses
    (for the rare pragma that is only conditionally live).

R7  benchmark timing windows.  A ``time.perf_counter()`` start/stop
    pair in ``benchmarks/`` must contain a ``block_until_ready`` call
    (method or ``jax.block_until_ready``) before the closing read —
    JAX dispatch is async, so an unsynchronized window times the
    enqueue, not the computation, and the numbers are fiction.

Pragmas: append ``# jaxlint: disable=R2`` (comma-separate for several
rules) to a line to suppress findings anchored there — every pragma in
this repo must carry a one-line justification.  R6 keeps the pragma
inventory honest: a suppression whose rule no longer fires must be
deleted, not carried.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

RULE_IDS = ("R1", "R2", "R3", "R4", "R5", "R6", "R7")

RULE_SUMMARIES = {
    "R1": "cache-key completeness (Options fields vs opts_static + "
          "DYNAMIC_FIELDS)",
    "R2": "PRNG discipline (hardcoded PRNGKey / key reuse without split)",
    "R3": "non-monotonic timing (time.time() in a duration subtraction)",
    "R4": "tracer-hostile control flow (Python if/while on jnp inside "
          "traced code)",
    "R5": "host-sync in hot paths (.item()/np.asarray/float() under "
          "tracing)",
    "R6": "stale pragma (disable= for a rule that no longer fires here)",
    "R7": "benchmark timing window without block_until_ready before the "
          "closing perf_counter read",
}

_PRAGMA_RE = re.compile(r"#\s*jaxlint:\s*disable=([A-Z0-9,\s]+)")

# jax.random draws that consume a key as their first positional argument
_DRAW_FNS = frozenset({
    "normal", "uniform", "randint", "bernoulli", "beta", "cauchy",
    "choice", "dirichlet", "exponential", "gamma", "gumbel", "laplace",
    "logistic", "maxwell", "multivariate_normal", "orthogonal", "pareto",
    "permutation", "poisson", "rademacher", "categorical",
    "truncated_normal", "t", "shuffle", "bits",
})
# key-deriving calls: rebinding a name from these REFRESHES it
_REFRESH_FNS = frozenset({"split", "fold_in", "PRNGKey", "key", "clone"})

# transforms whose function argument (or decorated function) is traced
_TRACING_TRANSFORMS = frozenset({
    "jit", "vmap", "pmap", "grad", "value_and_grad", "shard_map",
    "checkpoint", "remat", "scan", "while_loop", "fori_loop", "cond",
    "switch", "custom_vjp", "custom_jvp", "pallas_call",
})

# host-sync calls R5 traps inside traced hot-path code
_NUMPY_SYNC_FNS = frozenset({"asarray", "array", "copy"})
_BUILTIN_SYNC_FNS = frozenset({"float", "int", "bool"})


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass(frozen=True)
class Config:
    """Repo-specific knobs; the defaults ARE this repo's policy."""

    # R5 applies only inside these path fragments (posix, substring match)
    hot_paths: Sequence[str] = (
        "repro/core/engine.py",
        "repro/kernels/",
        "repro/runtime/batch.py",
    )
    # R2(a) hardcoded-key allowlist: test/example trees may pin seeds
    prng_allow: Sequence[str] = ("tests/", "examples/", "conftest.py")
    # R7 applies only inside these path fragments (posix, substring match)
    bench_paths: Sequence[str] = ("benchmarks/",)
    # extra jit-entry functions per path fragment (cross-module jit
    # targets the per-module decorator scan cannot see, e.g.
    # ``jax.jit(engine.solve_core, ...)`` living in core/pdhg.py)
    jit_entry_points: Sequence[tuple] = (
        ("repro/core/engine.py",
         ("solve_core", "pdhg_loop", "pdhg_step", "init_state",
          "draw_init", "adaptive_omega_init", "adaptive_shrink",
          "adaptive_omega_update")),
        ("repro/runtime/batch.py",
         ("_single_solve", "_prep_one", "_prep_one_sparse",
          "_prep_one_ell", "_coo_matvec", "_row_reduce",
          "make_bucket_pipeline", "make_sparse_bucket_pipeline",
          "make_ell_bucket_pipeline")),
        ("repro/core/lanczos.py",
         ("lanczos_svd_jit_mv", "lanczos_svd_jit", "power_iteration",
          "power_iteration_mv")),
        ("repro/kernels/ops.py",
         ("crossbar_mvm", "primal_update", "dual_update")),
        ("repro/kernels/sparse_mvm.py", ("ell_matvec", "ell_matvec_ref")),
        ("repro/kernels/pdhg_megakernel.py",
         ("fused_dense_steps", "fused_ell_steps", "_run_steps")),
        ("repro/kernels/ref.py",
         ("crossbar_mvm_ref", "primal_update_ref", "dual_update_ref")),
        ("repro/crossbar/solver.py", ("make_crossbar_bucket_pipeline",)),
        ("repro/distributed/pdhg_dist.py", ("make_dist_step",)),
    )
    select: Optional[frozenset] = None          # None = all rules

    def rule_enabled(self, rule: str) -> bool:
        return self.select is None or rule in self.select

    def is_hot_path(self, path: str) -> bool:
        return any(frag in path for frag in self.hot_paths)

    def prng_allowed(self, path: str) -> bool:
        return any(frag in path for frag in self.prng_allow)

    def is_bench_path(self, path: str) -> bool:
        return any(frag in path for frag in self.bench_paths)

    def entry_points_for(self, path: str) -> frozenset:
        names: set = set()
        for frag, fns in self.jit_entry_points:
            if frag in path:
                names.update(fns)
        return frozenset(names)


DEFAULT_CONFIG = Config()


# ------------------------------------------------------------- helpers ---

def _attr_chain(node: ast.AST) -> Optional[str]:
    """Dotted name of a Name/Attribute chain ('jax.random.PRNGKey'),
    or None when the chain roots in something dynamic."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _call_chain(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Call):
        return _attr_chain(node.func)
    return None


def _is_prngkey_call(node: ast.AST) -> bool:
    chain = _call_chain(node)
    return chain is not None and chain.split(".")[-1] == "PRNGKey"


def _contains_jnp(node: ast.AST) -> bool:
    """True when the expression tree references ``jnp.*`` (or
    ``jax.numpy.*`` / ``jax.lax.*``) — a device-value expression."""
    for sub in ast.walk(node):
        chain = _attr_chain(sub) if isinstance(sub, ast.Attribute) else None
        if chain and (chain.startswith("jnp.")
                      or chain.startswith("jax.numpy.")
                      or chain.startswith("jax.lax.")):
            return True
    return False


def _pragma_lines(source: str) -> dict:
    """line number -> set of disabled rule ids.

    Tokenize-based: only REAL comments count, so a pragma spelled inside
    a string literal (fixture sources, docstring examples) neither
    suppresses anything nor registers as stale for R6.  Falls back to a
    line scan when the file does not tokenize (lint_source has already
    bailed on syntax errors by then, so this is belt-and-braces)."""
    out = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type != tokenize.COMMENT:
                continue
            mt = _PRAGMA_RE.search(tok.string)
            if mt:
                out[tok.start[0]] = {
                    r.strip() for r in mt.group(1).split(",") if r.strip()}
    except (tokenize.TokenError, IndentationError):
        for i, text in enumerate(source.splitlines(), start=1):
            mt = _PRAGMA_RE.search(text)
            if mt:
                out[i] = {r.strip() for r in mt.group(1).split(",")
                          if r.strip()}
    return out


def _functions(tree: ast.AST) -> Iterable[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


# ------------------------------------------------- R1: cache-key audit ---

def _dataclass_fields(cls: ast.ClassDef) -> dict:
    """Annotated field name -> line, for a dataclass body."""
    fields = {}
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name):
            if isinstance(stmt.annotation, ast.Name) and \
                    stmt.annotation.id == "ClassVar":
                continue
            fields[stmt.target.id] = stmt.lineno
    return fields


def _is_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        chain = _attr_chain(dec.func if isinstance(dec, ast.Call) else dec)
        if chain and chain.split(".")[-1] == "dataclass":
            return True
    return False


def rule_r1(tree: ast.Module, path: str) -> List[Finding]:
    opts_cls = next(
        (n for n in tree.body
         if isinstance(n, ast.ClassDef) and n.name.endswith("Options")
         and _is_dataclass(n)), None)
    static_fn = next(
        (n for n in tree.body
         if isinstance(n, ast.FunctionDef) and n.name == "opts_static"),
        None)
    if opts_cls is None or static_fn is None:
        return []        # rule only binds where both halves live together

    fields = _dataclass_fields(opts_cls)
    opts_arg = static_fn.args.args[0].arg if static_fn.args.args else "opts"
    consumed = set()
    for node in ast.walk(static_fn):
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == opts_arg:
            consumed.add(node.attr)

    dynamic = None
    dynamic_line = static_fn.lineno
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and tgt.id == "DYNAMIC_FIELDS":
                    dynamic_line = node.lineno
                    if isinstance(node.value, (ast.Tuple, ast.List,
                                               ast.Set)):
                        dynamic = {
                            el.value for el in node.value.elts
                            if isinstance(el, ast.Constant)
                            and isinstance(el.value, str)}

    findings = []
    if dynamic is None:
        return [Finding(
            path, static_fn.lineno, "R1",
            f"{opts_cls.name} + opts_static found but no module-level "
            "DYNAMIC_FIELDS allowlist: every option field must be "
            "consumed by opts_static or explicitly declared dynamic")]
    for name, line in fields.items():
        in_static = name in consumed
        in_dynamic = name in dynamic
        if not in_static and not in_dynamic:
            findings.append(Finding(
                path, line, "R1",
                f"{opts_cls.name}.{name} is neither consumed by "
                "opts_static (executable cache key) nor listed in "
                "DYNAMIC_FIELDS — decide its cache-key fate"))
        elif in_static and in_dynamic:
            findings.append(Finding(
                path, line, "R1",
                f"{opts_cls.name}.{name} is consumed by opts_static AND "
                "listed in DYNAMIC_FIELDS — remove it from the "
                "allowlist"))
    for name in sorted(dynamic - set(fields)):
        findings.append(Finding(
            path, dynamic_line, "R1",
            f"DYNAMIC_FIELDS entry {name!r} is not a field of "
            f"{opts_cls.name} — stale allowlist"))
    return findings


# --------------------------------------------------- R2: PRNG discipline ---

def rule_r2(tree: ast.Module, path: str, cfg: Config) -> List[Finding]:
    findings = []

    # (a) hardcoded PRNGKey(<const>)
    if not cfg.prng_allowed(path):
        for node in ast.walk(tree):
            if _is_prngkey_call(node) and node.args and \
                    isinstance(node.args[0], ast.Constant) and \
                    isinstance(node.args[0].value, int):
                findings.append(Finding(
                    path, node.lineno, "R2",
                    f"hardcoded jax.random.PRNGKey({node.args[0].value}) "
                    "— thread a caller key/seed, or pragma with a "
                    "justification if the determinism is deliberate"))

    # (b) same key feeding two draws without an intervening split
    for fn in _functions(tree):
        findings.extend(_scan_key_reuse(fn, path))
    return findings


# callables whose ``key=`` kwarg is a comparator, not a PRNG key
_KEY_KWARG_EXEMPT = frozenset({
    "sorted", "min", "max", "sort", "nlargest", "nsmallest", "groupby",
})


def _key_uses(call: ast.Call) -> List[str]:
    """Key variable names this call CONSUMES (draw semantics)."""
    chain = _call_chain(call) or ""
    leaf = chain.split(".")[-1]
    used = []
    if ".random." in f".{chain}." and leaf in _DRAW_FNS and call.args and \
            isinstance(call.args[0], ast.Name):
        used.append(call.args[0].id)
    if leaf not in _KEY_KWARG_EXEMPT:
        for kw in call.keywords:
            if kw.arg == "key" and isinstance(kw.value, ast.Name):
                used.append(kw.value.id)
    return used


def _scan_key_reuse(fn, path: str) -> List[Finding]:
    """Branch-aware scan of one function body (nested defs get their own
    scan): a name consumed by two draws along one execution path with no
    refreshing rebinding in between is a reused key.  ``if``/``else``
    arms fork the used-set and merge as a union; draws in mutually
    exclusive branches never fire."""
    findings = []

    def scan_expr(node: ast.AST, used: set) -> None:
        """Record draws inside one expression/simple statement, in
        source order, skipping nested function/lambda bodies."""
        nested = {
            id(sub)
            for n in ast.walk(node)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda))
            for sub in ast.walk(n)}
        comp_targets: set = set()
        for sub in ast.walk(node):
            if isinstance(sub, ast.comprehension):
                comp_targets.update(_target_names(sub.target))
        ordered = sorted(
            (s for s in ast.walk(node) if id(s) not in nested),
            key=lambda s: (getattr(s, "lineno", 0),
                           getattr(s, "col_offset", 0)))
        for sub in ordered:
            if not isinstance(sub, ast.Call):
                continue
            for name in _key_uses(sub):
                if name in comp_targets:
                    continue        # fresh binding per comprehension iter
                if name in used:
                    findings.append(Finding(
                        path, sub.lineno, "R2",
                        f"key {name!r} feeds a second random draw "
                        "without an intervening split/fold_in — reused "
                        "keys correlate the two streams"))
                used.add(name)

    def refresh(stmt: ast.AST, used: set) -> None:
        if isinstance(stmt, ast.Assign):
            for tgt in stmt.targets:
                for name in _target_names(tgt):
                    used.discard(name)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)) and \
                isinstance(stmt.target, ast.Name):
            used.discard(stmt.target.id)

    def scan_block(stmts, used: set) -> set:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue        # scanned as their own scope
            if isinstance(stmt, ast.If):
                scan_expr(stmt.test, used)
                u_then = scan_block(stmt.body, set(used))
                u_else = scan_block(stmt.orelse, set(used))
                used = u_then | u_else
            elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                scan_expr(stmt.iter, used)
                for name in _target_names(stmt.target):
                    used.discard(name)
                # body scanned once: reuse WITHIN an iteration fires;
                # cross-iteration reuse is left to the loop author
                u_body = scan_block(stmt.body, set(used))
                used = u_body | scan_block(stmt.orelse, set(used))
            elif isinstance(stmt, ast.While):
                scan_expr(stmt.test, used)
                u_body = scan_block(stmt.body, set(used))
                used = u_body | scan_block(stmt.orelse, set(used))
            elif isinstance(stmt, ast.Try):
                merged = scan_block(stmt.body, set(used))
                for handler in stmt.handlers:
                    merged |= scan_block(handler.body, set(used))
                merged = scan_block(stmt.orelse, merged)
                used = scan_block(stmt.finalbody, merged)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    scan_expr(item.context_expr, used)
                used = scan_block(stmt.body, used)
            else:
                scan_expr(stmt, used)
                refresh(stmt, used)
        return used

    scan_block(fn.body, set())
    return findings


def _target_names(tgt: ast.AST) -> List[str]:
    if isinstance(tgt, ast.Name):
        return [tgt.id]
    if isinstance(tgt, (ast.Tuple, ast.List)):
        out = []
        for el in tgt.elts:
            out.extend(_target_names(el))
        return out
    return []


# ------------------------------------------------ R3: duration timing ---

def rule_r3(tree: ast.Module, path: str) -> List[Finding]:
    findings = []
    for scope in [tree, *list(_functions(tree))]:
        nested = set()
        if not isinstance(scope, ast.Module):
            nested = {
                id(sub)
                for n in ast.walk(scope)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                and n is not scope
                for sub in ast.walk(n)}
        else:
            nested = {
                id(sub)
                for n in ast.walk(scope)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                for sub in ast.walk(n)}
        own = [n for n in ast.walk(scope) if id(n) not in nested]
        walltime_names = set()
        for node in own:
            if isinstance(node, ast.Assign) and \
                    _call_chain(node.value) in ("time.time",):
                for tgt in node.targets:
                    walltime_names.update(_target_names(tgt))
        for node in own:
            if isinstance(node, ast.BinOp) and \
                    isinstance(node.op, ast.Sub):
                operands = (node.left, node.right)
                direct = any(_call_chain(op) == "time.time"
                             for op in operands)
                via_name = any(isinstance(op, ast.Name)
                               and op.id in walltime_names
                               for op in operands)
                if direct or via_name:
                    findings.append(Finding(
                        path, node.lineno, "R3",
                        "duration computed from time.time() — wall-clock "
                        "time is not monotonic; use "
                        "time.perf_counter()"))
    return findings


# --------------------------------------- R4/R5: traced-code reachability ---

def _traced_functions(tree: ast.Module, path: str, cfg: Config) -> set:
    """ids of FunctionDef nodes that execute under a JAX trace.

    Seeds: functions decorated with a tracing transform, functions
    passed by (local) name to a tracing transform, and the configured
    cross-module entry points.  Closure: a function called by name from
    a traced function, and every nested def of a traced function (all
    code inside a traced function runs at trace time).
    """
    by_name: dict = {}
    for fn in _functions(tree):
        by_name.setdefault(fn.name, []).append(fn)

    entry_names = cfg.entry_points_for(path)
    traced: set = set()

    def is_tracing_transform(node: ast.AST) -> bool:
        chain = _attr_chain(node)
        if chain is None:
            return False
        leaf = chain.split(".")[-1]
        if leaf not in _TRACING_TRANSFORMS:
            return False
        # functools.partial(jax.jit, ...) handled by caller
        return True

    for fn in _functions(tree):
        if fn.name in entry_names:
            traced.add(id(fn))
        for dec in fn.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if is_tracing_transform(target):
                traced.add(id(fn))
            # functools.partial(jax.jit, static_argnames=...)
            if isinstance(dec, ast.Call) and \
                    (_attr_chain(dec.func) or "").endswith("partial") and \
                    dec.args and is_tracing_transform(dec.args[0]):
                traced.add(id(fn))

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and \
                is_tracing_transform(node.func):
            for arg in node.args:
                if isinstance(arg, ast.Name):
                    for fn in by_name.get(arg.id, []):
                        traced.add(id(fn))

    # closure: by-name calls from traced bodies + nested defs
    changed = True
    while changed:
        changed = False
        for fn in _functions(tree):
            if id(fn) not in traced:
                continue
            for node in ast.walk(fn):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) and \
                        node is not fn and id(node) not in traced:
                    traced.add(id(node))
                    changed = True
                if isinstance(node, ast.Call):
                    callee = None
                    if isinstance(node.func, ast.Name):
                        callee = node.func.id
                    for cand in by_name.get(callee, []):
                        if id(cand) not in traced:
                            traced.add(id(cand))
                            changed = True
                # function names passed around inside traced code
                # (e.g. fori_loop bodies) are caught by the global
                # transform scan above
    return traced


def rule_r4(tree: ast.Module, path: str, cfg: Config) -> List[Finding]:
    traced = _traced_functions(tree, path, cfg)
    findings = []
    for fn in _functions(tree):
        if id(fn) not in traced:
            continue
        nested = {
            id(sub)
            for n in ast.walk(fn)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and n is not fn
            for sub in ast.walk(n)}
        for node in ast.walk(fn):
            if id(node) in nested:
                continue
            if isinstance(node, (ast.If, ast.While)) and \
                    _contains_jnp(node.test):
                kind = "while" if isinstance(node, ast.While) else "if"
                findings.append(Finding(
                    path, node.lineno, "R4",
                    f"Python `{kind}` on a jnp expression inside traced "
                    f"function {fn.name!r} — use lax.cond/while_loop or "
                    "jnp.where; under jit this either raises or bakes "
                    "in a trace-time constant"))
    return findings


def rule_r5(tree: ast.Module, path: str, cfg: Config) -> List[Finding]:
    if not cfg.is_hot_path(path):
        return []
    traced = _traced_functions(tree, path, cfg)
    findings = []
    for fn in _functions(tree):
        if id(fn) not in traced:
            continue
        nested = {
            id(sub)
            for n in ast.walk(fn)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and n is not fn
            for sub in ast.walk(n)}
        for node in ast.walk(fn):
            if id(node) in nested or not isinstance(node, ast.Call):
                continue
            chain = _call_chain(node) or ""
            leaf = chain.split(".")[-1]
            msg = None
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args:
                msg = ".item() forces a device->host sync"
            elif chain.split(".")[0] in ("np", "numpy") and \
                    leaf in _NUMPY_SYNC_FNS:
                msg = (f"{chain}() materializes a host copy of a device "
                       "value")
            elif chain in _BUILTIN_SYNC_FNS and node.args and not \
                    isinstance(node.args[0], (ast.Name, ast.Constant)):
                msg = (f"{chain}() on a computed value forces a "
                       "device->host sync")
            if msg:
                findings.append(Finding(
                    path, node.lineno, "R5",
                    f"{msg} inside traced hot-path function "
                    f"{fn.name!r} — keep the value on device (the "
                    "runtime transfer sanitizer traps this at run "
                    "time)"))
    return findings


# ----------------------------------------- R7: benchmark timing windows ---

def _scope_own_nodes(scope, is_module: bool):
    """Nodes belonging to ``scope`` but not to any nested function."""
    nested = {
        id(sub)
        for n in ast.walk(scope)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        and (is_module or n is not scope)
        for sub in ast.walk(n)}
    return [n for n in ast.walk(scope) if id(n) not in nested]


def _is_perf_counter(node: ast.AST) -> bool:
    return _call_chain(node) in ("time.perf_counter", "perf_counter")


def rule_r7(tree: ast.Module, path: str, cfg: Config) -> List[Finding]:
    """Every perf_counter start->stop subtraction in a benchmark must
    bracket a ``block_until_ready`` call, else async dispatch means the
    window times the enqueue, not the work."""
    if not cfg.is_bench_path(path):
        return []
    findings = []
    for scope in [tree, *list(_functions(tree))]:
        own = _scope_own_nodes(scope, isinstance(scope, ast.Module))
        perf_assigns: dict = {}       # name -> sorted assign lines
        sync_lines = []
        windows = []                  # (start_line, end_line)
        for node in own:
            if isinstance(node, ast.Assign) and _is_perf_counter(node.value):
                for tgt in node.targets:
                    for name in _target_names(tgt):
                        perf_assigns.setdefault(name, []).append(node.lineno)
            if isinstance(node, ast.Call):
                chain = _call_chain(node) or ""
                if chain.split(".")[-1] == "block_until_ready":
                    sync_lines.append(node.lineno)
        for node in own:
            if not (isinstance(node, ast.BinOp)
                    and isinstance(node.op, ast.Sub)):
                continue
            starts = []
            for op in (node.left, node.right):
                if isinstance(op, ast.Name) and op.id in perf_assigns:
                    # timer names get reused across windows in one scope:
                    # this read closes the LATEST assignment before it
                    prior = [ln for ln in perf_assigns[op.id]
                             if ln <= node.lineno]
                    if prior:
                        starts.append(max(prior))
            if starts:
                windows.append((min(starts), node.lineno))
        for start, end in windows:
            if not any(start <= ln <= end for ln in sync_lines):
                findings.append(Finding(
                    path, end, "R7",
                    "perf_counter timing window (opened at line "
                    f"{start}) closes without a block_until_ready — "
                    "async dispatch makes this time the enqueue, not "
                    "the computation"))
    return findings


# ------------------------------------------------- R6: stale pragmas ---

def rule_r6(findings: List[Finding], pragmas: dict, path: str,
            cfg: Config) -> List[Finding]:
    """A pragma entry whose rule did not fire on that line is stale.

    Runs AFTER the other rules so it can see what actually fired.
    ``R6`` entries themselves are exempt (they exist to self-suppress
    this rule); disabled rules are exempt too (a partial ``--select``
    run cannot judge pragmas for rules it never executed)."""
    fired = {(f.line, f.rule) for f in findings}
    out = []
    for line, rules in sorted(pragmas.items()):
        for rid in sorted(rules):
            if rid == "R6":
                continue
            if rid not in RULE_IDS:
                out.append(Finding(
                    path, line, "R6",
                    f"pragma disables unknown rule {rid!r} — typo or "
                    "removed rule; delete the entry"))
            elif cfg.rule_enabled(rid) and (line, rid) not in fired:
                out.append(Finding(
                    path, line, "R6",
                    f"stale pragma: {rid} does not fire on this line "
                    "any more — delete the suppression (or the whole "
                    "pragma) so it cannot silently license the next "
                    "real instance"))
    return out


# ------------------------------------------------------------- driver ---

def lint_source(source: str, path: str,
                cfg: Config = DEFAULT_CONFIG) -> List[Finding]:
    """Lint one file's source text; ``path`` drives per-path policy."""
    path = Path(path).as_posix()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding(path, exc.lineno or 1, "E0",
                        f"syntax error: {exc.msg}")]
    findings: List[Finding] = []
    if cfg.rule_enabled("R1"):
        findings.extend(rule_r1(tree, path))
    if cfg.rule_enabled("R2"):
        findings.extend(rule_r2(tree, path, cfg))
    if cfg.rule_enabled("R3"):
        findings.extend(rule_r3(tree, path))
    if cfg.rule_enabled("R4"):
        findings.extend(rule_r4(tree, path, cfg))
    if cfg.rule_enabled("R5"):
        findings.extend(rule_r5(tree, path, cfg))
    if cfg.rule_enabled("R7"):
        findings.extend(rule_r7(tree, path, cfg))
    pragmas = _pragma_lines(source)
    if cfg.rule_enabled("R6"):
        findings.extend(rule_r6(findings, pragmas, path, cfg))
    kept = [f for f in findings
            if f.rule not in pragmas.get(f.line, set())]
    return sorted(kept, key=lambda f: (f.path, f.line, f.rule))


def lint_file(path, cfg: Config = DEFAULT_CONFIG) -> List[Finding]:
    p = Path(path)
    return lint_source(p.read_text(), p.as_posix(), cfg)


def iter_python_files(paths: Iterable) -> List[Path]:
    out: List[Path] = []
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            out.extend(sorted(
                f for f in p.rglob("*.py")
                if not any(part.startswith(".") for part in f.parts)))
        elif p.suffix == ".py":
            out.append(p)
    return out


def lint_paths(paths: Iterable,
               cfg: Config = DEFAULT_CONFIG) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_python_files(paths):
        findings.extend(lint_file(f, cfg))
    return findings
